"""Fig. 3: global loss of the 5 device-selection schemes on the three
datasets (N=20, K=4, P_t=10 dBm, R=500 m)."""
from __future__ import annotations

from .common import POLICIES, emit, sim


def run(datasets=("mnist", "cifar10", "sst2"), seeds=(0,) if __import__("benchmarks.common", fromlist=["FAST"]).FAST else (0, 1)):
    rows = []
    for ds in datasets:
        for name, pol in POLICIES.items():
            losses, accs, lats = [], [], []
            for s in seeds:
                h = sim(ds, pol, seed=s)
                losses.append(h.global_loss[-1])
                accs.append(h.accuracy[-1])
                lats.append(h.latency_s.mean())
            rows.append([f"{ds}/{name}",
                         round(sum(losses) / len(losses), 4),
                         round(sum(accs) / len(accs), 4),
                         round(sum(lats) / len(lats), 3)])
    emit("fig3_global_loss", ["final_loss", "final_acc", "mean_latency_s"], rows)
    return rows


if __name__ == "__main__":
    run()
