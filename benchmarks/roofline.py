"""Deliverable (g): roofline table from the dry-run JSON dumps.

Reads results/dryrun_single_pod.json (+ multi_pod if present) and prints,
per (arch x shape x mesh): the three roofline terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS, and a one-line "what would move the
dominant term" note.  Also emits a markdown table to
results/roofline_table.md for EXPERIMENTS.md.
"""
from __future__ import annotations

import json
import os

from .common import emit

RESULTS = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                       "results")

ADVICE = {
    "compute_s": "reduce redundant compute (remat policy, MoE capacity factor, "
                 "MTP head) or add chips",
    "memory_s": "cut HBM traffic: fuse weighting into matmuls, shrink KV cache "
                "(MLA/SWA), bf16 states",
    "collective_s": "reshard to cut all-reduce volume (reduce-scatter grads, "
                    "fold FL weights into loss for ONE psum, overlap with compute)",
}


def _load(name):
    path = os.path.join(RESULTS, name)
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f).get("results", [])


def run(write_md: bool = True):
    rows = []
    md = ["| arch | shape | mesh | compute (ms) | memory (ms) | collective (ms) "
          "| dominant | useful FLOPs ratio |",
          "|---|---|---|---|---|---|---|---|"]
    for fname in ("dryrun_single_pod.json", "dryrun_multi_pod.json"):
        for r in _load(fname):
            roof = r["roofline"]
            c, m, k = roof["compute_s"], roof["memory_s"], roof["collective_s"]
            dom = roof["dominant"]
            rows.append([
                f"{r['arch']}/{r['shape']}/{r['mesh']}",
                round(c * 1e3, 3), round(m * 1e3, 3), round(k * 1e3, 3),
                dom.replace("_s", ""), round(roof["useful_ratio"], 3),
            ])
            md.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | {c*1e3:.2f} "
                f"| {m*1e3:.2f} | {k*1e3:.2f} | {dom.replace('_s','')} "
                f"| {roof['useful_ratio']:.2f} |")
    emit("roofline", ["compute_ms", "memory_ms", "collective_ms", "dominant",
                      "useful_ratio"], rows)
    if write_md and rows:
        os.makedirs(RESULTS, exist_ok=True)
        with open(os.path.join(RESULTS, "roofline_table.md"), "w") as f:
            f.write("\n".join(md) + "\n")
        print(f"# wrote {len(rows)} rows to results/roofline_table.md")
    if not rows:
        print("# no dry-run JSON found; run repro.launch.dryrun --all --json first")
    return rows


if __name__ == "__main__":
    run()
