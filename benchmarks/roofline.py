"""Deliverable (g): roofline tables from the dry-run JSON dumps, plus the
control-plane roofline (analytic op/byte bound of the polyblock solvers vs
the measured BENCH_control_plane.json timings).

Reads results/dryrun_single_pod.json (+ multi_pod if present) and prints,
per (arch x shape x mesh): the three roofline terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS, and a one-line "what would move the
dominant term" note.  Also emits a markdown table to
results/roofline_table.md for EXPERIMENTS.md.

A missing input is a *skip*, not an error — the dry-run dumps and the bench
JSON are build artifacts, not checked-in files, so a fresh clone prints the
command that regenerates each one and exits 0.  Pass ``--strict`` (the CI
bench job does) to turn missing inputs into a nonzero exit instead:

  PYTHONPATH=src python -m benchmarks.roofline            # tolerate missing
  PYTHONPATH=src python -m benchmarks.roofline --strict   # CI: must exist
"""
from __future__ import annotations

import json
import os
import sys

from repro.launch.analytic import polyblock_solve_cost, roofline_pct

from .common import emit

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS = os.path.join(_ROOT, "results")
CONTROL_PLANE_JSON = os.path.join(_ROOT, "BENCH_control_plane.json")

ADVICE = {
    "compute_s": "reduce redundant compute (remat policy, MoE capacity factor, "
                 "MTP head) or add chips",
    "memory_s": "cut HBM traffic: fuse weighting into matmuls, shrink KV cache "
                "(MLA/SWA), bf16 states",
    "collective_s": "reshard to cut all-reduce volume (reduce-scatter grads, "
                    "fold FL weights into loss for ONE psum, overlap with compute)",
}

# Maps a BENCH_control_plane.json section to the analytic solver model that
# bounds it (launch.analytic.polyblock_solve_cost).
_CP_SOLVERS = {"polyblock_fused": "fused", "solve_pairs_micro": "step"}


def _load(name):
    path = os.path.join(RESULTS, name)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f).get("results", [])


def _control_plane_rows(record):
    """Predicted-vs-measured rows for the Γ-solver sections of the bench
    record.  `roofline_pct` is measured efficiency against the analytic
    bound — the absolute tripwire behind the bench's `meets_target` gate."""
    rows = []
    for section, solver in _CP_SOLVERS.items():
        for key, entry in sorted(record.get(section, {}).items(),
                                 key=lambda kv: int(kv[0].lstrip("N"))):
            pairs = entry.get("pairs", record["settings"]["K"]
                              * int(key.lstrip("N")))
            measured = entry.get("fused_s", entry.get("jit_us", 0.0) * 1e-6)
            if not measured:
                continue
            cost = polyblock_solve_cost(pairs, solver=solver)
            rows.append([
                f"control_plane/{solver}/{key}",
                round(cost["bound_s"] * 1e3, 3),
                round(measured * 1e3, 3),
                cost["dominant"].replace("_s", ""),
                round(roofline_pct(measured, cost), 1),
            ])
    return rows


def run(write_md: bool = True, strict: bool = False):
    missing = []

    # ---- launch-stack roofline: dry-run HLO dumps -------------------------
    rows = []
    md = ["| arch | shape | mesh | compute (ms) | memory (ms) | collective (ms) "
          "| dominant | useful FLOPs ratio |",
          "|---|---|---|---|---|---|---|---|"]
    for fname in ("dryrun_single_pod.json", "dryrun_multi_pod.json"):
        results = _load(fname)
        if results is None:
            if fname == "dryrun_single_pod.json":  # multi_pod is optional
                missing.append(
                    f"results/{fname} — regenerate with: PYTHONPATH=src "
                    "python -m repro.launch.dryrun --all --json")
            continue
        for r in results:
            roof = r["roofline"]
            c, m, k = roof["compute_s"], roof["memory_s"], roof["collective_s"]
            dom = roof["dominant"]
            rows.append([
                f"{r['arch']}/{r['shape']}/{r['mesh']}",
                round(c * 1e3, 3), round(m * 1e3, 3), round(k * 1e3, 3),
                dom.replace("_s", ""), round(roof["useful_ratio"], 3),
            ])
            md.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | {c*1e3:.2f} "
                f"| {m*1e3:.2f} | {k*1e3:.2f} | {dom.replace('_s','')} "
                f"| {roof['useful_ratio']:.2f} |")
    emit("roofline", ["compute_ms", "memory_ms", "collective_ms", "dominant",
                      "useful_ratio"], rows)
    if write_md and rows:
        os.makedirs(RESULTS, exist_ok=True)
        with open(os.path.join(RESULTS, "roofline_table.md"), "w") as f:
            f.write("\n".join(md) + "\n")
        print(f"# wrote {len(rows)} rows to results/roofline_table.md")

    # ---- control-plane roofline: analytic bound vs bench timings ----------
    cp_rows = []
    if os.path.exists(CONTROL_PLANE_JSON):
        with open(CONTROL_PLANE_JSON) as f:
            cp_rows = _control_plane_rows(json.load(f))
        emit("roofline_control_plane",
             ["bound_ms", "measured_ms", "dominant", "pct_of_roofline"],
             cp_rows)
    else:
        missing.append(
            "BENCH_control_plane.json — regenerate with: PYTHONPATH=src "
            "python -m benchmarks.run --only control_plane --json")

    for m in missing:
        print(f"# skipped (missing input): {m}")
    if missing and strict:
        print("# --strict: missing inputs are fatal")
        sys.exit(1)
    return rows + cp_rows


if __name__ == "__main__":
    run(strict="--strict" in sys.argv)
