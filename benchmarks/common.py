"""Shared benchmark helpers: CSV emission + reduced-scale sim settings.

Scale note: the paper runs 300-500 rounds on the full datasets; benchmarks
default to reduced rounds/samples so the full suite finishes on CPU, with
--full restoring paper scale.  Scheme ORDERING (the papers' claims) is what
these reproduce; absolute losses differ (synthetic data, DESIGN.md §5).
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.core import RoundPolicy
from repro.fl import SimConfig, SimHistory, run_simulation

FAST = "--full" not in sys.argv

POLICIES = {
    "proposed": RoundPolicy(ds="alg3", ra="mo", sa="matching"),
    "aou_ds": RoundPolicy(ds="aou_topk", ra="mo", sa="matching"),
    "random_ds": RoundPolicy(ds="random", ra="mo", sa="matching"),
    "cluster_ds": RoundPolicy(ds="cluster", ra="mo", sa="matching"),
    "fixed_ds": RoundPolicy(ds="fixed", ra="mo", sa="matching"),
}


def emit(table: str, header: list[str], rows: list[list]):
    print(f"#table,{table}")
    print(",".join(["name"] + header))
    for r in rows:
        print(",".join(str(x) for x in r))
    sys.stdout.flush()


def sim(dataset: str, policy: RoundPolicy, *, rounds=None, n_samples=None,
        seed=0, **kw) -> SimHistory:
    if rounds is None:
        rounds = (25 if dataset == "cifar10" else 60) if FAST else 300
    if n_samples is None:
        n_samples = {"mnist": 500, "cifar10": 300 if FAST else 5000,
                     "sst2": 600 if FAST else 2000}[dataset]
    if FAST and dataset == "cifar10":
        # Table-I batch 512 is hours per sim on this 1-core container;
        # --full restores the paper's setting.
        kw.setdefault("batch", 64)
        kw.setdefault("local_steps", 2)
    return run_simulation(SimConfig(
        dataset=dataset, rounds=rounds, n_samples=n_samples,
        policy=policy, seed=seed, eval_every=max(rounds // 12, 1), **kw))


def timed(fn, *args, repeat=3, **kw):
    fn(*args, **kw)  # warmup / compile
    t0 = time.time()
    for _ in range(repeat):
        out = fn(*args, **kw)
    return out, (time.time() - t0) / repeat * 1e6  # us per call
