"""Fig. 6: impact of the cell radius R — larger radius degrades channels,
Prop. 1 locks out more devices, loss rises."""
from __future__ import annotations

from .common import POLICIES, emit, sim


def run(radii=(200.0, 500.0, 1000.0), seeds=(0,)):
    rows = []
    for r in radii:
        for name in ("proposed", "random_ds"):
            losses, ntx = [], []
            for s in seeds:
                h = sim("mnist", POLICIES[name], seed=s, radius_m=r)
                losses.append(h.global_loss[-1])
                ntx.append(h.n_transmitted.mean())
            rows.append([f"R{int(r)}/{name}",
                         round(sum(losses) / len(losses), 4),
                         round(sum(ntx) / len(ntx), 3)])
    emit("fig6_radius", ["final_loss", "mean_n_transmitted"], rows)
    return rows


if __name__ == "__main__":
    run()
