"""Fig. 7: impact of the number of sub-channels K — #selected devices and
per-round latency (proposed vs random DS)."""
from __future__ import annotations

from .common import POLICIES, emit, sim


def run(ks=(2, 4, 6, 8), seeds=(0,)):
    rows = []
    for k in ks:
        for name in ("proposed", "random_ds"):
            ntx, lat = [], []
            for s in seeds:
                h = sim("mnist", POLICIES[name], seed=s, n_subchannels=k,
                        rounds=30)
                ntx.append(h.n_transmitted.mean())
                lat.append(h.latency_s.mean())
            rows.append([f"K{k}/{name}", round(sum(ntx) / len(ntx), 3),
                         round(sum(lat) / len(lat), 3)])
    emit("fig7_subchannels", ["mean_n_transmitted", "mean_latency_s"], rows)
    return rows


if __name__ == "__main__":
    run()
