"""Control-plane benchmark: per-round NumPy Algorithm 1 vs the batched
jitted whole-horizon solver (core.monotonic_jax), plus the fused scan round
loop vs the host loop (fl.sim engines, DESIGN.md §8).

Emits a CSV table like the other benchmark modules and, when given
`json_path` (benchmarks/run.py --json), writes BENCH_control_plane.json so
the perf trajectory is machine-readable across PRs.  Acceptance rows:

  * `horizon/N512` — the whole-horizon (100 x 4 x 512) Γ solve must be
    >= 10x faster than the per-round NumPy loop, agreeing within 1e-6
    relative on feasible time_s;
  * `run_many/scan` — an 8-seed sweep through the scan+vmap engine must be
    >= 3x faster wall-clock than the host round loop (best of
    SWEEP_REPS runs per engine; FIX-RA keeps Algorithm 1 — measured by the
    horizon row, identical work for both engines — out of this one);
  * `sweep/grid8` — an 8-config policy x seed grid (the experiment
    harness's workload, DESIGN.md §10) through ONE grouped run_many scan
    dispatch must be >= 2x faster than the equivalent loop of solo
    `run_simulation(engine="scan")` calls (the grid compiles one
    lax.switch program and shares worlds/Γ across policy variants; the
    solo loop pays per-call compilation and preparation);
  * `scenario_traces` — whole-horizon environment generation + Γ solve
    (+ churn fold-in) for the `urban` stress preset vs `static`
    (DESIGN.md §11): the scenario layer's overhead over the legacy
    static world, measured end-to-end at control-plane scale.  Not an
    acceptance gate — trace generation is host-side numpy and runs once
    per world — but recorded so regressions in the dynamic path show up
    in the perf trajectory.
  * `async_event_loop` — the buffered event engine (DESIGN.md §12) on
    the same 8-seed sweep as `run_many/scan`: events/sec vs the sync
    engine's rounds/sec (an event carries the extra buffer state in its
    scan carry, so the ratio records the async engine's overhead), plus
    a one-rep full-buffer run pinning the degenerate limit's transmitted
    sets against the scan engine at benchmark scale.  Recorded, not
    gated.
  * `sustained_service` — the segment-chained streaming deployment of
    the buffered event engine (DESIGN.md §14): one warm-up segment, then
    4 closed-loop segments of 100 events at the sweep cell shape
    (N=64, K=16, churn scenario).  Gate: sustained throughput
    >= 55 events/s; p50/p99 commit latency and SLO attainment against a
    2 s budget are recorded alongside.
  * `hier_async` — the two-tier buffered async hierarchy (DESIGN.md §15)
    vs the sync hierarchy scan at city scale (8 cells x 32 devices = 256
    devices, churn scenario).  Gates: wall throughput >= 0.45x the sync
    hier scan's (the async event carries both tiers' buffer state; the
    measured ratio is ~0.63x — see the calibration note at HIER_CFG),
    simulated p99 commit latency <= 0.5x the sync hierarchy's p99 round
    latency (the async engine's actual win: no tier waits for its
    slowest member), and the full-buffer degenerate-limit anchor at
    bench scale.
  * `polyblock_fused` — the staged fused Γ driver (`solve_pairs_fused`,
    mixed-precision projections) vs the step driver (`solve_pairs_jit`,
    the previous whole-horizon path) at N in {512, 4096, 32768} devices
    x K=4 sub-channels.  Timed as min over FUSED_REPS *interleaved*
    rounds (A,B,A,B,... — back-to-back mins, not per-solver batches, so
    a background hiccup hits both solvers equally on a noisy shared
    box).  Gates: >= 1.8x at N=4096 with <= 1e-6 max relative time_s
    difference, and `roofline_pct` (measured against the analytic
    op/byte bound of `launch.analytic.polyblock_solve_cost`) >= 3% — an
    absolute tripwire that catches a slow solver even when both measured
    paths degrade together.
"""
from __future__ import annotations

import json
import platform
import time

import numpy as np

from repro.core import (
    RoundPolicy,
    WirelessConfig,
    sample_channel_gains,
    sample_topology,
    solve_pairs,
    solve_pairs_fused,
    solve_pairs_jit,
)
from repro.fl import (
    HierSimConfig,
    SimConfig,
    run_hier_many,
    run_many,
    run_simulation,
)
from repro.launch.analytic import polyblock_solve_cost, roofline_pct
from repro.scenarios import apply_dynamics, generate_traces
from repro.service import ServiceConfig, SustainedService

from .common import emit

K = 4
HORIZON_ROUNDS = 100
HORIZON_N = 512

FUSED_NS = (512, 4096, 32768)
FUSED_GATE_N = 4096
FUSED_REPS = 11
# Relative-speedup target carries ~10% margin below the measured floor,
# matching the other gates (scan 3.3x vs 3.0, horizon 14x vs 10): the
# step/fused ratio is host-dependent (2.05x on the original 2-core box,
# 1.95-2.0x converged on the current 1-core host), so the absolute
# roofline tripwire below is the gate that catches a genuinely slow
# solver; the ratio gate only guards against the fused path regressing
# relative to the step driver.
FUSED_TARGET_SPEEDUP = 1.8
FUSED_TARGET_REL = 1e-6
FUSED_TARGET_ROOFLINE_PCT = 3.0

SCN_ROUNDS = 100
SCN_N = 128
SCN_REPS = 2

SWEEP_SEEDS = 8
SWEEP_REPS = 3
SWEEP_CFG = dict(dataset="mnist", rounds=100, n_devices=64, n_subchannels=16,
                 n_samples=128, batch=16, eval_every=20, local_steps=1)

SERVICE_SEGMENTS = 4
SERVICE_SEGMENT_EVENTS = 100
SERVICE_EVAL_EVERY = 20
SERVICE_BUDGET_S = 2.0
SERVICE_TARGET_EV_PER_S = 55.0

HIER_CELLS = 8
HIER_REPS = 2
HIER_CFG = dict(dataset="mnist", n_cells=HIER_CELLS, devices_per_cell=32,
                subchannels_per_cell=8, rounds=50, n_samples=128, batch=16,
                eval_every=10, local_steps=1, scenario="churn")
# Honest calibration (DESIGN.md §15): a two-tier async event carries BOTH
# tiers' buffer state in its scan carry, so its wall throughput sits below
# the sync hierarchy scan's (0.63x measured at N=256 / 8 cells on this
# class of host — the same per-event overhead the flat async_event_loop
# row records).  The async win is SIMULATED time — no tier ever waits for
# its slowest member — pinned by the results/hier_async artifact and by
# the deterministic p99 gate below.  Gates: wall-throughput ratio floor
# with ~30% margin under the measured value, simulated p99 commit latency
# at most half the sync hierarchy's p99 round latency (deterministic
# given the config, measured 0.17x), and the full-buffer anchor.
HIER_TARGET_THROUGHPUT_RATIO = 0.45
HIER_TARGET_P99_RATIO = 0.5

GRID_DS = ("alg3", "random", "fixed", "cluster")
GRID_SEEDS = 2
GRID_REPS = 2
GRID_CFG = dict(dataset="mnist", rounds=60, n_devices=20, n_subchannels=4,
                n_samples=128, batch=16, eval_every=20, local_steps=1)


def _setup(n, rounds, seed=0):
    cfg = WirelessConfig(n_devices=n, n_subchannels=K)
    rng = np.random.default_rng(seed)
    topo = sample_topology(rng, cfg)
    h2 = np.stack([sample_channel_gains(rng, cfg, topo) for _ in range(rounds)])
    beta = rng.integers(5, 60, n).astype(float)
    return cfg, beta, h2


def _agreement(ref_time, jit, mask):
    return float(np.max(np.abs(ref_time[mask] - jit.time_s[mask])
                        / np.abs(ref_time[mask])))


def run(json_path: str | None = None):
    rows = []
    record = {
        "bench": "control_plane",
        "host": platform.machine(),
        "settings": {"K": K, "rounds": HORIZON_ROUNDS, "N": HORIZON_N},
        "solve_pairs_micro": {},
    }

    # ---- micro: one-round solve at growing N (NumPy vs jitted) ------------
    for n in (32, 512, 4096):
        cfg, beta, h2 = _setup(n, 1)
        t0 = time.perf_counter()
        ref = solve_pairs(beta[None, :], h2[0], cfg)
        t_np = time.perf_counter() - t0
        solve_pairs_jit(beta[None, :], h2[0], cfg)      # warm the jit caches
        t0 = time.perf_counter()
        jit = solve_pairs_jit(beta[None, :], h2[0], cfg)
        t_jit = time.perf_counter() - t0
        agree = _agreement(ref.time_s, jit, ref.feasible)
        rows.append([f"solve_pairs/np/N{n}", round(t_np * 1e6, 1), f"{K}x{n} pairs"])
        rows.append([f"solve_pairs/jit/N{n}", round(t_jit * 1e6, 1),
                     f"{t_np / t_jit:.1f}x, agree={agree:.1e}"])
        record["solve_pairs_micro"][f"N{n}"] = {
            "numpy_us": t_np * 1e6, "jit_us": t_jit * 1e6,
            "speedup": t_np / t_jit, "max_rel_diff": agree,
        }

    # ---- acceptance: whole-horizon Gamma precompute (always full scale) ---
    rounds = HORIZON_ROUNDS
    cfg, beta, h2_all = _setup(HORIZON_N, rounds)
    solve_pairs_jit(beta[None, None, :], h2_all, cfg)        # warm/compile
    t0 = time.perf_counter()
    jit = solve_pairs_jit(beta[None, None, :], h2_all, cfg)
    t_jit = time.perf_counter() - t0
    t0 = time.perf_counter()
    ref_time = np.stack(
        [solve_pairs(beta[None, :], h2_all[t], cfg).time_s
         for t in range(rounds)])
    t_np = time.perf_counter() - t0
    agree = _agreement(ref_time, jit, jit.feasible)
    speedup = t_np / t_jit
    rows.append([f"horizon/np_loop/N{HORIZON_N}", round(t_np * 1e6, 1),
                 f"{rounds} rounds"])
    rows.append([f"horizon/jit/N{HORIZON_N}", round(t_jit * 1e6, 1),
                 f"{speedup:.1f}x, agree={agree:.1e}"])
    record["horizon"] = {
        "rounds": rounds, "N": HORIZON_N, "K": K,
        "numpy_loop_s": t_np, "jit_s": t_jit,
        "speedup": speedup, "max_rel_diff": agree,
        "target_speedup": 10.0, "meets_target": bool(speedup >= 10.0),
    }

    # ---- acceptance: fused staged Γ driver vs the step driver -------------
    record["polyblock_fused"] = {}
    for n in FUSED_NS:
        cfg, beta, h2 = _setup(n, 1, seed=3)
        solve_pairs_jit(beta[None, :], h2[0], cfg)           # warm both jits
        fused = solve_pairs_fused(beta[None, :], h2[0], cfg)
        step = solve_pairs_jit(beta[None, :], h2[0], cfg)
        t_step, t_fused = [], []
        for _ in range(FUSED_REPS):                          # interleaved
            t0 = time.perf_counter()
            step = solve_pairs_jit(beta[None, :], h2[0], cfg)
            t_step.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            fused = solve_pairs_fused(beta[None, :], h2[0], cfg)
            t_fused.append(time.perf_counter() - t0)
        ts, tf = min(t_step), min(t_fused)
        agree = _agreement(step.time_s, fused, step.feasible)
        iters_eq = bool(np.array_equal(step.iterations, fused.iterations))
        speedup = ts / tf
        pct = roofline_pct(tf, polyblock_solve_cost(K * n, solver="fused"))
        rows.append([f"polyblock/step/N{n}", round(ts * 1e6, 1),
                     f"{K}x{n} pairs"])
        rows.append([f"polyblock/fused/N{n}", round(tf * 1e6, 1),
                     f"{speedup:.2f}x, agree={agree:.1e}, "
                     f"roofline={pct:.1f}%"])
        gated = n == FUSED_GATE_N
        record["polyblock_fused"][f"N{n}"] = {
            "pairs": K * n, "reps": FUSED_REPS,
            "step_s": ts, "fused_s": tf,
            "step_s_all": t_step, "fused_s_all": t_fused,
            "speedup": speedup, "max_rel_diff": agree,
            "iterations_equal": iters_eq,
            "roofline_pct": pct,
            "target_rel": FUSED_TARGET_REL,
            "meets_rel": bool(agree <= FUSED_TARGET_REL),
            **({"target_speedup": FUSED_TARGET_SPEEDUP,
                "target_roofline_pct": FUSED_TARGET_ROOFLINE_PCT,
                "meets_target": bool(speedup >= FUSED_TARGET_SPEEDUP
                                     and agree <= FUSED_TARGET_REL
                                     and pct >= FUSED_TARGET_ROOFLINE_PCT)}
               if gated else {}),
        }

    # ---- acceptance: fused scan round loop vs host loop, 8-seed sweep -----
    cfgs = [SimConfig(seed=s, policy=RoundPolicy(ra="fix"), **SWEEP_CFG)
            for s in range(SWEEP_SEEDS)]
    times = {"scan": [], "loop": []}
    hists = {}
    for _ in range(SWEEP_REPS):
        for engine in ("scan", "loop"):
            t0 = time.perf_counter()
            hists[engine] = run_many(cfgs, engine=engine)
            times[engine].append(time.perf_counter() - t0)
    tx_agree = all(
        np.array_equal(a.tx_trace, b.tx_trace)
        for a, b in zip(hists["scan"], hists["loop"]))
    t_scan, t_loop = min(times["scan"]), min(times["loop"])
    sweep_speedup = t_loop / t_scan
    rows.append([f"run_many/loop/seeds{SWEEP_SEEDS}", round(t_loop * 1e6, 1),
                 f"{SWEEP_CFG['rounds']} rounds, N={SWEEP_CFG['n_devices']}"])
    rows.append([f"run_many/scan/seeds{SWEEP_SEEDS}", round(t_scan * 1e6, 1),
                 f"{sweep_speedup:.1f}x, tx_agree={tx_agree}"])
    record["run_many_scan"] = {
        "seeds": SWEEP_SEEDS, "reps": SWEEP_REPS, **SWEEP_CFG,
        "loop_s": t_loop, "scan_s": t_scan,
        "loop_s_all": times["loop"], "scan_s_all": times["scan"],
        "speedup": sweep_speedup, "tx_traces_agree": bool(tx_agree),
        "target_speedup": 3.0, "meets_target": bool(sweep_speedup >= 3.0),
    }

    # ---- async event engine: events/sec vs sync rounds/sec ----------------
    acfgs = [SimConfig(seed=s, policy=RoundPolicy(ra="fix"),
                       aggregation="async", **SWEEP_CFG)
             for s in range(SWEEP_SEEDS)]
    t_async = []
    for _ in range(SWEEP_REPS):
        t0 = time.perf_counter()
        run_many(acfgs, engine="async")
        t_async.append(time.perf_counter() - t0)
    ta = min(t_async)
    events = SWEEP_SEEDS * SWEEP_CFG["rounds"]
    # Degenerate-limit anchor at benchmark scale: full buffer == scan.
    fcfgs = [SimConfig(seed=s, policy=RoundPolicy(ra="fix"),
                       aggregation="async_full", **SWEEP_CFG)
             for s in range(SWEEP_SEEDS)]
    fhists = run_many(fcfgs, engine="async")
    anchor = all(np.array_equal(f.tx_trace, h.tx_trace)
                 for f, h in zip(fhists, hists["scan"]))
    ev_per_s = events / ta
    sync_r_per_s = events / t_scan
    rows.append([f"async_event_loop/seeds{SWEEP_SEEDS}", round(ta * 1e6, 1),
                 f"{ev_per_s:.1f} ev/s vs {sync_r_per_s:.1f} sync r/s, "
                 f"anchor={anchor}"])
    record["async_event_loop"] = {
        "seeds": SWEEP_SEEDS, "reps": SWEEP_REPS, **SWEEP_CFG,
        "async_s": ta, "async_s_all": t_async,
        "events_per_s": ev_per_s, "sync_rounds_per_s": sync_r_per_s,
        "events_per_sync_round": ev_per_s / sync_r_per_s,
        "full_buffer_anchor_tx_agree": bool(anchor),
    }

    # ---- acceptance: sustained service, segment-chained async stream -----
    svc_sim = SimConfig(seed=0, policy=RoundPolicy(ra="fix"),
                        aggregation="async", scenario="churn", **SWEEP_CFG)
    svc = SustainedService(ServiceConfig(
        sim=svc_sim,
        segment_events=SERVICE_SEGMENT_EVENTS,
        eval_every_events=SERVICE_EVAL_EVERY,
        target_rate_events_per_s=None,               # closed loop: capacity
        latency_budget_s=SERVICE_BUDGET_S,
        warmup_segments=1))
    summ = svc.serve(SERVICE_SEGMENTS)["summary"]
    svc_ev_s = summ["throughput_events_per_s"]
    rows.append([f"sustained_service/N{SWEEP_CFG['n_devices']}",
                 round(summ["events"] / svc_ev_s * 1e6, 1),
                 f"{svc_ev_s:.1f} ev/s, "
                 f"p99={summ['latency_s']['p99'] * 1e3:.0f}ms, "
                 f"slo={summ['slo']['attained']:.0%}"])
    record["sustained_service"] = {
        "segments": SERVICE_SEGMENTS,
        "segment_events": SERVICE_SEGMENT_EVENTS,
        "eval_every_events": SERVICE_EVAL_EVERY,
        "events_measured": summ["events"],
        **{k: SWEEP_CFG[k] for k in ("dataset", "n_devices", "n_subchannels",
                                     "n_samples", "batch", "local_steps")},
        "scenario": "churn",
        "closed_loop": True,
        "events_per_s": svc_ev_s,
        "p50_latency_s": summ["latency_s"]["p50"],
        "p99_latency_s": summ["latency_s"]["p99"],
        "slo_budget_s": SERVICE_BUDGET_S,
        "slo_attained": summ["slo"]["attained"],
        "mean_pending": summ["buffer"]["mean_pending"],
        "target_events_per_s": SERVICE_TARGET_EV_PER_S,
        "meets_target": bool(svc_ev_s >= SERVICE_TARGET_EV_PER_S),
    }

    # ---- acceptance: two-tier async hierarchy vs the sync hier scan -------
    h_sync = HierSimConfig(policy=RoundPolicy(ra="fix"), **HIER_CFG)
    h_async = HierSimConfig(policy=RoundPolicy(ra="fix"),
                            aggregation="async", global_aggregation="async",
                            **HIER_CFG)
    h_times = {"scan": [], "async": []}
    h_hists = {}
    for _ in range(HIER_REPS):
        for eng, hcfg in (("scan", h_sync), ("async", h_async)):
            t0 = time.perf_counter()
            h_hists[eng] = run_hier_many([hcfg], engine=eng)[0]
            h_times[eng].append(time.perf_counter() - t0)
    t_hs, t_ha = min(h_times["scan"]), min(h_times["async"])
    hier_n = HIER_CELLS * HIER_CFG["devices_per_cell"]
    hier_r_per_s = HIER_CFG["rounds"] / t_hs
    hier_ev_per_s = HIER_CFG["rounds"] / t_ha
    hier_ratio = hier_ev_per_s / hier_r_per_s
    hier_p99_async = float(np.percentile(h_hists["async"].latency_all, 99))
    hier_p99_sync = float(np.percentile(h_hists["scan"].latency_all, 99))
    hier_p99_ratio = hier_p99_async / hier_p99_sync
    # Degenerate-limit anchor at bench scale: full buffers at BOTH tiers
    # reproduce the sync hierarchy's transmitted sets bit-exactly.
    h_full = HierSimConfig(policy=RoundPolicy(ra="fix"),
                           aggregation="async_full",
                           global_aggregation="async_full", **HIER_CFG)
    h_anchor = bool(np.array_equal(
        run_hier_many([h_full], engine="async")[0].tx_trace,
        h_hists["scan"].tx_trace))
    hier_meets = bool(hier_ratio >= HIER_TARGET_THROUGHPUT_RATIO
                      and hier_p99_ratio <= HIER_TARGET_P99_RATIO
                      and h_anchor)
    rows.append([f"hier_sync_scan/N{hier_n}x{HIER_CELLS}cells",
                 round(t_hs * 1e6, 1),
                 f"{hier_r_per_s:.1f} r/s, p99={hier_p99_sync:.2f}s sim"])
    rows.append([f"hier_async/N{hier_n}x{HIER_CELLS}cells",
                 round(t_ha * 1e6, 1),
                 f"{hier_ev_per_s:.1f} ev/s ({hier_ratio:.2f}x sync), "
                 f"p99={hier_p99_async:.2f}s sim, anchor={h_anchor}"])
    record["hier_async"] = {
        "n_cells": HIER_CELLS, "reps": HIER_REPS,
        **{k: HIER_CFG[k] for k in ("rounds", "devices_per_cell",
                                    "subchannels_per_cell", "n_samples",
                                    "batch", "local_steps", "scenario")},
        "n_devices_total": hier_n,
        "sync_scan_s": t_hs, "async_s": t_ha,
        "sync_scan_s_all": h_times["scan"], "async_s_all": h_times["async"],
        "sync_rounds_per_s": hier_r_per_s, "events_per_s": hier_ev_per_s,
        "throughput_ratio": hier_ratio,
        "p99_commit_latency_s": hier_p99_async,
        "p99_sync_round_latency_s": hier_p99_sync,
        "p99_latency_ratio": hier_p99_ratio,
        "full_buffer_anchor_tx_agree": h_anchor,
        "target_throughput_ratio": HIER_TARGET_THROUGHPUT_RATIO,
        "target_p99_ratio": HIER_TARGET_P99_RATIO,
        "meets_target": hier_meets,
    }

    # ---- acceptance: 8-config policy x seed grid vs solo-call loop --------
    grid = [SimConfig(seed=s, policy=RoundPolicy(ds=d, ra="fix"), **GRID_CFG)
            for d in GRID_DS for s in range(GRID_SEEDS)]
    t_grid, t_solo = [], []
    grid_hists = solo_hists = None
    for _ in range(GRID_REPS):
        t0 = time.perf_counter()
        grid_hists = run_many(grid, engine="scan")
        t_grid.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        solo_hists = [run_simulation(c, engine="scan") for c in grid]
        t_solo.append(time.perf_counter() - t0)
    grid_agree = all(
        np.array_equal(a.tx_trace, b.tx_trace)
        and np.array_equal(a.global_loss, b.global_loss)
        for a, b in zip(grid_hists, solo_hists))
    tg, ts = min(t_grid), min(t_solo)
    grid_speedup = ts / tg
    rows.append([f"sweep/solo_loop/{len(grid)}cfg", round(ts * 1e6, 1),
                 f"{GRID_CFG['rounds']} rounds, N={GRID_CFG['n_devices']}"])
    rows.append([f"sweep/grid/{len(grid)}cfg", round(tg * 1e6, 1),
                 f"{grid_speedup:.1f}x, agree={grid_agree}"])
    record["sweep_grid"] = {
        "policies": list(GRID_DS), "seeds": GRID_SEEDS, "reps": GRID_REPS,
        **GRID_CFG,
        "solo_loop_s": ts, "grid_s": tg,
        "solo_loop_s_all": t_solo, "grid_s_all": t_grid,
        "speedup": grid_speedup, "results_agree": bool(grid_agree),
        "target_speedup": 2.0, "meets_target": bool(grid_speedup >= 2.0),
    }

    # ---- scenario layer: trace-gen + solve overhead vs the static world ---
    wcfg = WirelessConfig(n_devices=SCN_N, n_subchannels=K)
    rng = np.random.default_rng(0)
    beta = rng.integers(5, 60, SCN_N).astype(float)
    emax0 = np.full((SCN_ROUNDS, SCN_N), wcfg.e_max_j)
    solve_pairs_jit(beta[None, None, :],
                    generate_traces(0, wcfg, "static", SCN_ROUNDS).h2_all,
                    wcfg, emax0[:, None, :])                # warm/compile
    scn_rec = {}
    for name in ("static", "urban"):
        t_gen, t_solve = [], []
        for _ in range(SCN_REPS):
            t0 = time.perf_counter()
            tr = generate_traces(0, wcfg, name, SCN_ROUNDS)
            t_gen.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            ra = solve_pairs_jit(beta[None, None, :], tr.h2_all, wcfg,
                                 np.broadcast_to(tr.e_max_j[:, None, :],
                                                 tr.h2_all.shape))
            apply_dynamics(ra, tr.avail, tr.slowdown, beta, wcfg)
            t_solve.append(time.perf_counter() - t0)
        scn_rec[name] = {"trace_gen_s": min(t_gen), "solve_s": min(t_solve),
                         "total_s": min(t_gen) + min(t_solve)}
        rows.append([f"scenario/{name}/N{SCN_N}",
                     round(scn_rec[name]["total_s"] * 1e6, 1),
                     f"{SCN_ROUNDS} rounds, gen={min(t_gen)*1e3:.1f}ms"])
    overhead = scn_rec["urban"]["total_s"] / scn_rec["static"]["total_s"]
    rows[-1][2] += f", {overhead:.2f}x vs static"
    record["scenario_traces"] = {
        "rounds": SCN_ROUNDS, "N": SCN_N, "K": K, "reps": SCN_REPS,
        **{f"{k}_{m}": v for k, d in scn_rec.items() for m, v in d.items()},
        "overhead_vs_static": overhead,
    }

    emit("control_plane", ["us_per_call", "derived"], rows)
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(record, fh, indent=2)
        print(f"# wrote {json_path}")
    return record


if __name__ == "__main__":
    run("BENCH_control_plane.json")
