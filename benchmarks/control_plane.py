"""Control-plane benchmark: per-round NumPy Algorithm 1 vs the batched
jitted whole-horizon solver (core.monotonic_jax).

Emits a CSV table like the other benchmark modules and, when given
`json_path` (benchmarks/run.py --json), writes BENCH_control_plane.json so
the perf trajectory is machine-readable across PRs.  The acceptance row is
`horizon/N512` — the whole-horizon (100 x 4 x 512) solve must be >= 10x
faster than the per-round NumPy loop, agreeing within 1e-6 relative on
feasible time_s.
"""
from __future__ import annotations

import json
import platform
import time

import numpy as np

from repro.core import (
    WirelessConfig,
    sample_channel_gains,
    sample_topology,
    solve_pairs,
    solve_pairs_jit,
)

from .common import emit

K = 4
HORIZON_ROUNDS = 100
HORIZON_N = 512


def _setup(n, rounds, seed=0):
    cfg = WirelessConfig(n_devices=n, n_subchannels=K)
    rng = np.random.default_rng(seed)
    topo = sample_topology(rng, cfg)
    h2 = np.stack([sample_channel_gains(rng, cfg, topo) for _ in range(rounds)])
    beta = rng.integers(5, 60, n).astype(float)
    return cfg, beta, h2


def _agreement(ref_time, jit, mask):
    return float(np.max(np.abs(ref_time[mask] - jit.time_s[mask])
                        / np.abs(ref_time[mask])))


def run(json_path: str | None = None):
    rows = []
    record = {
        "bench": "control_plane",
        "host": platform.machine(),
        "settings": {"K": K, "rounds": HORIZON_ROUNDS, "N": HORIZON_N},
        "solve_pairs_micro": {},
    }

    # ---- micro: one-round solve at growing N (NumPy vs jitted) ------------
    for n in (32, 512, 4096):
        cfg, beta, h2 = _setup(n, 1)
        t0 = time.time()
        ref = solve_pairs(beta[None, :], h2[0], cfg)
        t_np = time.time() - t0
        solve_pairs_jit(beta[None, :], h2[0], cfg)      # warm the jit caches
        t0 = time.time()
        jit = solve_pairs_jit(beta[None, :], h2[0], cfg)
        t_jit = time.time() - t0
        agree = _agreement(ref.time_s, jit, ref.feasible)
        rows.append([f"solve_pairs/np/N{n}", round(t_np * 1e6, 1), f"{K}x{n} pairs"])
        rows.append([f"solve_pairs/jit/N{n}", round(t_jit * 1e6, 1),
                     f"{t_np / t_jit:.1f}x, agree={agree:.1e}"])
        record["solve_pairs_micro"][f"N{n}"] = {
            "numpy_us": t_np * 1e6, "jit_us": t_jit * 1e6,
            "speedup": t_np / t_jit, "max_rel_diff": agree,
        }

    # ---- acceptance: whole-horizon Gamma precompute (always full scale) ---
    rounds = HORIZON_ROUNDS
    cfg, beta, h2_all = _setup(HORIZON_N, rounds)
    solve_pairs_jit(beta[None, None, :], h2_all, cfg)        # warm/compile
    t0 = time.time()
    jit = solve_pairs_jit(beta[None, None, :], h2_all, cfg)
    t_jit = time.time() - t0
    t0 = time.time()
    ref_time = np.stack(
        [solve_pairs(beta[None, :], h2_all[t], cfg).time_s
         for t in range(rounds)])
    t_np = time.time() - t0
    agree = _agreement(ref_time, jit, jit.feasible)
    speedup = t_np / t_jit
    rows.append([f"horizon/np_loop/N{HORIZON_N}", round(t_np * 1e6, 1),
                 f"{rounds} rounds"])
    rows.append([f"horizon/jit/N{HORIZON_N}", round(t_jit * 1e6, 1),
                 f"{speedup:.1f}x, agree={agree:.1e}"])
    record["horizon"] = {
        "rounds": rounds, "N": HORIZON_N, "K": K,
        "numpy_loop_s": t_np, "jit_s": t_jit,
        "speedup": speedup, "max_rel_diff": agree,
        "target_speedup": 10.0, "meets_target": bool(speedup >= 10.0),
    }

    emit("control_plane", ["us_per_call", "derived"], rows)
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(record, fh, indent=2)
        print(f"# wrote {json_path}")
    return record


if __name__ == "__main__":
    run("BENCH_control_plane.json")
