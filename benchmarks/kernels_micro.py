"""Kernel micro-benchmarks: wall time of the jnp oracle vs the Pallas kernel
in interpret mode (CPU container — interpret timings are NOT TPU perf; the
derived column reports achieved bytes or flops per call for the roofline)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import WirelessConfig
from repro.kernels.fedavg_agg.ops import fedavg_aggregate
from repro.kernels.fedavg_agg.ref import fedavg_agg_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.polyblock_project.ops import polyblock_project
from repro.kernels.rwkv6_wkv.ops import wkv6_pallas
from repro.kernels.rwkv6_wkv.ref import wkv6_scan_ref

from .common import emit, timed


def run():
    key = jax.random.PRNGKey(0)
    rows = []

    # flash attention (B=1, S=512, H=4, D=64)
    q = jax.random.normal(key, (1, 512, 4, 64))
    k = jax.random.normal(key, (1, 512, 4, 64))
    v = jax.random.normal(key, (1, 512, 4, 64))
    ref_fn = jax.jit(lambda q, k, v: attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)))
    _, us = timed(lambda: jax.block_until_ready(ref_fn(q, k, v)))
    flops = 4 * 512 * 512 * 4 * 64 / 2
    rows.append(["flash_attention/ref_jnp", round(us, 1), f"{flops/us/1e3:.2f}GF/s"])
    _, us = timed(lambda: jax.block_until_ready(
        flash_attention(q, k, v, interpret=True)))
    rows.append(["flash_attention/pallas_interp", round(us, 1), "interpret-mode"])

    # wkv6 (B=1, T=256, H=4, hs=64)
    r = jax.random.normal(key, (1, 256, 4, 64))
    kk = jax.random.normal(key, (1, 256, 4, 64))
    vv = jax.random.normal(key, (1, 256, 4, 64))
    w = jax.nn.sigmoid(jax.random.normal(key, (1, 256, 4, 64))) * 0.5 + 0.45
    u = 0.1 * jax.random.normal(key, (4, 64))
    s0 = jnp.zeros((1, 4, 64, 64))
    ref_fn = jax.jit(wkv6_scan_ref)
    _, us = timed(lambda: jax.block_until_ready(ref_fn(r, kk, vv, w, u, s0)[0]))
    rows.append(["rwkv6_wkv/ref_jnp", round(us, 1), f"T=256"])
    _, us = timed(lambda: jax.block_until_ready(
        wkv6_pallas(r, kk, vv, w, u, s0, interpret=True)[0]))
    rows.append(["rwkv6_wkv/pallas_interp", round(us, 1), "interpret-mode"])

    # fedavg aggregation (K=4, N=1M)
    x = jax.random.normal(key, (4, 1 << 20))
    wts = jnp.asarray([1.0, 2.0, 0.0, 1.0])
    ref_fn = jax.jit(fedavg_agg_ref)
    _, us = timed(lambda: jax.block_until_ready(ref_fn(x, wts)))
    gbs = x.size * 4 / us / 1e3
    rows.append(["fedavg_agg/ref_jnp", round(us, 1), f"{gbs:.2f}GB/s"])
    _, us = timed(lambda: jax.block_until_ready(
        fedavg_aggregate(x, wts, interpret=True)))
    rows.append(["fedavg_agg/pallas_interp", round(us, 1), "interpret-mode"])

    # polyblock projection (K=4, N sweep): NumPy 60-step bisection vs jitted
    # (jnp mirror + warm-started Newton) vs Pallas interpret
    wcfg = WirelessConfig()
    rng = np.random.default_rng(0)
    for n in (32, 512, 4096):
        sz = 4 * n
        v = np.stack([rng.uniform(0.05, 1, sz), rng.uniform(0.05, 1, sz)], -1)
        beta = rng.integers(5, 60, sz).astype(float)
        h2 = rng.exponential(size=sz) * 3
        em = np.full(sz, wcfg.e_max_j)
        _, us = timed(lambda: polyblock_project(v, beta, h2, em, wcfg,
                                                backend="ref"))
        rows.append([f"polyblock_project/ref_np/K4xN{n}", round(us, 1),
                     f"{60 * sz} g-evals"])
        from jax.experimental import enable_x64
        with enable_x64():  # the solver's production precision
            for be in ("bisect", "newton"):
                fn = jax.jit(lambda v, b, h, e, be=be: polyblock_project(
                    v, b, h, e, wcfg, backend=be))
                args = [jnp.asarray(x) for x in (v, beta, h2, em)]
                _, us = timed(lambda: jax.block_until_ready(fn(*args)))
                rows.append([f"polyblock_project/{be}_jit/K4xN{n}",
                             round(us, 1), f"{sz} pairs, f64"])
        _, us = timed(lambda: jax.block_until_ready(
            polyblock_project(v, beta, h2, em, wcfg, backend="pallas",
                              interpret=True)))
        rows.append([f"polyblock_project/pallas_interp/K4xN{n}", round(us, 1),
                     "interpret-mode"])

    emit("kernels_micro", ["us_per_call", "derived"], rows)
    return rows


if __name__ == "__main__":
    run()
