"""Proposition 3: the convergence-rate upper bound. Reports the measured
participation deficits per scheme (the bound's selection-dependent term) and
the bound/gap ratio on a strongly-convex quadratic FL instance."""
from __future__ import annotations

import numpy as np

from repro.core import convergence_bound

from .common import POLICIES, emit, sim


def run(seeds=(0,)):
    rows = []
    # (a) deficits per scheme: the quantity Prop. 3 says to minimize.
    for name, pol in POLICIES.items():
        ds = []
        for s in seeds:
            h = sim("mnist", pol, seed=s, rounds=30)
            ds.append(h.deficits.mean() / h.beta.sum())
        rows.append([f"deficit_frac/{name}", round(sum(ds) / len(ds), 4)])

    # (b) bound validity on a quadratic FL problem.
    rng = np.random.default_rng(0)
    n_dev, d = 8, 5
    beta = rng.integers(5, 20, n_dev)
    data = [(rng.normal(size=(b, d)), rng.normal(size=(b,))) for b in beta]
    a_all = np.concatenate([a for a, _ in data])
    y_all = np.concatenate([y for _, y in data])
    n_tot = len(y_all)
    h_mat = a_all.T @ a_all / n_tot
    eigs = np.linalg.eigvalsh(h_mat)
    mu, lips = max(eigs.min(), 1e-3), eigs.max()
    w_star = np.linalg.lstsq(a_all, y_all, rcond=None)[0]
    f = lambda w: 0.5 * float(np.sum((a_all @ w - y_all) ** 2)) / n_tot
    w = rng.normal(size=d)
    gap0 = f(w) - f(w_star)
    gnorms, defs, gaps, rho = [], [], [], 1.0
    for t in range(40):
        g_full = a_all.T @ (a_all @ w - y_all) / n_tot
        gnorms.append(float(g_full @ g_full))
        tx = rng.uniform(size=n_dev) < 0.6
        if not tx.any():
            tx[0] = True
        defs.append(float((beta * (~tx)).sum()))
        for i in np.where(tx)[0]:
            a, y = data[i]
            for j in range(len(y)):
                gi = a[j] * (a[j] @ w - y[j])
                rho = max(rho, float(gi @ gi) / max(gnorms[-1], 1e-12))
        num = sum(beta[i] * (w - (a.T @ (a @ w - y) / len(y)) / lips)
                  for i, (a, y) in enumerate(data) if tx[i])
        w = num / beta[tx].sum()
        gaps.append(f(w) - f(w_star))
    bound = convergence_bound(gap0, np.array(gnorms), np.array(defs),
                              float(beta.sum()), mu=mu, lips=lips, rho=rho)
    ratio = np.array(gaps) / np.maximum(bound, 1e-12)
    rows.append(["quadratic/max_gap_over_bound", round(float(ratio.max()), 4)])
    rows.append(["quadratic/bound_holds", int(bool((ratio <= 1.0 + 1e-6).all()))])
    emit("prop3_bound", ["value"], rows)
    return rows


if __name__ == "__main__":
    run()
