"""Fig. 4: RA/SA ablation — proposed device selection with each combination
of {MO-RA, FIX-RA} x {M-SA, R-SA}."""
from __future__ import annotations

from repro.core import RoundPolicy

from .common import emit, sim

COMBOS = {
    "MO-RA+M-SA": RoundPolicy(ds="alg3", ra="mo", sa="matching"),
    "MO-RA+R-SA": RoundPolicy(ds="alg3", ra="mo", sa="random"),
    "FIX-RA+M-SA": RoundPolicy(ds="alg3", ra="fix", sa="matching"),
    "FIX-RA+R-SA": RoundPolicy(ds="alg3", ra="fix", sa="random"),
}


def run(dataset="mnist", seeds=(0,) if __import__("benchmarks.common", fromlist=["FAST"]).FAST else (0, 1)):
    rows = []
    for name, pol in COMBOS.items():
        losses, ntx = [], []
        for s in seeds:
            h = sim(dataset, pol, seed=s)
            losses.append(h.global_loss[-1])
            ntx.append(h.n_transmitted.mean())
        rows.append([name, round(sum(losses) / len(losses), 4),
                     round(sum(ntx) / len(ntx), 3)])
    emit("fig4_ablation", ["final_loss", "mean_n_transmitted"], rows)
    return rows


if __name__ == "__main__":
    run()
