"""Benchmark entrypoint: one table per paper figure + Prop-3 + kernels +
control plane + roofline. Prints name,...,derived CSV blocks
(``#table,<name>`` headers).

  PYTHONPATH=src python -m benchmarks.run            # reduced scale
  PYTHONPATH=src python -m benchmarks.run --full     # paper scale
  PYTHONPATH=src python -m benchmarks.run --only fig3_global_loss
  PYTHONPATH=src python -m benchmarks.run --json     # + machine-readable
                                                     #   BENCH_control_plane.json
"""
from __future__ import annotations

import sys
import time

from . import (
    control_plane,
    fig3_global_loss,
    fig4_ablation,
    fig5_num_devices,
    fig6_radius,
    fig7_subchannels,
    fig8_energy,
    fig9_power,
    kernels_micro,
    prop3_bound,
    roofline,
)

CONTROL_PLANE_JSON = "BENCH_control_plane.json"


def _failed_gates(record, prefix=""):
    """Walk a benchmark record for `meets_target`/`meets_rel` False flags.

    The control-plane record marks each acceptance row with a boolean gate
    (horizon speedup, sweep grid, fused polyblock speedup/agreement/roofline
    floor).  Any False is a perf regression the bench must surface as a
    nonzero exit, not just a table row (ISSUE: "fail the bench if the fused
    solve regresses below target").
    """
    bad = []
    if isinstance(record, dict):
        for k, v in record.items():
            if k in ("meets_target", "meets_rel") and v is False:
                bad.append(f"{prefix}{k}")
            else:
                bad.extend(_failed_gates(v, f"{prefix}{k}."))
    return bad

ALL = {
    "fig3_global_loss": fig3_global_loss.run,
    "fig4_ablation": fig4_ablation.run,
    "fig5_num_devices": fig5_num_devices.run,
    "fig6_radius": fig6_radius.run,
    "fig7_subchannels": fig7_subchannels.run,
    "fig8_energy": fig8_energy.run,
    "fig9_power": fig9_power.run,
    "prop3_bound": prop3_bound.run,
    "kernels_micro": kernels_micro.run,
    "control_plane": control_plane.run,
    "roofline": roofline.run,
}


def main() -> None:
    only = None
    if "--only" in sys.argv:
        only = sys.argv[sys.argv.index("--only") + 1]
    runners = dict(ALL)
    if "--json" in sys.argv:  # bind options at registration, not dispatch
        runners["control_plane"] = lambda: control_plane.run(
            json_path=CONTROL_PLANE_JSON)
    t0 = time.time()
    failed = []
    for name, fn in runners.items():
        if only and name != only:
            continue
        t = time.time()
        try:
            record = fn()
        except Exception as e:  # noqa: BLE001
            print(f"#table,{name}\nERROR,{type(e).__name__}: {e}")
        else:
            failed += [f"{name}: {g}" for g in _failed_gates(record)]
        print(f"# {name} took {time.time()-t:.1f}s\n")
    print(f"# total {time.time()-t0:.1f}s")
    if failed:
        print("# GATE FAILURES:\n" + "\n".join(f"#   {g}" for g in failed))
        sys.exit(1)


if __name__ == "__main__":
    main()
