"""Fig. 8: impact of the energy budget E^max on participation and latency,
MO-RA vs FIX-RA (random DS as in the paper)."""
from __future__ import annotations

from repro.core import RoundPolicy

from .common import emit, sim


def run(budgets=(0.005, 0.01, 0.02, 0.05), seeds=(0,)):
    rows = []
    for e in budgets:
        for ra in ("mo", "fix"):
            pol = RoundPolicy(ds="random", ra=ra, sa="matching")
            ntx, lat = [], []
            for s in seeds:
                h = sim("mnist", pol, seed=s, e_max_j=e, rounds=30)
                ntx.append(h.n_transmitted.mean())
                lats = h.latency_s[h.latency_s > 0]
                lat.append(lats.mean() if lats.size else 0.0)
            rows.append([f"E{e}/{ra}-ra", round(sum(ntx) / len(ntx), 3),
                         round(sum(lat) / len(lat), 3)])
    emit("fig8_energy", ["mean_n_transmitted", "mean_latency_s"], rows)
    return rows


if __name__ == "__main__":
    run()
