"""Fig. 9: impact of the maximum transmit power P_t — latency falls with
power; FIX-RA loses participation above ~6 dBm (fixed p no longer meets the
energy budget), MO-RA adapts."""
from __future__ import annotations

from repro.core import RoundPolicy

from .common import emit, sim


def run(powers=(0.0, 4.0, 8.0, 12.0), seeds=(0,)):
    rows = []
    for pt in powers:
        for ra in ("mo", "fix"):
            pol = RoundPolicy(ds="random", ra=ra, sa="matching")
            ntx, lat = [], []
            for s in seeds:
                h = sim("mnist", pol, seed=s, pt_dbm=pt, rounds=30)
                ntx.append(h.n_transmitted.mean())
                lats = h.latency_s[h.latency_s > 0]
                lat.append(lats.mean() if lats.size else 0.0)
            rows.append([f"Pt{pt}dBm/{ra}-ra", round(sum(ntx) / len(ntx), 3),
                         round(sum(lat) / len(lat), 3)])
    emit("fig9_power", ["mean_n_transmitted", "mean_latency_s"], rows)
    return rows


if __name__ == "__main__":
    run()
