"""Fig. 5: impact of the number of devices N (fixed total data => more
devices = less data per selected round => higher loss)."""
from __future__ import annotations

from .common import POLICIES, emit, sim


def run(ns=(10, 20, 30), seeds=(0,)):
    rows = []
    for n in ns:
        for name in ("proposed", "random_ds"):
            losses = []
            for s in seeds:
                h = sim("mnist", POLICIES[name], seed=s, n_devices=n)
                losses.append(h.global_loss[-1])
            rows.append([f"N{n}/{name}", round(sum(losses) / len(losses), 4)])
    emit("fig5_num_devices", ["final_loss"], rows)
    return rows


if __name__ == "__main__":
    run()
