"""Federated-learning substrate: clients, server aggregation (eq. 34), and
the end-to-end FLOWN simulation harness."""
from .client import make_local_trainer
from .server import aggregate, masked_weighted_mean
from .sim import SimConfig, SimHistory, TABLE1, run_many, run_simulation

__all__ = [
    "make_local_trainer",
    "aggregate",
    "masked_weighted_mean",
    "SimConfig",
    "SimHistory",
    "TABLE1",
    "run_simulation",
    "run_many",
]
from .hierarchical import HierSimConfig, run_hierarchical  # noqa: E402

__all__ += ["HierSimConfig", "run_hierarchical"]
