"""Federated-learning substrate: clients, server aggregation (eq. 34), and
the end-to-end FLOWN simulation harness.

Public surface:
  make_local_trainer   -- jitted K-slot local-step trainer (eq. 33);
  aggregate            -- selection-masked weighted FedAvg (eq. 34);
  masked_weighted_mean -- its zero-weight-safe weighted-mean primitive;
  AsyncAggregation / get_aggregation / AGGREGATION_PRESETS /
  staleness_weight / aggregate_buffered
                       -- the buffered staleness-weighted server of the
                          async engine (DESIGN.md §12);
  SimConfig / SimHistory / run_simulation / run_many
                       -- the single-cell Sec.-VI simulation harness with
                          its three round-loop engines (host loop, fused
                          `lax.scan`, buffered event timeline;
                          DESIGN.md §8, §10, §12);
  TABLE1               -- the paper's Table-I per-dataset settings;
  HierSimConfig / run_hierarchical / run_hier_many
                       -- the multi-cell (two-tier FedAvg) extension:
                          loop/scan engine matrix plus the two-tier
                          buffered async event engine (`fl.hier_async`,
                          DESIGN.md §15) and its sweep entry point.

Sweeps over this surface (policy x seed grids, artifacts, figures) live
in `repro.experiments`.
"""
from .client import make_local_trainer
from .server import (
    AGGREGATION_PRESETS,
    AsyncAggregation,
    aggregate,
    aggregate_buffered,
    get_aggregation,
    masked_weighted_mean,
    staleness_weight,
)
from .sim import SimConfig, SimHistory, TABLE1, run_many, run_simulation
from .hierarchical import HierSimConfig, run_hier_many, run_hierarchical

__all__ = [
    "make_local_trainer",
    "aggregate",
    "masked_weighted_mean",
    "AsyncAggregation",
    "AGGREGATION_PRESETS",
    "get_aggregation",
    "staleness_weight",
    "aggregate_buffered",
    "SimConfig",
    "SimHistory",
    "TABLE1",
    "run_simulation",
    "run_many",
    "HierSimConfig",
    "run_hierarchical",
    "run_hier_many",
]
