"""End-to-end FLOWN simulation harness (reproduces paper Sec. VI).

Couples the control plane (Stackelberg round planning over a simulated
wireless network) with the learning plane (real JAX training of the paper's
models on seeded synthetic datasets).  One `run_simulation` call produces
the trajectory behind one curve of Figs. 3-9.

Control-plane scheduling is *hoisted out of the training loop*: Γ (the
Algorithm-1 minimum-time matrix) is selection-independent, so every round's
channel realization is pre-sampled and the full-horizon (rounds x K x N)
tensor is solved in one batched jitted call (`core.monotonic_jax`) before
the first training step.  `run_many` extends the same trick across
simulations: all configured runs' horizons are flattened into a single
solver batch, so planning cost is amortized over seeds/sweeps (Figs. 5-9
sweep many configs) and the learning plane never waits on the host solver
mid-run.  DESIGN.md §6.

Three round-loop engines (DESIGN.md §8, §12):

  engine="loop"  -- the host loop: per-round `plan_round` (NumPy leader)
                    interleaved with jitted training calls;
  engine="scan"  -- the device-resident loop: the jnp leader plane
                    (`core.leader_jax`) fused with training inside ONE
                    `lax.scan` over rounds, and — in `run_many` — `vmap`ped
                    across the seeds of a sweep so a Fig. 5-9 curve family
                    is a single compiled program;
  engine="async" -- the buffered event-timeline loop (`fl.async_loop`):
                    the eq.-9 round barrier is replaced by per-device
                    virtual clocks driven by the same precomputed Γ +
                    scenario traces, with the server committing
                    staleness-weighted updates as they land
                    (`SimConfig.aggregation` names the commit policy;
                    cells with an async aggregation route here
                    automatically from the other engines).

All engines consume identical pre-sampled randomness (`RoundRandomness`
permutations drawn in `_prepare`), so their transmitted sets, AoU
trajectories, and latencies coincide exactly; the differential harness
tests/test_scan_equivalence.py pins this for every RoundPolicy, and
tests/test_async_equivalence.py pins the async engine's degenerate
(full-buffer) limit bit-exactly against the scan engine.

Scenario layer (DESIGN.md §11): the wireless environment of a simulation
is a named `repro.scenarios.Scenario` — temporally correlated fading,
device mobility, churn/stragglers, and energy-harvesting budgets generated
as whole-horizon traces by `_prepare` (the `static` preset replays the
legacy inline sampling bit-exactly).  Traces enter through the SAME three
tensors both engines already consume — the channel horizon `h2_all`
(fading x mobility), the solver's per-element energy budgets
(harvesting), and the solved `RAResult` (churn availability folds into
the Prop-1 mask, straggler slowdowns into the eq.-1 compute share of Γ,
via `scenarios.apply_dynamics`) — so the loop/scan/vmap/shard paths stay
differentially equivalent under every scenario with zero engine changes.

Sweep extensions (DESIGN.md §10): configs that differ only in
`policy.ds`/`policy.sa` share ONE `_Prepared` world (same seed => same
data/topology/channels) and ONE whole-horizon Γ solve, and the scan engine
batches them into a single compiled program — `leader_round` branches become
a `lax.switch` on a per-element policy index, so a policy x seed grid is one
XLA program with a (policy x seed) batch axis.  When more than one local
device is visible, that batch axis is sharded across devices via
`shard_map` (`run_many(..., shard=...)`); on one device it stays a `vmap`.
The declarative front-end over this path lives in `repro.experiments`.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (
    RAResult,
    RoundPolicy,
    RoundRandomness,
    WirelessConfig,
    init_aou,
    make_clusters,
    participation_deficit,
    plan_round,
    solve_pairs_fused,
    solve_pairs_jit,
)
from ..core.monotonic import fixed_ra
from ..scenarios import (
    Scenario,
    apply_dynamics,
    compose_gains,
    get_scenario,
    sample_churn,
    sample_distances,
    sample_energy,
    sample_fading,
)
from ..data.fl_datasets import (
    Dataset,
    FLPartition,
    make_dataset,
    partition_dirichlet,
    partition_imbalanced_iid,
)
from ..models.small import SmallModel, get_small_model
from ..train.optimizer import make_optimizer
from .async_loop import build_async_runner
from .client import make_local_trainer
from .engine_common import (
    make_eval_fn,
    make_leader_branches,
    make_xs,
    run_leader,
    train_clients,
)
from .server import AsyncAggregation, aggregate, get_aggregation

__all__ = ["SimConfig", "SimHistory", "run_simulation", "run_many", "TABLE1"]

# Table I per-dataset settings: (model_bits, e_max, lr, batch, optimizer).
TABLE1 = {
    "mnist": dict(model_bits=1e6, e_max=0.02, lr=0.01, batch=32, optimizer="sgd"),
    "cifar10": dict(model_bits=5e6, e_max=0.1, lr=0.001, batch=512, optimizer="adam"),
    "sst2": dict(model_bits=5e6, e_max=0.1, lr=0.01, batch=128, optimizer="sgd"),
}


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """One Sec.-VI simulation: dataset + network size + scheme policy +
    seed (Table-I learning settings default per dataset; override fields
    are None = "use Table I")."""

    dataset: str = "mnist"
    n_devices: int = 20
    n_subchannels: int = 4
    rounds: int = 100
    policy: RoundPolicy = RoundPolicy()
    seed: int = 0
    n_samples: int | None = None       # dataset size (None -> dataset default)
    local_steps: int = 4
    radius_m: float = 500.0
    pt_dbm: float = 10.0
    e_max_j: float | None = None       # None -> Table I per-dataset value
    lr: float | None = None
    batch: int | None = None
    optimizer: str | None = None
    eval_every: int = 1
    track_gradnorm: bool = False       # needed for the Prop-3 bound benchmark
    partition: str = "iid"             # "iid" (paper) | "dirichlet" (non-IID ext.)
    dirichlet_alpha: float = 0.5
    scenario: str | Scenario = "static"  # environment preset name or Scenario
    # Server aggregation discipline: "sync" (eq. 34, round barrier) or an
    # async preset name / `AsyncAggregation` spec (buffered staleness-
    # weighted commits; routes the cell through engine="async").
    aggregation: str | AsyncAggregation = "sync"

    def wireless(self) -> WirelessConfig:
        t1 = TABLE1[self.dataset]
        return WirelessConfig(
            n_devices=self.n_devices,
            n_subchannels=self.n_subchannels,
            radius_m=self.radius_m,
            pt_dbm=self.pt_dbm,
            model_bits=t1["model_bits"],
            e_max_j=self.e_max_j if self.e_max_j is not None else t1["e_max"],
        )


@dataclasses.dataclass
class SimHistory:
    """One finished simulation's trajectory: eval-round curves (loss,
    accuracy, eq.-9 latency, cumulative convergence time) plus full
    per-round traces (`*_all`, `tx_trace`, `age_trace`) used by the
    differential harness and the sweep metrics."""

    label: str
    rounds: np.ndarray
    global_loss: np.ndarray
    accuracy: np.ndarray
    latency_s: np.ndarray          # per-round latency (eq. 9) at eval rounds
    cum_time_s: np.ndarray         # convergence time: cumsum over ALL rounds,
                                   # sampled at eval rounds
    n_selected: np.ndarray
    n_transmitted: np.ndarray
    energy_j: np.ndarray           # total energy spent per round (eval rounds)
    deficits: np.ndarray           # Prop-3 participation deficits
    grad_sq_norms: np.ndarray      # ||grad F||^2 per round (0 if untracked)
    beta: np.ndarray
    wall_s: float
    plan_wall_s: float = 0.0       # control-plane share (Γ precompute)
    # Full per-round traces (every round, not just eval rounds).  The
    # differential harness compares these across engines; cum_time_s above
    # is their cumsum sampled at eval rounds.
    latency_all: np.ndarray | None = None   # (rounds,)
    energy_all: np.ndarray | None = None    # (rounds,)
    tx_trace: np.ndarray | None = None      # (rounds, N) bool
    age_trace: np.ndarray | None = None     # (rounds, N) post-update AoU
    # Async-engine extras (None on sync runs).  For engine="async",
    # `tx_trace` records DISPATCHES and `commit_trace` the server-side
    # commits; `async_trace` holds the event-loop invariant traces
    # (n_pending / overflow / rem_dispatch) the property tests consume.
    commit_trace: np.ndarray | None = None  # (rounds, N) bool
    async_trace: dict | None = None


def _eval_rounds(rounds: int, eval_every: int) -> list[int]:
    return [t for t in range(rounds)
            if t % eval_every == 0 or t == rounds - 1]


def _pad_partition(ds: Dataset, part: FLPartition, bmax: int | None = None):
    """Pad per-device data to (N, Bmax, ...) + mask for vmapped training."""
    bmax = int(part.beta.max()) if bmax is None else bmax
    n = part.n_devices
    x = np.zeros((n, bmax) + ds.x.shape[1:], dtype=ds.x.dtype)
    y = np.zeros((n, bmax), dtype=ds.y.dtype)
    m = np.zeros((n, bmax), dtype=np.float32)
    for i, idx in enumerate(part.indices):
        x[i, : len(idx)] = ds.x[idx]
        y[i, : len(idx)] = ds.y[idx]
        m[i, : len(idx)] = 1.0
    return jnp.asarray(x), jnp.asarray(y), jnp.asarray(m)


def _sample_dataset(cfg: SimConfig, rng: np.random.Generator):
    """The world stream's dataset phase: dataset draw, device partition,
    padded client buffers.  This is the rng PREFIX of `_prepare` — it
    never consults the scenario — and it is reused verbatim by the
    sustained service (`repro.service`), whose open-ended world replays
    the same phase before handing the stream to `ScenarioStream`."""
    ds_kw = {} if cfg.n_samples is None else {"n": cfg.n_samples}
    ds = make_dataset(cfg.dataset, rng, **ds_kw)
    if cfg.partition == "dirichlet":
        part = partition_dirichlet(rng, ds.y, cfg.n_devices,
                                   cfg.dirichlet_alpha)
    else:
        part = partition_imbalanced_iid(rng, ds.n, cfg.n_devices)
    beta = part.beta.astype(np.float64)
    x_all, y_all, m_all = _pad_partition(ds, part)
    return ds, part, beta, x_all, y_all, m_all


@dataclasses.dataclass
class _Prepared:
    """Everything sampled ahead of the training loop for one simulation."""

    cfg: SimConfig
    wcfg: WirelessConfig
    rng: np.random.Generator
    ds: Dataset
    part: FLPartition
    beta: np.ndarray
    x_all: Any
    y_all: Any
    m_all: Any
    h2_all: np.ndarray             # (rounds, K, N) pre-sampled channel gains
    clusters: np.ndarray
    fixed_ids: np.ndarray
    sel_perms: np.ndarray          # (rounds, N) injected device permutations
    assign_perms: np.ndarray       # (rounds, K) injected channel permutations
    # Scenario traces (DESIGN.md §11): the whole-horizon environment.
    distances: np.ndarray          # (rounds, N) mobility distance trace
    avail: np.ndarray              # (rounds, N) bool churn availability
    slowdown: np.ndarray           # (rounds, N) straggler compute multipliers
    emax_all: np.ndarray           # (rounds, N) per-round energy budgets


def _prepare(cfg: SimConfig, _data_cache: dict | None = None) -> _Prepared:
    """Sample data + the whole-horizon scenario environment up front.

    The scenario processes replace the legacy inline topology / channel
    sampling at the SAME positions of the world rng stream (distances
    where `sample_topology` drew, fading where `sample_channel_gains`
    drew), and the scenario-only processes (churn, energy) draw strictly
    AFTER the legacy stream — so the `static` preset consumes the
    bit-identical stream and reproduces legacy trajectories exactly
    (tests/test_scenarios.py pins this).

    `_data_cache` (threaded in by `run_many`) shares the dataset phase —
    dataset, partition, padded client buffers — across worlds that differ
    only in scenario: the rng prefix through the partition draw never
    consults the scenario, so the cache stores the generator state at the
    branch point and replaying it is bit-identical to resampling.
    """
    rng = np.random.default_rng(cfg.seed)
    wcfg = cfg.wireless()
    scn = get_scenario(cfg.scenario)

    data_key = (cfg.dataset, cfg.n_samples, cfg.partition,
                cfg.dirichlet_alpha, cfg.n_devices, cfg.seed)
    if _data_cache is not None and data_key in _data_cache:
        ds, part, beta, x_all, y_all, m_all, state = _data_cache[data_key]
        rng.bit_generator.state = state
    else:
        ds, part, beta, x_all, y_all, m_all = _sample_dataset(cfg, rng)
        if _data_cache is not None:
            _data_cache[data_key] = (ds, part, beta, x_all, y_all, m_all,
                                     rng.bit_generator.state)

    distances = sample_distances(rng, wcfg, scn.mobility, cfg.rounds)
    clusters = make_clusters(cfg.n_devices, cfg.n_subchannels, rng)
    fixed_ids = rng.permutation(cfg.n_devices)[: cfg.n_subchannels]
    g2_all = sample_fading(rng, wcfg, scn.fading, cfg.rounds)
    h2_all = compose_gains(g2_all, distances, wcfg)
    # One randomness stream for BOTH engines (DESIGN.md §8): every round's
    # leader-plane permutations are drawn here, never inside the loop.
    sel_perms = np.stack([rng.permutation(cfg.n_devices)
                          for _ in range(cfg.rounds)])
    assign_perms = np.stack([rng.permutation(cfg.n_subchannels)
                             for _ in range(cfg.rounds)])
    avail, slowdown = sample_churn(rng, scn.churn, cfg.rounds, cfg.n_devices)
    emax_all = sample_energy(rng, wcfg, scn.energy, cfg.rounds)

    return _Prepared(cfg=cfg, wcfg=wcfg, rng=rng, ds=ds, part=part, beta=beta,
                     x_all=x_all, y_all=y_all, m_all=m_all, h2_all=h2_all,
                     clusters=clusters, fixed_ids=fixed_ids,
                     sel_perms=sel_perms, assign_perms=assign_perms,
                     distances=distances, avail=avail, slowdown=slowdown,
                     emax_all=emax_all)


def _solve_horizons(
    preps: Sequence[_Prepared], backend: str | None,
    solver: str = "fused", shard: bool | None = None,
) -> tuple[list[RAResult], list[float]]:
    """Algorithm 1 for every round of every prepared simulation, batched.

    All MO-RA horizons are flattened into ONE jitted solver call per
    wireless-constant group (the solver is elementwise over pairs, so
    heterogeneous seeds/radii/budgets concatenate freely); FIX-RA horizons
    are a closed form, evaluated per config.  Energy budgets are the
    scenario's per-round per-device trace (`_Prepared.emax_all`,
    constant = the legacy e_max_j under a static energy process), fed as
    the solver's per-element e_max operand.  Returns the per-sim RAResults
    and each sim's share of planning wall time (group time split
    proportionally to its pair count).

    solver: "fused" (default — `solve_pairs_fused`, staged whole-loop jit
    with optional device-axis row sharding via `shard`) or "step"
    (`solve_pairs_jit`, the per-iteration phase-split driver).  shard is
    forwarded to the fused driver only (the step driver has no row-shard
    path); None auto-shards when more than one local device is visible.

    Sims sharing a `_Prepared` world (policy-only variants deduped by
    `run_many`) and the same `policy.ra` have identical Γ by construction:
    they are solved ONCE and the duplicates alias the representative's
    RAResult (read-only downstream), at zero attributed planning time.
    """
    out: list[RAResult | None] = [None] * len(preps)
    secs = [0.0] * len(preps)

    # Γ dedup: channel horizon identity (shared _Prepared) + RA scheme.
    dup_of: list[int | None] = [None] * len(preps)
    rep_idx: dict[tuple[int, str], int] = {}
    for i, p in enumerate(preps):
        key = (id(p.h2_all), p.cfg.policy.ra)
        if key in rep_idx:
            dup_of[i] = rep_idx[key]
        else:
            rep_idx[key] = i

    # The solver is elementwise over pairs with e_max as a per-element
    # operand, but the remaining wireless constants (model_bits, P_t, B,
    # CPU model, ...) are baked into the closed forms — group by them.
    def solver_key(wcfg: WirelessConfig) -> WirelessConfig:
        return dataclasses.replace(
            wcfg, n_devices=0, n_subchannels=0, radius_m=0.0, e_max_j=0.0,
            min_dist_m=1.0)

    groups: dict[WirelessConfig, list[int]] = {}
    for i, p in enumerate(preps):
        if p.cfg.policy.ra == "mo" and dup_of[i] is None:
            groups.setdefault(solver_key(p.wcfg), []).append(i)

    for mo in groups.values():
        h2_cat = np.concatenate([preps[i].h2_all.reshape(-1) for i in mo])
        beta_cat = np.concatenate([
            np.broadcast_to(preps[i].beta[None, None, :],
                            preps[i].h2_all.shape).reshape(-1)
            for i in mo])
        emax_cat = np.concatenate([
            np.broadcast_to(preps[i].emax_all[:, None, :],
                            preps[i].h2_all.shape).reshape(-1)
            for i in mo])
        t0 = time.time()
        if solver == "fused":
            ra_flat = solve_pairs_fused(beta_cat, h2_cat, preps[mo[0]].wcfg,
                                        emax_cat, backend=backend,
                                        shard=shard)
        else:
            ra_flat = solve_pairs_jit(beta_cat, h2_cat, preps[mo[0]].wcfg,
                                      emax_cat, backend=backend)
        group_s = time.time() - t0
        group_pairs = h2_cat.size
        off = 0
        for i in mo:
            shp = preps[i].h2_all.shape
            sz = preps[i].h2_all.size
            sl = slice(off, off + sz)
            out[i] = RAResult(
                tau=ra_flat.tau[sl].reshape(shp),
                p=ra_flat.p[sl].reshape(shp),
                time_s=ra_flat.time_s[sl].reshape(shp),
                energy_j=ra_flat.energy_j[sl].reshape(shp),
                feasible=ra_flat.feasible[sl].reshape(shp),
                iterations=ra_flat.iterations[sl].reshape(shp),
            )
            secs[i] = group_s * sz / group_pairs
            off += sz

    for i, p in enumerate(preps):
        if out[i] is None and dup_of[i] is None:
            t0 = time.time()
            out[i] = fixed_ra(p.beta[None, None, :], p.h2_all, p.wcfg,
                              np.broadcast_to(p.emax_all[:, None, :],
                                              p.h2_all.shape))
            secs[i] = time.time() - t0
    for i, rep in enumerate(dup_of):
        if rep is not None:
            out[i] = out[rep]
    return out, secs


def _slice_ra(ra: RAResult, t: int) -> RAResult:
    return RAResult(tau=ra.tau[t], p=ra.p[t], time_s=ra.time_s[t],
                    energy_j=ra.energy_j[t], feasible=ra.feasible[t],
                    iterations=ra.iterations[t])


# ---------------------------------------------------------------------------
# engine="loop": the host round loop
# ---------------------------------------------------------------------------

def _run_prepared(prep: _Prepared, ra_all: RAResult, plan_wall_s: float) -> SimHistory:
    cfg, wcfg, rng, beta = prep.cfg, prep.wcfg, prep.rng, prep.beta
    t_start = time.time()
    t1 = TABLE1[cfg.dataset]

    # ---- model + trainer --------------------------------------------------
    model: SmallModel = get_small_model(cfg.dataset)
    key = jax.random.PRNGKey(cfg.seed)
    key, k_init = jax.random.split(key)
    params = model.init(k_init)
    opt = make_optimizer(cfg.optimizer or t1["optimizer"], cfg.lr or t1["lr"])
    trainer = make_local_trainer(
        model.loss, opt, batch_size=cfg.batch or t1["batch"],
        local_steps=cfg.local_steps, loss_per_example=model.loss_per_example,
    )
    x_full, y_full = jnp.asarray(prep.ds.x), jnp.asarray(prep.ds.y)
    eval_loss = jax.jit(model.loss)
    eval_acc = jax.jit(model.accuracy)
    grad_norm_sq = jax.jit(
        lambda p: sum(
            jnp.sum(jnp.square(g))
            for g in jax.tree_util.tree_leaves(jax.grad(model.loss)(p, x_full, y_full))
        )
    )

    aou = init_aou(cfg.n_devices)
    k_slots = cfg.n_subchannels
    eval_at = set(_eval_rounds(cfg.rounds, cfg.eval_every))
    hist: dict[str, list] = {k: [] for k in (
        "round", "loss", "acc", "nsel", "ntx", "deficit", "gnorm")}
    # Per-round traces recorded EVERY round: convergence time (the paper's
    # headline metric) must accumulate unsampled rounds too, and the
    # differential harness compares full trajectories across engines.
    lat_all = np.zeros(cfg.rounds)
    energy_all = np.zeros(cfg.rounds)
    tx_trace = np.zeros((cfg.rounds, cfg.n_devices), dtype=bool)
    age_trace = np.zeros((cfg.rounds, cfg.n_devices), dtype=np.int64)

    for t in range(cfg.rounds):
        plan = plan_round(
            aou, beta, prep.h2_all[t], wcfg, rng,
            policy=cfg.policy, round_idx=t, clusters=prep.clusters,
            fixed_ids=prep.fixed_ids, ra=_slice_ra(ra_all, t),
            randomness=RoundRandomness(sel_perm=prep.sel_perms[t],
                                       assign_perm=prep.assign_perms[t]),
        )
        aou = plan.aou_next
        lat_all[t] = plan.latency_s
        energy_all[t] = float(plan.energy_per_device.sum())
        tx_trace[t] = plan.transmitted
        age_trace[t] = aou.age

        # ---- learning plane: train the transmitting devices. -------------
        tx_ids = np.where(plan.transmitted)[0]
        slot_ids = np.zeros(k_slots, dtype=np.int64)
        slot_w = np.zeros(k_slots, dtype=np.float32)
        slot_ids[: len(tx_ids)] = tx_ids
        slot_w[: len(tx_ids)] = beta[tx_ids]

        if len(tx_ids) > 0:
            key, k_round = jax.random.split(key)
            keys = jax.random.split(k_round, k_slots)
            client_params = trainer(
                params, prep.x_all[slot_ids], prep.y_all[slot_ids],
                prep.m_all[slot_ids], keys
            )
            params = aggregate(params, client_params, jnp.asarray(slot_w))

        # ---- bookkeeping ---------------------------------------------------
        if t in eval_at:
            hist["round"].append(t)
            hist["loss"].append(float(eval_loss(params, x_full, y_full)))
            hist["acc"].append(float(eval_acc(params, x_full, y_full)))
            hist["nsel"].append(int(plan.selected.sum()))
            hist["ntx"].append(int(plan.transmitted.sum()))
            hist["deficit"].append(participation_deficit(beta, plan.transmitted))
            hist["gnorm"].append(float(grad_norm_sq(params)) if cfg.track_gradnorm else 0.0)

    ev = np.asarray(hist["round"])
    return SimHistory(
        label=cfg.policy.label,
        rounds=ev,
        global_loss=np.asarray(hist["loss"]),
        accuracy=np.asarray(hist["acc"]),
        latency_s=lat_all[ev],
        cum_time_s=np.cumsum(lat_all)[ev],
        n_selected=np.asarray(hist["nsel"]),
        n_transmitted=np.asarray(hist["ntx"]),
        energy_j=energy_all[ev],
        deficits=np.asarray(hist["deficit"]),
        grad_sq_norms=np.asarray(hist["gnorm"]),
        beta=beta,
        wall_s=time.time() - t_start + plan_wall_s,
        plan_wall_s=plan_wall_s,
        latency_all=lat_all,
        energy_all=energy_all,
        tx_trace=tx_trace,
        age_trace=age_trace,
    )


# ---------------------------------------------------------------------------
# engine="scan": the device-resident round loop (DESIGN.md §8)
# ---------------------------------------------------------------------------

def _scan_inputs(prep: _Prepared, ra: RAResult, bmax: int,
                 policy_idx: int = 0) -> dict:
    """Per-cell device arrays consumed by the scanned round loop.

    Leader-plane operands are cast to float32 (the learning plane's dtype);
    equality of the two engines' decisions survives the cast because every
    comparison is between continuous channel draws (documented in
    DESIGN.md §8).  `bmax` pads client data to the group-wide max so cells
    stack for vmap; `policy_idx` selects this cell's leader branch in the
    runner's `lax.switch` (0 for single-policy groups).
    """
    cfg = prep.cfg
    if bmax == prep.x_all.shape[1]:        # single-sim / homogeneous group
        x_all, y_all, m_all = prep.x_all, prep.y_all, prep.m_all
    else:
        x_all, y_all, m_all = _pad_partition(prep.ds, prep.part, bmax)
    key = jax.random.PRNGKey(cfg.seed)
    key, k_init = jax.random.split(key)
    model = get_small_model(cfg.dataset)
    return dict(
        params0=model.init(k_init),
        policy_idx=jnp.int32(policy_idx),
        key0=key,
        beta=jnp.asarray(prep.beta, jnp.float32),
        x_all=x_all, y_all=y_all, m_all=m_all,
        x_full=jnp.asarray(prep.ds.x), y_full=jnp.asarray(prep.ds.y),
        clusters=jnp.asarray(prep.clusters, jnp.int32),
        fixed_ids=jnp.asarray(prep.fixed_ids, jnp.int32),
        gamma=jnp.asarray(ra.time_s, jnp.float32),
        feas=jnp.asarray(ra.feasible),
        energy=jnp.asarray(np.where(np.isfinite(ra.energy_j),
                                    ra.energy_j, 0.0), jnp.float32),
        sel_perms=jnp.asarray(prep.sel_perms, jnp.int32),
        assign_perms=jnp.asarray(prep.assign_perms, jnp.int32),
    )


def _build_scan_runner(cfg: SimConfig, model: SmallModel, trainer,
                       policies: Sequence[tuple[str, str]] | None = None):
    """One fused `lax.scan` over rounds: leader plane + learning plane.

    carry = (params, key, age); xs = per-round Γ slices + injected
    permutations.  Returns the raw traceable fn(data) -> ys so the caller
    can `jit` it directly or `jit(vmap(...))` it across stacked cells.

    `policies` lists the distinct (ds, sa) leader variants of the group; a
    multi-policy group dispatches on `data["policy_idx"]` through
    `lax.switch`, so one compiled program covers a whole policy x seed grid
    (under `vmap` the switch lowers to a select — every branch runs on the
    batch, which is cheap next to the training plane and buys one XLA
    compilation instead of one per policy; DESIGN.md §10).
    """
    k, n = cfg.n_subchannels, cfg.n_devices
    rounds, eval_every = cfg.rounds, cfg.eval_every
    n_clusters = int(math.ceil(n / k))
    ndev = jnp.arange(n)
    kslot = jnp.arange(k)
    f0 = jnp.float32(0.0)
    if policies is None:
        policies = [(cfg.policy.ds, cfg.policy.sa)]

    def run(data):
        branches = make_leader_branches(policies, data, k=k, n=n,
                                        n_clusters=n_clusters)
        ev = make_eval_fn(model, data, cfg.track_gradnorm)

        def body(carry, x):
            params, key, age = carry

            # ---- leader plane (Algorithms 2-3 + AoU), pure jnp ------------
            lead = run_leader(branches, data["policy_idx"], age,
                              x["feas"], x)
            tx = lead["transmitted"]
            ch_g = jnp.where(tx, lead["channel_of"], 0)
            t_dev = x["gamma"][ch_g, ndev]
            latency = jnp.where(
                tx.any(), jnp.max(jnp.where(tx, t_dev, -jnp.inf)), f0)
            energy = jnp.sum(jnp.where(tx, x["energy"][ch_g, ndev], f0))

            # ---- learning plane: train the transmitting devices -----------
            tx_ids = jnp.nonzero(tx, size=k, fill_value=0)[0]
            cnt = tx.sum()
            slot_w = jnp.where(kslot < cnt, data["beta"][tx_ids], f0)

            def do_train(ops):
                p, kk = ops
                cp, kk = train_clients(trainer, data, k, p, kk, tx_ids)
                return aggregate(p, cp, slot_w), kk

            params, key = jax.lax.cond(
                cnt > 0, do_train, lambda ops: ops, (params, key))

            # ---- bookkeeping: evaluate only at eval rounds ----------------
            loss, acc, gnorm = jax.lax.cond(
                x["eval_mask"], ev, lambda p: (f0, f0, f0), params)

            ys = dict(loss=loss, acc=acc, gnorm=gnorm, latency=latency,
                      energy=energy, selected=lead["selected"],
                      transmitted=tx, age=lead["age_next"])
            return (params, key, lead["age_next"]), ys

        # One source of truth for eval rounds: the same helper the history
        # builders index with (an unbatched xs leaf, so the eval cond stays
        # a real branch under vmap).
        eval_mask = np.zeros(rounds, bool)
        eval_mask[_eval_rounds(rounds, eval_every)] = True
        carry0 = (data["params0"], data["key0"], jnp.ones(n, jnp.int32))
        _, ys = jax.lax.scan(body, carry0, make_xs(data, rounds, eval_mask))
        return ys

    return run


def _history_from_scan(cfg: SimConfig, beta: np.ndarray, ys: dict,
                       wall_s: float, plan_wall_s: float) -> SimHistory:
    lat_all = np.asarray(ys["latency"], np.float64)
    energy_all = np.asarray(ys["energy"], np.float64)
    tx = np.asarray(ys["transmitted"])
    sel = np.asarray(ys["selected"])
    age = np.asarray(ys["age"], np.int64)
    ev = np.asarray(_eval_rounds(cfg.rounds, cfg.eval_every))
    return SimHistory(
        label=cfg.policy.label,
        rounds=ev,
        global_loss=np.asarray(ys["loss"], np.float64)[ev],
        accuracy=np.asarray(ys["acc"], np.float64)[ev],
        latency_s=lat_all[ev],
        cum_time_s=np.cumsum(lat_all)[ev],
        n_selected=sel[ev].sum(axis=1),
        n_transmitted=tx[ev].sum(axis=1),
        energy_j=energy_all[ev],
        deficits=np.asarray([participation_deficit(beta, tx[t]) for t in ev]),
        grad_sq_norms=np.asarray(ys["gnorm"], np.float64)[ev],
        beta=beta,
        wall_s=wall_s,
        plan_wall_s=plan_wall_s,
        latency_all=lat_all,
        energy_all=energy_all,
        tx_trace=tx,
        age_trace=age,
    )


def _scan_group_key(cfg: SimConfig) -> SimConfig:
    """Configs identical up to seed/wireless-data/policy/scenario fields
    share one compiled scan program: policy.ra only selects which
    precomputed Γ is fed in, policy.ds/sa select a `lax.switch` leader
    branch inside the shared program (DESIGN.md §10), and a scenario only
    changes the DATA flowing through the fixed-shape traces (channel
    horizon, Prop-1 mask, budgets), never the program — so a policy x
    scenario x seed grid is ONE compiled dispatch (DESIGN.md §11).  The
    aggregation spec normalizes away too: the async engine's buffer size
    and staleness exponent are traced operands (DESIGN.md §12), so an
    aggregation axis varies data, not programs — run_many partitions
    sync-mode from async-mode cells BEFORE grouping (different carries)."""
    return dataclasses.replace(
        cfg, seed=0, radius_m=0.0, pt_dbm=0.0, e_max_j=None,
        policy=RoundPolicy(), scenario="static", aggregation="sync")


def _prep_key(cfg: SimConfig) -> SimConfig:
    """Configs identical up to the policy sample the same `_Prepared` world:
    dataset, partition, scenario traces (topology, channel horizon, churn,
    budgets), and injected permutations are all drawn from `seed` before
    the policy is ever consulted.  The scenario stays in the key — it IS
    part of the world.  The aggregation discipline does not: sync and
    async variants of one world share its samples and its Γ solve, which
    is exactly what makes the sync-vs-async comparison differential."""
    return dataclasses.replace(cfg, policy=RoundPolicy(), aggregation="sync")


def _group_trainer_and_policies(cfgs: Sequence[SimConfig]):
    """Shared scan/async group setup: model, un-jitted trainer (the group
    program jits around it), and the group's distinct (ds, sa) leader
    variants in first-appearance order with each cell's branch index."""
    cfg = cfgs[0]
    t1 = TABLE1[cfg.dataset]
    model = get_small_model(cfg.dataset)
    opt = make_optimizer(cfg.optimizer or t1["optimizer"], cfg.lr or t1["lr"])
    trainer = make_local_trainer(
        model.loss, opt, batch_size=cfg.batch or t1["batch"],
        local_steps=cfg.local_steps, loss_per_example=model.loss_per_example,
        jit=False,
    )
    policies: list[tuple[str, str]] = []
    pol_idx = []
    for c in cfgs:
        key = (c.policy.ds, c.policy.sa)
        if key not in policies:
            policies.append(key)
        pol_idx.append(policies.index(key))
    return model, trainer, policies, pol_idx


def _check_f32_priorities(preps: Sequence[_Prepared]) -> None:
    # The device-resident leaders rank float32 age*beta products
    # (core.leader_jax.priority_order); they are integer-exact — and hence
    # tie/order identical to the host's f64 ranking — only below 2^24.
    # Ages are bounded by rounds + 1.
    for p in preps:
        worst = (p.cfg.rounds + 1) * float(p.beta.max())
        if worst >= 2 ** 24:
            raise ValueError(
                f"scan engine: age*beta products may reach {worst:.3g} >= "
                f"2^24, where float32 priorities lose host equivalence — "
                f"use engine='loop' or shrink rounds/data sizes")


def _dispatch_group(run, datas: list[dict], shard: bool):
    """Dispatch one static-shape group: solo jit, jit(vmap), or — with
    more than one visible local device — `shard_map` over a 1-D batch
    mesh (padded to a device-count multiple by repeating cell 0; pad rows
    are dropped by the caller).  Returns the blocked-on ys."""
    n_dev = jax.local_device_count()
    if len(datas) == 1:
        ys = jax.jit(run)(datas[0])
    elif shard and n_dev > 1:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec

        pad = (-len(datas)) % n_dev
        stacked = jax.tree_util.tree_map(
            lambda *leaves: jnp.stack(leaves),
            *(list(datas) + [datas[0]] * pad))
        mesh = Mesh(np.asarray(jax.local_devices()), ("batch",))
        sharded = shard_map(jax.vmap(run), mesh=mesh,
                            in_specs=PartitionSpec("batch"),
                            out_specs=PartitionSpec("batch"),
                            check_rep=False)
        ys = jax.jit(sharded)(stacked)
    else:
        stacked = jax.tree_util.tree_map(
            lambda *leaves: jnp.stack(leaves), *datas)
        ys = jax.jit(jax.vmap(run))(stacked)
    jax.block_until_ready(ys)
    return ys


def _run_group_scan(cfgs: Sequence[SimConfig], preps: Sequence[_Prepared],
                    ras: Sequence[RAResult], plan_walls: Sequence[float],
                    shard: bool = False) -> list[SimHistory]:
    """Run one static-shape group of simulations through the scan engine.

    Members differing in seed/wireless data/policy stack into one batch:
    a single `jit(vmap(run))` program (distinct ds/sa pairs become
    `lax.switch` branches selected per batch element).  With `shard=True`
    and more than one visible local device, the batch axis is additionally
    sharded across devices via `shard_map` — the batch is padded to a
    device-count multiple by repeating cell 0 and the pad rows are dropped
    from the histories (per-cell programs are independent, so padding
    cannot perturb real cells).
    """
    cfg = cfgs[0]
    model, trainer, policies, pol_idx = _group_trainer_and_policies(cfgs)
    run = _build_scan_runner(cfg, model, trainer, policies)
    _check_f32_priorities(preps)

    t_start = time.time()
    bmax = max(int(p.part.beta.max()) for p in preps)
    datas = [_scan_inputs(p, ra, bmax, i)
             for p, ra, i in zip(preps, ras, pol_idx)]
    ys = _dispatch_group(run, datas, shard)
    wall_each = (time.time() - t_start) / len(datas)

    out = []
    for i, (c, p, w) in enumerate(zip(cfgs, preps, plan_walls)):
        ys_i = ys if len(datas) == 1 else jax.tree_util.tree_map(
            lambda leaf: leaf[i], ys)
        out.append(_history_from_scan(c, p.beta, ys_i, wall_each + w, w))
    return out


# ---------------------------------------------------------------------------
# engine="async": the buffered event-timeline loop (DESIGN.md §12)
# ---------------------------------------------------------------------------

def _async_spec(cfg: SimConfig) -> AsyncAggregation:
    """The cell's commit policy.  A "sync" cell forced through the event
    engine runs the degenerate full-buffer barrier, which reproduces the
    scan engine bit-exactly — the differential anchor."""
    spec = get_aggregation(cfg.aggregation)
    if spec is None:
        spec = AsyncAggregation(buffer="full", staleness="const")
    return spec


def _history_from_async(cfg: SimConfig, beta: np.ndarray, ys: dict,
                        wall_s: float, plan_wall_s: float) -> SimHistory:
    hist = _history_from_scan(cfg, beta, ys, wall_s, plan_wall_s)
    hist.commit_trace = np.asarray(ys["committed"])
    hist.async_trace = dict(
        n_pending=np.asarray(ys["n_pending"], np.int64),
        overflow=np.asarray(ys["overflow"]),
        rem_dispatch=np.asarray(ys["rem_dispatch"], np.float64),
    )
    return hist


def _run_group_async(cfgs: Sequence[SimConfig], preps: Sequence[_Prepared],
                     ras: Sequence[RAResult], plan_walls: Sequence[float],
                     shard: bool = False) -> list[SimHistory]:
    """Run one static-shape group through the buffered event-timeline
    engine (`fl.async_loop`).  Grouping/batching/sharding mirror the scan
    engine exactly; each cell's commit batch size and staleness exponent
    enter as traced operands, so a whole aggregation axis shares one
    compiled event program per shape.
    """
    cfg = cfgs[0]
    model, trainer, policies, pol_idx = _group_trainer_and_policies(cfgs)
    eval_mask = np.zeros(cfg.rounds, bool)
    eval_mask[_eval_rounds(cfg.rounds, cfg.eval_every)] = True
    run = build_async_runner(
        model, trainer, policies, k=cfg.n_subchannels, n=cfg.n_devices,
        rounds=cfg.rounds, eval_mask=eval_mask,
        track_gradnorm=cfg.track_gradnorm)
    _check_f32_priorities(preps)

    t_start = time.time()
    bmax = max(int(p.part.beta.max()) for p in preps)
    datas = []
    for c, p, ra, i in zip(cfgs, preps, ras, pol_idx):
        d = _scan_inputs(p, ra, bmax, i)
        spec = _async_spec(c)
        d["buffer"] = jnp.int32(
            spec.resolve_buffer(cfg.n_devices, cfg.n_subchannels))
        d["stale_exp"] = jnp.float32(spec.stale_exponent())
        d["server_lr"] = jnp.float32(spec.server_lr)
        datas.append(d)
    ys = _dispatch_group(run, datas, shard)
    wall_each = (time.time() - t_start) / len(datas)

    out = []
    for i, (c, p, w) in enumerate(zip(cfgs, preps, plan_walls)):
        ys_i = ys if len(datas) == 1 else jax.tree_util.tree_map(
            lambda leaf: leaf[i], ys)
        out.append(_history_from_async(c, p.beta, ys_i, wall_each + w, w))
    return out


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def run_many(cfgs: Sequence[SimConfig], *,
             ra_backend: str | None = None,
             ra_solver: str = "fused",
             engine: str = "loop",
             shard: bool | None = None) -> list[SimHistory]:
    """Run several simulations, sharing ONE batched whole-horizon Γ solve.

    The control-plane cost of a sweep (multiple seeds / radii / budgets /
    policies, Figs. 3-9) collapses into a single device batch; each
    simulation then replays its precomputed per-round slices — through
    `plan_round` on the host (engine="loop"), or through the fused
    `lax.scan` round loop (engine="scan"), where configs differing only in
    seed / wireless data / policy.ds / policy.sa are additionally batched
    into one compiled program (DESIGN.md §8, §10).

    Configs identical up to the policy also share one `_Prepared` world
    (dataset, topology, channel horizon, injected permutations — all drawn
    before the policy is consulted) and one Γ solve per RA scheme, so a
    policy grid over S seeds samples and solves S worlds, not S x P.

    Args:
      cfgs: the simulations to run; results are returned in the same order.
      ra_backend: projection backend for the Γ solver (None = default;
        see `kernels.polyblock_project.ops`).
      ra_solver: "fused" (default — staged whole-loop Γ driver with
        device-axis row sharding when `shard` allows) or "step" (the
        per-iteration phase-split driver); see `core.monotonic_jax`.
      engine: "loop" (host round loop), "scan" (device-resident), or
        "async" (buffered event-timeline loop, DESIGN.md §12).  Cells
        whose `SimConfig.aggregation` names an async commit policy route
        through the async engine REGARDLESS of this argument (the sync
        engines cannot express buffered commits); engine="async" forces
        every cell through the event engine, where "sync"-aggregation
        cells run the degenerate full-buffer barrier and reproduce the
        scan engine bit-exactly.
      shard: shard the scan/async engines' batch axis — and the fused Γ
        solve's row axis — across local devices via `shard_map`.  None
        (default) auto-enables sharding when more than one local device
        is visible; False forces single-device `vmap`; True asks for
        sharding (a no-op on one device).  Ignored by engine="loop"
        (the Γ solve still shards).
    """
    if engine not in ("loop", "scan", "async"):
        raise ValueError(f"unknown engine: {engine}")
    if ra_solver not in ("fused", "step"):
        raise ValueError(f"unknown ra_solver: {ra_solver}")
    if shard is None:
        shard = jax.local_device_count() > 1
    # Per-cell execution mode: an async aggregation spec overrides the
    # requested sync engine (and validates eagerly, before any sampling).
    modes = ["async" if engine == "async" or get_aggregation(c.aggregation)
             is not None else engine for c in cfgs]

    # One _Prepared world per policy-free config: policy-only variants
    # share data/topology/channels by construction (and hence Γ, below).
    # Scenario-only variants are distinct worlds but still share the
    # dataset phase (dataset/partition/padded buffers) via `data_cache` —
    # the rng prefix up to the partition draw is scenario-independent.
    preps_by_key: dict[SimConfig, _Prepared] = {}
    data_cache: dict = {}
    preps: list[_Prepared] = []
    for c in cfgs:
        key = _prep_key(c)
        if key not in preps_by_key:
            preps_by_key[key] = _prepare(c, data_cache)
        shared = preps_by_key[key]
        preps.append(shared if shared.cfg == c
                     else dataclasses.replace(shared, cfg=c))

    ras, plan_walls = _solve_horizons(preps, ra_backend,
                                      solver=ra_solver, shard=shard)
    # Scenario dynamics (DESIGN.md §11): churn availability knocks out
    # Prop-1 feasibility, straggler slowdowns stretch the eq.-1 compute
    # share of Γ — folded into the whole-horizon RAResult ONCE, before
    # either engine runs, so loop and scan consume identical tensors.
    # Γ-deduped sims alias one RAResult and one world, so the transform is
    # applied per unique object and re-aliased.
    transformed: dict[int, RAResult] = {}
    for i, (p, ra) in enumerate(zip(preps, ras)):
        if id(ra) not in transformed:
            transformed[id(ra)] = apply_dynamics(
                ra, p.avail, p.slowdown, p.beta, p.wcfg)
        ras[i] = transformed[id(ra)]
    out: list[SimHistory | None] = [None] * len(cfgs)
    for i, mode in enumerate(modes):
        if mode == "loop":
            out[i] = _run_prepared(preps[i], ras[i], plan_walls[i])

    # Sync-mode and async-mode cells never share a program (different scan
    # carries), so group within each mode; inside a mode the aggregation
    # spec is data (buffer / exponent operands), not program shape.
    groups: dict[tuple[str, SimConfig], list[int]] = {}
    for i, (c, mode) in enumerate(zip(cfgs, modes)):
        if mode != "loop":
            groups.setdefault((mode, _scan_group_key(c)), []).append(i)
    for (mode, _), idx in groups.items():
        run_group = _run_group_scan if mode == "scan" else _run_group_async
        hists = run_group([cfgs[i] for i in idx],
                          [preps[i] for i in idx],
                          [ras[i] for i in idx],
                          [plan_walls[i] for i in idx],
                          shard=shard)
        for i, h in zip(idx, hists):
            out[i] = h
    return out


def run_simulation(cfg: SimConfig, *, ra_backend: str | None = None,
                   ra_solver: str = "fused",
                   engine: str = "loop") -> SimHistory:
    """Run ONE simulation (the trajectory behind one curve of Figs. 3-9).

    Equivalent to ``run_many([cfg])[0]``: the whole channel horizon is
    pre-sampled and Γ solved in one batched Algorithm-1 call, then the
    round loop runs on the chosen engine ("loop" = host, "scan" =
    device-resident `lax.scan`, "async" = buffered event timeline; all
    consume identical randomness and pre-solved traces — DESIGN.md §8,
    §12).
    """
    return run_many([cfg], ra_backend=ra_backend, ra_solver=ra_solver,
                    engine=engine)[0]
