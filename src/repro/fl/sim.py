"""End-to-end FLOWN simulation harness (reproduces paper Sec. VI).

Couples the control plane (Stackelberg round planning over a simulated
wireless network) with the learning plane (real JAX training of the paper's
models on seeded synthetic datasets).  One `run_simulation` call produces
the trajectory behind one curve of Figs. 3-9.

Control-plane scheduling is *hoisted out of the training loop*: Γ (the
Algorithm-1 minimum-time matrix) is selection-independent, so every round's
channel realization is pre-sampled and the full-horizon (rounds x K x N)
tensor is solved in one batched jitted call (`core.monotonic_jax`) before
the first training step.  `run_many` extends the same trick across
simulations: all configured runs' horizons are flattened into a single
solver batch, so planning cost is amortized over seeds/sweeps (Figs. 5-9
sweep many configs) and the learning plane never waits on the host solver
mid-run.  DESIGN.md §6.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (
    RAResult,
    RoundPolicy,
    WirelessConfig,
    init_aou,
    make_clusters,
    participation_deficit,
    plan_round,
    sample_channel_gains,
    sample_topology,
    solve_pairs_jit,
)
from ..core.monotonic import fixed_ra
from ..data.fl_datasets import (
    Dataset,
    FLPartition,
    make_dataset,
    partition_dirichlet,
    partition_imbalanced_iid,
)
from ..models.small import SmallModel, get_small_model
from ..train.optimizer import make_optimizer
from .client import make_local_trainer
from .server import aggregate

__all__ = ["SimConfig", "SimHistory", "run_simulation", "run_many", "TABLE1"]

# Table I per-dataset settings: (model_bits, e_max, lr, batch, optimizer).
TABLE1 = {
    "mnist": dict(model_bits=1e6, e_max=0.02, lr=0.01, batch=32, optimizer="sgd"),
    "cifar10": dict(model_bits=5e6, e_max=0.1, lr=0.001, batch=512, optimizer="adam"),
    "sst2": dict(model_bits=5e6, e_max=0.1, lr=0.01, batch=128, optimizer="sgd"),
}


@dataclasses.dataclass(frozen=True)
class SimConfig:
    dataset: str = "mnist"
    n_devices: int = 20
    n_subchannels: int = 4
    rounds: int = 100
    policy: RoundPolicy = RoundPolicy()
    seed: int = 0
    n_samples: int | None = None       # dataset size (None -> dataset default)
    local_steps: int = 4
    radius_m: float = 500.0
    pt_dbm: float = 10.0
    e_max_j: float | None = None       # None -> Table I per-dataset value
    lr: float | None = None
    batch: int | None = None
    optimizer: str | None = None
    eval_every: int = 1
    track_gradnorm: bool = False       # needed for the Prop-3 bound benchmark
    partition: str = "iid"             # "iid" (paper) | "dirichlet" (non-IID ext.)
    dirichlet_alpha: float = 0.5

    def wireless(self) -> WirelessConfig:
        t1 = TABLE1[self.dataset]
        return WirelessConfig(
            n_devices=self.n_devices,
            n_subchannels=self.n_subchannels,
            radius_m=self.radius_m,
            pt_dbm=self.pt_dbm,
            model_bits=t1["model_bits"],
            e_max_j=self.e_max_j if self.e_max_j is not None else t1["e_max"],
        )


@dataclasses.dataclass
class SimHistory:
    label: str
    rounds: np.ndarray
    global_loss: np.ndarray
    accuracy: np.ndarray
    latency_s: np.ndarray          # per-round latency (eq. 9)
    cum_time_s: np.ndarray         # convergence time = sum of latencies
    n_selected: np.ndarray
    n_transmitted: np.ndarray
    energy_j: np.ndarray           # total energy spent per round
    deficits: np.ndarray           # Prop-3 participation deficits
    grad_sq_norms: np.ndarray      # ||grad F||^2 per round (0 if untracked)
    beta: np.ndarray
    wall_s: float
    plan_wall_s: float = 0.0       # control-plane share (Γ precompute)


def _pad_partition(ds: Dataset, part: FLPartition):
    """Pad per-device data to (N, Bmax, ...) + mask for vmapped training."""
    bmax = int(part.beta.max())
    n = part.n_devices
    x = np.zeros((n, bmax) + ds.x.shape[1:], dtype=ds.x.dtype)
    y = np.zeros((n, bmax), dtype=ds.y.dtype)
    m = np.zeros((n, bmax), dtype=np.float32)
    for i, idx in enumerate(part.indices):
        x[i, : len(idx)] = ds.x[idx]
        y[i, : len(idx)] = ds.y[idx]
        m[i, : len(idx)] = 1.0
    return jnp.asarray(x), jnp.asarray(y), jnp.asarray(m)


@dataclasses.dataclass
class _Prepared:
    """Everything sampled ahead of the training loop for one simulation."""

    cfg: SimConfig
    wcfg: WirelessConfig
    rng: np.random.Generator
    ds: Dataset
    beta: np.ndarray
    x_all: Any
    y_all: Any
    m_all: Any
    h2_all: np.ndarray             # (rounds, K, N) pre-sampled channel gains
    clusters: np.ndarray
    fixed_ids: np.ndarray


def _prepare(cfg: SimConfig) -> _Prepared:
    """Sample data, topology, and the whole channel horizon up front."""
    rng = np.random.default_rng(cfg.seed)
    wcfg = cfg.wireless()

    ds_kw = {} if cfg.n_samples is None else {"n": cfg.n_samples}
    ds = make_dataset(cfg.dataset, rng, **ds_kw)
    if cfg.partition == "dirichlet":
        part = partition_dirichlet(rng, ds.y, cfg.n_devices, cfg.dirichlet_alpha)
    else:
        part = partition_imbalanced_iid(rng, ds.n, cfg.n_devices)
    beta = part.beta.astype(np.float64)
    x_all, y_all, m_all = _pad_partition(ds, part)

    topo = sample_topology(rng, wcfg)
    clusters = make_clusters(cfg.n_devices, cfg.n_subchannels, rng)
    fixed_ids = rng.permutation(cfg.n_devices)[: cfg.n_subchannels]
    h2_all = np.stack(
        [sample_channel_gains(rng, wcfg, topo) for _ in range(cfg.rounds)])

    return _Prepared(cfg=cfg, wcfg=wcfg, rng=rng, ds=ds, beta=beta,
                     x_all=x_all, y_all=y_all, m_all=m_all, h2_all=h2_all,
                     clusters=clusters, fixed_ids=fixed_ids)


def _solve_horizons(
    preps: Sequence[_Prepared], backend: str | None
) -> tuple[list[RAResult], list[float]]:
    """Algorithm 1 for every round of every prepared simulation, batched.

    All MO-RA horizons are flattened into ONE jitted solver call per
    wireless-constant group (the solver is elementwise over pairs, so
    heterogeneous seeds/radii/budgets concatenate freely); FIX-RA horizons
    are a closed form, evaluated per config.  Returns the per-sim RAResults
    and each sim's share of planning wall time (group time split
    proportionally to its pair count).
    """
    out: list[RAResult | None] = [None] * len(preps)
    secs = [0.0] * len(preps)

    # The solver is elementwise over pairs with e_max as a per-element
    # operand, but the remaining wireless constants (model_bits, P_t, B,
    # CPU model, ...) are baked into the closed forms — group by them.
    def solver_key(wcfg: WirelessConfig) -> WirelessConfig:
        return dataclasses.replace(
            wcfg, n_devices=0, n_subchannels=0, radius_m=0.0, e_max_j=0.0)

    groups: dict[WirelessConfig, list[int]] = {}
    for i, p in enumerate(preps):
        if p.cfg.policy.ra == "mo":
            groups.setdefault(solver_key(p.wcfg), []).append(i)

    for mo in groups.values():
        h2_cat = np.concatenate([preps[i].h2_all.reshape(-1) for i in mo])
        beta_cat = np.concatenate([
            np.broadcast_to(preps[i].beta[None, None, :],
                            preps[i].h2_all.shape).reshape(-1)
            for i in mo])
        emax_cat = np.concatenate([
            np.full(preps[i].h2_all.size, preps[i].wcfg.e_max_j) for i in mo])
        t0 = time.time()
        ra_flat = solve_pairs_jit(beta_cat, h2_cat, preps[mo[0]].wcfg,
                                  emax_cat, backend=backend)
        group_s = time.time() - t0
        group_pairs = h2_cat.size
        off = 0
        for i in mo:
            shp = preps[i].h2_all.shape
            sz = preps[i].h2_all.size
            sl = slice(off, off + sz)
            out[i] = RAResult(
                tau=ra_flat.tau[sl].reshape(shp),
                p=ra_flat.p[sl].reshape(shp),
                time_s=ra_flat.time_s[sl].reshape(shp),
                energy_j=ra_flat.energy_j[sl].reshape(shp),
                feasible=ra_flat.feasible[sl].reshape(shp),
                iterations=ra_flat.iterations[sl].reshape(shp),
            )
            secs[i] = group_s * sz / group_pairs
            off += sz

    for i, p in enumerate(preps):
        if out[i] is None:
            t0 = time.time()
            out[i] = fixed_ra(p.beta[None, None, :], p.h2_all, p.wcfg)
            secs[i] = time.time() - t0
    return out, secs


def _slice_ra(ra: RAResult, t: int) -> RAResult:
    return RAResult(tau=ra.tau[t], p=ra.p[t], time_s=ra.time_s[t],
                    energy_j=ra.energy_j[t], feasible=ra.feasible[t],
                    iterations=ra.iterations[t])


def _run_prepared(prep: _Prepared, ra_all: RAResult, plan_wall_s: float) -> SimHistory:
    cfg, wcfg, rng, beta = prep.cfg, prep.wcfg, prep.rng, prep.beta
    t_start = time.time()
    t1 = TABLE1[cfg.dataset]

    # ---- model + trainer --------------------------------------------------
    model: SmallModel = get_small_model(cfg.dataset)
    key = jax.random.PRNGKey(cfg.seed)
    key, k_init = jax.random.split(key)
    params = model.init(k_init)
    opt = make_optimizer(cfg.optimizer or t1["optimizer"], cfg.lr or t1["lr"])
    trainer = make_local_trainer(
        model.loss, opt, batch_size=cfg.batch or t1["batch"],
        local_steps=cfg.local_steps, loss_per_example=model.loss_per_example,
    )
    x_full, y_full = jnp.asarray(prep.ds.x), jnp.asarray(prep.ds.y)
    eval_loss = jax.jit(model.loss)
    eval_acc = jax.jit(model.accuracy)
    grad_norm_sq = jax.jit(
        lambda p: sum(
            jnp.sum(jnp.square(g))
            for g in jax.tree_util.tree_leaves(jax.grad(model.loss)(p, x_full, y_full))
        )
    )

    aou = init_aou(cfg.n_devices)
    k_slots = cfg.n_subchannels
    hist: dict[str, list] = {k: [] for k in (
        "round", "loss", "acc", "lat", "nsel", "ntx", "energy", "deficit", "gnorm")}

    for t in range(cfg.rounds):
        plan = plan_round(
            aou, beta, prep.h2_all[t], wcfg, rng,
            policy=cfg.policy, round_idx=t, clusters=prep.clusters,
            fixed_ids=prep.fixed_ids, ra=_slice_ra(ra_all, t),
        )
        aou = plan.aou_next

        # ---- learning plane: train the transmitting devices. -------------
        tx_ids = np.where(plan.transmitted)[0]
        slot_ids = np.zeros(k_slots, dtype=np.int64)
        slot_w = np.zeros(k_slots, dtype=np.float32)
        slot_ids[: len(tx_ids)] = tx_ids
        slot_w[: len(tx_ids)] = beta[tx_ids]

        if len(tx_ids) > 0:
            key, k_round = jax.random.split(key)
            keys = jax.random.split(k_round, k_slots)
            client_params = trainer(
                params, prep.x_all[slot_ids], prep.y_all[slot_ids],
                prep.m_all[slot_ids], keys
            )
            params = aggregate(params, client_params, jnp.asarray(slot_w))

        # ---- bookkeeping ---------------------------------------------------
        if (t % cfg.eval_every == 0) or (t == cfg.rounds - 1):
            hist["round"].append(t)
            hist["loss"].append(float(eval_loss(params, x_full, y_full)))
            hist["acc"].append(float(eval_acc(params, x_full, y_full)))
            hist["lat"].append(plan.latency_s)
            hist["nsel"].append(int(plan.selected.sum()))
            hist["ntx"].append(int(plan.transmitted.sum()))
            hist["energy"].append(float(plan.energy_per_device.sum()))
            hist["deficit"].append(participation_deficit(beta, plan.transmitted))
            hist["gnorm"].append(float(grad_norm_sq(params)) if cfg.track_gradnorm else 0.0)

    lat = np.asarray(hist["lat"])
    return SimHistory(
        label=cfg.policy.label,
        rounds=np.asarray(hist["round"]),
        global_loss=np.asarray(hist["loss"]),
        accuracy=np.asarray(hist["acc"]),
        latency_s=lat,
        cum_time_s=np.cumsum(lat),
        n_selected=np.asarray(hist["nsel"]),
        n_transmitted=np.asarray(hist["ntx"]),
        energy_j=np.asarray(hist["energy"]),
        deficits=np.asarray(hist["deficit"]),
        grad_sq_norms=np.asarray(hist["gnorm"]),
        beta=beta,
        wall_s=time.time() - t_start + plan_wall_s,
        plan_wall_s=plan_wall_s,
    )


def run_many(cfgs: Sequence[SimConfig], *,
             ra_backend: str | None = None) -> list[SimHistory]:
    """Run several simulations, sharing ONE batched whole-horizon Γ solve.

    The control-plane cost of a sweep (multiple seeds / radii / budgets,
    Figs. 5-9) collapses into a single device batch; each simulation then
    replays its precomputed per-round slices through `plan_round`.
    """
    preps = [_prepare(c) for c in cfgs]
    ras, plan_walls = _solve_horizons(preps, ra_backend)
    return [_run_prepared(p, ra, s) for p, ra, s in zip(preps, ras, plan_walls)]


def run_simulation(cfg: SimConfig) -> SimHistory:
    return run_many([cfg])[0]
