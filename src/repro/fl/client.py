"""Client-side local training (paper eq. 33 generalized to the Table-I
settings: minibatch local steps with SGD/Adam).

All selected clients train *in parallel* via vmap over a fixed number of
slots K (the sub-channel count), so the per-round computation jits once.
Empty slots (no transmitting device) carry weight 0 and are discarded at
aggregation.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..train.optimizer import Optimizer, apply_updates

__all__ = ["make_local_trainer"]


def make_local_trainer(
    loss_fn: Callable[[Any, jax.Array, jax.Array], jax.Array],
    opt: Optimizer,
    *,
    batch_size: int,
    local_steps: int,
    loss_per_example: Callable[[Any, jax.Array, jax.Array], jax.Array] | None = None,
    jit: bool = True,
):
    """Build a jitted vmapped local trainer.

    Returns fn(params, x_slots, y_slots, mask_slots, keys) -> stacked params
    with shapes x_slots (K, Bmax, ...), mask_slots (K, Bmax), keys (K, 2).

    loss_per_example, when provided, computes the whole minibatch in ONE
    model application (essential for conv models: the vmap fallback runs
    batch-1 forwards, ~50x slower on CPU).

    jit=False returns the raw traceable function instead of a `jax.jit`
    wrapper — the scan engine (fl.sim) embeds it inside its fused round
    loop, where an inner jit boundary would only add dispatch overhead.
    """

    def masked_loss(params, x, y, m):
        # Per-sample loss weighted by the padding mask.
        if loss_per_example is not None:
            per = loss_per_example(params, x, y)
        else:
            per = jax.vmap(lambda xi, yi: loss_fn(params, xi[None], yi[None]))(x, y)
        return (per * m).sum() / jnp.maximum(m.sum(), 1.0)

    def one_client(params, x, y, mask, key):
        # Local steps UNROLLED (local_steps is small + static): XLA-CPU
        # executes a lax.scan of this body ~30x slower than the unrolled
        # form (measured; conv grads inside scan hit a slow path).
        opt_state = opt.init(params)
        # Minibatches sample only the device's REAL rows (mask prefix), via
        # floor(u * n_valid): the draw is independent of how far the slot
        # buffer happens to be padded, so a simulation's trajectory cannot
        # depend on which other sims share its (group-padded) vmap batch.
        n_valid = jnp.maximum(mask.sum(), 1.0)
        for k in jax.random.split(key, local_steps):
            u = jax.random.uniform(k, (batch_size,))
            idx = (u * n_valid).astype(jnp.int32)    # u < 1 => idx < n_valid
            g = jax.grad(masked_loss)(params, x[idx], y[idx], mask[idx])
            upd, opt_state = opt.update(g, opt_state, params)
            params = apply_updates(params, upd)
        return params

    def train_slots(params, x_slots, y_slots, mask_slots, keys):
        # Unrolled over the K slots, NOT vmap/lax.map: XLA-CPU executes both
        # vmapped and scanned conv gradients ~30-400x slower than the plain
        # unrolled form (measured); K = n_subchannels is small and static.
        # On TPU flip this to vmap for true client parallelism.
        outs = [
            one_client(params, x_slots[i], y_slots[i], mask_slots[i], keys[i])
            for i in range(x_slots.shape[0])
        ]
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)

    return jax.jit(train_slots) if jit else train_slots
