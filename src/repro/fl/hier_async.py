"""Two-tier buffered async engine: the event loop of `fl.async_loop`
run per edge cell, committing into a global server that is ITSELF a
buffered staleness-weighted aggregator (DESIGN.md §15).

Topology and timing model
-------------------------

Each of the C cells runs PR 5's buffered event loop over its own devices'
virtual clocks: the cell leader re-runs the Stackelberg step every global
event (busy devices drop out of the Prop-1 mask), dispatched devices
train from the CELL model `pcell[c]`, and their uploads fly for their own
Γ-trace duration.  When the cell's `buffer` earliest uploads land, the
cell commits them into `pcell[c]` exactly as the flat engine commits into
its global model — translated updates w_i + (p_c - b_i), weights
beta_n * f(staleness) — and the freshly committed cell model is then
dispatched UPSTREAM as one in-flight update to the global tier:

  gbuf[c]   the cell model in flight;
  gbase[c]  the global model the flight was translated against;
  g_rem[c]  its remaining upload time = the cell commit's event duration
            delta_c (the global tier's per-cell virtual clock is derived
            from cell commit-event times);
  g_w[c]    its weight mass = the cell commit's total committed weight.

The global server runs the SAME commit rule over cells that each cell
runs over devices: `commit_event(g_rem, g_active, g_buffer, C)` waits for
the `g_buffer` earliest cell flights, commits them with translated
updates gbuf[c] + (w - gbase[c]) weighted g_w[c] * f(staleness), and the
event's recorded latency is the global delta.

Two structural rules keep the hierarchy well-posed:

  * cell-commit gating — while a cell has a flight outstanding at the
    global tier (`g_active[c]`), it makes NO further local commits (its
    device clocks freeze; dispatches continue).  At most one flight per
    cell is ever outstanding, so the cell-indexed global buffer (slot c =
    cell c) structurally cannot overflow — exactly the per-device
    invariant of the flat engine, lifted one tier.
  * down-sync — after a global commit, EVERY cell with no outstanding
    flight re-bases its cell model to the new global model (not only the
    cells that just committed: a quiet cell would otherwise train from a
    stale base forever).  Gating guarantees a re-based cell loses at most
    one uncommitted local commit — and in the degenerate limits below it
    loses exactly nothing.

Degenerate limits (tests/test_hier_async_equivalence.py):

  * full buffers at BOTH tiers: every dispatch commits locally the same
    event, every cell flight commits globally the same event, staleness
    is 0 at both tiers (weight multiplier exactly 1.0), both translations
    vanish identically, and the recorded latency is max_c delta_c — the
    sync hierarchy's cell-parallel eq.-9 barrier.  Every arithmetic step
    reproduces `fl.hierarchical`'s scan engine bit-for-bit.
  * C == 1: the cell model provably tracks the global model bitwise (the
    single-slot global commit is an exact select), so the two-tier loop
    collapses to the flat `engine="async"` event loop bit-for-bit.

Segment resume (DESIGN.md §14): the carry is the loop's COMPLETE state,
so ``build_hier_async_runner(..., segmented=True)`` returns a
``run(data, carry) -> (carry, ys)`` closure — the grid analogue of
`fl.async_loop`'s segmented mode, chaining S segments of length L into
the single scan of length S*L bit-for-bit (``data["t0"]`` offsets the
event index; `init_hier_async_carry` builds the t=0 carry).
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .async_loop import commit_event
from .engine_common import (
    make_eval_fn,
    make_leader_branches,
    make_xs,
    run_leader,
    train_clients,
)
from .server import aggregate_buffered, staleness_weight

__all__ = ["init_hier_async_carry", "build_hier_async_runner"]


def init_hier_async_carry(params0, key0, n_cells: int, n: int):
    """The two-tier event loop's t=0 carry.

    Cell models start as exact copies of the global model; both buffer
    pairs are zero-initialized (reads are gated by the active masks, so
    the fill is unobservable — zeros keep the carry deterministic for the
    segment-resume contract).  `gbase` zeros additionally make a
    never-flown cell's translated global slot come out to exactly the
    current global model, mirroring the sync engine's identity slot.
    """
    pcell0 = jax.tree_util.tree_map(
        lambda l: jnp.repeat(l[None], n_cells, axis=0), params0)
    buf0 = jax.tree_util.tree_map(
        lambda l: jnp.zeros((n_cells, n + 1) + l.shape, l.dtype), params0)
    g0 = jax.tree_util.tree_map(
        lambda l: jnp.zeros((n_cells,) + l.shape, l.dtype), params0)
    return (params0, key0, jnp.ones((n_cells, n), jnp.int32), pcell0,
            buf0, buf0,
            jnp.zeros((n_cells, n), jnp.int32),
            jnp.zeros((n_cells, n), jnp.float32),
            jnp.zeros((n_cells, n), bool),
            g0, g0,
            jnp.zeros(n_cells, jnp.int32),
            jnp.zeros(n_cells, jnp.float32),
            jnp.zeros(n_cells, bool),
            jnp.zeros(n_cells, jnp.float32))


def build_hier_async_runner(model, trainer,
                            policies: Sequence[tuple[str, str]], *,
                            n_cells: int, k: int, n: int, rounds: int,
                            eval_mask: np.ndarray,
                            track_gradnorm: bool = False,
                            max_rounds: int = 200,
                            segmented: bool = False):
    """One fused `lax.scan` over global events, the (static) cell list
    unrolled in its body: C cell event loops + the global commit tier.

    Mirrors `fl.sim` runner conventions — same `data` dict contract as
    `_scan_inputs` with a leading cell axis on the per-cell tensors
    (beta/clusters/fixed_ids (C, ...), x_all/y_all/m_all (C, N, B, ...),
    gamma/feas/energy (rounds, C, K, N), perms (rounds, C, ...)) plus the
    commit-policy operands `buffer`/`stale_exp`/`server_lr` (cell tier)
    and `g_buffer`/`g_stale_exp`/`g_server_lr` (global tier), all traced
    so a whole two-tier aggregation grid shares one compiled program per
    shape.  Returns the raw traceable fn(data) -> ys for the caller to
    `jit` / `jit(vmap(...))`; with ``segmented=True`` returns
    ``fn(data, carry) -> (carry, ys)`` instead (see module docstring).
    """
    n_clusters = int(math.ceil(n / k))
    ndev = jnp.arange(n)
    kslot = jnp.arange(k)
    f0 = jnp.float32(0.0)

    def scan_events(data, carry0):
        cell_data = [
            dict(data, beta=data["beta"][c], clusters=data["clusters"][c],
                 fixed_ids=data["fixed_ids"][c], x_all=data["x_all"][c],
                 y_all=data["y_all"][c], m_all=data["m_all"][c])
            for c in range(n_cells)]
        branches = [
            make_leader_branches(policies, cell_data[c], k=k, n=n,
                                 n_clusters=n_clusters,
                                 max_rounds=max_rounds)
            for c in range(n_cells)]
        ev = make_eval_fn(model, data, track_gradnorm)

        def body(carry, x):
            (params, key, age, pcell, buf, base, disp_e, rem, active,
             gbuf, gbase, g_disp, g_rem, g_active, g_w) = carry
            # Gating snapshot: a cell whose flight is outstanding at the
            # global tier makes no local commits THIS event.
            busy = g_active

            ages, deltas, energies = [], [], []
            sel_all, tx_all, commit_all, remd_all = [], [], [], []
            overflow = jnp.bool_(False)
            for c in range(n_cells):
                dc = cell_data[c]
                xc = dict(x, gamma=x["gamma"][c], feas=x["feas"][c],
                          energy=x["energy"][c],
                          sel_perm=x["sel_perm"][c],
                          assign_perm=x["assign_perm"][c])
                act_c = active[c]
                p_c = jax.tree_util.tree_map(lambda l: l[c], pcell)

                # ---- cell leader plane: AoU selection over the FREE
                # population of cell c ---------------------------------
                feas_free = xc["feas"] & ~act_c[None, :]
                lead = run_leader(branches[c], data["policy_idx"], age[c],
                                  feas_free, xc)
                tx = lead["transmitted"]
                ch_g = jnp.where(tx, lead["channel_of"], 0)
                t_dev = xc["gamma"][ch_g, ndev]
                energies.append(
                    jnp.sum(jnp.where(tx, xc["energy"][ch_g, ndev], f0)))
                overflow = overflow | (tx & act_c).any()

                # ---- cell learning plane: dispatched devices train from
                # the CELL model (same PRNG discipline as the sync scan) -
                tx_ids = jnp.nonzero(tx, size=k, fill_value=0)[0]
                cnt = tx.sum()

                def do_train(ops, dc=dc, tx_ids=tx_ids):
                    p, kk = ops
                    return train_clients(trainer, dc, k, p, kk, tx_ids)

                def no_train(ops):
                    p, kk = ops
                    cp = jax.tree_util.tree_map(
                        lambda l: jnp.zeros((k,) + l.shape, l.dtype), p)
                    return cp, kk

                cp, key = jax.lax.cond(cnt > 0, do_train, no_train,
                                       (p_c, key))

                # ---- buffer the flights (device-indexed; empty slots on
                # the sacrificial row n) -------------------------------
                ids_s = jnp.where(kslot < cnt, tx_ids, n)
                buf = jax.tree_util.tree_map(
                    lambda b, cl: b.at[c, ids_s].set(cl), buf, cp)
                base = jax.tree_util.tree_map(
                    lambda b, g: b.at[c, ids_s].set(
                        jnp.broadcast_to(g, (k,) + g.shape)), base, p_c)
                act_c = act_c | tx
                rem_c = jnp.where(tx, t_dev, rem[c])
                disp_c = jnp.where(tx, x["t"], disp_e[c])

                # ---- cell commit, gated on the upstream flight --------
                delta_raw, commit_raw = commit_event(rem_c, act_c,
                                                     data["buffer"], k)
                delta_c = jnp.where(busy[c], f0, delta_raw)
                commit = commit_raw & ~busy[c]
                stale = x["t"] - disp_c
                w_st = staleness_weight(stale, data["stale_exp"])
                cids = jnp.nonzero(commit, size=k, fill_value=0)[0]
                commit_cnt = commit.sum()
                cw = jnp.where(kslot < commit_cnt,
                               dc["beta"][cids] * w_st[cids], f0)
                translated = jax.tree_util.tree_map(
                    lambda cl, bb, g: cl + (g - bb),
                    jax.tree_util.tree_map(lambda b: b[c, cids], buf),
                    jax.tree_util.tree_map(lambda b: b[c, cids], base),
                    p_c)
                p_c = aggregate_buffered(p_c, translated, cw,
                                         data["server_lr"])
                pcell = jax.tree_util.tree_map(
                    lambda pl, l: pl.at[c].set(l), pcell, p_c)

                # ---- post-commit cell state; committed cells dispatch
                # their model upstream as ONE global flight -------------
                act_c = act_c & ~commit
                rem = rem.at[c].set(jnp.where(act_c, rem_c - delta_c, f0))
                active = active.at[c].set(act_c)
                disp_e = disp_e.at[c].set(disp_c)
                ages.append(jnp.where(commit, 1, age[c] + 1)
                            .astype(age.dtype))
                deltas.append(delta_c)

                fly = commit_cnt > 0
                overflow = overflow | (fly & busy[c])
                gbuf = jax.tree_util.tree_map(
                    lambda gb, l: gb.at[c].set(
                        jnp.where(fly, l, gb[c])), gbuf, p_c)
                gbase = jax.tree_util.tree_map(
                    lambda gb, l: gb.at[c].set(
                        jnp.where(fly, l, gb[c])), gbase, params)
                g_rem = g_rem.at[c].set(jnp.where(fly, delta_c, g_rem[c]))
                g_disp = g_disp.at[c].set(jnp.where(fly, x["t"], g_disp[c]))
                g_w = g_w.at[c].set(jnp.where(fly, cw.sum(), g_w[c]))
                g_active = g_active.at[c].set(g_active[c] | fly)

                sel_all.append(lead["selected"])
                tx_all.append(tx)
                commit_all.append(commit)
                remd_all.append(jnp.where(tx, t_dev, f0))

            # ---- global tier: the SAME commit rule, one tier up.  The
            # buffer is cell-indexed (slot c = cell c), so weight-0 slots
            # occupy the same summation positions as the sync engine's
            # stacked cells ---------------------------------------------
            g_delta, g_commit = commit_event(g_rem, g_active,
                                             data["g_buffer"], n_cells)
            g_stale = x["t"] - g_disp
            gw = jnp.where(g_commit,
                           g_w * staleness_weight(g_stale,
                                                  data["g_stale_exp"]),
                           f0)
            translated_g = jax.tree_util.tree_map(
                lambda gb, bb, g: gb + (g - bb), gbuf, gbase, params)
            params = aggregate_buffered(params, translated_g, gw,
                                        data["g_server_lr"])

            g_active = g_active & ~g_commit
            g_rem = jnp.where(g_active, g_rem - g_delta, f0)
            # Down-sync: every flight-free cell re-bases onto the new
            # global model (exact select; see module docstring).
            free = ~g_active
            pcell = jax.tree_util.tree_map(
                lambda pl, g: jnp.where(
                    free.reshape((n_cells,) + (1,) * g.ndim), g[None], pl),
                pcell, params)

            age_next = jnp.stack(ages)
            loss, acc, gnorm = jax.lax.cond(
                x["eval_mask"], ev, lambda p: (f0, f0, f0), params)

            ys = dict(loss=loss, acc=acc, gnorm=gnorm, latency=g_delta,
                      energy=jnp.stack(energies).sum(),
                      selected=jnp.stack(sel_all),
                      transmitted=jnp.stack(tx_all),
                      age=age_next,
                      committed=jnp.stack(commit_all),
                      cell_committed=g_commit,
                      latency_cells=jnp.stack(deltas),
                      n_pending=active.sum(dtype=jnp.int32),
                      g_pending=g_active.sum(dtype=jnp.int32),
                      overflow=overflow,
                      rem_dispatch=jnp.stack(remd_all))
            return (params, key, age_next, pcell, buf, base, disp_e, rem,
                    active, gbuf, gbase, g_disp, g_rem, g_active, g_w), ys

        xs = make_xs(data, rounds, eval_mask)
        if segmented:
            xs["t"] = data["t0"] + xs["t"]
        return jax.lax.scan(body, carry0, xs)

    if segmented:
        return scan_events

    def run(data):
        carry0 = init_hier_async_carry(data["params0"], data["key0"],
                                       n_cells, n)
        _, ys = scan_events(data, carry0)
        return ys

    return run
