"""engine="async": the buffered, staleness-weighted event-timeline loop.

The synchronous engines close every round with the eq.-9 barrier — the
round is as slow as its slowest transmitter.  This module replaces the
barrier with a buffered server (DESIGN.md §12): the leader still runs the
Stackelberg step each *event* (AoU selection re-prioritizes on the event
stream, busy devices drop out of the Prop-1 mask), dispatched devices
train immediately from the current global model, and their uploads fly
for their OWN Γ-trace duration.  The server commits an event once the
`AsyncAggregation.buffer` earliest uploads have landed, weighting each
committed update by beta_n * f(staleness) (`server.staleness_weight`) and
stepping by the spec's server_lr (`server.aggregate_buffered`).

Everything is one fixed-shape `lax.scan` over `rounds` server events, so
the async engine inherits the scan engine's whole toolchain: `jit`,
`vmap` across sweep cells, `lax.switch` policy batching, `shard_map`
sharding, and the precomputed whole-horizon Γ/scenario traces
(`fl.sim` builds the inputs and owns dispatch; this module only builds
the traced event body).

Carry layout (DESIGN.md §12) — the sync carry (params, key, age) plus the
event buffer:

  buf     pytree, leaves (N+1, ...)   in-flight client models, device-
                                      indexed (row N is the sacrificial
                                      scatter target for empty slots);
  base    pytree, leaves (N+1, ...)   the global model each flight was
                                      dispatched FROM.  A commit applies
                                      the TRANSLATED update
                                      w_i + (w - b_i) — the flight's local
                                      progress grafted onto the current
                                      model (FedBuff-style delta
                                      application), so a stale commit can
                                      never drag the server back toward
                                      the old state it trained from;
  disp_e  (N,) int32                  event index of each flight's dispatch
                                      (staleness = current event - disp_e);
  rem     (N,) float32                remaining upload time; RELATIVE times
                                      keep the degenerate limit bit-exact —
                                      an absolute-clock formulation would
                                      round (t + T) - t through float32;
  active  (N,) bool                   device has an uncommitted upload in
                                      flight (at most ONE per device, so the
                                      buffer structurally cannot overflow).

Segment resume (DESIGN.md §14): the carry above is the event loop's
COMPLETE state — params, PRNG key, ages, the in-flight buffer pair, and
the virtual clocks.  `build_async_runner(..., segmented=True)` therefore
returns a ``run(data, carry) -> (carry, ys)`` closure instead of building
and discarding the carry internally: the sustained service chains the
carry across fixed-size segments (one compiled program per segment
shape), offsetting the event index by the traced scalar ``data["t0"]`` so
absolute staleness, AoU cluster rotation, and the dispatch bookkeeping
continue seamlessly — S segments of length L replay the single scan of
length S*L bit-for-bit (`disp_e` holds absolute indices; `rem` stays
RELATIVE, so chaining adds no float round-trips).  `init_async_carry`
builds the t=0 carry both modes share.

Degenerate limit: with `buffer="full"` every in-flight upload commits at
its own event, so commit == dispatch, staleness == 0 (weight multiplier
exactly 1.0), the server_lr=1 mixing is an exact endpoint select, the
translation vanishes identically (b_i IS the current model bitwise, so
w_i + (w - b_i) = w_i + 0.0 = w_i), and the event latency is the max over
dispatched rem — the scan engine's eq.-9 barrier.  Every arithmetic step
on that path reproduces the sync ops bit-for-bit (pinned by
tests/test_async_equivalence.py for every scenario preset).
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .engine_common import (
    make_eval_fn,
    make_leader_branches,
    make_xs,
    run_leader,
    train_clients,
)
from .server import aggregate_buffered, staleness_weight

__all__ = ["commit_event", "init_async_carry", "build_async_runner"]


def init_async_carry(params0, key0, n: int):
    """The event loop's t=0 carry: fresh model, unit ages, empty buffer.

    The buffer pair (`buf`, `base`) is zero-initialized — rows are only
    ever read after a dispatch wrote them (`active` gates every commit),
    so the fill value is unobservable; zeros keep the carry deterministic
    for the segment-resume contract.
    """
    buf0 = jax.tree_util.tree_map(
        lambda l: jnp.zeros((n + 1,) + l.shape, l.dtype), params0)
    return (params0, key0, jnp.ones(n, jnp.int32), buf0, buf0,
            jnp.zeros(n, jnp.int32), jnp.zeros(n, jnp.float32),
            jnp.zeros(n, bool))


def commit_event(rem: jax.Array, active: jax.Array, buffer: jax.Array,
                 k: int) -> tuple[jax.Array, jax.Array]:
    """The buffered server's commit decision for one event.

    Args:
      rem:    (N,) float32 remaining upload time per device.
      active: (N,) bool in-flight mask (`rem` is meaningful where True).
      buffer: scalar int commit batch size M (traced operand, so sweeps
        may vary it per cell without recompiling).
      k: static sub-channel count — the server drains at most K uploads
        per event.

    Returns (delta, commit): the event's latency (time until the M-th
    earliest in-flight upload lands; 0 when nothing is in flight) and the
    committed-device mask (every upload landing within `delta`, ties
    committing together, capped at the K earliest by (rem, id) order).
    """
    n = rem.shape[0]
    n_active = active.sum()
    r_sorted = jnp.sort(jnp.where(active, rem, jnp.inf))
    m_idx = jnp.clip(jnp.minimum(buffer, n_active) - 1, 0, n - 1)
    delta = jnp.where(n_active > 0, r_sorted[m_idx], jnp.float32(0.0))
    arrived = active & (rem <= delta)
    # Serve at most K uploads per event: rank arrivals by (rem, id) —
    # argsort is stable, so ties break by device id like the host leader.
    order = jnp.argsort(jnp.where(arrived, rem, jnp.inf))
    rank = jnp.zeros(n, jnp.int32).at[order].set(
        jnp.arange(n, dtype=jnp.int32))
    return delta, arrived & (rank < k)


def build_async_runner(model, trainer, policies: Sequence[tuple[str, str]],
                       *, k: int, n: int, rounds: int,
                       eval_mask: np.ndarray, track_gradnorm: bool = False,
                       max_rounds: int = 200, segmented: bool = False):
    """One fused `lax.scan` over server events: leader + training + commits.

    Mirrors `fl.sim._build_scan_runner` (same `data` dict contract plus
    the async operands `buffer` and `stale_exp`), returning the raw
    traceable fn(data) -> ys for the caller to `jit` / `jit(vmap(...))`.

    With ``segmented=True`` the returned closure is instead
    ``fn(data, carry) -> (carry, ys)``: the caller owns the carry (seed
    it with `init_async_carry`, thread it across segments) and `data`
    additionally provides the traced int32 scalar ``t0`` — the absolute
    event index of the segment's first event, added to the per-event
    round counter so staleness, AoU rotation, and `disp_e` bookkeeping
    stay absolute across segment boundaries (DESIGN.md §14).
    """
    n_clusters = int(math.ceil(n / k))
    ndev = jnp.arange(n)
    kslot = jnp.arange(k)
    f0 = jnp.float32(0.0)

    def scan_events(data, carry0):
        branches = make_leader_branches(policies, data, k=k, n=n,
                                        n_clusters=n_clusters,
                                        max_rounds=max_rounds)
        ev = make_eval_fn(model, data, track_gradnorm)

        def body(carry, x):
            params, key, age, buf, base, disp_e, rem, active = carry

            # ---- leader plane: busy devices lose Prop-1 feasibility, so
            # AoU selection re-prioritizes over the FREE population --------
            feas_free = x["feas"] & ~active[None, :]
            lead = run_leader(branches, data["policy_idx"], age,
                              feas_free, x)
            tx = lead["transmitted"]
            ch_g = jnp.where(tx, lead["channel_of"], 0)
            t_dev = x["gamma"][ch_g, ndev]
            energy = jnp.sum(jnp.where(tx, x["energy"][ch_g, ndev], f0))
            overflow = (tx & active).any()      # must be structurally False

            # ---- learning plane: dispatched devices train from the
            # CURRENT global model (same PRNG discipline as sync) ----------
            tx_ids = jnp.nonzero(tx, size=k, fill_value=0)[0]
            cnt = tx.sum()

            def do_train(ops):
                p, kk = ops
                return train_clients(trainer, data, k, p, kk, tx_ids)

            def no_train(ops):
                p, kk = ops
                cp = jax.tree_util.tree_map(
                    lambda l: jnp.zeros((k,) + l.shape, l.dtype), p)
                return cp, kk

            cp, key = jax.lax.cond(cnt > 0, do_train, no_train, (params, key))

            # ---- buffer the flights: device-indexed scatter (empty slots
            # land on the sacrificial row n) -------------------------------
            ids_s = jnp.where(kslot < cnt, tx_ids, n)
            buf = jax.tree_util.tree_map(
                lambda b, c: b.at[ids_s].set(c), buf, cp)
            base = jax.tree_util.tree_map(
                lambda b, g: b.at[ids_s].set(
                    jnp.broadcast_to(g, (k,) + g.shape)), base, params)
            active = active | tx
            rem = jnp.where(tx, t_dev, rem)
            disp_e = jnp.where(tx, x["t"], disp_e)

            # ---- commit: wait for the buffer-many earliest arrivals ------
            delta, commit = commit_event(rem, active, data["buffer"], k)
            stale = x["t"] - disp_e
            w_st = staleness_weight(stale, data["stale_exp"])
            cids = jnp.nonzero(commit, size=k, fill_value=0)[0]
            commit_cnt = commit.sum()
            cw = jnp.where(kslot < commit_cnt,
                           data["beta"][cids] * w_st[cids], f0)
            # Graft each committed flight's local progress onto the CURRENT
            # model: w_i + (w - b_i).  Fresh commits have b_i == w bitwise,
            # so the translation is an exact no-op in the sync limit.
            translated = jax.tree_util.tree_map(
                lambda c, bb, g: c + (g - bb),
                jax.tree_util.tree_map(lambda b: b[cids], buf),
                jax.tree_util.tree_map(lambda b: b[cids], base),
                params)
            params = aggregate_buffered(params, translated, cw,
                                        data["server_lr"])

            # ---- post-commit state: AoU resets when the SERVER ingests the
            # update; surviving flights advance by the event's duration ----
            active = active & ~commit
            rem = jnp.where(active, rem - delta, f0)
            age_next = jnp.where(commit, 1, age + 1).astype(age.dtype)

            loss, acc, gnorm = jax.lax.cond(
                x["eval_mask"], ev, lambda p: (f0, f0, f0), params)

            ys = dict(loss=loss, acc=acc, gnorm=gnorm, latency=delta,
                      energy=energy, selected=lead["selected"],
                      transmitted=tx, age=age_next, committed=commit,
                      n_pending=active.sum(dtype=jnp.int32),
                      overflow=overflow,
                      rem_dispatch=jnp.where(tx, t_dev, f0))
            return (params, key, age_next, buf, base, disp_e, rem,
                    active), ys

        xs = make_xs(data, rounds, eval_mask)
        if segmented:
            xs["t"] = data["t0"] + xs["t"]
        return jax.lax.scan(body, carry0, xs)

    if segmented:
        return scan_events

    def run(data):
        carry0 = init_async_carry(data["params0"], data["key0"], n)
        _, ys = scan_events(data, carry0)
        return ys

    return run
