"""Traced-body pieces shared by the scan and async engines.

The async engine's bit-exact sync-limit contract (DESIGN.md §12) holds
only while both engines trace the SAME float ops for the leader step,
the client-training PRNG discipline, and the eval path — so those pieces
live here once, imported by `fl.sim._build_scan_runner` and
`fl.async_loop.build_async_runner`, instead of being mirrored by hand.
Everything here is pure tracing scaffolding over the `data` dict contract
of `fl.sim._scan_inputs`; no dispatch or history logic.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from ..core import leader_round

__all__ = ["make_leader_branches", "run_leader", "train_clients",
           "make_eval_fn", "make_xs"]


def make_leader_branches(policies: Sequence[tuple[str, str]], data, *,
                         k: int, n: int, n_clusters: int,
                         max_rounds: int = 200):
    """One `leader_round` closure per distinct (ds, sa) policy variant.

    Each branch takes ``(age, feasible, x)`` — the feasibility mask is an
    explicit operand so the async engine can knock busy devices out of
    Prop-1 (the scan engine passes ``x["feas"]`` unchanged).
    """
    def leader_branch(ds, sa):
        def branch(ops):
            age, feas, x = ops
            return leader_round(
                age, data["beta"], x["gamma"], feas,
                x["sel_perm"], x["assign_perm"], x["t"],
                data["clusters"], data["fixed_ids"],
                ds=ds, sa=sa, k=k, n=n, n_clusters=n_clusters,
                max_rounds=max_rounds)
        return branch

    return [leader_branch(ds, sa) for ds, sa in policies]


def run_leader(branches, policy_idx, age, feasible, x):
    """Dispatch one leader step: direct call for single-policy groups,
    `lax.switch` on the cell's policy index otherwise (DESIGN.md §10)."""
    if len(branches) == 1:
        return branches[0]((age, feasible, x))
    return jax.lax.switch(policy_idx, branches, (age, feasible, x))


def train_clients(trainer, data, k: int, params, key, tx_ids):
    """The engines' shared training step and PRNG discipline: exactly one
    key split per training event, then K per-slot keys — both engines
    MUST consume the stream identically or the differential contracts
    break.  Returns (client_params, advanced_key)."""
    key, k_round = jax.random.split(key)
    keys = jax.random.split(k_round, k)
    cp = trainer(params, data["x_all"][tx_ids], data["y_all"][tx_ids],
                 data["m_all"][tx_ids], keys)
    return cp, key


def make_eval_fn(model, data, track_gradnorm: bool):
    """The eval-round branch: (loss, accuracy, grad-norm^2-if-tracked)."""
    f0 = jnp.float32(0.0)

    def gnorm_fn(p):
        return sum(
            jnp.sum(jnp.square(g))
            for g in jax.tree_util.tree_leaves(
                jax.grad(model.loss)(p, data["x_full"], data["y_full"])))

    def ev(p):
        gn = gnorm_fn(p) if track_gradnorm else f0
        return (model.loss(p, data["x_full"], data["y_full"]),
                model.accuracy(p, data["x_full"], data["y_full"]),
                jnp.float32(gn))

    return ev


def make_xs(data, rounds: int, eval_mask) -> dict:
    """The per-round scan xs both engines consume: Γ slices, injected
    permutations, the eval mask, and the round index."""
    return dict(gamma=data["gamma"], feas=data["feas"],
                energy=data["energy"], sel_perm=data["sel_perms"],
                assign_perm=data["assign_perms"],
                eval_mask=jnp.asarray(eval_mask),
                t=jnp.arange(rounds, dtype=jnp.int32))
