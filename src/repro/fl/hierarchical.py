"""Hierarchical (multi-cell) FLOWN — the FL semantics of the `pod` mesh axis.

Beyond-paper extension: the paper studies a single server; at city scale
the natural topology is C cells, each with its own base station running
the paper's FULL Stackelberg round (own channels, own sub-channels, own
AoU state), followed by an inter-cell aggregation of the cell models
weighted by transmitted data:

    cell c:   w_c = eq.(34) over its transmitting devices
    global:   w   = sum_c W_c w_c / sum_c W_c ,  W_c = sum_{n in tx_c} beta_n

Like the single-cell harness (`fl.sim`), the multi-cell loop pre-samples
every cell's whole environment horizon up front (scenario processes
threaded through `_prepare_hier`: ONE shared mobility field across all
C*N devices, cross-cell interference as coupled fading
(`scenarios.sample_coupled_fading`), per-cell Markov churn and energy
budgets), solves Γ for all cells in one batched Algorithm-1 call, and
offers three engines (DESIGN.md §8, §10, §15):

  engine="loop"  -- host round loop: per-cell `plan_round` + jitted
                    training;
  engine="scan"  -- ONE `lax.scan` over rounds whose body unrolls the
                    (static) cell list: per-cell jnp leader + training +
                    the inter-cell aggregation, fused into a single
                    compiled program;
  engine="async" -- the two-tier buffered event loop (`fl.hier_async`):
                    each cell runs the staleness-weighted event engine
                    over its devices' virtual clocks and commits
                    asynchronously into a global server that is itself a
                    buffered staleness-weighted aggregator
                    (`HierSimConfig.aggregation` names the cell tier's
                    commit policy, `.global_aggregation` the global
                    tier's; either being async routes here).

All engines consume identical pre-sampled randomness, so their per-cell
transmitted sets, latencies, and losses coincide
(tests/test_hierarchical.py pins loop == scan;
tests/test_hier_async_equivalence.py pins the async engine's degenerate
limits — full buffers at both tiers == the sync scan bit-exactly, and a
single-cell hierarchy == the flat `engine="async"` path bit-exactly).

`run_hier_many` is the sweep entry point: like `fl.sim.run_many` it
dedups worlds across policy/aggregation variants, groups compatible
configs into one compiled program per shape (`_hier_group_key`), and
dispatches groups through `fl.sim._dispatch_group` (solo jit /
jit(vmap) / `shard_map`), returning flat-compatible `SimHistory` records
with (rounds, C*N) traces so every sweep metric works unchanged.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (
    RoundPolicy,
    RoundRandomness,
    WirelessConfig,
    init_aou,
    make_clusters,
    plan_round,
)
from ..core.monotonic import RAResult, fixed_ra
from ..core.monotonic_jax import solve_pairs_fused, solve_pairs_jit
from ..data.fl_datasets import make_dataset, partition_imbalanced_iid
from ..models.small import get_small_model
from ..scenarios import (
    Scenario,
    apply_dynamics,
    compose_gains,
    get_scenario,
    sample_churn,
    sample_coupled_fading,
    sample_distances,
    sample_energy,
)
from .engine_common import make_eval_fn, make_leader_branches, make_xs, \
    run_leader, train_clients
from .hier_async import build_hier_async_runner
from .server import AsyncAggregation, aggregate, get_aggregation
from .sim import (
    TABLE1,
    SimHistory,
    _dispatch_group,
    _eval_rounds,
    _group_trainer_and_policies,
    _history_from_async,
    _history_from_scan,
    _pad_partition,
    _slice_ra,
)

__all__ = ["HierSimConfig", "run_hierarchical", "run_hier_many"]


@dataclasses.dataclass(frozen=True)
class HierSimConfig:
    """Multi-cell simulation settings (one Stackelberg game per cell).

    `n_cells` base stations each serve `devices_per_cell` devices over
    `subchannels_per_cell` uplink sub-channels; all cells share the global
    model and the Table-I learning settings of `dataset` (None overrides =
    "use Table I", like `SimConfig`).  `scenario` names the shared
    environment (one mobility field spans ALL cells; churn and energy are
    per-cell processes), `cell_coupling` the cross-cell fading
    correlation, and the two aggregation fields the commit policies of
    the cell tier (`aggregation`) and the global tier
    (`global_aggregation`) — either being async routes the simulation
    through the two-tier event engine (`fl.hier_async`).
    """

    dataset: str = "mnist"
    n_cells: int = 2
    devices_per_cell: int = 10
    subchannels_per_cell: int = 4
    rounds: int = 40
    policy: RoundPolicy = RoundPolicy()
    seed: int = 0
    n_samples: int | None = 400
    local_steps: int = 3
    radius_m: float = 500.0
    pt_dbm: float = 10.0
    e_max_j: float | None = None       # None -> Table I per-dataset value
    lr: float | None = None
    batch: int | None = None
    optimizer: str | None = None
    eval_every: int = 1
    track_gradnorm: bool = False
    scenario: str | Scenario = "static"
    cell_coupling: float = 0.0         # cross-cell fading correlation in [0, 1]
    aggregation: str | AsyncAggregation = "sync"         # cell tier
    global_aggregation: str | AsyncAggregation = "sync"  # global tier

    @property
    def n_devices(self) -> int:
        """Total device count across cells (sweep-metric compatibility)."""
        return self.n_cells * self.devices_per_cell

    @property
    def n_subchannels(self) -> int:
        """Total sub-channel count across cells."""
        return self.n_cells * self.subchannels_per_cell

    def wireless(self) -> WirelessConfig:
        """The PER-CELL wireless world (each cell is one paper network)."""
        t1 = TABLE1[self.dataset]
        return WirelessConfig(
            n_devices=self.devices_per_cell,
            n_subchannels=self.subchannels_per_cell,
            radius_m=self.radius_m,
            pt_dbm=self.pt_dbm,
            model_bits=t1["model_bits"],
            e_max_j=self.e_max_j if self.e_max_j is not None else t1["e_max"],
        )


@dataclasses.dataclass
class _HierPrepared:
    """Per-cell worlds + whole-horizon scenario traces, sampled up front."""

    cfg: HierSimConfig
    wcfg: WirelessConfig           # per-cell wireless constants
    rng: np.random.Generator
    ds: Any
    parts: list                    # per-cell FLPartition (for re-padding)
    beta: np.ndarray               # (C, N) float64
    x: Any                         # (C, N, Bmax, ...) padded client data
    y: Any
    m: Any
    clusters: np.ndarray           # (C, N)
    fixed_ids: np.ndarray          # (C, S)
    h2_all: np.ndarray             # (C, rounds, K, N)
    sel_perms: np.ndarray          # (C, rounds, N)
    assign_perms: np.ndarray       # (C, rounds, K)
    distances: np.ndarray          # (C, rounds, N) shared mobility field
    avail: np.ndarray              # (C, rounds, N) per-cell churn
    slowdown: np.ndarray           # (C, rounds, N)
    emax_all: np.ndarray           # (C, rounds, N)


def _prepare_hier(cfg: HierSimConfig) -> _HierPrepared:
    """Sample the multi-cell world + whole-horizon scenario environment.

    The stream mirrors `fl.sim._prepare` phase for phase with per-cell
    blocks — dataset, per-cell partitions, ONE shared mobility field over
    all C*N devices (one physical city; cells are spatial neighborhoods
    of the same walker population), per-cell leader state
    (clusters/fixed_ids), coupled cross-cell fading, per-cell injected
    permutations, per-cell churn, per-cell energy.  At C == 1 every block
    degenerates to exactly one flat-stream call in the flat order, so a
    single-cell hierarchy consumes the BIT-IDENTICAL rng stream of the
    flat `_prepare` — the anchor of the cell-of-one differential pin.
    """
    rng = np.random.default_rng(cfg.seed)
    wcfg = cfg.wireless()
    scn = get_scenario(cfg.scenario)
    c_n, n, k = cfg.n_cells, cfg.devices_per_cell, cfg.subchannels_per_cell

    ds_kw = {} if cfg.n_samples is None else {"n": cfg.n_samples}
    ds = make_dataset(cfg.dataset, rng, **ds_kw)
    parts = [partition_imbalanced_iid(rng, ds.n, n) for _ in range(c_n)]
    beta = np.stack([p.beta.astype(np.float64) for p in parts])
    bmax = max(int(p.beta.max()) for p in parts)
    padded = [_pad_partition(ds, p, bmax) for p in parts]
    x = jnp.stack([p[0] for p in padded])
    y = jnp.stack([p[1] for p in padded])
    m = jnp.stack([p[2] for p in padded])

    # One SHARED mobility field: all C*N devices walk one world draw.
    dist_flat = sample_distances(
        rng, dataclasses.replace(wcfg, n_devices=c_n * n), scn.mobility,
        cfg.rounds)                                     # (rounds, C*N)
    distances = np.ascontiguousarray(
        dist_flat.reshape(cfg.rounds, c_n, n).transpose(1, 0, 2))

    clusters, fixed_ids = [], []
    for _ in range(c_n):
        clusters.append(make_clusters(n, k, rng))
        fixed_ids.append(rng.permutation(n)[: min(k, n)])

    g2_all = sample_coupled_fading(rng, wcfg, scn.fading, cfg.rounds, c_n,
                                   cfg.cell_coupling)   # (C, rounds, K, N)
    h2_all = np.stack([compose_gains(g2_all[c], distances[c], wcfg)
                       for c in range(c_n)])

    sel_perms = np.stack([
        np.stack([rng.permutation(n) for _ in range(cfg.rounds)])
        for _ in range(c_n)])
    assign_perms = np.stack([
        np.stack([rng.permutation(k) for _ in range(cfg.rounds)])
        for _ in range(c_n)])

    churn = [sample_churn(rng, scn.churn, cfg.rounds, n) for _ in range(c_n)]
    avail = np.stack([a for a, _ in churn])
    slowdown = np.stack([s for _, s in churn])
    emax_all = np.stack([sample_energy(rng, wcfg, scn.energy, cfg.rounds)
                         for _ in range(c_n)])

    return _HierPrepared(
        cfg=cfg, wcfg=wcfg, rng=rng, ds=ds, parts=parts, beta=beta,
        x=x, y=y, m=m,
        clusters=np.stack(clusters), fixed_ids=np.stack(fixed_ids),
        h2_all=h2_all, sel_perms=sel_perms, assign_perms=assign_perms,
        distances=distances, avail=avail, slowdown=slowdown,
        emax_all=emax_all)


def _solve_hier_horizons(
    preps: Sequence[_HierPrepared], backend: str | None,
    solver: str = "fused", shard: bool | None = None,
) -> tuple[list[list[RAResult]], list[float]]:
    """Algorithm 1 for every (cell, round) of every prepared simulation.

    Each unique world's C cell horizons flatten into ONE solver call (the
    solver is elementwise over pairs, so cells concatenate freely and the
    per-cell slices equal solo solves bitwise — including, at C == 1, the
    flat `_solve_horizons` result).  Worlds shared across policy-only /
    aggregation-only variants are solved once and aliased.
    """
    out: list[list[RAResult] | None] = [None] * len(preps)
    secs = [0.0] * len(preps)
    rep_idx: dict[tuple[int, str], int] = {}
    for i, p in enumerate(preps):
        key = (id(p.h2_all), p.cfg.policy.ra)
        if key in rep_idx:
            out[i] = out[rep_idx[key]]
            continue
        rep_idx[key] = i
        c_n = p.cfg.n_cells
        shp = p.h2_all.shape[1:]                  # (rounds, K, N)
        sz = int(np.prod(shp))
        t0 = time.time()
        if p.cfg.policy.ra == "mo":
            beta_cat = np.broadcast_to(
                p.beta[:, None, None, :], p.h2_all.shape).reshape(-1)
            emax_cat = np.broadcast_to(
                p.emax_all[:, :, None, :], p.h2_all.shape).reshape(-1)
            h2_cat = p.h2_all.reshape(-1)
            if solver == "fused":
                flat = solve_pairs_fused(beta_cat, h2_cat, p.wcfg, emax_cat,
                                         backend=backend, shard=shard)
            else:
                flat = solve_pairs_jit(beta_cat, h2_cat, p.wcfg, emax_cat,
                                       backend=backend)
            out[i] = [
                RAResult(*(getattr(flat, f.name)[c * sz:(c + 1) * sz]
                           .reshape(shp)
                           for f in dataclasses.fields(RAResult)))
                for c in range(c_n)]
        else:
            out[i] = [
                fixed_ra(p.beta[c][None, None, :], p.h2_all[c], p.wcfg,
                         np.broadcast_to(p.emax_all[c][:, None, :], shp))
                for c in range(c_n)]
        secs[i] = time.time() - t0
    return out, secs


def _apply_hier_dynamics(prep: _HierPrepared,
                         ras: list[RAResult]) -> list[RAResult]:
    """Fold per-cell churn availability + straggler slowdowns into each
    cell's solved whole-horizon RAResult (DESIGN.md §11), once, before
    any engine runs."""
    return [apply_dynamics(ra, prep.avail[c], prep.slowdown[c],
                           prep.beta[c], prep.wcfg)
            for c, ra in enumerate(ras)]


def _check_hier_f32(preps: Sequence[_HierPrepared]) -> None:
    # Mirror of `fl.sim._check_f32_priorities`: device-resident leaders
    # rank float32 age*beta products, exact only below 2^24.
    for p in preps:
        worst = (p.cfg.rounds + 1) * float(p.beta.max())
        if worst >= 2 ** 24:
            raise ValueError(
                f"hier scan/async engines: age*beta products may reach "
                f"{worst:.3g} >= 2^24, where float32 priorities lose host "
                f"equivalence — use engine='loop' or shrink rounds/data")


# ---------------------------------------------------------------------------
# engine="scan" / engine="async": device-resident two-tier loops
# ---------------------------------------------------------------------------

def _hier_scan_inputs(prep: _HierPrepared, ras: list[RAResult], bmax: int,
                      policy_idx: int = 0) -> dict:
    """The hier `data` dict: `fl.sim._scan_inputs` with a leading cell
    axis on the per-cell tensors (beta/clusters/fixed_ids/client data)
    and a cell axis SECOND on the per-round traces (gamma/feas/energy
    (rounds, C, K, N), perms (rounds, C, ...))."""
    cfg = prep.cfg
    if bmax == prep.x.shape[2]:
        x, y, m = prep.x, prep.y, prep.m
    else:
        padded = [_pad_partition(prep.ds, p, bmax) for p in prep.parts]
        x = jnp.stack([p[0] for p in padded])
        y = jnp.stack([p[1] for p in padded])
        m = jnp.stack([p[2] for p in padded])
    key = jax.random.PRNGKey(cfg.seed)
    key, k_init = jax.random.split(key)
    model = get_small_model(cfg.dataset)
    return dict(
        params0=model.init(k_init),
        policy_idx=jnp.int32(policy_idx),
        key0=key,
        beta=jnp.asarray(prep.beta, jnp.float32),
        x_all=x, y_all=y, m_all=m,
        x_full=jnp.asarray(prep.ds.x), y_full=jnp.asarray(prep.ds.y),
        clusters=jnp.asarray(prep.clusters, jnp.int32),
        fixed_ids=jnp.asarray(prep.fixed_ids, jnp.int32),
        gamma=jnp.asarray(np.stack([ra.time_s for ra in ras], axis=1),
                          jnp.float32),
        feas=jnp.asarray(np.stack([ra.feasible for ra in ras], axis=1)),
        energy=jnp.asarray(
            np.stack([np.where(np.isfinite(ra.energy_j), ra.energy_j, 0.0)
                      for ra in ras], axis=1), jnp.float32),
        sel_perms=jnp.asarray(prep.sel_perms.swapaxes(0, 1), jnp.int32),
        assign_perms=jnp.asarray(prep.assign_perms.swapaxes(0, 1),
                                 jnp.int32),
    )


def _build_hier_scan_runner(cfg: HierSimConfig, model, trainer,
                            policies: Sequence[tuple[str, str]] | None = None):
    """The fused multi-cell SYNC round loop: one `lax.scan` over rounds,
    cells unrolled in the body, eq.-34 at both tiers.  Per-cell pieces
    (leader branches, training PRNG discipline, eval) are the shared
    `engine_common` ops, traced in the SAME order the two-tier async
    engine traces them — the sync side of the full-buffer differential."""
    n, k = cfg.devices_per_cell, cfg.subchannels_per_cell
    n_cells = cfg.n_cells
    rounds, eval_every = cfg.rounds, cfg.eval_every
    n_clusters = int(math.ceil(n / k))
    ndev = jnp.arange(n)
    kslot = jnp.arange(k)
    f0 = jnp.float32(0.0)
    if policies is None:
        policies = [(cfg.policy.ds, cfg.policy.sa)]

    def run(data):
        cell_data = [
            dict(data, beta=data["beta"][c], clusters=data["clusters"][c],
                 fixed_ids=data["fixed_ids"][c], x_all=data["x_all"][c],
                 y_all=data["y_all"][c], m_all=data["m_all"][c])
            for c in range(n_cells)]
        branches = [
            make_leader_branches(policies, cell_data[c], k=k, n=n,
                                 n_clusters=n_clusters)
            for c in range(n_cells)]
        ev = make_eval_fn(model, data, cfg.track_gradnorm)

        def body(carry, x):
            params, key, age = carry                     # age (C, N)
            cell_out, weights, ages, energies = [], [], [], []
            sel_all, tx_all = [], []
            latency = f0
            for c in range(n_cells):
                dc = cell_data[c]
                xc = dict(x, gamma=x["gamma"][c], feas=x["feas"][c],
                          energy=x["energy"][c],
                          sel_perm=x["sel_perm"][c],
                          assign_perm=x["assign_perm"][c])
                lead = run_leader(branches[c], data["policy_idx"], age[c],
                                  xc["feas"], xc)
                tx = lead["transmitted"]
                ch_g = jnp.where(tx, lead["channel_of"], 0)
                t_dev = xc["gamma"][ch_g, ndev]
                cell_lat = jnp.where(
                    tx.any(), jnp.max(jnp.where(tx, t_dev, -jnp.inf)), f0)
                latency = jnp.maximum(latency, cell_lat)
                energies.append(
                    jnp.sum(jnp.where(tx, xc["energy"][ch_g, ndev], f0)))
                tx_ids = jnp.nonzero(tx, size=k, fill_value=0)[0]
                cnt = tx.sum()
                slot_w = jnp.where(kslot < cnt, dc["beta"][tx_ids], f0)

                def do_train(ops, dc=dc, tx_ids=tx_ids, slot_w=slot_w):
                    p, kk = ops
                    cp, kk = train_clients(trainer, dc, k, p, kk, tx_ids)
                    return aggregate(p, cp, slot_w), kk

                w_cell, key = jax.lax.cond(
                    cnt > 0, do_train, lambda ops: ops, (params, key))
                cell_out.append(w_cell)
                weights.append(slot_w.sum())
                ages.append(lead["age_next"])
                sel_all.append(lead["selected"])
                tx_all.append(tx)

            stacked = jax.tree_util.tree_map(
                lambda *leaves: jnp.stack(leaves), *cell_out)
            params = aggregate(params, stacked, jnp.stack(weights))
            age_next = jnp.stack(ages)
            loss, acc, gnorm = jax.lax.cond(
                x["eval_mask"], ev, lambda p: (f0, f0, f0), params)
            ys = dict(loss=loss, acc=acc, gnorm=gnorm, latency=latency,
                      energy=jnp.stack(energies).sum(),
                      selected=jnp.stack(sel_all),
                      transmitted=jnp.stack(tx_all), age=age_next)
            return (params, key, age_next), ys

        eval_mask = np.zeros(rounds, bool)
        eval_mask[_eval_rounds(rounds, eval_every)] = True
        carry0 = (data["params0"], data["key0"],
                  jnp.ones((n_cells, n), jnp.int32))
        _, ys = jax.lax.scan(body, carry0, make_xs(data, rounds, eval_mask))
        return ys

    return run


def _hier_async_specs(cfg: HierSimConfig) -> tuple[AsyncAggregation,
                                                   AsyncAggregation]:
    """Cell-tier and global-tier commit policies.  A "sync" tier forced
    through the event engine runs the degenerate full-buffer barrier —
    the differential anchor at that tier."""
    barrier = AsyncAggregation(buffer="full", staleness="const")
    spec = get_aggregation(cfg.aggregation) or barrier
    g_spec = get_aggregation(cfg.global_aggregation) or barrier
    return spec, g_spec


def _flatten_hier_ys(ys: dict, rounds: int) -> dict:
    """Collapse (rounds, C, N) device traces to the flat engines'
    (rounds, C*N) layout so `fl.sim`'s history builders apply verbatim."""
    out = dict(ys)
    for key in ("selected", "transmitted", "age", "committed",
                "rem_dispatch"):
        if key in out:
            out[key] = np.asarray(out[key]).reshape(rounds, -1)
    return out


def _history_from_hier(cfg: HierSimConfig, beta_flat: np.ndarray, ys: dict,
                       wall_s: float, plan_wall_s: float,
                       mode: str) -> SimHistory:
    flat = _flatten_hier_ys(ys, cfg.rounds)
    if mode == "async":
        hist = _history_from_async(cfg, beta_flat, flat, wall_s,
                                   plan_wall_s)
        hist.async_trace.update(
            g_pending=np.asarray(ys["g_pending"], np.int64),
            cell_committed=np.asarray(ys["cell_committed"]),
            latency_cells=np.asarray(ys["latency_cells"], np.float64),
        )
    else:
        hist = _history_from_scan(cfg, beta_flat, flat, wall_s, plan_wall_s)
    return hist


def _run_hier_group(mode: str, cfgs: Sequence[HierSimConfig],
                    preps: Sequence[_HierPrepared],
                    ras_list: Sequence[list[RAResult]],
                    plan_walls: Sequence[float],
                    shard: bool = False) -> list[SimHistory]:
    """Run one static-shape group of hierarchical simulations through the
    scan or two-tier async engine — grouping/batching/sharding mirror
    `fl.sim` exactly (stacked cells, `lax.switch` policy branches,
    `_dispatch_group`); the four commit-policy operands are traced data,
    so a whole two-tier aggregation grid shares one compiled program."""
    cfg = cfgs[0]
    model, trainer, policies, pol_idx = _group_trainer_and_policies(cfgs)
    _check_hier_f32(preps)
    if mode == "scan":
        run = _build_hier_scan_runner(cfg, model, trainer, policies)
    else:
        eval_mask = np.zeros(cfg.rounds, bool)
        eval_mask[_eval_rounds(cfg.rounds, cfg.eval_every)] = True
        run = build_hier_async_runner(
            model, trainer, policies, n_cells=cfg.n_cells,
            k=cfg.subchannels_per_cell, n=cfg.devices_per_cell,
            rounds=cfg.rounds, eval_mask=eval_mask,
            track_gradnorm=cfg.track_gradnorm)

    t_start = time.time()
    bmax = max(int(p.x.shape[2]) for p in preps)
    datas = []
    for c, p, ras, i in zip(cfgs, preps, ras_list, pol_idx):
        d = _hier_scan_inputs(p, ras, bmax, i)
        if mode == "async":
            spec, g_spec = _hier_async_specs(c)
            d["buffer"] = jnp.int32(spec.resolve_buffer(
                cfg.devices_per_cell, cfg.subchannels_per_cell))
            d["stale_exp"] = jnp.float32(spec.stale_exponent())
            d["server_lr"] = jnp.float32(spec.server_lr)
            d["g_buffer"] = jnp.int32(g_spec.resolve_buffer(
                cfg.n_cells, cfg.n_cells))
            d["g_stale_exp"] = jnp.float32(g_spec.stale_exponent())
            d["g_server_lr"] = jnp.float32(g_spec.server_lr)
        datas.append(d)
    ys = _dispatch_group(run, datas, shard)
    wall_each = (time.time() - t_start) / len(datas)

    out = []
    for i, (c, p, w) in enumerate(zip(cfgs, preps, plan_walls)):
        ys_i = ys if len(datas) == 1 else jax.tree_util.tree_map(
            lambda leaf: leaf[i], ys)
        out.append(_history_from_hier(c, p.beta.reshape(-1), ys_i,
                                      wall_each + w, w, mode))
    return out


def _hier_group_key(cfg: HierSimConfig) -> HierSimConfig:
    """Configs identical up to seed/wireless-data/policy/scenario/
    aggregation fields share one compiled two-tier program — same
    normalization logic as `fl.sim._scan_group_key`, extended with the
    hier-only data axes (global aggregation, cell coupling)."""
    return dataclasses.replace(
        cfg, seed=0, radius_m=0.0, pt_dbm=0.0, e_max_j=None,
        policy=RoundPolicy(), scenario="static", cell_coupling=0.0,
        aggregation="sync", global_aggregation="sync")


def _hier_prep_key(cfg: HierSimConfig) -> HierSimConfig:
    """Configs identical up to policy/aggregation share one prepared
    world (all sampling precedes both), like `fl.sim._prep_key`."""
    return dataclasses.replace(cfg, policy=RoundPolicy(),
                               aggregation="sync",
                               global_aggregation="sync")


def run_hier_many(cfgs: Sequence[HierSimConfig], *,
                  engine: str = "scan",
                  ra_backend: str | None = None,
                  ra_solver: str = "fused",
                  shard: bool | None = None) -> list[SimHistory]:
    """Run several hierarchical simulations as few compiled programs.

    The multi-cell analogue of `fl.sim.run_many`: worlds are deduped
    across policy/aggregation variants, Γ is solved once per world (all
    cells in one elementwise batch), scenario dynamics fold in once, and
    compatible configs group into one jit / jit(vmap) / `shard_map`
    program per shape.  Histories come back flat-compatible: (rounds,
    C*N) traces, so every `repro.experiments` metric applies unchanged.

    engine: "scan" (sync two-tier barrier) or "async" (two-tier buffered
    event loop).  Cells whose `aggregation` OR `global_aggregation` name
    an async policy route through the async engine regardless; the host
    "loop" engine is single-sim only (`run_hierarchical`).
    """
    if engine not in ("scan", "async"):
        raise ValueError(f"unknown engine: {engine} "
                         f"(run_hier_many supports 'scan' and 'async'; the "
                         f"host 'loop' engine is run_hierarchical-only)")
    if ra_solver not in ("fused", "step"):
        raise ValueError(f"unknown ra_solver: {ra_solver}")
    if shard is None:
        shard = jax.local_device_count() > 1
    modes = ["async" if engine == "async"
             or get_aggregation(c.aggregation) is not None
             or get_aggregation(c.global_aggregation) is not None
             else engine for c in cfgs]

    preps_by_key: dict[HierSimConfig, _HierPrepared] = {}
    preps: list[_HierPrepared] = []
    for c in cfgs:
        key = _hier_prep_key(c)
        if key not in preps_by_key:
            preps_by_key[key] = _prepare_hier(c)
        shared = preps_by_key[key]
        preps.append(shared if shared.cfg == c
                     else dataclasses.replace(shared, cfg=c))

    ras_list, plan_walls = _solve_hier_horizons(
        preps, ra_backend, solver=ra_solver, shard=shard)
    transformed: dict[int, list[RAResult]] = {}
    for i, (p, ras) in enumerate(zip(preps, ras_list)):
        if id(ras) not in transformed:
            transformed[id(ras)] = _apply_hier_dynamics(p, ras)
        ras_list[i] = transformed[id(ras)]

    out: list[SimHistory | None] = [None] * len(cfgs)
    groups: dict[tuple[str, HierSimConfig], list[int]] = {}
    for i, (c, mode) in enumerate(zip(cfgs, modes)):
        groups.setdefault((mode, _hier_group_key(c)), []).append(i)
    for (mode, _), idx in groups.items():
        hists = _run_hier_group(mode, [cfgs[i] for i in idx],
                                [preps[i] for i in idx],
                                [ras_list[i] for i in idx],
                                [plan_walls[i] for i in idx],
                                shard=shard)
        for i, h in zip(idx, hists):
            out[i] = h
    return out


# ---------------------------------------------------------------------------
# engine="loop" + the single-sim dict entry point
# ---------------------------------------------------------------------------

def _run_hier_loop(cfg: HierSimConfig, ra_backend: str | None) -> dict:
    """Host round loop: per-cell `plan_round` + jitted training."""
    t_start = time.time()
    prep = _prepare_hier(cfg)
    ras_list, plan_walls = _solve_hier_horizons([prep], ra_backend)
    ras = _apply_hier_dynamics(prep, ras_list[0])
    t1 = TABLE1[cfg.dataset]
    model = get_small_model(cfg.dataset)
    key = jax.random.PRNGKey(cfg.seed)
    key, k0 = jax.random.split(key)
    params = model.init(k0)
    from ..train.optimizer import make_optimizer
    from .client import make_local_trainer
    opt = make_optimizer(cfg.optimizer or t1["optimizer"],
                         cfg.lr or t1["lr"])
    trainer = make_local_trainer(
        model.loss, opt, batch_size=cfg.batch or t1["batch"],
        local_steps=cfg.local_steps,
        loss_per_example=model.loss_per_example)
    eval_loss = jax.jit(model.loss)
    eval_acc = jax.jit(model.accuracy)
    x_full, y_full = jnp.asarray(prep.ds.x), jnp.asarray(prep.ds.y)

    aous = [init_aou(cfg.devices_per_cell) for _ in range(cfg.n_cells)]
    k_slots = cfg.subchannels_per_cell
    eval_at = set(_eval_rounds(cfg.rounds, cfg.eval_every))
    losses, accs, eval_rounds = [], [], []
    # Full per-round traces regardless of eval sampling: convergence time
    # accumulates unsampled rounds too (the PR-2 cum_time_s lesson).
    lat_all = np.zeros(cfg.rounds)
    energy_all = np.zeros(cfg.rounds)
    tx_trace = np.zeros((cfg.rounds, cfg.n_cells, cfg.devices_per_cell),
                        bool)
    age_trace = np.zeros((cfg.rounds, cfg.n_cells, cfg.devices_per_cell),
                         np.int64)
    for t in range(cfg.rounds):
        cell_params, cell_weights, round_lat, round_e = [], [], 0.0, 0.0
        for c in range(cfg.n_cells):
            plan = plan_round(
                aous[c], prep.beta[c], prep.h2_all[c][t], prep.wcfg,
                prep.rng, policy=cfg.policy, round_idx=t,
                clusters=prep.clusters[c], fixed_ids=prep.fixed_ids[c],
                ra=_slice_ra(ras[c], t),
                randomness=RoundRandomness(
                    sel_perm=prep.sel_perms[c][t],
                    assign_perm=prep.assign_perms[c][t]))
            aous[c] = plan.aou_next
            round_lat = max(round_lat, plan.latency_s)  # cells in parallel
            round_e += float(plan.energy_per_device.sum())
            tx_trace[t, c] = plan.transmitted
            age_trace[t, c] = aous[c].age
            tx = np.where(plan.transmitted)[0]
            slot_ids = np.zeros(k_slots, dtype=np.int64)
            slot_w = np.zeros(k_slots, dtype=np.float32)
            slot_ids[: len(tx)] = tx
            slot_w[: len(tx)] = prep.beta[c][tx]
            if len(tx):
                key, k_cell = jax.random.split(key)
                keys = jax.random.split(k_cell, k_slots)
                client = trainer(params, prep.x[c][slot_ids],
                                 prep.y[c][slot_ids], prep.m[c][slot_ids],
                                 keys)
                cell_params.append(aggregate(params, client,
                                             jnp.asarray(slot_w)))
                cell_weights.append(float(slot_w.sum()))
        if cell_params:
            stacked = jax.tree_util.tree_map(
                lambda *leaves: jnp.stack(leaves), *cell_params)
            params = aggregate(params, stacked,
                               jnp.asarray(cell_weights, jnp.float32))
        lat_all[t] = round_lat
        energy_all[t] = round_e
        if t in eval_at:
            eval_rounds.append(t)
            losses.append(float(eval_loss(params, x_full, y_full)))
            accs.append(float(eval_acc(params, x_full, y_full)))
    ev = np.asarray(eval_rounds)
    return {"loss": np.asarray(losses), "accuracy": np.asarray(accs),
            "eval_rounds": ev, "cum_time_s": np.cumsum(lat_all)[ev],
            "latency": lat_all, "energy": energy_all, "tx": tx_trace,
            "age": age_trace, "wall_s": time.time() - t_start}


def run_hierarchical(cfg: HierSimConfig, *, engine: str = "loop",
                     ra_backend: str | None = None) -> dict:
    """Two-tier FedAvg: per-cell Stackelberg rounds + inter-cell
    aggregation (sync barrier or buffered async at either tier).

    Args:
      cfg: multi-cell settings; `cfg.policy` applies to every cell.
      engine: "loop" (host round loop), "scan" (one fused `lax.scan` over
        rounds with the cell list unrolled), or "async" (the two-tier
        buffered event loop, DESIGN.md §15).  Configs whose cell- or
        global-tier aggregation is async route through the event engine
        regardless.
      ra_backend: Γ-solver projection backend override.

    Returns a dict with FULL per-round traces regardless of
    `cfg.eval_every` — "latency"/"energy" (rounds,), "tx"/"age"
    (rounds, n_cells, N) — plus eval-sampled curves "loss"/"accuracy"/
    "cum_time_s" at "eval_rounds", and "wall_s".  engine="async" adds
    "committed" (rounds, n_cells, N), "cell_committed" and
    "latency_cells" (rounds, n_cells).
    """
    if engine not in ("loop", "scan", "async"):
        raise ValueError(f"unknown engine: {engine}")
    async_mode = (engine == "async"
                  or get_aggregation(cfg.aggregation) is not None
                  or get_aggregation(cfg.global_aggregation) is not None)
    if engine == "loop" and not async_mode:
        return _run_hier_loop(cfg, ra_backend)
    hist = run_hier_many([cfg], engine="async" if async_mode else "scan",
                         ra_backend=ra_backend)[0]
    shape = (cfg.rounds, cfg.n_cells, cfg.devices_per_cell)
    out = {"loss": hist.global_loss, "accuracy": hist.accuracy,
           "eval_rounds": hist.rounds, "cum_time_s": hist.cum_time_s,
           "latency": hist.latency_all, "energy": hist.energy_all,
           "tx": hist.tx_trace.reshape(shape),
           "age": hist.age_trace.reshape(shape), "wall_s": hist.wall_s}
    if hist.commit_trace is not None:
        out["committed"] = hist.commit_trace.reshape(shape)
        out["cell_committed"] = hist.async_trace["cell_committed"]
        out["latency_cells"] = hist.async_trace["latency_cells"]
    return out
