"""Hierarchical (multi-cell) FLOWN — the FL semantics of the `pod` mesh axis.

Beyond-paper extension: the paper studies a single server; on the 2-pod
production mesh the natural topology is two cells, each with its own base
station running the paper's FULL Stackelberg round (own channels, own
sub-channels, own AoU state), followed by an inter-cell (cross-pod)
aggregation of the cell models weighted by transmitted data:

    cell c:   w_c = eq.(34) over its transmitting devices
    global:   w   = sum_c W_c w_c / sum_c W_c ,  W_c = sum_{n in tx_c} beta_n

This is exactly what the multi-pod train_step computes when the gradient
all-reduce crosses the `pod` axis with fl_weights set per cohort — this
module provides the simulation-plane counterpart so cell-level scheduling
policies can be compared end-to-end.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (
    RoundPolicy,
    WirelessConfig,
    init_aou,
    plan_round,
    sample_channel_gains,
    sample_topology,
)
from ..data.fl_datasets import make_dataset, partition_imbalanced_iid
from ..models.small import get_small_model
from ..train.optimizer import make_optimizer
from .client import make_local_trainer
from .server import aggregate
from .sim import TABLE1

__all__ = ["HierSimConfig", "run_hierarchical"]


@dataclasses.dataclass(frozen=True)
class HierSimConfig:
    dataset: str = "mnist"
    n_cells: int = 2
    devices_per_cell: int = 10
    subchannels_per_cell: int = 4
    rounds: int = 40
    policy: RoundPolicy = RoundPolicy()
    seed: int = 0
    n_samples: int = 400
    local_steps: int = 3


def run_hierarchical(cfg: HierSimConfig) -> dict:
    """Two-tier FedAvg: per-cell Stackelberg rounds + inter-cell aggregation."""
    rng = np.random.default_rng(cfg.seed)
    t1 = TABLE1[cfg.dataset]
    ds = make_dataset(cfg.dataset, rng, n=cfg.n_samples)
    model = get_small_model(cfg.dataset)
    key = jax.random.PRNGKey(cfg.seed)
    key, k0 = jax.random.split(key)
    params = model.init(k0)
    opt = make_optimizer(t1["optimizer"], t1["lr"])
    trainer = make_local_trainer(model.loss, opt, batch_size=t1["batch"],
                                 local_steps=cfg.local_steps,
                                 loss_per_example=model.loss_per_example)
    eval_loss = jax.jit(model.loss)
    x_full, y_full = jnp.asarray(ds.x), jnp.asarray(ds.y)

    # Per-cell wireless worlds + data partitions.
    from .sim import _pad_partition

    cells = []
    for c in range(cfg.n_cells):
        wcfg = WirelessConfig(
            n_devices=cfg.devices_per_cell,
            n_subchannels=cfg.subchannels_per_cell,
            model_bits=t1["model_bits"], e_max_j=t1["e_max"],
        )
        part = partition_imbalanced_iid(rng, ds.n, cfg.devices_per_cell)
        x, y, m = _pad_partition(ds, part)
        cells.append({
            "wcfg": wcfg,
            "topo": sample_topology(rng, wcfg),
            "aou": init_aou(cfg.devices_per_cell),
            "beta": part.beta.astype(np.float64),
            "x": x, "y": y, "m": m,
        })

    losses, latencies = [], []
    k_slots = cfg.subchannels_per_cell
    for t in range(cfg.rounds):
        cell_params, cell_weights, round_lat = [], [], 0.0
        for cell in cells:
            h2 = sample_channel_gains(rng, cell["wcfg"], cell["topo"])
            plan = plan_round(cell["aou"], cell["beta"], h2, cell["wcfg"],
                              rng, policy=cfg.policy, round_idx=t)
            cell["aou"] = plan.aou_next
            round_lat = max(round_lat, plan.latency_s)  # cells run in parallel
            tx = np.where(plan.transmitted)[0]
            slot_ids = np.zeros(k_slots, dtype=np.int64)
            slot_w = np.zeros(k_slots, dtype=np.float32)
            slot_ids[: len(tx)] = tx
            slot_w[: len(tx)] = cell["beta"][tx]
            if len(tx):
                key_l, key = jax.random.split(key)[0], jax.random.split(key)[1]
                keys = jax.random.split(key_l, k_slots)
                client = trainer(params, cell["x"][slot_ids], cell["y"][slot_ids],
                                 cell["m"][slot_ids], keys)
                w_cell = aggregate(params, client, jnp.asarray(slot_w))
                cell_params.append(w_cell)
                cell_weights.append(float(slot_w.sum()))
        if cell_params:
            stacked = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *cell_params)
            params = aggregate(params, stacked,
                               jnp.asarray(cell_weights, jnp.float32))
        losses.append(float(eval_loss(params, x_full, y_full)))
        latencies.append(round_lat)
    return {"loss": np.asarray(losses), "latency": np.asarray(latencies)}
