"""Hierarchical (multi-cell) FLOWN — the FL semantics of the `pod` mesh axis.

Beyond-paper extension: the paper studies a single server; on the 2-pod
production mesh the natural topology is two cells, each with its own base
station running the paper's FULL Stackelberg round (own channels, own
sub-channels, own AoU state), followed by an inter-cell (cross-pod)
aggregation of the cell models weighted by transmitted data:

    cell c:   w_c = eq.(34) over its transmitting devices
    global:   w   = sum_c W_c w_c / sum_c W_c ,  W_c = sum_{n in tx_c} beta_n

This is exactly what the multi-pod train_step computes when the gradient
all-reduce crosses the `pod` axis with fl_weights set per cohort — this
module provides the simulation-plane counterpart so cell-level scheduling
policies can be compared end-to-end.

Like the single-cell harness (`fl.sim`), the multi-cell loop pre-samples
every cell's whole channel horizon and leader permutations up front,
solves Γ for all cells in one batched Algorithm-1 call, and offers the
same two engines (DESIGN.md §8, §10):

  engine="loop"  -- host round loop: per-cell `plan_round` + jitted training;
  engine="scan"  -- ONE `lax.scan` over rounds whose body unrolls the
                    (static) cell list: per-cell jnp leader + training +
                    the inter-cell aggregation, fused into a single
                    compiled program.

Both engines consume identical pre-sampled randomness, so their per-cell
transmitted sets, latencies, and losses coincide (differential test:
tests/test_hierarchical.py::test_hierarchical_engine_equivalence).
"""
from __future__ import annotations

import dataclasses
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (
    RoundPolicy,
    RoundRandomness,
    WirelessConfig,
    init_aou,
    leader_round,
    make_clusters,
    plan_round,
    sample_channel_gains,
    sample_topology,
    solve_pairs_jit,
)
from ..core.monotonic import RAResult, fixed_ra
from ..data.fl_datasets import make_dataset, partition_imbalanced_iid
from ..models.small import get_small_model
from ..train.optimizer import make_optimizer
from .client import make_local_trainer
from .server import aggregate
from .sim import TABLE1, _pad_partition, _slice_ra

__all__ = ["HierSimConfig", "run_hierarchical"]


@dataclasses.dataclass(frozen=True)
class HierSimConfig:
    """Multi-cell simulation settings (one Stackelberg game per cell).

    `n_cells` base stations each serve `devices_per_cell` devices over
    `subchannels_per_cell` uplink sub-channels; all cells share the global
    model and the Table-I learning settings of `dataset`.
    """

    dataset: str = "mnist"
    n_cells: int = 2
    devices_per_cell: int = 10
    subchannels_per_cell: int = 4
    rounds: int = 40
    policy: RoundPolicy = RoundPolicy()
    seed: int = 0
    n_samples: int = 400
    local_steps: int = 3


@dataclasses.dataclass
class _HierPrepared:
    """Per-cell worlds + whole-horizon Γ, sampled before the round loop."""

    ds: object
    beta: np.ndarray          # (C, N)
    x: object                 # (C, N, Bmax, ...) padded client data
    y: object
    m: object
    clusters: np.ndarray      # (C, N)
    fixed_ids: np.ndarray     # (C, S)
    h2_all: np.ndarray        # (C, rounds, K, N)
    sel_perms: np.ndarray     # (C, rounds, N)
    assign_perms: np.ndarray  # (C, rounds, K)
    ras: list[RAResult]       # per cell, fields (rounds, K, N)
    wcfg: WirelessConfig
    rng: np.random.Generator


def _prepare_hier(cfg: HierSimConfig, ra_backend: str | None) -> _HierPrepared:
    rng = np.random.default_rng(cfg.seed)
    t1 = TABLE1[cfg.dataset]
    ds = make_dataset(cfg.dataset, rng, n=cfg.n_samples)
    n, k = cfg.devices_per_cell, cfg.subchannels_per_cell
    wcfg = WirelessConfig(n_devices=n, n_subchannels=k,
                          model_bits=t1["model_bits"], e_max_j=t1["e_max"])

    beta, xs, ys_, ms, clusters, fixed_ids, topos = [], [], [], [], [], [], []
    bmax = 0
    parts = []
    for _ in range(cfg.n_cells):
        part = partition_imbalanced_iid(rng, ds.n, n)
        parts.append(part)
        bmax = max(bmax, int(part.beta.max()))
        topos.append(sample_topology(rng, wcfg))
        clusters.append(make_clusters(n, k, rng))
        fixed_ids.append(rng.permutation(n)[: min(k, n)])
    for part in parts:
        beta.append(part.beta.astype(np.float64))
        x, y, m = _pad_partition(ds, part, bmax)
        xs.append(x); ys_.append(y); ms.append(m)

    h2_all = np.stack([
        np.stack([sample_channel_gains(rng, wcfg, topo)
                  for _ in range(cfg.rounds)])
        for topo in topos])
    sel_perms = np.stack([
        np.stack([rng.permutation(n) for _ in range(cfg.rounds)])
        for _ in range(cfg.n_cells)])
    assign_perms = np.stack([
        np.stack([rng.permutation(k) for _ in range(cfg.rounds)])
        for _ in range(cfg.n_cells)])

    beta = np.stack(beta)
    if cfg.policy.ra == "mo":
        # One batched Algorithm-1 call over every (cell, round, k, n) pair.
        flat = solve_pairs_jit(
            np.broadcast_to(beta[:, None, None, :], h2_all.shape).reshape(-1),
            h2_all.reshape(-1), wcfg, backend=ra_backend)
        shp = h2_all.shape[1:]
        sz = int(np.prod(shp))
        ras = [RAResult(*(getattr(flat, f.name)[c * sz:(c + 1) * sz]
                          .reshape(shp) for f in dataclasses.fields(RAResult)))
               for c in range(cfg.n_cells)]
    else:
        ras = [fixed_ra(beta[c][None, None, :], h2_all[c], wcfg)
               for c in range(cfg.n_cells)]

    return _HierPrepared(
        ds=ds, beta=beta,
        x=jnp.stack(xs), y=jnp.stack(ys_), m=jnp.stack(ms),
        clusters=np.stack(clusters), fixed_ids=np.stack(fixed_ids),
        h2_all=h2_all, sel_perms=sel_perms, assign_perms=assign_perms,
        ras=ras, wcfg=wcfg, rng=rng)


def run_hierarchical(cfg: HierSimConfig, *, engine: str = "loop",
                     ra_backend: str | None = None) -> dict:
    """Two-tier FedAvg: per-cell Stackelberg rounds + inter-cell aggregation.

    Args:
      cfg: multi-cell settings; `cfg.policy` applies to every cell.
      engine: "loop" (host round loop) or "scan" (one fused `lax.scan`
        over rounds with the cell list unrolled in its body).  Both
        consume identical pre-sampled randomness and agree on per-cell
        transmitted sets and losses (DESIGN.md §10).
      ra_backend: Γ-solver projection backend override.

    Returns {"loss": (rounds,), "latency": (rounds,),
             "tx": (rounds, n_cells, N) bool, "wall_s": float}.
    """
    if engine not in ("loop", "scan"):
        raise ValueError(f"unknown engine: {engine}")
    t_start = time.time()
    prep = _prepare_hier(cfg, ra_backend)
    t1 = TABLE1[cfg.dataset]
    model = get_small_model(cfg.dataset)
    key = jax.random.PRNGKey(cfg.seed)
    key, k0 = jax.random.split(key)
    params = model.init(k0)
    opt = make_optimizer(t1["optimizer"], t1["lr"])
    x_full, y_full = jnp.asarray(prep.ds.x), jnp.asarray(prep.ds.y)

    if engine == "scan":
        trainer = make_local_trainer(
            model.loss, opt, batch_size=t1["batch"],
            local_steps=cfg.local_steps,
            loss_per_example=model.loss_per_example, jit=False)
        out = _run_hier_scan(cfg, prep, model, trainer, params, key,
                             x_full, y_full)
        out["wall_s"] = time.time() - t_start
        return out

    trainer = make_local_trainer(
        model.loss, opt, batch_size=t1["batch"], local_steps=cfg.local_steps,
        loss_per_example=model.loss_per_example)
    eval_loss = jax.jit(model.loss)
    aous = [init_aou(cfg.devices_per_cell) for _ in range(cfg.n_cells)]
    k_slots = cfg.subchannels_per_cell
    losses, latencies = [], []
    tx_trace = np.zeros((cfg.rounds, cfg.n_cells, cfg.devices_per_cell), bool)
    for t in range(cfg.rounds):
        cell_params, cell_weights, round_lat = [], [], 0.0
        for c in range(cfg.n_cells):
            plan = plan_round(
                aous[c], prep.beta[c], prep.h2_all[c][t], prep.wcfg,
                prep.rng, policy=cfg.policy, round_idx=t,
                clusters=prep.clusters[c], fixed_ids=prep.fixed_ids[c],
                ra=_slice_ra(prep.ras[c], t),
                randomness=RoundRandomness(sel_perm=prep.sel_perms[c][t],
                                           assign_perm=prep.assign_perms[c][t]))
            aous[c] = plan.aou_next
            round_lat = max(round_lat, plan.latency_s)  # cells run in parallel
            tx_trace[t, c] = plan.transmitted
            tx = np.where(plan.transmitted)[0]
            slot_ids = np.zeros(k_slots, dtype=np.int64)
            slot_w = np.zeros(k_slots, dtype=np.float32)
            slot_ids[: len(tx)] = tx
            slot_w[: len(tx)] = prep.beta[c][tx]
            if len(tx):
                key, k_cell = jax.random.split(key)
                keys = jax.random.split(k_cell, k_slots)
                client = trainer(params, prep.x[c][slot_ids],
                                 prep.y[c][slot_ids], prep.m[c][slot_ids],
                                 keys)
                cell_params.append(aggregate(params, client,
                                             jnp.asarray(slot_w)))
                cell_weights.append(float(slot_w.sum()))
        if cell_params:
            stacked = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *cell_params)
            params = aggregate(params, stacked,
                               jnp.asarray(cell_weights, jnp.float32))
        losses.append(float(eval_loss(params, x_full, y_full)))
        latencies.append(round_lat)
    return {"loss": np.asarray(losses), "latency": np.asarray(latencies),
            "tx": tx_trace, "wall_s": time.time() - t_start}


def _run_hier_scan(cfg: HierSimConfig, prep: _HierPrepared, model, trainer,
                   params0, key0, x_full, y_full) -> dict:
    """The fused multi-cell round loop: one `lax.scan`, cells unrolled."""
    n, k = cfg.devices_per_cell, cfg.subchannels_per_cell
    n_cells = cfg.n_cells
    n_clusters = int(math.ceil(n / k))
    ndev = jnp.arange(n)
    kslot = jnp.arange(k)
    f0 = jnp.float32(0.0)
    pol = cfg.policy

    data = dict(
        beta=jnp.asarray(prep.beta, jnp.float32),
        x=prep.x, y=prep.y, m=prep.m,
        clusters=jnp.asarray(prep.clusters, jnp.int32),
        fixed_ids=jnp.asarray(prep.fixed_ids, jnp.int32),
    )
    xs = dict(
        gamma=jnp.asarray(np.stack([ra.time_s for ra in prep.ras], 1),
                          jnp.float32),                     # (rounds, C, K, N)
        feas=jnp.asarray(np.stack([ra.feasible for ra in prep.ras], 1)),
        sel_perm=jnp.asarray(prep.sel_perms.swapaxes(0, 1), jnp.int32),
        assign_perm=jnp.asarray(prep.assign_perms.swapaxes(0, 1), jnp.int32),
        t=jnp.arange(cfg.rounds, dtype=jnp.int32),
    )

    def body(carry, x):
        params, key, age = carry                            # age (C, N)
        cell_out, weights, ages = [], [], []
        latency = f0
        tx_all = []
        for c in range(n_cells):
            lead = leader_round(
                age[c], data["beta"][c], x["gamma"][c], x["feas"][c],
                x["sel_perm"][c], x["assign_perm"][c], x["t"],
                data["clusters"][c], data["fixed_ids"][c],
                ds=pol.ds, sa=pol.sa, k=k, n=n, n_clusters=n_clusters)
            tx = lead["transmitted"]
            ch_g = jnp.where(tx, lead["channel_of"], 0)
            t_dev = x["gamma"][c][ch_g, ndev]
            cell_lat = jnp.where(
                tx.any(), jnp.max(jnp.where(tx, t_dev, -jnp.inf)), f0)
            latency = jnp.maximum(latency, cell_lat)
            tx_ids = jnp.nonzero(tx, size=k, fill_value=0)[0]
            cnt = tx.sum()
            slot_w = jnp.where(kslot < cnt, data["beta"][c][tx_ids], f0)

            def do_train(ops, c=c, tx_ids=tx_ids, slot_w=slot_w):
                p, kk = ops
                kk, k_cell = jax.random.split(kk)
                keys = jax.random.split(k_cell, k)
                cp = trainer(p, data["x"][c][tx_ids], data["y"][c][tx_ids],
                             data["m"][c][tx_ids], keys)
                return aggregate(p, cp, slot_w), kk

            w_cell, key = jax.lax.cond(
                cnt > 0, do_train, lambda ops: ops, (params, key))
            cell_out.append(w_cell)
            weights.append(slot_w.sum())
            ages.append(lead["age_next"])
            tx_all.append(tx)

        stacked = jax.tree_util.tree_map(
            lambda *leaves: jnp.stack(leaves), *cell_out)
        params = aggregate(params, stacked, jnp.stack(weights))
        loss = model.loss(params, x_full, y_full)
        ys = dict(loss=loss, latency=latency, tx=jnp.stack(tx_all))
        return (params, key, jnp.stack(ages)), ys

    carry0 = (params0, key0, jnp.ones((n_cells, n), jnp.int32))
    _, ys = jax.jit(
        lambda c0, xs_: jax.lax.scan(body, c0, xs_))(carry0, xs)
    jax.block_until_ready(ys)
    return {"loss": np.asarray(ys["loss"], np.float64),
            "latency": np.asarray(ys["latency"], np.float64),
            "tx": np.asarray(ys["tx"])}
