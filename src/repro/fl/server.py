"""Server-side aggregation: the selection-masked weighted FedAvg of eq. (34),
plus the staleness-weighted buffered commit of the async engine.

    w^{t+1} = sum_n S_n (sum_k psi_kn) beta_n w_n / sum_n S_n (sum_k psi_kn) beta_n

Synchronous implementations:
  * `aggregate`       -- stacked-leaf weighted mean (single-host simulation);
  * `masked_psum_agg` -- the distributed form used inside the big-model
    train_step: each data shard contributes grad * weight, followed by ONE
    psum over the data/pod axes (see repro.train.train_step).  The Pallas
    kernel repro.kernels.fedavg_agg fuses the weighting+reduction for the
    stacked single-host case.

If no device transmits in a round (all-infeasible corner of Prop. 1), the
global model is unchanged (weights sum to 0 -> guarded).

Asynchronous surface (`engine="async"`, DESIGN.md §12): an
`AsyncAggregation` spec names the buffered server's commit policy —
how many in-flight uploads the server waits for per event (`buffer`),
the staleness decay `f(age)` applied to each committed update's weight
(`staleness_weight`: polynomial and constant presets), and the server
step size.  `aggregate_buffered` performs one commit:

    w <- (1-m) w + m * WeightedMean(committed; beta_n * f(s_n)),
    m = server_lr (1.0 by default; 0 when nothing committed).

The engine feeds it TRANSLATED updates w_n + (w - b_n) — each flight's
local progress grafted onto the current model (see fl.async_loop) — so
at m = 1 the commit is a full FedBuff-style step on the staleness-
weighted mean of the committed deltas.  When every upload is fresh
(f(0) = 1 exactly, translation an exact no-op) the commit IS eq. (34)
bit-for-bit — the degenerate limit the differential harness
(tests/test_async_equivalence.py) pins against the synchronous scan
engine.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "aggregate",
    "masked_weighted_mean",
    "AsyncAggregation",
    "AGGREGATION_PRESETS",
    "get_aggregation",
    "staleness_weight",
    "aggregate_buffered",
]


def masked_weighted_mean(stacked: jax.Array, weights: jax.Array) -> jax.Array:
    """Weighted mean over the leading axis; identity-safe at zero weight."""
    wsum = weights.sum()
    w = weights / jnp.maximum(wsum, 1e-30)
    shape = (-1,) + (1,) * (stacked.ndim - 1)
    return (stacked * w.reshape(shape)).sum(axis=0)


@jax.jit
def aggregate(global_params: Any, client_params: Any, weights: jax.Array) -> Any:
    """Eq. (34).  client_params leaves have a leading slot axis (K, ...);
    weights (K,) = S_n * sum_k psi_kn * beta_n per slot (0 for empty slots).

    Falls back to the previous global model when sum(weights) == 0.
    """
    wsum = weights.sum()

    def leaf(g, c):
        agg = masked_weighted_mean(c, weights)
        return jnp.where(wsum > 0, agg, g).astype(g.dtype)

    return jax.tree_util.tree_map(leaf, global_params, client_params)


# ---------------------------------------------------------------------------
# Asynchronous (buffered, staleness-weighted) aggregation — DESIGN.md §12
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AsyncAggregation:
    """Commit policy of the buffered async server (`engine="async"`).

    Attributes:
      buffer: how many in-flight uploads the server waits for before
        committing an event — the FedBuff-style aggregation goal.
        An int M >= 1 waits for the M earliest arrivals (ties commit
        together); "full" waits for EVERY in-flight upload, which is the
        synchronous barrier — the degenerate limit that reproduces the
        scan engine bit-exactly; None (default) resolves to
        max(1, K // 2): wait for half the sub-channels.
      staleness: weight-decay preset applied per committed update,
        "poly" -> f(s) = (1 + s)^-exponent, "const" -> f(s) = 1.
        s counts server events since the update's dispatch; f(0) == 1.0
        exactly on either preset, so fresh commits are never reweighted.
      exponent: the polynomial decay rate (ignored by "const").
      server_lr: the commit step size m — how far the model moves toward
        the staleness-weighted mean of the committed (translated)
        updates.  The default 1.0 is the full FedBuff-style step and the
        bit-exact sync endpoint; smaller values damp commit variance.
    """

    buffer: int | str | None = None
    staleness: str = "poly"
    exponent: float = 0.5
    server_lr: float = 1.0

    def __post_init__(self):
        if isinstance(self.buffer, str) and self.buffer != "full":
            raise ValueError(f"buffer must be an int, None, or 'full': "
                             f"{self.buffer!r}")
        if isinstance(self.buffer, int) and self.buffer < 1:
            raise ValueError(f"buffer must be >= 1: {self.buffer}")
        if self.staleness not in ("poly", "const"):
            raise ValueError(f"unknown staleness preset: {self.staleness!r}")
        if self.exponent < 0:
            raise ValueError(f"exponent must be >= 0: {self.exponent}")
        if not 0.0 < self.server_lr <= 1.0:
            raise ValueError(f"server_lr must be in (0, 1]: {self.server_lr}")

    def resolve_buffer(self, n: int, k: int) -> int:
        """The concrete commit batch size M for an (N, K) network.

        An int buffer must be strictly below the K sub-channels: with at
        most K dispatches per event, any M >= K already drains every
        flight each event — i.e. silently degenerates to the synchronous
        barrier — so those values are rejected rather than letting a
        buffer sweep report identical "async" rows without warning.
        (K = 1 is exempt: buffer=1 is the only value and the engines
        coincide there by construction.)
        """
        if self.buffer == "full":
            return n
        if self.buffer is None:
            return max(1, k // 2)
        if self.buffer >= k and k > 1:
            raise ValueError(
                f"buffer={self.buffer} >= K={k} waits for every in-flight "
                f"upload each event — that IS the synchronous barrier; say "
                f"buffer='full' if that is intended")
        return int(self.buffer)

    def stale_exponent(self) -> float:
        """The decay fed to `staleness_weight` (0.0 encodes "const")."""
        return 0.0 if self.staleness == "const" else float(self.exponent)


# Named presets usable as `SimConfig.aggregation` / the SweepSpec
# `aggregation` axis ("sync" is the absence of an AsyncAggregation).
AGGREGATION_PRESETS: dict[str, AsyncAggregation] = {
    "async": AsyncAggregation(),
    "async_const": AsyncAggregation(staleness="const"),
    "async_full": AsyncAggregation(buffer="full"),
}


def get_aggregation(agg: "str | AsyncAggregation") -> AsyncAggregation | None:
    """Resolve an aggregation spec; None means synchronous eq.-34."""
    if isinstance(agg, AsyncAggregation):
        return agg
    if agg == "sync":
        return None
    try:
        return AGGREGATION_PRESETS[agg]
    except KeyError:
        raise ValueError(
            f"unknown aggregation: {agg!r} "
            f"(known: ['sync'] + {sorted(AGGREGATION_PRESETS)})") from None


def staleness_weight(staleness: jax.Array, exponent: jax.Array) -> jax.Array:
    """f(s) = (1 + s)^-exponent, EXACTLY 1.0 at s = 0 (and everywhere when
    exponent = 0, the "const" preset) — the bit-exact sync anchor relies on
    fresh commits carrying weight multiplier 1.0, not a float power
    round-trip."""
    s = staleness.astype(jnp.float32)
    return jnp.where(s <= 0, jnp.float32(1.0),
                     jnp.power(1.0 + s, -exponent).astype(jnp.float32))


def aggregate_buffered(global_params: Any, committed_params: Any,
                       weights: jax.Array, server_lr: jax.Array) -> Any:
    """One buffered commit (async engine, DESIGN.md §12).

    committed_params leaves have a leading commit-slot axis (K, ...) and
    hold the TRANSLATED updates w_n + (w - b_n) (fl.async_loop);
    weights (K,) = beta_n * f(staleness_n) per slot (0 for empty slots).

    The committed updates' weighted mean is mixed into the global model
    with m = server_lr (0 when nothing committed, so an empty event
    leaves the model untouched).  Both endpoints are exact selects:
    m == 1 on fresh full commits is bitwise `aggregate` (eq. 34) — the
    degenerate sync limit — and m == 0 is bitwise identity.
    """
    wsum = weights.sum()
    m = jnp.where(wsum > 0, jnp.float32(server_lr), jnp.float32(0.0))

    def leaf(g, c):
        agg = masked_weighted_mean(c, weights)
        agg = jnp.where(wsum > 0, agg, g).astype(g.dtype)
        mixed = ((1.0 - m) * g + m * agg).astype(g.dtype)
        return jnp.where(m >= 1.0, agg, jnp.where(m <= 0.0, g, mixed))

    return jax.tree_util.tree_map(leaf, global_params, committed_params)
