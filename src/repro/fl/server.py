"""Server-side aggregation: the selection-masked weighted FedAvg of eq. (34).

    w^{t+1} = sum_n S_n (sum_k psi_kn) beta_n w_n / sum_n S_n (sum_k psi_kn) beta_n

Two implementations:
  * `aggregate`       -- stacked-leaf weighted mean (single-host simulation);
  * `masked_psum_agg` -- the distributed form used inside the big-model
    train_step: each data shard contributes grad * weight, followed by ONE
    psum over the data/pod axes (see repro.train.train_step).  The Pallas
    kernel repro.kernels.fedavg_agg fuses the weighting+reduction for the
    stacked single-host case.

If no device transmits in a round (all-infeasible corner of Prop. 1), the
global model is unchanged (weights sum to 0 -> guarded).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["aggregate", "masked_weighted_mean"]


def masked_weighted_mean(stacked: jax.Array, weights: jax.Array) -> jax.Array:
    """Weighted mean over the leading axis; identity-safe at zero weight."""
    wsum = weights.sum()
    w = weights / jnp.maximum(wsum, 1e-30)
    shape = (-1,) + (1,) * (stacked.ndim - 1)
    return (stacked * w.reshape(shape)).sum(axis=0)


@jax.jit
def aggregate(global_params: Any, client_params: Any, weights: jax.Array) -> Any:
    """Eq. (34).  client_params leaves have a leading slot axis (K, ...);
    weights (K,) = S_n * sum_k psi_kn * beta_n per slot (0 for empty slots).

    Falls back to the previous global model when sum(weights) == 0.
    """
    wsum = weights.sum()

    def leaf(g, c):
        agg = masked_weighted_mean(c, weights)
        return jnp.where(wsum > 0, agg, g).astype(g.dtype)

    return jax.tree_util.tree_map(leaf, global_params, client_params)
