"""Checkpointing: pytree <-> .npz with path-keyed arrays (no orbax offline).

Works for params, optimizer states and decode caches; bf16 leaves round-trip
via a uint16 view (npz has no bfloat16).
"""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint"]

_SEP = "|"
_BF16_TAG = "__bf16__"


def _key_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return _SEP.join(parts)


def save_checkpoint(path: str, tree: Any, *, step: int | None = None) -> None:
    flat = jax.tree_util.tree_leaves_with_path(tree)
    arrays = {}
    for p, leaf in flat:
        key = _key_str(p)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            arrays[_BF16_TAG + key] = arr.view(np.uint16)
        else:
            arrays[key] = arr
    if step is not None:
        arrays["__step__"] = np.asarray(step)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    np.savez(tmp, **arrays)
    os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)


def restore_checkpoint(path: str, like: Any) -> tuple[Any, int | None]:
    """Restore into the structure of `like` (shapes/dtypes must match)."""
    with np.load(path) as data:
        step = int(data["__step__"]) if "__step__" in data else None

        def fill(p, leaf):
            key = _key_str(p)
            if _BF16_TAG + key in data:
                arr = data[_BF16_TAG + key].view(jnp.bfloat16)
            else:
                arr = data[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"shape mismatch at {key}: "
                                 f"{arr.shape} vs {leaf.shape}")
            return jnp.asarray(arr)

        return jax.tree_util.tree_map_with_path(fill, like), step
