"""Scenario dynamics: time-varying wireless environments as trace generators.

The paper's AoI/AoU-aware selection story only bites when the environment
*changes* between rounds; this subsystem turns the single static world of
the seed simulator into a pluggable layer of composable, seed-deterministic
*environment processes* — temporally correlated fading, device mobility,
churn/stragglers, and energy harvesting — that generate whole-horizon
traces consumable by both round-loop engines unchanged (DESIGN.md §11).

Public surface:
  processes -- `FadingProcess` / `MobilityProcess` / `ChurnProcess` /
               `EnergyProcess` configs and their pure
               ``(rng, cfg, horizon) -> trace`` generators;
  scenario  -- the `Scenario` bundle, the named-preset registry
               (``static`` reproduces the legacy behavior bit-exactly),
               `generate_traces`, and `apply_dynamics` (folds churn into
               a solved whole-horizon `RAResult`);
  stream    -- `ScenarioStream`, the open-ended per-round extension of
               the same processes: segment s of ONE long seed-
               deterministic trace, for the sustained service
               (DESIGN.md §14).

`fl.SimConfig(scenario=...)` and the `SweepSpec(scenarios=...)` axis are
the consumer entry points; see examples/reproduce_figures.py --scenario.
"""
from .processes import (
    ChurnProcess,
    EnergyProcess,
    FadingProcess,
    MobilityProcess,
    compose_gains,
    sample_churn,
    sample_coupled_fading,
    sample_distances,
    sample_energy,
    sample_fading,
)
from .scenario import (
    PRESETS,
    Scenario,
    ScenarioTraces,
    apply_dynamics,
    generate_traces,
    get_scenario,
    register_scenario,
    scenario_name,
)
from .stream import ScenarioStream

__all__ = [
    # process configs + generators
    "FadingProcess", "MobilityProcess", "ChurnProcess", "EnergyProcess",
    "sample_fading", "sample_coupled_fading", "sample_distances",
    "sample_churn", "sample_energy", "compose_gains",
    # scenario bundle + registry
    "Scenario", "ScenarioTraces", "PRESETS", "get_scenario",
    "register_scenario", "scenario_name", "generate_traces",
    "apply_dynamics",
    # open-ended stream extension
    "ScenarioStream",
]
