"""Composable wireless-FL scenarios: named bundles of environment processes.

A `Scenario` is four orthogonal processes (fading x mobility x churn x
energy) plus a name; `generate_traces` materializes the whole-horizon
environment as plain numpy arrays and `apply_dynamics` folds the
availability / straggler components into a solved whole-horizon
`RAResult` so BOTH round-loop engines (host loop and fused `lax.scan`)
consume the identical modified Γ and stay differentially equivalent
under every scenario (DESIGN.md §11).

The ``static`` preset is the identity: its processes replay the exact
rng stream the pre-scenario simulator drew inline (fading ``iid`` +
mobility ``static``) and consume nothing else, so static trajectories
are bit-identical to the legacy behavior on both engines — pinned by
tests/test_scenarios.py.

Presets (see `PRESETS`; `register_scenario` adds project-local ones):

  static        today's world: i.i.d. Rayleigh, fixed topology, no churn,
                constant budget;
  corr_fading   temporally correlated fading (AR(1), rho = 0.9 — ~0.81
                power autocorrelation at lag 1);
  mobility      random-waypoint drift at pedestrian 1.5 m/s, 10 s rounds;
  churn         Markov availability (5% drop / 50% rejoin) + 20%-straggler
                rounds up to 4x compute time;
  harvest       energy-harvesting budgets, mean = Table-I E^max with a
                10% floor;
  urban         the stress composite: corr_fading + mobility + churn.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.monotonic import RAResult
from ..core.wireless import WirelessConfig, compute_energy, compute_time
from .processes import (
    ChurnProcess,
    EnergyProcess,
    FadingProcess,
    MobilityProcess,
    compose_gains,
    sample_churn,
    sample_distances,
    sample_energy,
    sample_fading,
)

__all__ = [
    "Scenario",
    "ScenarioTraces",
    "PRESETS",
    "get_scenario",
    "register_scenario",
    "scenario_name",
    "generate_traces",
    "apply_dynamics",
]


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One named environment: fading x mobility x churn x energy."""

    name: str = "static"
    fading: FadingProcess = FadingProcess()
    mobility: MobilityProcess = MobilityProcess()
    churn: ChurnProcess = ChurnProcess()
    energy: EnergyProcess = EnergyProcess()


@dataclasses.dataclass
class ScenarioTraces:
    """The materialized whole-horizon environment of one world."""

    scenario: Scenario
    h2_all: np.ndarray       # (rounds, K, N) eq.-3 normalized channel gains
    distances_m: np.ndarray  # (rounds, N) device-to-server distances
    avail: np.ndarray        # (rounds, N) bool availability mask
    slowdown: np.ndarray     # (rounds, N) compute-time multipliers, >= 1
    e_max_j: np.ndarray      # (rounds, N) per-round energy budgets


PRESETS: dict[str, Scenario] = {
    s.name: s for s in (
        Scenario("static"),
        Scenario("corr_fading", fading=FadingProcess("ar1", rho=0.9)),
        Scenario("mobility",
                 mobility=MobilityProcess("waypoint", speed_mps=1.5,
                                          round_s=10.0)),
        Scenario("churn",
                 churn=ChurnProcess("markov", p_drop=0.05, p_join=0.5,
                                    straggler_prob=0.2, slowdown_max=4.0)),
        Scenario("harvest",
                 energy=EnergyProcess("harvest", mean_frac=1.0,
                                      floor_frac=0.1)),
        Scenario("urban",
                 fading=FadingProcess("ar1", rho=0.9),
                 mobility=MobilityProcess("waypoint", speed_mps=1.5,
                                          round_s=10.0),
                 churn=ChurnProcess("markov", p_drop=0.05, p_join=0.5,
                                    straggler_prob=0.2, slowdown_max=4.0)),
    )
}


def get_scenario(scenario: str | Scenario) -> Scenario:
    """Resolve a preset name or pass a `Scenario` through unchanged."""
    if isinstance(scenario, Scenario):
        return scenario
    try:
        return PRESETS[scenario]
    except KeyError:
        raise ValueError(
            f"unknown scenario preset: {scenario!r} "
            f"(known: {sorted(PRESETS)})") from None


def register_scenario(scenario: Scenario, *, overwrite: bool = False) -> Scenario:
    """Add a named scenario to the preset registry (sweepable by name)."""
    if scenario.name in PRESETS and not overwrite:
        raise ValueError(f"scenario {scenario.name!r} already registered")
    PRESETS[scenario.name] = scenario
    return scenario


def scenario_name(scenario: str | Scenario) -> str:
    return scenario if isinstance(scenario, str) else scenario.name


def generate_traces(rng: np.random.Generator | int, cfg: WirelessConfig,
                    scenario: str | Scenario, rounds: int) -> ScenarioTraces:
    """Materialize one world's whole-horizon environment.

    Canonical process order: mobility (distances) -> fading -> churn ->
    energy.  NOTE `fl.sim._prepare` interleaves its legacy cluster /
    fixed-id / permutation draws between the mobility and fading calls to
    keep the static preset's stream bit-exact; this standalone entry point
    (tests, benchmarks, notebooks) uses the canonical order, so its traces
    match `_prepare`'s statistically, not bitwise.
    """
    scn = get_scenario(scenario)
    if not isinstance(rng, np.random.Generator):
        rng = np.random.default_rng(rng)
    d_all = sample_distances(rng, cfg, scn.mobility, rounds)
    g2_all = sample_fading(rng, cfg, scn.fading, rounds)
    avail, slowdown = sample_churn(rng, scn.churn, rounds, cfg.n_devices)
    e_max = sample_energy(rng, cfg, scn.energy, rounds)
    return ScenarioTraces(scenario=scn, h2_all=compose_gains(g2_all, d_all, cfg),
                          distances_m=d_all, avail=avail, slowdown=slowdown,
                          e_max_j=e_max)


def apply_dynamics(ra: RAResult, avail: np.ndarray, slowdown: np.ndarray,
                   beta: np.ndarray, cfg: WirelessConfig) -> RAResult:
    """Fold churn into a solved whole-horizon `RAResult` (fields (T, K, N)).

    Unavailable devices lose Proposition-1 feasibility for the round
    (time -> inf, energy masked), so neither selection, matching, nor the
    learning plane can touch them — on either engine, since both consume
    this same tensor.  Straggler slowdowns scale the COMPUTE share of the
    solved round time: the plan's (tau*, p*) stay fixed (Algorithm 1
    plans against nominal DVFS), the realized clock is C/s, so

        T' = T + (s - 1) * T^cp(tau*)          (eq. 1 at the slowed clock)
        E' = E + (1/s^2 - 1) * E^cp(tau*)      (eq. 2: DVFS energy falls
                                                quadratically with clock)

    With s >= 1 (validated by `ChurnProcess`) the energy budget can only
    gain slack, so the Prop-1 feasibility mask remains valid.  A
    churn-free scenario returns `ra` unchanged (the static preset's
    bit-exactness does not survive a float round-trip, so the identity is
    literal, not numeric).
    """
    if bool(avail.all()) and not bool((slowdown != 1.0).any()):
        return ra
    avail_b = np.broadcast_to(avail[:, None, :], ra.time_s.shape)
    slow_b = np.broadcast_to(slowdown[:, None, :], ra.time_s.shape)
    beta_b = np.broadcast_to(np.asarray(beta, np.float64)[None, None, :],
                             ra.time_s.shape)
    feas = ra.feasible & avail_b
    # Evaluate the eq.-1/2 compute shares only where the plan exists
    # (tau is NaN at infeasible pairs and would poison the arithmetic).
    tau = np.where(feas, ra.tau, 0.5)
    t_cp = compute_time(tau, beta_b, cfg)
    e_cp = compute_energy(tau, beta_b, cfg)
    time_s = np.where(feas, ra.time_s + (slow_b - 1.0) * t_cp, np.inf)
    energy = np.where(feas, ra.energy_j + (1.0 / slow_b**2 - 1.0) * e_cp,
                      np.nan)
    return RAResult(tau=np.where(feas, ra.tau, np.nan),
                    p=np.where(feas, ra.p, np.nan),
                    time_s=time_s, energy_j=energy, feasible=feas,
                    iterations=ra.iterations)
