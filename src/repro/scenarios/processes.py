"""Environment processes: seed-deterministic whole-horizon trace generators.

Each process is a pure ``(rng, cfg, horizon) -> trace`` generator — a
``np.random.Generator`` plays the role of the key, so a seeded generator
always reproduces the same trace — and each has a *degenerate kind* that
consumes the rng stream exactly as the pre-scenario simulator did (or not
at all), which is what makes the ``static`` preset bit-exact
(DESIGN.md §11):

  fading    ``iid``     draws ``rng.exponential((K, N))`` per round — the
                        identical Rayleigh stream `core.wireless
                        .sample_channel_gains` consumed inline;
            ``ar1``     Gauss-Markov AR(1) on COMPLEX gains
                        g_t = rho g_{t-1} + sqrt(1-rho^2) w_t with
                        g_0, w_t ~ CN(0, 1): the marginal |g|^2 stays
                        Exp(1) (Rayleigh power) at every lag while the
                        power autocorrelation decays as rho^(2*lag);
                        rho=0 recovers the i.i.d. law (different draws,
                        same distribution).
  mobility  ``static``  one `sample_topology` draw broadcast over rounds;
            ``waypoint`` random-waypoint drift inside the disc: each
                        device walks at `speed_mps` toward a uniform
                        waypoint, re-drawing on arrival.  Distances are
                        clamped to `WirelessConfig.min_dist_m`, so a
                        trace can never tunnel below the eq.-3 path-loss
                        floor.
  churn     ``none``    everyone available at nominal speed, NO rng use;
            ``markov``  per-device 2-state availability chain
                        (P[up->down] = p_drop, P[down->up] = p_join, all
                        up at t=0) plus i.i.d. straggler slowdowns
                        (prob `straggler_prob` of a Uniform(1,
                        `slowdown_max`] compute-time multiplier).
  energy    ``static``  the constant Table-I budget, NO rng use;
            ``harvest`` use-it-or-lose-it harvesting: the round-t budget
                        is E^max * (floor_frac + Exp(mean_frac -
                        floor_frac)) — mean E^max * mean_frac — i.e. the
                        energy harvested since the previous round.  No
                        battery carry-over: that would couple the budget
                        to the selection history and break the
                        whole-horizon Γ precompute (Γ must stay
                        selection-independent, DESIGN.md §6).

All traces are host-side float64/bool numpy arrays; `fl.sim` converts them
to jnp exactly where it already converted the inline-sampled equivalents.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.wireless import WirelessConfig, sample_topology

__all__ = [
    "FadingProcess",
    "MobilityProcess",
    "ChurnProcess",
    "EnergyProcess",
    "sample_fading",
    "sample_coupled_fading",
    "sample_distances",
    "sample_churn",
    "sample_energy",
    "compose_gains",
]


@dataclasses.dataclass(frozen=True)
class FadingProcess:
    """Small-scale fading law for the |g|^2 factor of eq. (3)."""

    kind: str = "iid"     # "iid" | "ar1"
    rho: float = 0.0      # AR(1) coefficient on the complex gain per round

    def __post_init__(self):
        if self.kind not in ("iid", "ar1"):
            raise ValueError(f"unknown fading kind: {self.kind!r}")
        if not 0.0 <= self.rho < 1.0:
            raise ValueError(f"fading rho must be in [0, 1), got {self.rho}")


@dataclasses.dataclass(frozen=True)
class MobilityProcess:
    """Device-position process behind the eq.-3 path-loss distances."""

    kind: str = "static"  # "static" | "waypoint"
    speed_mps: float = 0.0
    round_s: float = 1.0  # wall-clock seconds represented by one round

    def __post_init__(self):
        if self.kind not in ("static", "waypoint"):
            raise ValueError(f"unknown mobility kind: {self.kind!r}")
        if self.speed_mps < 0.0 or self.round_s <= 0.0:
            raise ValueError("mobility needs speed_mps >= 0 and round_s > 0")


@dataclasses.dataclass(frozen=True)
class ChurnProcess:
    """Availability + compute-speed process (device churn and stragglers)."""

    kind: str = "none"          # "none" | "markov"
    p_drop: float = 0.0         # P(available -> unavailable) per round
    p_join: float = 1.0         # P(unavailable -> available) per round
    straggler_prob: float = 0.0  # P(a device straggles in a given round)
    slowdown_max: float = 1.0   # straggler compute-time multiplier cap (>= 1)

    def __post_init__(self):
        if self.kind not in ("none", "markov"):
            raise ValueError(f"unknown churn kind: {self.kind!r}")
        for name in ("p_drop", "p_join", "straggler_prob"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"churn {name} must be in [0, 1], got {v}")
        if self.slowdown_max < 1.0:
            raise ValueError(
                f"slowdown_max must be >= 1 (stragglers only slow down; a "
                f"speed-up could overdraw the solved energy budget), got "
                f"{self.slowdown_max}")


@dataclasses.dataclass(frozen=True)
class EnergyProcess:
    """Per-round energy-budget process generalizing the static E^max."""

    kind: str = "static"    # "static" | "harvest"
    mean_frac: float = 1.0  # mean budget as a fraction of cfg.e_max_j
    floor_frac: float = 0.1  # guaranteed floor as a fraction of cfg.e_max_j

    def __post_init__(self):
        if self.kind not in ("static", "harvest"):
            raise ValueError(f"unknown energy kind: {self.kind!r}")
        if not 0.0 <= self.floor_frac < self.mean_frac:
            raise ValueError(
                f"energy needs 0 <= floor_frac < mean_frac, got "
                f"floor={self.floor_frac}, mean={self.mean_frac}")


# ---------------------------------------------------------------------------
# generators: (rng, cfg, horizon) -> trace
# ---------------------------------------------------------------------------

def sample_fading(rng: np.random.Generator, cfg: WirelessConfig,
                  proc: FadingProcess, rounds: int) -> np.ndarray:
    """Small-scale power gains |g_{k,n}|^2, shape (rounds, K, N), mean 1.

    ``iid`` reproduces the legacy per-round Exp(1) draws verbatim (one
    ``rng.exponential((K, N))`` call per round, in round order — the exact
    stream the inline sampler consumed); ``ar1`` runs a complex
    Gauss-Markov recursion whose |g|^2 marginal is Exp(1) at every lag.
    """
    k, n = cfg.n_subchannels, cfg.n_devices
    if proc.kind == "iid":
        return np.stack([rng.exponential(size=(k, n)) for _ in range(rounds)])
    # AR(1): g_t = rho g_{t-1} + sqrt(1-rho^2) w_t, g_0 / w_t ~ CN(0, 1).
    def cn(size):
        return (rng.standard_normal(size) + 1j * rng.standard_normal(size)) \
            / np.sqrt(2.0)

    rho = proc.rho
    g = np.empty((rounds, k, n), dtype=np.complex128)
    g[0] = cn((k, n))
    scale = np.sqrt(1.0 - rho * rho)
    for t in range(1, rounds):
        g[t] = rho * g[t - 1] + scale * cn((k, n))
    return np.abs(g) ** 2


def sample_coupled_fading(rng: np.random.Generator, cfg: WirelessConfig,
                          proc: FadingProcess, rounds: int, n_cells: int,
                          coupling: float) -> np.ndarray:
    """Cross-cell coupled small-scale fading, shape (C, rounds, K, N).

    Models inter-cell interference correlation: every cell's complex gain
    is the mixture ``sqrt(c) * g_shared + sqrt(1 - c) * g_local`` of one
    field shared by ALL cells and a per-cell independent field, each
    CN(0, 1) under the cell's `FadingProcess` (iid or AR(1)).  Because the
    mixing coefficients satisfy c + (1 - c) = 1 and the two fields are
    independent, the per-cell marginal stays CN(0, 1) — so |g|^2 keeps the
    Exp(1) Rayleigh-power law (and, under ``ar1``, the rho^(2*lag) power
    autocorrelation) at EVERY coupling, while the cross-cell power
    correlation grows with `coupling`
    (tests/test_hier_async_properties.py pins the marginals).

    ``coupling == 0`` must not change the world stream of uncoupled
    preparation: it delegates to per-cell `sample_fading` calls in cell
    order, bit-identical to the uncoupled path (and to the flat
    single-cell stream when C == 1).
    """
    if not 0.0 <= coupling <= 1.0:
        raise ValueError(f"cell coupling must be in [0, 1], got {coupling}")
    if coupling == 0.0:
        return np.stack([sample_fading(rng, cfg, proc, rounds)
                         for _ in range(n_cells)])
    k, n = cfg.n_subchannels, cfg.n_devices

    def cn(size):
        return (rng.standard_normal(size) + 1j * rng.standard_normal(size)) \
            / np.sqrt(2.0)

    a, b = np.sqrt(coupling), np.sqrt(1.0 - coupling)
    if proc.kind == "iid":
        shared = cn((rounds, k, n))
        local = cn((n_cells, rounds, k, n))
        return np.abs(a * shared[None] + b * local) ** 2
    # AR(1): run the shared and local recursions side by side — a fixed
    # mixture of two independent AR(1) CN(0, 1) processes with the same
    # rho is itself AR(1) CN(0, 1) with that rho.
    rho = proc.rho
    scale = np.sqrt(1.0 - rho * rho)
    g = np.empty((n_cells, rounds, k, n), dtype=np.complex128)
    gs = cn((k, n))
    gl = cn((n_cells, k, n))
    g[:, 0] = a * gs[None] + b * gl
    for t in range(1, rounds):
        gs = rho * gs + scale * cn((k, n))
        gl = rho * gl + scale * cn((n_cells, k, n))
        g[:, t] = a * gs[None] + b * gl
    return np.abs(g) ** 2


def sample_distances(rng: np.random.Generator, cfg: WirelessConfig,
                     proc: MobilityProcess, rounds: int) -> np.ndarray:
    """Device-to-server distances, shape (rounds, N), clamped to min_dist_m.

    ``static`` consumes exactly one `sample_topology`-style uniform draw
    (bit-compatible with the legacy inline call) and broadcasts it;
    ``waypoint`` additionally draws angles and per-round waypoint
    candidates and walks each device `speed_mps * round_s` per round.
    """
    n = cfg.n_devices
    if proc.kind == "static":
        # Bit-exactness-critical: the legacy sampler IS the source of truth.
        d = sample_topology(rng, cfg).distances_m
        return np.broadcast_to(d, (rounds, n)).copy()
    # Initial radii: same uniform-area-density draw, at the same stream
    # position, but kept raw — walkers need positions, not clamped ranges.
    r0 = cfg.radius_m * np.sqrt(rng.uniform(size=n))

    def disc_points(radius, theta):
        return np.stack([radius * np.cos(theta), radius * np.sin(theta)], -1)

    pos = disc_points(r0, rng.uniform(0.0, 2.0 * np.pi, size=n))
    wp = disc_points(cfg.radius_m * np.sqrt(rng.uniform(size=n)),
                     rng.uniform(0.0, 2.0 * np.pi, size=n))
    step = proc.speed_mps * proc.round_s
    d_all = np.empty((rounds, n))
    for t in range(rounds):
        d_all[t] = np.maximum(np.linalg.norm(pos, axis=-1), cfg.min_dist_m)
        vec = wp - pos
        dist = np.linalg.norm(vec, axis=-1)
        arrived = dist <= step
        # Fixed-size draws every round keep the stream shape data-independent.
        cand = disc_points(cfg.radius_m * np.sqrt(rng.uniform(size=n)),
                           rng.uniform(0.0, 2.0 * np.pi, size=n))
        pos = np.where(arrived[:, None], wp,
                       pos + vec * (step / np.maximum(dist, 1e-30))[:, None])
        wp = np.where(arrived[:, None], cand, wp)
    return d_all


def sample_churn(rng: np.random.Generator, proc: ChurnProcess, rounds: int,
                 n: int) -> tuple[np.ndarray, np.ndarray]:
    """Availability mask (rounds, N) bool + compute slowdowns (rounds, N).

    ``none`` consumes NO randomness (the static preset must leave the
    world stream untouched).  ``markov`` runs the 2-state chain from
    all-available and overlays i.i.d. straggler multipliers in [1,
    slowdown_max]; an unavailable device's slowdown is forced to 1 (it
    does not run at all — availability, not speed, removes it).
    """
    if proc.kind == "none":
        return (np.ones((rounds, n), dtype=bool),
                np.ones((rounds, n), dtype=np.float64))
    avail = np.empty((rounds, n), dtype=bool)
    avail[0] = True
    for t in range(1, rounds):
        u = rng.uniform(size=n)
        avail[t] = np.where(avail[t - 1], u >= proc.p_drop, u < proc.p_join)
    hit = rng.uniform(size=(rounds, n)) < proc.straggler_prob
    mult = 1.0 + rng.uniform(size=(rounds, n)) * (proc.slowdown_max - 1.0)
    slowdown = np.where(hit & avail, mult, 1.0)
    return avail, slowdown


def sample_energy(rng: np.random.Generator, cfg: WirelessConfig,
                  proc: EnergyProcess, rounds: int) -> np.ndarray:
    """Per-round per-device energy budgets E^max_{t,n}, shape (rounds, N).

    ``static`` consumes NO randomness and returns the constant
    `cfg.e_max_j`; ``harvest`` draws shifted-exponential arrivals with
    mean ``mean_frac * e_max_j`` and floor ``floor_frac * e_max_j``.
    """
    n = cfg.n_devices
    if proc.kind == "static":
        return np.full((rounds, n), cfg.e_max_j, dtype=np.float64)
    scale = (proc.mean_frac - proc.floor_frac) * cfg.e_max_j
    floor = proc.floor_frac * cfg.e_max_j
    return floor + rng.exponential(scale=scale, size=(rounds, n))


def compose_gains(g2_all: np.ndarray, d_all: np.ndarray,
                  cfg: WirelessConfig) -> np.ndarray:
    """Eq. (3): |h|^2 = P_t |g|^2 eta d^-a / sigma^2, shape (rounds, K, N).

    The expression mirrors `core.wireless.sample_channel_gains`
    operation-for-operation (path factor first, then P_t * g2 * path /
    noise), so a static scenario's h2 horizon is bit-identical to the
    legacy per-round inline computation.
    """
    path = cfg.eta * d_all[:, None, :] ** (-cfg.pathloss_exp)
    return cfg.pt_w * g2_all * path / cfg.noise_w
