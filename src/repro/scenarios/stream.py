"""Stream-extension of the scenario layer: an OPEN-ENDED environment.

`generate_traces` / `fl.sim._prepare` draw whole-horizon blocks, so the
rng stream layout depends on the horizon length — two horizons of the
same world are different random worlds, which is exactly what a
long-running service cannot have.  `ScenarioStream` regenerates the same
four processes as per-round *incremental* recursions with explicitly
carried state (the AR(1) complex gain, walker positions/waypoints, the
Markov availability vector), each process on its own `SeedSequence`
child, so that for any split points

    next_segment(a) ++ next_segment(b)  ==  next_segment(a + b)

of a fresh stream with the same seed — segment boundaries are invisible,
and segment s really is rounds [t, t+s) of ONE infinite trace
(DESIGN.md §14).  The per-round recursions are the exact per-t update
rules of `scenarios.processes` (AR(1) step, waypoint walk, Markov
transition, shifted-exponential harvest), so marginals and dynamics
match the fixed-horizon generators law-for-law; the draws themselves
differ because the stream deliberately abandons the horizon-shaped
block layout.  Bit-identity of segment chaining is pinned by
tests/test_service.py.
"""
from __future__ import annotations

import numpy as np

from ..core.wireless import WirelessConfig, sample_topology
from .processes import compose_gains
from .scenario import Scenario, ScenarioTraces, get_scenario

__all__ = ["ScenarioStream"]


class ScenarioStream:
    """One seed-deterministic infinite environment, served in segments.

    Four independent child generators (mobility, fading, churn, energy —
    spawned from one `SeedSequence`) make each process's stream position
    a pure function of how many rounds have been served, never of how
    the caller chunked them.
    """

    def __init__(self, seed: int | np.random.SeedSequence,
                 cfg: WirelessConfig, scenario: str | Scenario):
        self.cfg = cfg
        self.scenario = get_scenario(scenario)
        ss = (seed if isinstance(seed, np.random.SeedSequence)
              else np.random.SeedSequence(seed))
        self._rng_mob, self._rng_fad, self._rng_chu, self._rng_ene = (
            np.random.default_rng(child) for child in ss.spawn(4))
        self._t = 0
        # Carried process state (None = not yet initialized; every
        # process initializes on its round-0 step, so a fresh stream
        # consumes nothing until the first segment is requested).
        self._static_d: np.ndarray | None = None   # static mobility
        self._pos: np.ndarray | None = None        # waypoint walker
        self._wp: np.ndarray | None = None
        self._g: np.ndarray | None = None          # AR(1) complex gain
        self._avail: np.ndarray | None = None      # Markov chain state

    @property
    def t(self) -> int:
        """Absolute round index of the next segment's first round."""
        return self._t

    # ---- per-round process steps (the eq.-for-eq. recursions of
    # scenarios.processes, with the loop-carried state made explicit) ----

    def _step_mobility(self) -> np.ndarray:
        cfg, proc = self.cfg, self.scenario.mobility
        n = cfg.n_devices
        if proc.kind == "static":
            if self._static_d is None:
                self._static_d = sample_topology(self._rng_mob,
                                                 cfg).distances_m
            return self._static_d
        rng = self._rng_mob

        def disc_points(radius, theta):
            return np.stack([radius * np.cos(theta),
                             radius * np.sin(theta)], -1)

        if self._pos is None:
            r0 = cfg.radius_m * np.sqrt(rng.uniform(size=n))
            self._pos = disc_points(r0, rng.uniform(0.0, 2.0 * np.pi, size=n))
            self._wp = disc_points(
                cfg.radius_m * np.sqrt(rng.uniform(size=n)),
                rng.uniform(0.0, 2.0 * np.pi, size=n))
        d = np.maximum(np.linalg.norm(self._pos, axis=-1), cfg.min_dist_m)
        step = proc.speed_mps * proc.round_s
        vec = self._wp - self._pos
        dist = np.linalg.norm(vec, axis=-1)
        arrived = dist <= step
        cand = disc_points(cfg.radius_m * np.sqrt(rng.uniform(size=n)),
                           rng.uniform(0.0, 2.0 * np.pi, size=n))
        self._pos = np.where(arrived[:, None], self._wp,
                             self._pos + vec *
                             (step / np.maximum(dist, 1e-30))[:, None])
        self._wp = np.where(arrived[:, None], cand, self._wp)
        return d

    def _step_fading(self) -> np.ndarray:
        cfg, proc = self.cfg, self.scenario.fading
        k, n = cfg.n_subchannels, cfg.n_devices
        rng = self._rng_fad
        if proc.kind == "iid":
            return rng.exponential(size=(k, n))

        def cn():
            return (rng.standard_normal((k, n))
                    + 1j * rng.standard_normal((k, n))) / np.sqrt(2.0)

        if self._g is None:
            self._g = cn()
        else:
            rho = proc.rho
            self._g = rho * self._g + np.sqrt(1.0 - rho * rho) * cn()
        return np.abs(self._g) ** 2

    def _step_churn(self) -> tuple[np.ndarray, np.ndarray]:
        proc = self.scenario.churn
        n = self.cfg.n_devices
        if proc.kind == "none":
            return np.ones(n, dtype=bool), np.ones(n, dtype=np.float64)
        rng = self._rng_chu
        if self._avail is None:
            self._avail = np.ones(n, dtype=bool)
        else:
            u = rng.uniform(size=n)
            self._avail = np.where(self._avail, u >= proc.p_drop,
                                   u < proc.p_join)
        hit = rng.uniform(size=n) < proc.straggler_prob
        mult = 1.0 + rng.uniform(size=n) * (proc.slowdown_max - 1.0)
        slowdown = np.where(hit & self._avail, mult, 1.0)
        return self._avail.copy(), slowdown

    def _step_energy(self) -> np.ndarray:
        cfg, proc = self.cfg, self.scenario.energy
        n = cfg.n_devices
        if proc.kind == "static":
            return np.full(n, cfg.e_max_j, dtype=np.float64)
        scale = (proc.mean_frac - proc.floor_frac) * cfg.e_max_j
        floor = proc.floor_frac * cfg.e_max_j
        return floor + self._rng_ene.exponential(scale=scale, size=n)

    # ---- segment service ------------------------------------------------

    def next_segment(self, rounds: int) -> ScenarioTraces:
        """The next `rounds` rounds of the stream, as `ScenarioTraces`."""
        if rounds < 1:
            raise ValueError(f"segment needs >= 1 round, got {rounds}")
        k, n = self.cfg.n_subchannels, self.cfg.n_devices
        d_all = np.empty((rounds, n))
        g2_all = np.empty((rounds, k, n))
        avail = np.empty((rounds, n), dtype=bool)
        slowdown = np.empty((rounds, n))
        e_max = np.empty((rounds, n))
        for i in range(rounds):
            d_all[i] = self._step_mobility()
            g2_all[i] = self._step_fading()
            avail[i], slowdown[i] = self._step_churn()
            e_max[i] = self._step_energy()
            self._t += 1
        return ScenarioTraces(
            scenario=self.scenario,
            h2_all=compose_gains(g2_all, d_all, self.cfg),
            distances_m=d_all, avail=avail, slowdown=slowdown,
            e_max_j=e_max)
