from .fl_datasets import (
    Dataset,
    FLPartition,
    make_dataset,
    mnist_like,
    cifar_like,
    sst2_like,
    partition_imbalanced_iid,
)
from .pipeline import synthetic_token_batch, synthetic_lm_stream

__all__ = [
    "Dataset",
    "FLPartition",
    "make_dataset",
    "mnist_like",
    "cifar_like",
    "sst2_like",
    "partition_imbalanced_iid",
    "synthetic_token_batch",
    "synthetic_lm_stream",
]
