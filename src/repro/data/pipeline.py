"""Deterministic synthetic token pipeline for the large-architecture
training/serving paths (dry-run, examples, smoke tests).

Everything is seeded and allocation-free until the batch is materialized;
the dry-run never calls these (it uses ShapeDtypeStructs from
repro.launch.input_specs).
"""
from __future__ import annotations

from typing import Iterator

import numpy as np

__all__ = ["synthetic_token_batch", "synthetic_lm_stream"]


def synthetic_token_batch(
    rng: np.random.Generator, batch: int, seq_len: int, vocab: int
) -> dict[str, np.ndarray]:
    """One causal-LM batch: Zipf-distributed tokens, labels = inputs shifted."""
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = 1.0 / ranks**1.1
    probs /= probs.sum()
    toks = rng.choice(vocab, size=(batch, seq_len + 1), p=probs).astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def synthetic_lm_stream(
    seed: int, batch: int, seq_len: int, vocab: int
) -> Iterator[dict[str, np.ndarray]]:
    """Infinite deterministic stream of LM batches."""
    rng = np.random.default_rng(seed)
    while True:
        yield synthetic_token_batch(rng, batch, seq_len, vocab)
