"""Federated datasets + the paper's imbalanced-IID partition (Sec. VI).

MNIST / CIFAR-10 / SST-2 are not downloadable in this offline container, so
we generate *seeded synthetic* datasets with identical tensor shapes, class
counts and sizes (DESIGN.md §5).  Samples are drawn from class-conditional
distributions so the paper's models actually learn and the scheme ordering
of Figs. 3-9 is reproducible:

  mnist_like : 28x28 grayscale, 10 classes — class prototype blobs + noise.
  cifar_like : 32x32x3, 10 classes — low-freq class textures + noise.
  sst2_like  : token sequences (len 32, vocab 4000), 2 classes — class-tilted
               unigram distributions over a shared vocabulary.

Partition: the paper's imbalanced IID — c_n ~ U[1, 10] per device, shuffled
samples split by fraction c_n / sum_i c_i.
"""
from __future__ import annotations

import dataclasses
import numpy as np

__all__ = [
    "Dataset",
    "FLPartition",
    "mnist_like",
    "cifar_like",
    "sst2_like",
    "make_dataset",
    "partition_imbalanced_iid",
]


@dataclasses.dataclass(frozen=True)
class Dataset:
    name: str
    x: np.ndarray        # (n, ...) float32 inputs or int32 tokens
    y: np.ndarray        # (n,) int32 labels
    n_classes: int

    @property
    def n(self) -> int:
        return int(self.x.shape[0])


@dataclasses.dataclass(frozen=True)
class FLPartition:
    """Per-device sample index lists + sizes beta_n."""

    indices: tuple[np.ndarray, ...]   # len N, each (beta_n,)
    beta: np.ndarray                  # (N,) int64

    @property
    def n_devices(self) -> int:
        return len(self.indices)


def mnist_like(rng: np.random.Generator, n: int = 500) -> Dataset:
    """28x28 digits stand-in: 10 Gaussian-blob prototypes + pixel noise."""
    protos = rng.normal(0.0, 1.0, size=(10, 28, 28)).astype(np.float32)
    # Smooth prototypes a little so classes are separable but not trivial.
    k = np.ones((5, 5), np.float32) / 25.0
    sm = np.stack([_conv2d_same(p, k) for p in protos])
    y = rng.integers(0, 10, size=n).astype(np.int32)
    x = sm[y] + rng.normal(0.0, 0.6, size=(n, 28, 28)).astype(np.float32)
    return Dataset("mnist_like", x.reshape(n, 784), y, 10)


def cifar_like(rng: np.random.Generator, n: int = 2000) -> Dataset:
    """32x32x3 stand-in: low-frequency class textures + noise.

    The paper trains on 50k CIFAR images; the simulation default is reduced
    (configurable) so benchmark sweeps finish on CPU.
    """
    freqs = rng.uniform(0.5, 3.0, size=(10, 2))
    phases = rng.uniform(0, 2 * np.pi, size=(10, 3))
    yy, xx = np.mgrid[0:32, 0:32].astype(np.float32) / 32.0
    protos = np.stack(
        [
            np.stack(
                [
                    np.sin(2 * np.pi * (f[0] * xx + f[1] * yy) + ph[c])
                    for c in range(3)
                ],
                axis=-1,
            )
            for f, ph in zip(freqs, phases)
        ]
    ).astype(np.float32)
    y = rng.integers(0, 10, size=n).astype(np.int32)
    x = protos[y] + rng.normal(0.0, 0.8, size=(n, 32, 32, 3)).astype(np.float32)
    return Dataset("cifar_like", x, y, 10)


def sst2_like(
    rng: np.random.Generator, n: int = 2000, vocab: int = 4000, seq: int = 32
) -> Dataset:
    """Binary sentiment stand-in: class-tilted unigram token draws.

    A shared Zipf-ish base distribution; each class boosts a disjoint set of
    'sentiment' tokens, mimicking bag-of-words separability.
    """
    base = 1.0 / (np.arange(1, vocab + 1) ** 1.1)
    cls_tokens = rng.choice(vocab, size=(2, 100), replace=False)
    probs = np.stack([base.copy(), base.copy()])
    for c in range(2):
        probs[c, cls_tokens[c]] *= 40.0
    probs /= probs.sum(axis=1, keepdims=True)
    y = rng.integers(0, 2, size=n).astype(np.int32)
    x = np.stack([rng.choice(vocab, size=seq, p=probs[c]) for c in y]).astype(np.int32)
    return Dataset("sst2_like", x, y, 2)


_MAKERS = {"mnist": mnist_like, "cifar10": cifar_like, "sst2": sst2_like}


def make_dataset(name: str, rng: np.random.Generator, **kw) -> Dataset:
    try:
        return _MAKERS[name](rng, **kw)
    except KeyError:
        raise ValueError(f"unknown dataset {name!r}; choose from {sorted(_MAKERS)}")


def partition_imbalanced_iid(
    rng: np.random.Generator, n_samples: int, n_devices: int
) -> FLPartition:
    """Paper Sec. VI: c_n ~ U[1,10]; shuffled samples split by c_n/sum c."""
    c = rng.uniform(1.0, 10.0, size=n_devices)
    frac = c / c.sum()
    counts = np.maximum(1, np.floor(frac * n_samples).astype(np.int64))
    # Fix rounding so the counts sum to <= n_samples.
    while counts.sum() > n_samples:
        counts[np.argmax(counts)] -= 1
    perm = rng.permutation(n_samples)
    splits = np.cumsum(counts)[:-1]
    idx = tuple(np.array(a) for a in np.split(perm[: counts.sum()], splits))
    return FLPartition(indices=idx, beta=counts)


def partition_dirichlet(
    rng: np.random.Generator,
    labels: np.ndarray,
    n_devices: int,
    alpha: float = 0.5,
) -> FLPartition:
    """Label-skewed NON-IID partition (Dirichlet over class proportions).

    Beyond-paper extension: the paper evaluates imbalanced IID only; AoU
    weighting matters *more* under label skew (each device's update is more
    distinctive, so staleness costs more) — examples/non_iid_aou.py
    demonstrates this with the same harness.
    """
    n_classes = int(labels.max()) + 1
    idx_by_class = [np.where(labels == c)[0] for c in range(n_classes)]
    for idx in idx_by_class:
        rng.shuffle(idx)
    device_bins: list[list[np.ndarray]] = [[] for _ in range(n_devices)]
    for c, idx in enumerate(idx_by_class):
        props = rng.dirichlet(np.full(n_devices, alpha))
        counts = np.floor(props * len(idx)).astype(np.int64)
        counts[-1] = len(idx) - counts[:-1].sum()
        start = 0
        for dev, cnt in enumerate(counts):
            device_bins[dev].append(idx[start : start + cnt])
            start += cnt
    indices = []
    for bins in device_bins:
        merged = np.concatenate(bins) if bins else np.array([], np.int64)
        if merged.size == 0:  # guarantee beta_n >= 1
            merged = np.array([int(rng.integers(len(labels)))], np.int64)
        indices.append(merged)
    beta = np.array([len(i) for i in indices], np.int64)
    return FLPartition(indices=tuple(indices), beta=beta)


def _conv2d_same(img: np.ndarray, k: np.ndarray) -> np.ndarray:
    """Tiny same-padding 2-D convolution for prototype smoothing."""
    kh, kw = k.shape
    ph, pw = kh // 2, kw // 2
    pad = np.pad(img, ((ph, ph), (pw, pw)), mode="edge")
    out = np.zeros_like(img)
    for i in range(kh):
        for j in range(kw):
            out += k[i, j] * pad[i : i + img.shape[0], j : j + img.shape[1]]
    return out
