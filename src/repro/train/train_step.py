"""Distributed training / serving steps for the model zoo.

train_step folds the paper's eq.-(34) aggregation into the loss: each data
shard is a device-cohort whose contribution is scaled by its Stackelberg
selection weight (batch["fl_weights"]), so the weighted FedAvg aggregate
emerges from the single gradient all-reduce XLA inserts across the
data/pod axes — no separate aggregation pass.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models.moe import ShardCtx
from ..models.transformer import decode_step, forward, lm_loss
from .optimizer import Optimizer, apply_updates, global_norm

__all__ = ["make_train_step", "make_prefill_step", "make_serve_step"]


def make_train_step(cfg: ArchConfig, opt: Optimizer, ctx: ShardCtx = ShardCtx(),
                    *, remat: bool = True, clip_norm: float = 1.0):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def loss_fn(params, batch):
        return lm_loss(cfg, params, batch, ctx)

    if remat:
        # Save matmul outputs AND the MoE psum outputs ("moe_out"): the
        # latter keeps rematerialization from re-running the expert-combine
        # all-reduce in the backward pass (§Perf iteration on MoE archs).
        policy = jax.checkpoint_policies.save_from_both_policies(
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            jax.checkpoint_policies.save_only_these_names("moe_out"),
        )
        loss_fn = jax.checkpoint(loss_fn, policy=policy)

    def train_step(params, opt_state, batch):
        (loss, extras), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        gnorm = global_norm(grads)
        if clip_norm > 0:
            scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = {"loss": loss, "grad_norm": gnorm, "aux": extras["aux"]}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, ctx: ShardCtx = ShardCtx()):
    """prefill_step(params, batch) -> (last_logits, cache)."""

    def prefill_step(params, batch):
        logits, _, cache = forward(cfg, params, batch, ctx, mode="prefill")
        return logits[:, -1:], cache

    return prefill_step


def make_serve_step(cfg: ArchConfig, ctx: ShardCtx = ShardCtx()):
    """serve_step(params, batch, cache) -> (next_token, logits, cache).

    ONE new token against the existing KV/state cache (greedy sampling; the
    decode shapes of the assignment lower exactly this function).
    """

    def serve_step(params, batch, cache):
        logits, cache = decode_step(cfg, params, batch, cache, ctx)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return next_tok, logits, cache

    return serve_step
