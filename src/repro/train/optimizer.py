"""Pure-JAX optimizers (no optax available offline).

Minimal GradientTransformation-style API:

    opt = adam(1e-3)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

Implemented: sgd, momentum, adam, adamw, adafactor (factored second moment,
for the >=100B dry-run configs where Adam state would not fit HBM), plus
clip_by_global_norm and chain.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "Optimizer",
    "apply_updates",
    "sgd",
    "momentum",
    "adam",
    "adamw",
    "adafactor",
    "clip_by_global_norm",
    "chain",
    "global_norm",
    "make_optimizer",
]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]  # (grads, state, params)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params=None):
        return jax.tree_util.tree_map(lambda g: -lr * g, grads), state

    return Optimizer(init, update)


def momentum(lr: float, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return jax.tree_util.tree_map(jnp.zeros_like, params)

    def update(grads, state, params=None):
        new_m = jax.tree_util.tree_map(lambda m, g: beta * m + g, state, grads)
        if nesterov:
            upd = jax.tree_util.tree_map(lambda m, g: -lr * (beta * m + g), new_m, grads)
        else:
            upd = jax.tree_util.tree_map(lambda m: -lr * m, new_m)
        return upd, new_m

    return Optimizer(init, update)


class AdamState(NamedTuple):
    count: jax.Array
    mu: Any
    nu: Any


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamState(
            count=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(z, params),
            nu=jax.tree_util.tree_map(z, params),
        )

    def update(grads, state, params=None):
        count = state.count + 1
        grads32 = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads32)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g), state.nu, grads32
        )
        bc1 = 1 - b1 ** count.astype(jnp.float32)
        bc2 = 1 - b2 ** count.astype(jnp.float32)
        upd = jax.tree_util.tree_map(
            lambda m, v: -lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps), mu, nu
        )
        return upd, AdamState(count=count, mu=mu, nu=nu)

    return Optimizer(init, update)


def adamw(
    lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8, wd: float = 0.01
) -> Optimizer:
    base = adam(lr, b1, b2, eps)

    def update(grads, state, params):
        upd, state = base.update(grads, state, params)
        upd = jax.tree_util.tree_map(
            lambda u, p: u - lr * wd * p.astype(jnp.float32), upd, params
        )
        return upd, state

    return Optimizer(base.init, update)


class AdafactorState(NamedTuple):
    count: jax.Array
    row: Any   # per-leaf row second moments (or full moment for <2D leaves)
    col: Any


def adafactor(
    lr: float = 1e-2, eps: float = 1e-30, clip_threshold: float = 1.0, decay: float = 0.8
) -> Optimizer:
    """Factored second-moment estimator (Shazeer & Stern 2018), memory
    O(rows+cols) per matrix. Used for the >=100B-parameter dry-run configs."""

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def rows(p):
            if _factored(p):
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros_like(p, dtype=jnp.float32)

        def cols(p):
            if _factored(p):
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((), jnp.float32)

        return AdafactorState(
            count=jnp.zeros((), jnp.int32),
            row=jax.tree_util.tree_map(rows, params),
            col=jax.tree_util.tree_map(cols, params),
        )

    def update(grads, state, params=None):
        count = state.count + 1
        beta = 1.0 - count.astype(jnp.float32) ** (-decay)

        def upd_leaf(g, r, c, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(p):
                new_r = beta * r + (1 - beta) * g2.mean(axis=-1)
                new_c = beta * c + (1 - beta) * g2.mean(axis=-2)
                denom = new_r.mean(axis=-1, keepdims=True)
                vr = new_r / jnp.maximum(denom, eps)
                u = g / jnp.sqrt(vr)[..., None] / jnp.sqrt(jnp.maximum(new_c, eps))[..., None, :]
            else:
                new_r = beta * r + (1 - beta) * g2
                new_c = c
                u = g / jnp.sqrt(jnp.maximum(new_r, eps))
            scale = jnp.maximum(1.0, jnp.sqrt(jnp.mean(jnp.square(u))) / clip_threshold)
            return -lr * u / scale, new_r, new_c

        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_r = jax.tree_util.tree_leaves(state.row)
        flat_c = jax.tree_util.tree_leaves(state.col)
        flat_p = jax.tree_util.tree_leaves(params if params is not None else grads)
        out = [upd_leaf(g, r, c, p) for g, r, c, p in zip(flat_g, flat_r, flat_c, flat_p)]
        upd = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
        row = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
        col = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
        return upd, AdafactorState(count=count, row=row, col=col)

    return Optimizer(init, update)


def clip_by_global_norm(max_norm: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params=None):
        norm = global_norm(grads)
        scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
        return jax.tree_util.tree_map(lambda g: g * scale, grads), state

    return Optimizer(init, update)


def chain(*opts: Optimizer) -> Optimizer:
    def init(params):
        return tuple(o.init(params) for o in opts)

    def update(grads, state, params=None):
        new_states = []
        for o, s in zip(opts, state):
            grads, s = o.update(grads, s, params)
            new_states.append(s)
        return grads, tuple(new_states)

    return Optimizer(init, update)


def make_optimizer(name: str, lr: float, **kw) -> Optimizer:
    table = {
        "sgd": sgd,
        "momentum": momentum,
        "adam": adam,
        "adamw": adamw,
        "adafactor": adafactor,
    }
    try:
        return table[name](lr, **kw)
    except KeyError:
        raise ValueError(f"unknown optimizer {name!r}; choose from {sorted(table)}")
