from .optimizer import (
    Optimizer,
    adafactor,
    adam,
    adamw,
    apply_updates,
    chain,
    clip_by_global_norm,
    global_norm,
    make_optimizer,
    momentum,
    sgd,
)
