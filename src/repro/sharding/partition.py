"""Parameter / optimizer-state / cache sharding rules for the production mesh.

Logical layout (megatron-style):
  * fan-out projections (wq/wk/wv, ffn gate/up, moe experts, embed vocab)
    shard their OUTPUT dim on `model`;
  * fan-in projections (wo, ffn down) shard their INPUT dim on `model`;
  * experts additionally shard the leading expert dim on `model`
    (expert parallelism; see repro.models.moe);
  * everything small (norms, biases, routers, loras) is replicated;
  * stacked per-layer leading dims (from scan-over-layers) are never sharded;
  * a dim is sharded only when divisible by the axis size — odd vocabularies
    (whisper's 51865) fall back to replicated rather than uneven shards.

Activations are constrained only at the residual stream and logits
(see repro.models.transformer._shard_act); attention internals are left to
GSPMD so head counts that don't divide the axis (qwen2's 28) still lower.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "param_spec",
    "param_shardings",
    "opt_state_shardings",
    "cache_shardings",
    "batch_shardings",
]

MODEL_AXIS = "model"

# (match keys in path, base spec builder). First match wins; specs are for
# the *unstacked* trailing dims of the leaf.
_FANOUT_2D = ("wq", "wk", "wv", "gate", "up", "fc", "q_up", "kv_up",
              "wr", "wg", "ck", "cr", "in_proj", "dt_proj", "lm_head", "mtp_head",
              "w_lora_b")
_FANIN_2D = ("wo", "down", "proj", "out_proj", "cv")
_REPLICATED = ("router", "q_down", "kv_down", "w_lora_a", "x_proj")


def _path_names(path) -> list[str]:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(str(k.idx))
        else:
            names.append(str(k))
    return names


def _divisible(n: int, mesh: Mesh) -> bool:
    return n % mesh.shape[MODEL_AXIS] == 0


def param_spec(path, leaf, mesh: Mesh) -> P:
    """Base spec for the trailing dims + None-padding for stacked dims."""
    names = _path_names(path)
    shape = leaf.shape
    ndim = len(shape)

    def pad(base: tuple) -> P:
        return P(*([None] * (ndim - len(base)) + list(base)))

    # --- embeddings -------------------------------------------------------
    if "embed" in names:
        if ndim >= 2 and _divisible(shape[-2], mesh):
            return pad((MODEL_AXIS, None))
        return pad((None, None))

    # --- MoE expert banks: (E, d, ff) / (E, ff, d) --------------------------
    if names[-1] in ("gate", "up", "down") and ndim >= 3 and "shared" not in names:
        if _divisible(shape[-3], mesh):
            return pad((MODEL_AXIS, None, None))
        return pad((None, None, None))

    # --- shared experts: REPLICATED (§Perf iteration 3) ---------------------
    # deepseek's shared expert is tiny (3 x d x 2048 ~ 88 MB bf16); sharding
    # it megatron-style costs a full (B,S,d) all-reduce per MoE layer, which
    # dwarfs the redundant-compute cost of just replicating the weights.
    if "shared" in names:
        return P(*([None] * ndim))

    parent = names[-2] if len(names) >= 2 else ""
    leafname = names[-1]
    key = parent if leafname in ("w", "b") else leafname

    if key in _REPLICATED:
        return P(*([None] * ndim))
    if key in _FANOUT_2D:
        if leafname == "b" or ndim < 2:
            ax = MODEL_AXIS if _divisible(shape[-1], mesh) else None
            return pad((ax,))
        ax = MODEL_AXIS if _divisible(shape[-1], mesh) else None
        return pad((None, ax))
    if key in _FANIN_2D:
        if leafname == "b" or ndim < 2:
            return pad((None,))
        ax = MODEL_AXIS if _divisible(shape[-2], mesh) else None
        return pad((ax, None))
    if key == "conv_w":  # (kw, d_inner)
        ax = MODEL_AXIS if _divisible(shape[-1], mesh) else None
        return pad((None, ax))
    if key in ("a_log",):  # (d_inner, N)
        ax = MODEL_AXIS if _divisible(shape[-2], mesh) else None
        return pad((ax, None))
    if key in ("dt_bias", "d_skip", "conv_b"):
        ax = MODEL_AXIS if _divisible(shape[-1], mesh) else None
        return pad((ax,))
    # norms, mu, u, w0, scalars, everything else: replicated.
    return P(*([None] * ndim))


def param_shardings(param_shapes, mesh: Mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_spec(path, leaf, mesh)),
        param_shapes,
    )


def opt_state_shardings(opt_state_shapes, param_shardings_tree, mesh: Mesh):
    """Mirror parameter specs onto optimizer moments.

    Works structurally: any state leaf whose shape equals the corresponding
    parameter's (mu/nu/momentum) inherits its spec; adafactor's factored
    (row/col) moments get the param spec with the corresponding dim removed;
    scalars are replicated.
    """
    flat_params = {
        tuple(_path_names(p)): s
        for p, s in jax.tree_util.tree_leaves_with_path(param_shardings_tree)
    }

    def match(path, leaf):
        names = tuple(_path_names(path))
        # Strip the optimizer-state wrapper prefix (e.g. ('mu',...) / (0,'row',...)).
        for start in range(len(names)):
            if names[start:] in flat_params:
                pspec = flat_params[names[start:]].spec
                if len(pspec) == leaf.ndim:
                    return NamedSharding(mesh, pspec)
                if len(pspec) == leaf.ndim + 1:  # factored row: drop last dim
                    return NamedSharding(mesh, P(*pspec[:-1]))
            # factored col: param path matches but shape is (..., cols)
        # fall back: find a param whose path suffix matches ignoring the
        # state-kind component (row/col indices differ in shape).
        for start in range(len(names)):
            suffix = names[start:]
            for ppath, psh in flat_params.items():
                if ppath == suffix:
                    return psh
        return NamedSharding(mesh, P(*([None] * leaf.ndim)))

    return jax.tree_util.tree_map_with_path(match, opt_state_shapes)


def cache_shardings(cache_shapes, mesh: Mesh, dp_axes) -> Any:
    """Decode caches: shard the cache-length dim on `model` (robust for any
    kv-head count), batch on the data axes, recurrent states on `model`
    along heads/channels."""
    dp = tuple(dp_axes)
    dp_total = int(np.prod([mesh.shape[a] for a in dp]))
    m = mesh.shape[MODEL_AXIS]

    def spec(path, leaf):
        names = _path_names(path)
        shape = leaf.shape
        nd = len(shape)
        name = names[-1]

        def ax_b(i):  # batch dim at index i (after leading stack dim)
            return dp if shape[i] % dp_total == 0 else None

        if name in ("k", "v"):            # (R, B, C, Hkv, dh)
            c_ax = MODEL_AXIS if shape[2] % m == 0 else None
            return P(None, ax_b(1), c_ax, None, None)
        if name in ("c_kv", "k_pe"):      # (R, B, C, r)
            c_ax = MODEL_AXIS if shape[2] % m == 0 else None
            return P(None, ax_b(1), c_ax, None)
        if name == "wkv":                 # (R, B, H, hs, hs)
            h_ax = MODEL_AXIS if shape[2] % m == 0 else None
            return P(None, ax_b(1), h_ax, None, None)
        if name == "ssm":                 # (R, B, di, N)
            d_ax = MODEL_AXIS if shape[2] % m == 0 else None
            return P(None, ax_b(1), d_ax, None)
        if name == "conv":                # (R, B, kw-1, di)
            d_ax = MODEL_AXIS if shape[3] % m == 0 else None
            return P(None, ax_b(1), None, d_ax)
        if name in ("prev_tok", "cm_prev"):  # (R, B, d)
            return P(None, ax_b(1), None)
        if name == "enc_out":             # (B, Se, d) -- unstacked
            b_ax = dp if shape[0] % dp_total == 0 else None
            return P(b_ax, None, None)
        if name in ("pos", "idx"):
            return P(*([None] * nd))
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, spec(p, l)), cache_shapes
    )


def batch_shardings(batch_shapes, mesh: Mesh, dp_axes):
    """Input batches: batch dim on the data axes (replicated if indivisible,
    e.g. long_500k's batch of 1)."""
    dp = tuple(dp_axes)
    dp_total = int(np.prod([mesh.shape[a] for a in dp]))

    def spec(path, leaf):
        shape = leaf.shape
        if leaf.ndim == 0:
            return P()
        b_ax = dp if shape[0] % dp_total == 0 else None
        return P(b_ax, *([None] * (leaf.ndim - 1)))

    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, spec(p, l)), batch_shapes
    )
