from .partition import (
    batch_shardings,
    cache_shardings,
    opt_state_shardings,
    param_shardings,
    param_spec,
)
