"""Algorithm 1: polyblock outer approximation for the per-pair resource
allocation problem (paper Sec. IV-A, eqs. 19-29).

For every (device n, sub-channel k) combination we solve

    min  T^cp(tau) + T^cm(p)
    s.t. E^cp(tau) + E^cm(p) <= E_n^max,   tau, p in [0, 1]

which, in canonical monotonic form (eq. 20), is  max f(z) over z in G with

    f(z) = -mu*beta/(tau*C) - D / (B log2(1 + p |h|^2))            (eq. 21)
    g(z) =  kappa0*mu*beta*(tau*C)^2
            + p*P_t*D / (B log2(1 + p |h|^2)) - E^max               (eq. 22)

f is increasing and g is increasing (Proposition 2), so the optimum lies on
the upper boundary of G = {z : g(z) <= 0} and the polyblock algorithm
converges to it from the outside.

Deviations from the paper (documented in DESIGN.md §5):
  * the projection root g(zeta * v) = 0 (eq. 29) is solved by *bisection*
    (g is strictly increasing in zeta), not MATLAB fsolve;
  * the whole algorithm is vectorized across all (K x N) pairs at once --
    each pair keeps its own vertex set in a preallocated array and pairs
    retire independently when their eq. (26) tolerance is met.

This module is the host-side (NumPy) reference implementation.  The
device-resident port — jitted `lax.while_loop` solver, Pallas-fused
projection, whole-horizon batching — lives in `core.monotonic_jax` and
`kernels.polyblock_project` (DESIGN.md §6) and is held to 1e-6 relative
agreement with this module by tests/test_monotonic_jax.py.
"""
from __future__ import annotations

import dataclasses
import numpy as np

from ..kernels.polyblock_project.ref import project_ref
from .feasibility import is_infeasible
from .wireless import WirelessConfig, total_energy, total_time

__all__ = ["RAResult", "solve_pairs", "fixed_ra", "grid_oracle", "f_obj", "g_con"]

_TINY = 1e-12


@dataclasses.dataclass(frozen=True)
class RAResult:
    """Optimal resource allocation for a batch of (device, channel) pairs."""

    tau: np.ndarray       # computational-capacity fraction tau*
    p: np.ndarray         # power fraction p*
    time_s: np.ndarray    # T(tau*, p*), +inf where infeasible
    energy_j: np.ndarray  # E(tau*, p*)
    feasible: np.ndarray  # Proposition-1 mask
    iterations: np.ndarray  # polyblock iterations consumed per pair


def f_obj(tau, p, beta, h2, cfg: WirelessConfig):
    """Eq. (21): negative total time (to be maximized)."""
    return -total_time(tau, p, beta, h2, cfg)


def g_con(tau, p, beta, h2, cfg: WirelessConfig, e_max):
    """Eq. (22): total energy minus budget (feasible iff <= 0)."""
    return total_energy(tau, p, beta, h2, cfg) - e_max


def _project(v, beta, h2, e_max, cfg: WirelessConfig, n_bisect: int = 60):
    """Projection phi(v) = zeta*v onto the boundary of G (eqs. 27-29).

    Vectorized bisection on zeta in (0, 1]: g(zeta*v) is strictly increasing
    in zeta, g -> (Prop-1 threshold - E^max) < 0 as zeta -> 0 for feasible
    pairs, so a root exists whenever g(v) > 0; otherwise zeta = 1 (the vertex
    itself is feasible -- paper's theta=1 corner case).

    Canonical implementation shared with the device backends:
    `kernels.polyblock_project` (ref.py / ops.py / kernel.py).
    """
    return project_ref(v, beta, h2, e_max, cfg, n_bisect=n_bisect)


def solve_pairs(
    beta,
    h2,
    cfg: WirelessConfig,
    e_max=None,
    *,
    eps: float | None = None,
    max_iter: int = 64,
) -> RAResult:
    """Run Algorithm 1 for a batch of pairs.

    Args:
      beta: samples per device, broadcastable to h2's shape.
      h2:   normalized channel gains |h_{k,n}|^2, any shape (typically (K, N)).
      e_max: per-pair energy budgets (default cfg.e_max_j).
      eps:  eq. (26) stopping tolerance on |f| change (default 0.01 = Table I).
    """
    h2 = np.asarray(h2, dtype=np.float64)
    shape = h2.shape
    beta = np.broadcast_to(np.asarray(beta, np.float64), shape).reshape(-1).copy()
    h2f = h2.reshape(-1).copy()
    e_max = cfg.e_max_j if e_max is None else e_max
    e_maxf = np.broadcast_to(np.asarray(e_max, np.float64), shape).reshape(-1).copy()
    eps = 0.01 if eps is None else eps

    n = h2f.shape[0]
    feas = ~is_infeasible(h2f, cfg, e_maxf)

    # Vertex store: one row per pair, up to max_iter+1 vertices each.
    m = max_iter + 2
    verts = np.zeros((n, m, 2))
    vproj = np.zeros((n, m, 2))
    vfval = np.full((n, m), -np.inf)
    valid = np.zeros((n, m), dtype=bool)

    verts[:, 0] = 1.0
    vproj[:, 0] = _project(verts[:, 0], beta, h2f, e_maxf, cfg)
    vfval[:, 0] = f_obj(vproj[:, 0, 0], vproj[:, 0, 1], beta, h2f, cfg)
    valid[:, 0] = True

    active = feas.copy()
    prev_best = np.full(n, np.inf)
    best_proj = vproj[:, 0].copy()
    best_f = vfval[:, 0].copy()
    iters = np.zeros(n, dtype=np.int64)

    for t in range(max_iter):
        if not active.any():
            break
        fv = np.where(valid, vfval, -np.inf)
        idx = np.argmax(fv, axis=1)                    # paper step 9
        rows = np.arange(n)
        fbest = fv[rows, idx]

        improved = fbest > best_f
        best_f = np.where(improved, fbest, best_f)
        best_proj = np.where(improved[:, None], vproj[rows, idx], best_proj)

        done = np.abs(fbest - prev_best) <= eps        # eq. (26)
        prev_best = fbest
        newly_done = active & done
        active &= ~done
        iters[active] += 1
        if not active.any():
            break

        a = np.where(active)[0]
        v = verts[a, idx[a]]                           # (na, 2)
        phi = vproj[a, idx[a]]
        # Children (eq. 23): v - (v_i - phi_i) e_i.
        child1 = v.copy(); child1[:, 0] = phi[:, 0]
        child2 = v.copy(); child2[:, 1] = phi[:, 1]
        # Replace the split vertex with child1, append child2 (eq. 24).
        slot_new = t + 1
        for child, slot in ((child1, idx[a]), (child2, np.full(len(a), slot_new))):
            pj = _project(child, beta[a], h2f[a], e_maxf[a], cfg)
            fj = f_obj(pj[:, 0], pj[:, 1], beta[a], h2f[a], cfg)
            verts[a, slot] = child
            vproj[a, slot] = pj
            vfval[a, slot] = fj
            valid[a, slot] = True
        del newly_done

    tau = np.where(feas, best_proj[:, 0], np.nan)
    p = np.where(feas, best_proj[:, 1], np.nan)
    time_s = np.where(feas, -best_f, np.inf)
    energy = np.where(
        feas, total_energy(best_proj[:, 0], best_proj[:, 1], beta, h2f, cfg), np.nan
    )
    return RAResult(
        tau=tau.reshape(shape),
        p=p.reshape(shape),
        time_s=time_s.reshape(shape),
        energy_j=energy.reshape(shape),
        feasible=feas.reshape(shape),
        iterations=iters.reshape(shape),
    )


def fixed_ra(beta, h2, cfg: WirelessConfig, e_max=None, *, tau0=0.5, p0=0.5) -> RAResult:
    """FIX-RA baseline: tau = p = 0.5 (Sec. VI); infeasible where the budget
    is violated at the fixed point."""
    h2 = np.asarray(h2, dtype=np.float64)
    e_max = cfg.e_max_j if e_max is None else e_max
    beta_b = np.broadcast_to(np.asarray(beta, np.float64), h2.shape)
    e_b = np.broadcast_to(np.asarray(e_max, np.float64), h2.shape)
    tau = np.full(h2.shape, tau0)
    p = np.full(h2.shape, p0)
    energy = total_energy(tau, p, beta_b, h2, cfg)
    feas = energy <= e_b
    time_s = np.where(feas, total_time(tau, p, beta_b, h2, cfg), np.inf)
    return RAResult(
        tau=np.where(feas, tau, np.nan),
        p=np.where(feas, p, np.nan),
        time_s=time_s,
        energy_j=np.where(feas, energy, np.nan),
        feasible=feas,
        iterations=np.zeros(h2.shape, dtype=np.int64),
    )


def grid_oracle(beta, h2, cfg: WirelessConfig, e_max=None, *, n_grid=400):
    """Brute-force oracle for tests: dense grid over [0,1]^2 + boundary refine.

    Returns the minimum feasible time for a SINGLE pair (scalars in, scalar
    out). Used to validate Algorithm 1; never called in production paths.
    """
    e_max = cfg.e_max_j if e_max is None else e_max
    if is_infeasible(h2, cfg, e_max):
        return np.inf
    taus = np.linspace(1e-4, 1.0, n_grid)
    # For each tau the remaining energy budget fixes the max feasible p
    # (E^cm increasing in p) -> bisect p for the active boundary.
    from .wireless import comm_energy

    e_cp = cfg.kappa0 * cfg.mu_cycles * beta * (taus * cfg.cpu_hz) ** 2
    budget = e_max - e_cp
    best = np.inf
    for tau, b in zip(taus, budget):
        if b <= 0:
            continue
        # Largest p in (0,1] with E^cm(p) <= b (E^cm increasing in p).
        if comm_energy(1.0, h2, cfg) <= b:
            p = 1.0
        else:
            lo, hi = _TINY, 1.0
            for _ in range(60):
                mid = 0.5 * (lo + hi)
                if comm_energy(mid, h2, cfg) > b:
                    hi = mid
                else:
                    lo = mid
            p = lo
        t = float(total_time(tau, p, beta, h2, cfg))
        best = min(best, t)
    return best
