"""Stackelberg-game round orchestration (paper Sec. III + Definition 1).

Each communication round:
  follower substrate : Algorithm 1 (MO-RA) evaluates the minimum-time matrix
                       Gamma over all (sub-channel, device) pairs + the
                       Proposition-1 feasibility mask;
  leader             : Algorithm 3 selects devices by AoU x data-size
                       priority, *predicting* the follower's matching;
  follower           : Algorithm 2 (M-SA) fixes the final assignment;
  bookkeeping        : per-round latency (eq. 9), energies, AoU update (eq. 6).

The leader/follower pair returned by `plan_round` is a Stackelberg
equilibrium in the sense of Definition 1: the leader's set maximizes the
weighted participation objective (eq. 42) given the follower's best response,
and the follower's (psi, tau, p) minimize latency given the leader's set.

Benchmark schemes of Sec. VI are selected via `RoundPolicy`:
  ds in {"alg3", "aou_topk", "random", "cluster", "fixed"}
  ra in {"mo", "fix"}          (Algorithm 1 vs tau=p=0.5)
  sa in {"matching", "random"} (Algorithm 2 vs uniform random)
"""
from __future__ import annotations

import dataclasses
import numpy as np

from .aou import AoUState, step_aou
from .monotonic import RAResult, fixed_ra, solve_pairs
from .selection import (
    SelectionOutcome,
    select_aou_alg3,
    select_cluster,
    select_fixed,
    select_random,
    select_topk,
)
from .wireless import WirelessConfig

__all__ = ["RoundPolicy", "RoundPlan", "RoundRandomness", "plan_round",
           "make_clusters", "policy_grid", "DS_SCHEMES", "RA_SCHEMES",
           "SA_SCHEMES", "PAPER_BASELINE_DS"]

# The scheme axes of Sec. VI (RoundPolicy validates against these).
DS_SCHEMES = ("alg3", "aou_topk", "random", "cluster", "fixed")
RA_SCHEMES = ("mo", "fix")
SA_SCHEMES = ("matching", "random")
# The paper's headline comparison (Fig. 3): the proposed Algorithm 3 vs the
# Sec.-VI device-selection baselines.
PAPER_BASELINE_DS = ("alg3", "random", "fixed", "cluster")


@dataclasses.dataclass(frozen=True)
class RoundRandomness:
    """Pre-sampled per-round permutations, injected in place of `rng` draws.

    `fl.sim` samples one of these per round up front so the host loop and
    the scan engine (core.leader_jax) consume the identical randomness
    stream and the differential harness can pin exact equivalence
    (DESIGN.md §8).  Within an Algorithm-3 replacement loop the single
    `assign_perm` seeds every iteration's initial matching — a documented
    deviation from the legacy per-iteration `rng` draw.
    """

    sel_perm: np.ndarray     # (N,) device permutation (random DS)
    assign_perm: np.ndarray  # (K,) channel permutation (matching init / R-SA)


@dataclasses.dataclass(frozen=True)
class RoundPolicy:
    """One Sec.-VI scheme combination: device selection x resource
    allocation x sub-channel assignment (see module docstring for the
    axes; `policy_grid` builds Cartesian grids of these)."""

    ds: str = "alg3"        # device selection scheme
    ra: str = "mo"          # resource allocation scheme
    sa: str = "matching"    # sub-channel assignment scheme

    def __post_init__(self):
        if self.ds not in DS_SCHEMES:
            raise ValueError(f"unknown ds: {self.ds}")
        if self.ra not in RA_SCHEMES:
            raise ValueError(f"unknown ra: {self.ra}")
        if self.sa not in SA_SCHEMES:
            raise ValueError(f"unknown sa: {self.sa}")

    @property
    def label(self) -> str:
        ds = {"alg3": "Proposed(Alg3)", "aou_topk": "AoU-DS", "random": "Random-DS",
              "cluster": "Cluster-DS", "fixed": "Fixed-DS"}[self.ds]
        return f"{ds}+{'MO-RA' if self.ra == 'mo' else 'FIX-RA'}+" + (
            "M-SA" if self.sa == "matching" else "R-SA")


@dataclasses.dataclass(frozen=True)
class RoundPlan:
    """Everything the learning plane needs for one round."""

    selected: np.ndarray       # (N,) bool S_n
    transmitted: np.ndarray    # (N,) bool S_n * sum_k psi_kn (feasible uplink)
    channel_of: np.ndarray     # (N,) int, sub-channel or -1
    tau: np.ndarray            # (N,) tau_{k,n} on the assigned channel (nan if none)
    p: np.ndarray              # (N,) power fraction (nan if none)
    time_per_device: np.ndarray  # (N,) T_{k,n}, inf where not transmitting
    energy_per_device: np.ndarray  # (N,) joules spent (0 where not transmitting)
    latency_s: float           # eq. (9): max over transmitting devices (0 if none)
    aou_next: AoUState         # AoU state after eq. (6) update
    outcome: SelectionOutcome
    gamma: np.ndarray          # (K, N) min-time matrix (Algorithm 1 output)
    feasible: np.ndarray       # (K, N) Proposition-1 mask


def policy_grid(
    ds: str | tuple[str, ...] = ("alg3",),
    ra: str | tuple[str, ...] = ("mo",),
    sa: str | tuple[str, ...] = ("matching",),
) -> list[RoundPolicy]:
    """Cartesian grid of `RoundPolicy` over the Sec.-VI scheme axes.

    Axes accept a single scheme name or a tuple of names; the grid is
    ds-major, then ra, then sa — the ordering the sweep harness
    (`repro.experiments`) uses for stable cell ids.  Each policy is
    validated by `RoundPolicy.__post_init__`.

    >>> [p.ds for p in policy_grid(ds=("alg3", "random"))]
    ['alg3', 'random']
    """
    ds_t = (ds,) if isinstance(ds, str) else tuple(ds)
    ra_t = (ra,) if isinstance(ra, str) else tuple(ra)
    sa_t = (sa,) if isinstance(sa, str) else tuple(sa)
    return [RoundPolicy(ds=d, ra=r, sa=s)
            for d in ds_t for r in ra_t for s in sa_t]


def make_clusters(n_devices: int, k: int, rng: np.random.Generator) -> np.ndarray:
    """Random partition into ceil(N/K) clusters of size <= K (Sec. VI)."""
    n_clusters = int(np.ceil(n_devices / k))
    ids = rng.permutation(n_devices)
    clusters = np.zeros(n_devices, dtype=np.int64)
    for c in range(n_clusters):
        clusters[ids[c * k : (c + 1) * k]] = c
    return clusters


def plan_round(
    aou: AoUState,
    beta: np.ndarray,
    h2: np.ndarray,
    cfg: WirelessConfig,
    rng: np.random.Generator,
    *,
    policy: RoundPolicy = RoundPolicy(),
    round_idx: int = 0,
    clusters: np.ndarray | None = None,
    fixed_ids: np.ndarray | None = None,
    e_max: np.ndarray | float | None = None,
    ra: RAResult | None = None,
    randomness: RoundRandomness | None = None,
) -> RoundPlan:
    """Solve one Stackelberg round. h2 is the (K, N) channel realization.

    `ra` optionally supplies this round's precomputed Algorithm-1 solution
    (fields shaped (K, N)).  Γ is selection-independent, so the whole-horizon
    batch solver (`monotonic_jax.precompute_gamma`) can solve every round
    before the training loop and `fl.sim` passes per-round slices here.
    `randomness` optionally injects this round's pre-sampled permutations
    in place of `rng` draws (scan-engine stream sharing, DESIGN.md §8).
    """
    k, n = h2.shape
    beta = np.asarray(beta, np.float64)

    # ---- follower substrate: Algorithm 1 over ALL pairs (leader predicts
    # the follower from the same Gamma; values are selection-independent). --
    if ra is None:
        if policy.ra == "mo":
            ra = solve_pairs(beta[None, :], h2, cfg, e_max)
        else:
            ra = fixed_ra(beta[None, :], h2, cfg, e_max)
    gamma, feas = ra.time_s, ra.feasible

    # ---- leader: device selection (Algorithm 3 or a benchmark scheme). ----
    # Eq. (43) ranks by alpha_n * beta_n; the eq. (7) normalizer sum_i A_i
    # is a positive constant across n, so ranking raw ages is equivalent —
    # and keeps integer-exact products, so `priority_list`'s documented
    # by-id tie-break really happens (dividing first leaks float rounding
    # noise into exact ties, silently reordering them).
    alpha = aou.age.astype(np.float64)
    sel_perm = None if randomness is None else randomness.sel_perm
    assign_perm = None if randomness is None else randomness.assign_perm
    if policy.ds == "alg3":
        out = select_aou_alg3(alpha, beta, gamma, feas, rng, sa=policy.sa,
                              assign_perm=assign_perm)
    elif policy.ds == "aou_topk":
        out = select_topk(alpha, beta, gamma, feas, rng, sa=policy.sa,
                          assign_perm=assign_perm)
    elif policy.ds == "random":
        out = select_random(gamma, feas, rng, sa=policy.sa,
                            sel_perm=sel_perm, assign_perm=assign_perm)
    elif policy.ds == "cluster":
        if clusters is None:
            raise ValueError("cluster DS needs `clusters`")
        out = select_cluster(gamma, feas, rng, round_idx, clusters,
                             sa=policy.sa, assign_perm=assign_perm)
    else:  # fixed
        if fixed_ids is None:
            raise ValueError("fixed DS needs `fixed_ids`")
        out = select_fixed(gamma, feas, rng, fixed_ids, sa=policy.sa,
                           assign_perm=assign_perm)

    # ---- assemble per-device quantities on the assigned channels. --------
    tau = np.full(n, np.nan)
    p = np.full(n, np.nan)
    t_dev = np.full(n, np.inf)
    e_dev = np.zeros(n)
    tx = out.transmitted
    ids = np.where(tx)[0]
    ch = out.channel_of[ids]
    tau[ids] = ra.tau[ch, ids]
    p[ids] = ra.p[ch, ids]
    t_dev[ids] = ra.time_s[ch, ids]
    e_dev[ids] = ra.energy_j[ch, ids]
    latency = float(t_dev[ids].max()) if ids.size else 0.0

    return RoundPlan(
        selected=out.selected,
        transmitted=tx,
        channel_of=out.channel_of,
        tau=tau,
        p=p,
        time_per_device=t_dev,
        energy_per_device=e_dev,
        latency_s=latency,
        aou_next=step_aou(aou, tx),
        outcome=out,
        gamma=gamma,
        feasible=feas,
    )
