"""Proposition 1: energy-feasibility of a (device, sub-channel) pair.

A selected device n on sub-channel k cannot complete its uplink within the
energy budget iff

    ln(2) * P_t * D(w) >= E_n^max * B * |h_{k,n}|^2        (eq. 15)

This is exactly the p -> 0+ limit of the communication-energy term: as the
power fraction vanishes, E^cm -> ln(2) P_t D / (B |h|^2), the *infimum* of
communication energy; if even that exceeds the budget, no (tau, p) in (0,1]^2
is feasible.

Backend-agnostic like `core.wireless` (DESIGN.md §6): numpy in, numpy out;
JAX arrays (or tracers) in, jax.numpy out.
"""
from __future__ import annotations

import numpy as np

from .wireless import WirelessConfig, _asfloat, _xp

__all__ = ["min_comm_energy", "is_infeasible", "feasible_mask"]


def min_comm_energy(h2, cfg: WirelessConfig):
    """Infimum over p in (0,1] of E^cm(p) = p P_t D / (B log2(1+p|h|^2)).

    E^cm is increasing in p (Proposition 2), so the infimum is the p->0 limit:
    ln(2) P_t D / (B |h|^2).
    """
    xp = _xp(h2)
    h2 = _asfloat(xp, h2)
    return np.log(2.0) * cfg.pt_w * cfg.model_bits / (cfg.bandwidth_hz * xp.maximum(h2, 1e-300))


def is_infeasible(h2, cfg: WirelessConfig, e_max=None):
    """Eq. (15) per element; True where the pair can never meet the budget."""
    e_max = cfg.e_max_j if e_max is None else e_max
    return min_comm_energy(h2, cfg) >= _asfloat(_xp(h2, e_max), e_max)


def feasible_mask(h2, cfg: WirelessConfig, e_max=None):
    """Boolean mask (same shape as h2) of *feasible* pairs."""
    return ~is_infeasible(h2, cfg, e_max)
