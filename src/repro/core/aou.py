"""Age-of-Update state machine (paper Sec. II-C, eqs. 6-7, Fig. 1).

A_n counts communication rounds since device n last *transmitted* (selected
AND assigned to a sub-channel).  alpha_n = A_n / sum_i A_i is the selection
weight: devices skipped for longer carry fresher/more informative updates and
get prioritized.
"""
from __future__ import annotations

import dataclasses
import numpy as np

__all__ = ["AoUState", "init_aou", "step_aou", "aou_weights"]


@dataclasses.dataclass
class AoUState:
    """Age-of-Update state: per-device rounds since last update (eq. 6).

    `age[n]` is A_n >= 1; `weights` exposes the normalized alpha_n of
    eq. (7) used by the eq.-43 selection priority."""

    age: np.ndarray  # (N,) int64, A_n >= 1

    @property
    def weights(self) -> np.ndarray:
        """alpha_n of eq. (7)."""
        return self.age.astype(np.float64) / float(self.age.sum())


def init_aou(n_devices: int) -> AoUState:
    """All devices start with age 1 (every update equally fresh at t=1)."""
    return AoUState(age=np.ones(n_devices, dtype=np.int64))


def step_aou(state: AoUState, transmitted: np.ndarray) -> AoUState:
    """Eq. (6).  `transmitted[n] = S_n * sum_k psi_{k,n}` for the round just
    finished: 1 iff device n was selected *and* assigned a sub-channel (and
    hence its local model reached the server)."""
    transmitted = np.asarray(transmitted).astype(bool)
    if transmitted.shape != state.age.shape:
        raise ValueError("transmitted mask has wrong shape")
    new_age = np.where(transmitted, 1, state.age + 1)
    return AoUState(age=new_age.astype(np.int64))


def aou_weights(state: AoUState) -> np.ndarray:
    """Normalized AoU weights alpha_n = A_n / sum_i A_i (eq. 7)."""
    return state.weights
