"""Algorithm 3: AoU-based device selection (paper Sec. V) + the benchmark
selection schemes of Sec. VI.

The leader (server) reformulates global-loss minimization as the weighted
selection problem (eq. 42): maximize sum_n alpha_n beta_n S_n sum_k psi_kn.
Devices are ranked by priority alpha_n * beta_n (eq. 43); the top-K are
proposed, the follower's sub-channel assignment is *predicted*, and any
device that cannot be assigned a feasible sub-channel is replaced by the
next unselected device in the priority list until either all K sub-channels
carry a transmitting device or the list is exhausted.
"""
from __future__ import annotations

import dataclasses
import numpy as np

from .matching import MatchResult, swap_matching, random_assignment, U_MAX

__all__ = [
    "SelectionOutcome",
    "priority_list",
    "select_aou_alg3",
    "select_topk",
    "select_random",
    "select_cluster",
    "select_fixed",
]


@dataclasses.dataclass(frozen=True)
class SelectionOutcome:
    """One device-selection decision: the leader's set S_n plus the
    predicted follower matching over it (Algorithm 3 / Sec.-VI schemes)."""

    selected: np.ndarray          # (N,) bool, S_n
    channel_of: np.ndarray        # (N,) int, assigned sub-channel or -1
    transmitted: np.ndarray       # (N,) bool, S_n * sum_k psi_kn == 1 AND feasible
    match: MatchResult | None     # final follower matching (over the selected set)
    selected_ids: np.ndarray      # (n_sel,) device ids in matching order
    iterations: int               # Algorithm-3 replacement iterations


def priority_list(alpha: np.ndarray, beta: np.ndarray) -> np.ndarray:
    """Eq. (43): device ids sorted by alpha_n * beta_n, descending.

    Ties broken by device id for determinism (stable sort on -priority).
    """
    prio = np.asarray(alpha, np.float64) * np.asarray(beta, np.float64)
    return np.argsort(-prio, kind="stable")


def _assign(gamma, feasible, ids, sa, rng, assign_perm=None):
    """Run the follower's sub-channel assignment over the candidate set.

    `assign_perm` optionally injects the K-permutation used as the initial
    matching (M-SA) or the assignment itself (R-SA) in place of an `rng`
    draw, so the host loop and the scan engine share one randomness stream
    (DESIGN.md §8).  Within an Algorithm-3 replacement loop the same
    injected permutation is reused for every iteration — a documented
    deviation from the legacy per-iteration draw.
    """
    sub_gamma = gamma[:, ids]
    sub_feas = feasible[:, ids]
    n_sel = len(ids)
    initial = None if assign_perm is None else np.asarray(assign_perm)[:n_sel]
    if sa == "matching":
        return swap_matching(sub_gamma, sub_feas, rng, initial=initial)
    elif sa == "random":
        return random_assignment(sub_gamma, sub_feas, rng, perm=assign_perm)
    raise ValueError(f"unknown sub-channel assignment scheme: {sa}")


def _finalize(n, ids, match: MatchResult, iterations: int) -> SelectionOutcome:
    selected = np.zeros(n, dtype=bool)
    channel_of = np.full(n, -1, dtype=np.int64)
    transmitted = np.zeros(n, dtype=bool)
    selected[ids] = True
    channel_of[ids] = np.where(match.feasible, match.assignment, -1)
    transmitted[ids] = match.feasible
    return SelectionOutcome(
        selected=selected,
        channel_of=channel_of,
        transmitted=transmitted,
        match=match,
        selected_ids=ids,
        iterations=iterations,
    )


def select_aou_alg3(
    alpha: np.ndarray,
    beta: np.ndarray,
    gamma: np.ndarray,
    feasible: np.ndarray,
    rng: np.random.Generator,
    *,
    sa: str = "matching",
    max_iter: int | None = None,
    assign_perm: np.ndarray | None = None,
) -> SelectionOutcome:
    """The proposed scheme: Algorithm 3 with follower prediction.

    Args:
      gamma:    (K, N) minimum-time matrix over ALL devices (Algorithm 1).
      feasible: (K, N) Proposition-1 feasibility over all devices.
    """
    k, n = gamma.shape
    order = priority_list(alpha, beta)
    n_take = min(k, n)
    ids = list(order[:n_take])
    next_ptr = n_take
    max_iter = n if max_iter is None else max_iter

    it = 0
    while True:
        it += 1
        match = _assign(gamma, feasible, np.asarray(ids), sa, rng, assign_perm)
        unfeas = [i for i, ok in enumerate(match.feasible) if not ok]
        # Paper line 6: stop when every sub-channel carries a transmitting
        # device, or the priority list is exhausted.
        if not unfeas or next_ptr >= n or it >= max_iter:
            break
        replaced = False
        for i in unfeas:
            if next_ptr >= n:
                break
            ids[i] = order[next_ptr]      # lines 9-10: replace with next in Q
            next_ptr += 1
            replaced = True
        if not replaced:
            break
    return _finalize(n, np.asarray(ids), match, it)


def select_topk(
    alpha, beta, gamma, feasible, rng, *, sa: str = "matching",
    assign_perm: np.ndarray | None = None,
) -> SelectionOutcome:
    """"AoU based DS" benchmark: top-K of eq. (43), no replacement loop."""
    k, n = gamma.shape
    ids = priority_list(alpha, beta)[: min(k, n)]
    match = _assign(gamma, feasible, ids, sa, rng, assign_perm)
    return _finalize(n, ids, match, 1)


def select_random(gamma, feasible, rng, *, sa: str = "matching",
                  sel_perm: np.ndarray | None = None,
                  assign_perm: np.ndarray | None = None) -> SelectionOutcome:
    """Random DS benchmark: K devices uniformly at random.

    `sel_perm` optionally injects the device permutation (scan-engine
    stream sharing, DESIGN.md §8)."""
    k, n = gamma.shape
    perm = rng.permutation(n) if sel_perm is None else np.asarray(sel_perm)
    ids = perm[: min(k, n)]
    match = _assign(gamma, feasible, ids, sa, rng, assign_perm)
    return _finalize(n, ids, match, 1)


def select_cluster(
    gamma, feasible, rng, round_idx: int, clusters: np.ndarray, *,
    sa: str = "matching", assign_perm: np.ndarray | None = None,
) -> SelectionOutcome:
    """Cluster-based DS: devices pre-partitioned into ceil(N/K) clusters,
    clusters selected in rotation."""
    k, n = gamma.shape
    n_clusters = int(clusters.max()) + 1
    ids = np.where(clusters == (round_idx % n_clusters))[0][: min(k, n)]
    match = _assign(gamma, feasible, ids, sa, rng, assign_perm)
    return _finalize(n, ids, match, 1)


def select_fixed(gamma, feasible, rng, fixed_ids: np.ndarray, *,
                 sa: str = "matching",
                 assign_perm: np.ndarray | None = None) -> SelectionOutcome:
    """Fixed DS: the same K devices every round."""
    match = _assign(gamma, feasible, np.asarray(fixed_ids), sa, rng, assign_perm)
    return _finalize(gamma.shape[1], np.asarray(fixed_ids), match, 1)
