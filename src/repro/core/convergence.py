"""Proposition 3: upper bound on the FedAvg convergence gap under partial
participation (paper Sec. V, eq. 40).

    E[F(w^{t+1}) - F(w*)] <= (1 - mu/L)^t E[F(w^1) - F(w*)]
        + (2 rho / L) sum_{i=1}^t (1 - mu/L)^{t-i}
            * ||grad F(w^i)||^2 / (sum_n beta_n)
            * sum_n beta_n (1 - S_n^i sum_k psi_kn^i)

The learning plane records ||grad F||^2 and the transmitted masks each round;
this module evaluates the bound so tests/benchmarks can check that the
*measured* gap stays below it (for strongly-convex objectives) and that
selecting more data per round tightens it.
"""
from __future__ import annotations

import numpy as np

__all__ = ["convergence_bound", "participation_deficit"]


def participation_deficit(beta: np.ndarray, transmitted: np.ndarray) -> float:
    """sum_n beta_n (1 - S_n sum_k psi_kn)  -- the data left out this round."""
    beta = np.asarray(beta, np.float64)
    tx = np.asarray(transmitted).astype(np.float64)
    return float((beta * (1.0 - tx)).sum())


def convergence_bound(
    gap0: float,
    grad_sq_norms: np.ndarray,
    deficits: np.ndarray,
    beta_total: float,
    *,
    mu: float,
    lips: float,
    rho: float,
) -> np.ndarray:
    """Evaluate eq. (40) for every round t = 1..T.

    Args:
      gap0: E[F(w^1) - F(w*)].
      grad_sq_norms: (T,) ||grad F(w^i)||^2 for i = 1..T.
      deficits: (T,) participation deficits per round.
      beta_total: sum_n beta_n.
      mu, lips, rho: strong-convexity, Lipschitz, gradient-diversity constants.

    Returns:
      (T,) bound on E[F(w^{t+1}) - F(w*)].
    """
    grad_sq_norms = np.asarray(grad_sq_norms, np.float64)
    deficits = np.asarray(deficits, np.float64)
    t_max = grad_sq_norms.shape[0]
    r = 1.0 - mu / lips
    if not (0.0 <= r < 1.0):
        raise ValueError("need 0 < mu <= L")
    bounds = np.empty(t_max)
    acc = 0.0
    for t in range(t_max):
        # acc = sum_{i<=t} r^{t-i} * term_i, built incrementally.
        acc = r * acc + grad_sq_norms[t] * deficits[t] / beta_total
        bounds[t] = (r ** (t + 1)) * gap0 + (2.0 * rho / lips) * acc
    return bounds
