"""Algorithm 2: matching-based sub-channel assignment (paper Sec. IV-B).

One-to-one matching between the selected device set N_t and the K
sub-channels.  Utilities come from the minimum-time matrix Gamma produced by
Algorithm 1; infeasible (device, channel) combinations (Proposition 1) carry
the sentinel utility U_max, giving players *incomplete preference lists*.
Devices repeatedly propose pairwise swaps; a swap is executed iff it is a
swap-blocking pair (Definition 2: neither involved device's utility rises and
at least one strictly falls).  Termination at a two-sided exchange-stable
matching (Definition 3) is guaranteed because the total utility strictly
decreases with every executed swap and the matching space is finite.
"""
from __future__ import annotations

import dataclasses
import numpy as np

__all__ = ["MatchResult", "swap_matching", "random_assignment", "U_MAX", "is_two_sided_exchange_stable"]

U_MAX = 1e30  # sentinel utility for infeasible pairs (eq. 30)


@dataclasses.dataclass(frozen=True)
class MatchResult:
    """assignment[i] = sub-channel of the i-th selected device."""

    assignment: np.ndarray   # (n_sel,) int, channel index per device
    utilities: np.ndarray    # (n_sel,) float, Gamma[assignment[i], i] or U_MAX
    feasible: np.ndarray     # (n_sel,) bool: assigned to a *feasible* channel
    n_swaps: int
    n_rounds: int


def _utilities(gamma_u: np.ndarray, assignment: np.ndarray) -> np.ndarray:
    return gamma_u[assignment, np.arange(assignment.shape[0])]


def prepare_utility(gamma: np.ndarray, feasible: np.ndarray) -> np.ndarray:
    """Eq. (30): U = Gamma where feasible, U_max otherwise."""
    gamma_u = np.where(feasible, gamma, U_MAX)
    # Guard: any non-finite time is treated as infeasible too.
    return np.where(np.isfinite(gamma_u), gamma_u, U_MAX)


def swap_matching(
    gamma: np.ndarray,
    feasible: np.ndarray,
    rng: np.random.Generator | None = None,
    *,
    initial: np.ndarray | None = None,
    max_rounds: int = 200,
) -> MatchResult:
    """Run Algorithm 2.

    Args:
      gamma:    (K, n_sel) minimum-time matrix from Algorithm 1.
      feasible: (K, n_sel) Proposition-1 mask.
      rng:      used only for the random initial matching (paper line 2).
      initial:  optional explicit initial assignment (for tests).
    """
    k, n_sel = gamma.shape
    if n_sel > k:
        raise ValueError(f"cannot match {n_sel} devices to {k} sub-channels")
    gamma_u = prepare_utility(gamma, feasible)

    if initial is not None:
        assignment = np.asarray(initial, dtype=np.int64).copy()
    else:
        rng = np.random.default_rng(0) if rng is None else rng
        assignment = rng.permutation(k)[:n_sel].astype(np.int64)

    n_swaps = 0
    for rnd in range(max_rounds):
        swapped_this_round = False
        for n in range(n_sel):           # active device (paper line 4)
            for n2 in range(n_sel):      # proposal target (paper line 5)
                if n2 == n:
                    continue
                ch_n, ch_n2 = assignment[n], assignment[n2]
                u_n, u_n2 = gamma_u[ch_n, n], gamma_u[ch_n2, n2]
                u_n_new, u_n2_new = gamma_u[ch_n2, n], gamma_u[ch_n, n2]
                # Definition 2: swap-blocking pair.
                if (
                    u_n_new <= u_n
                    and u_n2_new <= u_n2
                    and (u_n_new < u_n or u_n2_new < u_n2)
                ):
                    assignment[n], assignment[n2] = ch_n2, ch_n
                    n_swaps += 1
                    swapped_this_round = True
        if not swapped_this_round:       # full round without a blocking pair
            break
    utils = _utilities(gamma_u, assignment)
    return MatchResult(
        assignment=assignment,
        utilities=utils,
        feasible=utils < U_MAX,
        n_swaps=n_swaps,
        n_rounds=rnd + 1,
    )


def is_two_sided_exchange_stable(gamma_u: np.ndarray, assignment: np.ndarray) -> bool:
    """Definition 3 checker (used by property tests): no swap-blocking pair."""
    n_sel = assignment.shape[0]
    for n in range(n_sel):
        for n2 in range(n_sel):
            if n2 == n:
                continue
            u_n = gamma_u[assignment[n], n]
            u_n2 = gamma_u[assignment[n2], n2]
            u_n_new = gamma_u[assignment[n2], n]
            u_n2_new = gamma_u[assignment[n], n2]
            if u_n_new <= u_n and u_n2_new <= u_n2 and (u_n_new < u_n or u_n2_new < u_n2):
                return False
    return True


def random_assignment(
    gamma: np.ndarray, feasible: np.ndarray, rng: np.random.Generator
) -> MatchResult:
    """R-SA baseline (Sec. VI): a uniformly random one-to-one assignment."""
    k, n_sel = gamma.shape
    gamma_u = prepare_utility(gamma, feasible)
    assignment = rng.permutation(k)[:n_sel].astype(np.int64)
    utils = _utilities(gamma_u, assignment)
    return MatchResult(
        assignment=assignment,
        utilities=utils,
        feasible=utils < U_MAX,
        n_swaps=0,
        n_rounds=0,
    )
