"""Algorithm 2: matching-based sub-channel assignment (paper Sec. IV-B).

One-to-one matching between the selected device set N_t and the K
sub-channels.  Utilities come from the minimum-time matrix Gamma produced by
Algorithm 1; infeasible (device, channel) combinations (Proposition 1) carry
the sentinel utility U_max, giving players *incomplete preference lists*.
Devices repeatedly propose pairwise swaps; a swap is executed iff it is a
swap-blocking pair (Definition 2: neither involved device's utility rises and
at least one strictly falls).  Termination at a two-sided exchange-stable
matching (Definition 3) is guaranteed because the total utility strictly
decreases with every executed swap and the matching space is finite.

`swap_matching` finds each blocking pair with a vectorized pairwise
utility-delta formulation — the full n x n blocking matrix is evaluated with
numpy broadcasting and the lexicographically first blocking pair is executed
— so the interpreter cost is O(#swaps), not O(rounds * n^2) as in the
textbook triple loop (kept as `swap_matching_loop`, the reference
implementation; both terminate at a 2ES matching and the tests pin their
agreement on total utility).  DESIGN.md §7.
"""
from __future__ import annotations

import dataclasses
import numpy as np

__all__ = [
    "MatchResult",
    "swap_matching",
    "swap_matching_loop",
    "random_assignment",
    "U_MAX",
    "is_two_sided_exchange_stable",
]

U_MAX = 1e30  # sentinel utility for infeasible pairs (eq. 30)


@dataclasses.dataclass(frozen=True)
class MatchResult:
    """assignment[i] = sub-channel of the i-th selected device."""

    assignment: np.ndarray   # (n_sel,) int, channel index per device
    utilities: np.ndarray    # (n_sel,) float, Gamma[assignment[i], i] or U_MAX
    feasible: np.ndarray     # (n_sel,) bool: assigned to a *feasible* channel
    n_swaps: int
    n_rounds: int


def _utilities(gamma_u: np.ndarray, assignment: np.ndarray) -> np.ndarray:
    return gamma_u[assignment, np.arange(assignment.shape[0])]


def prepare_utility(gamma: np.ndarray, feasible: np.ndarray) -> np.ndarray:
    """Eq. (30): U = Gamma where feasible, U_max otherwise."""
    gamma_u = np.where(feasible, gamma, U_MAX)
    # Guard: any non-finite time is treated as infeasible too.
    return np.where(np.isfinite(gamma_u), gamma_u, U_MAX)


def _initial_assignment(rng, initial, k, n_sel):
    if initial is not None:
        return np.asarray(initial, dtype=np.int64).copy()
    rng = np.random.default_rng(0) if rng is None else rng
    return rng.permutation(k)[:n_sel].astype(np.int64)


def swap_matching(
    gamma: np.ndarray,
    feasible: np.ndarray,
    rng: np.random.Generator | None = None,
    *,
    initial: np.ndarray | None = None,
    max_rounds: int = 200,
) -> MatchResult:
    """Run Algorithm 2 (vectorized pairwise utility-delta formulation).

    Each iteration evaluates every candidate swap at once: with the current
    assignment, A[i, j] = U[channel_of(i), j] is the utility device j would
    get from device i's channel, so the Definition-2 blocking condition for
    the ordered pair (n, n2) is

        A.T <= u[:, None]  &  A <= u[None, :]  &  (one strict)

    evaluated as three broadcast comparisons.  The scan cursor replicates the
    reference proposal order of `swap_matching_loop` exactly — the first
    blocking pair at or after the cursor is executed and the cursor advances
    past it, wrapping into a new round like the reference's nested loops —
    so both implementations terminate at the *same* 2ES matching.  The
    Python interpreter does O(1) work per executed swap (plus one per round)
    instead of O(n^2) per scan.

    Args:
      gamma:    (K, n_sel) minimum-time matrix from Algorithm 1.
      feasible: (K, n_sel) Proposition-1 mask.
      rng:      used only for the random initial matching (paper line 2).
      initial:  optional explicit initial assignment (for tests).
      max_rounds: bound on full proposal rounds (same meaning as the
        reference; a generous convergence guard, not a tuning knob).
    """
    k, n_sel = gamma.shape
    if n_sel > k:
        raise ValueError(f"cannot match {n_sel} devices to {k} sub-channels")
    gamma_u = prepare_utility(gamma, feasible)
    assignment = _initial_assignment(rng, initial, k, n_sel)

    n_swaps = 0
    n_rounds = 0
    cursor = 0                       # flat (n, n2) scan position, row-major
    swapped_this_round = False
    dev = np.arange(n_sel)
    nn = n_sel * n_sel
    while n_rounds < max_rounds:
        u = gamma_u[assignment, dev]                 # (n_sel,)
        a = gamma_u[assignment]                      # A[i, j] = U[ch_i, j]
        no_worse_n = a.T <= u[:, None]               # device n on n2's channel
        no_worse_n2 = a <= u[None, :]                # device n2 on n's channel
        strict = (a.T < u[:, None]) | (a < u[None, :])
        blocking = no_worse_n & no_worse_n2 & strict
        np.fill_diagonal(blocking, False)
        ahead = np.flatnonzero(blocking.ravel()[cursor:])
        if ahead.size:
            q = cursor + int(ahead[0])
            n, n2 = divmod(q, n_sel)
            assignment[n], assignment[n2] = assignment[n2], assignment[n]
            n_swaps += 1
            swapped_this_round = True
            cursor = q + 1
            if cursor < nn:
                continue
        # Reached the end of a full proposal round.
        n_rounds += 1
        if not swapped_this_round:   # full round without a blocking pair
            break
        cursor = 0
        swapped_this_round = False
    utils = _utilities(gamma_u, assignment)
    return MatchResult(
        assignment=assignment,
        utilities=utils,
        feasible=utils < U_MAX,
        n_swaps=n_swaps,
        n_rounds=n_rounds,
    )


def swap_matching_loop(
    gamma: np.ndarray,
    feasible: np.ndarray,
    rng: np.random.Generator | None = None,
    *,
    initial: np.ndarray | None = None,
    max_rounds: int = 200,
) -> MatchResult:
    """Reference Algorithm 2: the paper's literal proposal loop (kept for
    equivalence tests against the vectorized `swap_matching`)."""
    k, n_sel = gamma.shape
    if n_sel > k:
        raise ValueError(f"cannot match {n_sel} devices to {k} sub-channels")
    gamma_u = prepare_utility(gamma, feasible)
    assignment = _initial_assignment(rng, initial, k, n_sel)

    n_swaps = 0
    rnd = -1                             # stays -1 when max_rounds == 0
    for rnd in range(max_rounds):
        swapped_this_round = False
        for n in range(n_sel):           # active device (paper line 4)
            for n2 in range(n_sel):      # proposal target (paper line 5)
                if n2 == n:
                    continue
                ch_n, ch_n2 = assignment[n], assignment[n2]
                u_n, u_n2 = gamma_u[ch_n, n], gamma_u[ch_n2, n2]
                u_n_new, u_n2_new = gamma_u[ch_n2, n], gamma_u[ch_n, n2]
                # Definition 2: swap-blocking pair.
                if (
                    u_n_new <= u_n
                    and u_n2_new <= u_n2
                    and (u_n_new < u_n or u_n2_new < u_n2)
                ):
                    assignment[n], assignment[n2] = ch_n2, ch_n
                    n_swaps += 1
                    swapped_this_round = True
        if not swapped_this_round:       # full round without a blocking pair
            break
    utils = _utilities(gamma_u, assignment)
    return MatchResult(
        assignment=assignment,
        utilities=utils,
        feasible=utils < U_MAX,
        n_swaps=n_swaps,
        n_rounds=rnd + 1,
    )


def is_two_sided_exchange_stable(gamma_u: np.ndarray, assignment: np.ndarray) -> bool:
    """Definition 3 checker (used by property tests): no swap-blocking pair."""
    n_sel = assignment.shape[0]
    for n in range(n_sel):
        for n2 in range(n_sel):
            if n2 == n:
                continue
            u_n = gamma_u[assignment[n], n]
            u_n2 = gamma_u[assignment[n2], n2]
            u_n_new = gamma_u[assignment[n2], n]
            u_n2_new = gamma_u[assignment[n], n2]
            if u_n_new <= u_n and u_n2_new <= u_n2 and (u_n_new < u_n or u_n2_new < u_n2):
                return False
    return True


def random_assignment(
    gamma: np.ndarray,
    feasible: np.ndarray,
    rng: np.random.Generator,
    *,
    perm: np.ndarray | None = None,
) -> MatchResult:
    """R-SA baseline (Sec. VI): a uniformly random one-to-one assignment.

    `perm` optionally injects the K-permutation instead of drawing it from
    `rng` — the scan engine pre-samples per-round permutations so both
    engines consume one stream (DESIGN.md §8).
    """
    k, n_sel = gamma.shape
    gamma_u = prepare_utility(gamma, feasible)
    if perm is None:
        perm = rng.permutation(k)
    assignment = np.asarray(perm)[:n_sel].astype(np.int64)
    utils = _utilities(gamma_u, assignment)
    return MatchResult(
        assignment=assignment,
        utilities=utils,
        feasible=utils < U_MAX,
        n_swaps=0,
        n_rounds=0,
    )
