"""Wireless system model for FLOWN (paper Sec. II, eqs. 1-10).

Implements the computation model (DVFS CPU time/energy), the communication
model (Shannon rate over sub-channels with Rayleigh small-scale fading and
power-law path loss), and per-round channel realizations.

This is the *control plane* of the framework: it runs on the server between
training rounds (the paper notes server compute is free, Sec. III-3).  All
model functions are *backend-agnostic*: they dispatch to numpy or jax.numpy
based on their array arguments (DESIGN.md §6), so the same closed forms back
both the host-side reference solver (`core.monotonic`) and the jitted /
Pallas device solver (`core.monotonic_jax`, `kernels.polyblock_project`).
Vectorized over (K sub-channels x N devices) — or (rounds x K x N) for the
whole-horizon batched path — a full round's model evaluates in microseconds.
"""
from __future__ import annotations

import dataclasses
import numpy as np

try:  # The learning plane requires JAX; the control plane merely exploits it.
    import jax
    import jax.numpy as jnp
except ImportError:  # pragma: no cover - jax is baked into the image
    jax = None
    jnp = None

__all__ = [
    "WirelessConfig",
    "Topology",
    "sample_topology",
    "sample_channel_gains",
    "compute_time",
    "compute_energy",
    "comm_rate",
    "comm_time",
    "comm_energy",
    "total_time",
    "total_energy",
]


@dataclasses.dataclass(frozen=True)
class WirelessConfig:
    """Table I of the paper (defaults reproduce the MNIST setting)."""

    n_devices: int = 20              # N
    n_subchannels: int = 4           # K
    bandwidth_hz: float = 1e6        # B, per sub-channel
    pt_dbm: float = 10.0             # maximum transmit power P_t per sub-channel
    noise_dbm_per_hz: float = -174.0  # AWGN PSD sigma^2 (per Hz)
    carrier_hz: float = 1e9          # f, for the frequency-dependent factor eta
    pathloss_exp: float = 3.76       # a
    radius_m: float = 500.0          # disc radius R
    kappa0: float = 1e-28            # CPU power coefficient per cycle
    mu_cycles: float = 1e7           # CPU cycles per training sample
    cpu_hz: float = 1e9              # C_n (homogeneous default; can be per-device)
    model_bits: float = 1e6          # D(w) uplink payload in bits
    e_max_j: float = 0.02            # per-round energy budget E_n^max
    min_dist_m: float = 1.0          # physical path-loss floor (d >= this)

    def __post_init__(self):
        if not self.min_dist_m > 0.0:
            raise ValueError(
                f"min_dist_m must be > 0 (the eq.-3 path loss d^-a diverges "
                f"at d=0), got {self.min_dist_m}")

    @property
    def pt_w(self) -> float:
        return 10.0 ** (self.pt_dbm / 10.0) * 1e-3

    @property
    def noise_w(self) -> float:
        # PSD (dBm/Hz) integrated over the sub-channel bandwidth.
        return 10.0 ** (self.noise_dbm_per_hz / 10.0) * 1e-3 * self.bandwidth_hz

    @property
    def eta(self) -> float:
        """Frequency-dependent factor: free-space reference gain (c/4/pi/f)^2."""
        c = 3e8
        return (c / (4.0 * np.pi * self.carrier_hz)) ** 2


@dataclasses.dataclass(frozen=True)
class Topology:
    """Static device placement: distances to the server (paper: uniform disc)."""

    distances_m: np.ndarray  # (N,)

    @property
    def n_devices(self) -> int:
        return int(self.distances_m.shape[0])


def sample_topology(rng: np.random.Generator, cfg: WirelessConfig) -> Topology:
    """Devices uniform on a disc of radius R centred at the server."""
    # Uniform area density => r = R * sqrt(u).
    r = cfg.radius_m * np.sqrt(rng.uniform(size=cfg.n_devices))
    # Keep a minimum distance so the path loss stays physical.
    return Topology(distances_m=np.maximum(r, cfg.min_dist_m))


def sample_channel_gains(
    rng: np.random.Generator, cfg: WirelessConfig, topo: Topology
) -> np.ndarray:
    """Normalized channel gains |h_{k,n}|^2 of eq. (3), shape (K, N).

    |h|^2 = P_t * |g|^2 * eta * d^-a / sigma^2  with g ~ CN(0,1) i.i.d. per
    (sub-channel, device, round) -- Rayleigh => |g|^2 ~ Exp(1).
    """
    g2 = rng.exponential(size=(cfg.n_subchannels, topo.n_devices))
    path = cfg.eta * topo.distances_m[None, :] ** (-cfg.pathloss_exp)
    return cfg.pt_w * g2 * path / cfg.noise_w


# --------------------------------------------------------------------------
# Backend dispatch: numpy by default, jax.numpy when any argument is a JAX
# array (incl. tracers inside jit). numpy inputs are promoted to float64;
# JAX inputs keep their dtype (float64 under an enable_x64 scope).
# --------------------------------------------------------------------------

def _xp(*args):
    if jnp is not None and any(isinstance(a, jax.Array) for a in args):
        return jnp
    return np


def _asfloat(xp, x):
    return np.asarray(x, dtype=np.float64) if xp is np else jnp.asarray(x)


# --------------------------------------------------------------------------
# Computation model, eqs. (1)-(2).
# --------------------------------------------------------------------------

def compute_time(tau, beta, cfg: WirelessConfig):
    """T^cp = mu * beta / (tau * C)  (eq. 1)."""
    xp = _xp(tau, beta)
    tau = _asfloat(xp, tau)
    return cfg.mu_cycles * _asfloat(xp, beta) / xp.maximum(tau, 1e-30) / cfg.cpu_hz


def compute_energy(tau, beta, cfg: WirelessConfig):
    """E^cp = kappa0 * mu * beta * (tau*C)^2  (eq. 2)."""
    xp = _xp(tau, beta)
    tau = _asfloat(xp, tau)
    return cfg.kappa0 * cfg.mu_cycles * _asfloat(xp, beta) * (tau * cfg.cpu_hz) ** 2


# --------------------------------------------------------------------------
# Communication model, eqs. (3)-(5).
# --------------------------------------------------------------------------

def comm_rate(p, h2, cfg: WirelessConfig):
    """R = B log2(1 + p |h|^2)  (eq. 3), bits/s.  log1p for precision at
    vanishing SNR (the Prop-1 infimum regime)."""
    xp = _xp(p, h2)
    p = _asfloat(xp, p)
    return cfg.bandwidth_hz * xp.log1p(p * _asfloat(xp, h2)) / np.log(2.0)


def comm_time(p, h2, cfg: WirelessConfig):
    """T^cm = D(w) / R  (eq. 4)."""
    r = comm_rate(p, h2, cfg)
    return cfg.model_bits / _xp(p, h2).maximum(r, 1e-30)


def comm_energy(p, h2, cfg: WirelessConfig):
    """E^cm = p * P_t * T^cm  (eq. 5).

    Note the paper's convention: p in [0,1] is the *fraction* of P_t used;
    |h|^2 is already normalized by P_t / sigma^2.
    """
    p = _asfloat(_xp(p, h2), p)
    return p * cfg.pt_w * comm_time(p, h2, cfg)


# --------------------------------------------------------------------------
# Totals, eqs. (8) and (10).
# --------------------------------------------------------------------------

def total_time(tau, p, beta, h2, cfg: WirelessConfig):
    """Per-round device time T = T^cp + T^cm (eq. 8): local compute at CPU
    share tau plus uplink at power fraction p over channel gain h2."""
    return compute_time(tau, beta, cfg) + comm_time(p, h2, cfg)


def total_energy(tau, p, beta, h2, cfg: WirelessConfig):
    """Per-round device energy E = E^cp + E^cm (eq. 10), the Prop.-1 /
    Alg.-1 budget constraint left-hand side (E <= E^max)."""
    return compute_energy(tau, beta, cfg) + comm_energy(p, h2, cfg)
