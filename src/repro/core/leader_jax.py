"""Device-resident leader plane: Algorithms 2-3 + AoU as pure jnp (DESIGN.md §8).

The host leader (`aou` / `selection` / `matching`) re-enters Python every
round; this module ports the whole per-round Stackelberg leader step to
fixed-shape jax.numpy so `fl.sim` can fuse it with the learning plane inside
one `lax.scan` over rounds (and `vmap` it across seeds):

  * AoU update (eq. 6) — a `where` over the age vector;
  * priority list (eq. 43) — stable argsort of age_n * beta_n (the positive
    normalizer sum_i A_i divides out of eq. 7, so integer-exact products
    replace the host's alpha_n * beta_n without reordering anything);
  * Algorithm 3 — a `lax.while_loop` over a FIXED-SIZE id buffer of
    S = min(K, N) slots: each iteration re-matches the candidate buffer,
    then replaces the j-th infeasible slot with `order[next_ptr + j]` via a
    cumsum-indexed masked gather (the host's sequential "next unselected in
    Q" walk, vectorized);
  * Algorithm 2 — a `lax.while_loop` over the S x S utility-delta blocking
    matrix with the host implementation's scan-cursor proposal order, so
    both terminate at the *same* two-sided exchange-stable matching;
  * the benchmark schemes (top-K / random / cluster / fixed DS, R-SA).

Candidate buffers are padded to S with invalid slots (cluster DS selects a
variable-size rotation class): pad slots carry U_MAX utilities and are
masked out of the blocking matrix, so real devices can neither swap with a
pad nor grab its channel — exactly the host semantics where unassigned
sub-channels are simply absent from the proposal loop.  Randomness is
INJECTED, not drawn: callers pass per-round permutations (`sel_perm` for
random DS, `assign_perm` for the initial matching / R-SA) pre-sampled on the
host, so the scan engine and the host loop consume the identical stream and
the differential harness (tests/test_scan_equivalence.py) can pin exact
transmitted-set / AoU equivalence.  See DESIGN.md §8 for the documented
RNG-stream deviation from the legacy `np.random.Generator` path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .matching import U_MAX

__all__ = [
    "prepare_utility_jnp",
    "step_age",
    "priority_order",
    "swap_matching_jnp",
    "leader_round",
]


def prepare_utility_jnp(gamma, feasible):
    """Eq. (30): U = Gamma where feasible, U_max otherwise (jnp mirror)."""
    gamma_u = jnp.where(feasible, gamma, U_MAX)
    return jnp.where(jnp.isfinite(gamma_u), gamma_u, U_MAX)


def step_age(age, transmitted):
    """Eq. (6): transmitted devices reset to 1, everyone else ages by 1."""
    return jnp.where(transmitted, 1, age + 1).astype(age.dtype)


def priority_order(age, beta):
    """Eq. (43) order: ids sorted by alpha_n * beta_n descending, ties by id.

    alpha_n = A_n / sum_i A_i (eq. 7); the normalizer is a positive constant
    across n, so sorting A_n * beta_n is order-identical — and exact in
    float32 for the integer ages/data-sizes of the simulation (products stay
    far below 2^24).  jnp argsort is stable, matching the host's
    `np.argsort(-prio, kind="stable")` tie-break.
    """
    prio = age.astype(jnp.float32) * beta.astype(jnp.float32)
    return jnp.argsort(-prio).astype(jnp.int32)


def swap_matching_jnp(gamma_u, valid, initial, *, max_rounds: int = 200):
    """Algorithm 2 over a fixed S-slot candidate buffer (jnp while_loop).

    Mirrors `matching.swap_matching`'s vectorized cursor formulation: each
    iteration evaluates the full S x S Definition-2 blocking matrix with
    three broadcast comparisons, executes the first blocking pair at or
    after the flat row-major cursor, and wraps into a new proposal round
    exactly like the reference nested loops.  `valid` masks pad slots out of
    the blocking matrix (pairs touching a pad are never blocking), so the
    real slots — always a prefix of the buffer — replay the host trajectory
    pair-for-pair and the wrap bookkeeping (which only observes blocking
    pairs) stays aligned.

    Args:
      gamma_u: (K, S) utilities, U_MAX at infeasible/pad entries.
      valid:   (S,) slot-validity mask (real device vs padding).
      initial: (S,) initial channel per slot (the injected K-permutation
        prefix; pads hold the leftover channels, which the host never
        assigns — masked swaps keep them parked there).

    Returns:
      (assignment, feasible, n_swaps, n_rounds) with assignment (S,) int32
      and feasible (S,) = assigned channel is Prop-1 feasible AND the slot
      is real.
    """
    s = gamma_u.shape[1]
    nn = s * s
    dev = jnp.arange(s)
    pos = jnp.arange(nn)
    pair_ok = (valid[:, None] & valid[None, :] & ~jnp.eye(s, dtype=bool)).ravel()

    def blocking(assignment):
        u = gamma_u[assignment, dev]                 # (S,)
        a = gamma_u[assignment]                      # A[i, j] = U[ch_i, j]
        no_worse_n = a.T <= u[:, None]
        no_worse_n2 = a <= u[None, :]
        strict = (a.T < u[:, None]) | (a < u[None, :])
        return (no_worse_n & no_worse_n2 & strict).ravel() & pair_ok

    def cond(st):
        return ~st[-1]

    def body(st):
        assignment, cursor, swapped, n_rounds, n_swaps, _ = st
        cand = blocking(assignment) & (pos >= cursor)
        has = cand.any()
        q = jnp.argmax(cand).astype(jnp.int32)       # first blocking >= cursor
        n1, n2 = q // s, q % s
        swap = assignment.at[n1].set(assignment[n2]).at[n2].set(assignment[n1])
        assignment = jnp.where(has, swap, assignment)
        n_swaps = n_swaps + has.astype(jnp.int32)
        swapped = swapped | has
        # End of a full proposal round: scanned past the last pair, or no
        # blocking pair remains ahead of the cursor.
        end = (~has) | (q + 1 >= nn)
        n_rounds = n_rounds + end.astype(jnp.int32)
        done = end & ((~swapped) | (n_rounds >= max_rounds))
        cursor = jnp.where(end, 0, q + 1)
        swapped = swapped & ~end
        return (assignment, cursor, swapped, n_rounds, n_swaps, done)

    init = (jnp.asarray(initial, jnp.int32), jnp.int32(0), jnp.bool_(False),
            jnp.int32(0), jnp.int32(0), jnp.bool_(max_rounds <= 0))
    assignment, _, _, n_rounds, n_swaps, _ = jax.lax.while_loop(cond, body, init)
    feasible = (gamma_u[assignment, dev] < U_MAX) & valid
    return assignment, feasible, n_swaps, n_rounds


def leader_round(
    age,
    beta,
    gamma,
    feasible,
    sel_perm,
    assign_perm,
    round_idx,
    clusters,
    fixed_ids,
    *,
    ds: str,
    sa: str,
    k: int,
    n: int,
    n_clusters: int = 1,
    max_rounds: int = 200,
):
    """One leader step (Algorithm 3 or a benchmark DS + Algorithm 2 or R-SA).

    Pure fixed-shape function of the round state — trace it inside
    `lax.scan` / `vmap`.  `ds`/`sa`/`k`/`n` are static.

    Args:
      age:         (N,) int AoU ages.
      beta:        (N,) data sizes.
      gamma:       (K, N) minimum-time matrix (Algorithm 1 output).
      feasible:    (K, N) Proposition-1 mask.
      sel_perm:    (N,) injected device permutation (random DS).
      assign_perm: (K,) injected channel permutation (matching init / R-SA).
      round_idx:   scalar round index (cluster rotation).
      clusters:    (N,) cluster id per device; `n_clusters` static.
      fixed_ids:   (S,) fixed DS ids, S = min(K, N).

    Returns a dict: selected/transmitted (N,) bool, channel_of (N,) int32
    (-1 where unassigned), age_next (N,), iterations (Algorithm-3 count).
    """
    s = min(k, n)
    slot = jnp.arange(s)
    gamma_u = prepare_utility_jnp(gamma, feasible)
    all_valid = jnp.ones(s, dtype=bool)

    def match(ids, valid):
        """Follower prediction over the candidate buffer."""
        ids_g = jnp.where(valid, ids, 0)
        sub = jnp.where(valid[None, :], gamma_u[:, ids_g], U_MAX)
        init = assign_perm[:s].astype(jnp.int32)
        if sa == "matching":
            assignment, feas_m, _, _ = swap_matching_jnp(
                sub, valid, init, max_rounds=max_rounds)
        else:  # R-SA: the injected permutation IS the assignment
            assignment = init
            feas_m = (sub[assignment, slot] < U_MAX) & valid
        return assignment, feas_m

    it = jnp.int32(1)
    if ds in ("alg3", "aou_topk"):
        order = priority_order(age, beta)

    if ds == "alg3":
        max_iter = n                      # host default: one pass over Q

        def a3_cond(st):
            return ~st[-1]

        def a3_body(st):
            ids, next_ptr, a3_it, _, _, _ = st
            assignment, feas_m = match(ids, all_valid)
            a3_it = a3_it + 1
            unfeas = ~feas_m
            # Paper line 6: stop when every sub-channel carries a
            # transmitting device, or Q is exhausted, or out of iterations.
            stop = (~unfeas.any()) | (next_ptr >= n) | (a3_it >= max_iter)
            # Lines 9-10: the j-th infeasible slot takes order[next_ptr + j].
            j = jnp.cumsum(unfeas.astype(jnp.int32)) - 1
            src = next_ptr + j
            take = unfeas & (src < n) & ~stop
            ids = jnp.where(take, order[jnp.clip(src, 0, n - 1)], ids)
            next_ptr = next_ptr + take.sum(dtype=jnp.int32)
            return (ids, next_ptr, a3_it, assignment, feas_m, stop)

        st0 = (order[:s], jnp.int32(s), jnp.int32(0),
               jnp.zeros(s, jnp.int32), jnp.zeros(s, bool), jnp.bool_(False))
        ids, _, it, assignment, feas_m, _ = jax.lax.while_loop(
            a3_cond, a3_body, st0)
        valid = all_valid
    elif ds == "aou_topk":
        ids, valid = order[:s], all_valid
        assignment, feas_m = match(ids, valid)
    elif ds == "random":
        ids, valid = sel_perm[:s].astype(jnp.int32), all_valid
        assignment, feas_m = match(ids, valid)
    elif ds == "cluster":
        mask = clusters == (round_idx % n_clusters)
        ids = jnp.nonzero(mask, size=s, fill_value=0)[0].astype(jnp.int32)
        valid = slot < mask.sum()
        assignment, feas_m = match(ids, valid)
    elif ds == "fixed":
        ids, valid = fixed_ids.astype(jnp.int32), all_valid
        assignment, feas_m = match(ids, valid)
    else:
        raise ValueError(f"unknown ds: {ds}")

    # ---- scatter slots back to device-indexed arrays (pads land on the
    # sacrificial row n and are sliced away). ------------------------------
    tx_slot = feas_m & valid
    ids_s = jnp.where(valid, ids, n)
    selected = jnp.zeros(n + 1, bool).at[ids_s].set(True)[:n]
    transmitted = jnp.zeros(n + 1, bool).at[ids_s].set(tx_slot)[:n]
    ch = jnp.where(tx_slot, assignment, -1)
    channel_of = jnp.full(n + 1, -1, jnp.int32).at[ids_s].set(ch)[:n]

    return {
        "selected": selected,
        "transmitted": transmitted,
        "channel_of": channel_of,
        "age_next": step_age(age, transmitted),
        "iterations": it,
    }
