"""Paper core: Stackelberg-game convergence acceleration for wireless FL.

Control-plane algorithms (all vectorized, run server-side between rounds):
  wireless      -- system model, eqs. 1-10 (np/jnp backend-agnostic)
  feasibility   -- Proposition 1 (np/jnp backend-agnostic)
  monotonic     -- Algorithm 1 (polyblock outer approximation, MO-RA)
  monotonic_jax -- Algorithm 1, jitted/batched whole-horizon port
  matching      -- Algorithm 2 (swap matching, M-SA)
  aou           -- Age-of-Update state, eqs. 6-7
  selection     -- Algorithm 3 (+ benchmark schemes)
  leader_jax    -- Algorithms 2-3 + AoU as pure jnp (scan-engine leader)
  stackelberg   -- per-round game orchestration + policy grids
  convergence   -- Proposition 3 bound

Everything re-exported here is public API with a stable signature; the
sweep harness (`repro.experiments`) and the simulation engines (`repro.fl`)
build exclusively on this surface.
"""
from .aou import AoUState, aou_weights, init_aou, step_aou
from .convergence import convergence_bound, participation_deficit
from .feasibility import feasible_mask, is_infeasible, min_comm_energy
from .matching import (
    U_MAX,
    MatchResult,
    is_two_sided_exchange_stable,
    random_assignment,
    swap_matching,
    swap_matching_loop,
)
from .leader_jax import (
    leader_round,
    prepare_utility_jnp,
    priority_order,
    step_age,
    swap_matching_jnp,
)
from .monotonic import RAResult, fixed_ra, grid_oracle, solve_pairs
from .monotonic_jax import precompute_gamma, solve_pairs_fused, solve_pairs_jit
from .selection import (
    SelectionOutcome,
    priority_list,
    select_aou_alg3,
    select_cluster,
    select_fixed,
    select_random,
    select_topk,
)
from .stackelberg import (
    DS_SCHEMES,
    PAPER_BASELINE_DS,
    RA_SCHEMES,
    SA_SCHEMES,
    RoundPlan,
    RoundPolicy,
    RoundRandomness,
    make_clusters,
    plan_round,
    policy_grid,
)
from .wireless import (
    Topology,
    WirelessConfig,
    comm_energy,
    comm_rate,
    comm_time,
    compute_energy,
    compute_time,
    sample_channel_gains,
    sample_topology,
    total_energy,
    total_time,
)

__all__ = [
    # aou (eqs. 6-7)
    "AoUState", "init_aou", "step_aou", "aou_weights",
    # convergence (Proposition 3)
    "convergence_bound", "participation_deficit",
    # feasibility (Proposition 1)
    "feasible_mask", "is_infeasible", "min_comm_energy",
    # matching (Algorithm 2)
    "U_MAX", "MatchResult", "swap_matching", "swap_matching_loop",
    "random_assignment", "is_two_sided_exchange_stable",
    # leader_jax (scan-engine leader plane)
    "leader_round", "prepare_utility_jnp", "priority_order", "step_age",
    "swap_matching_jnp",
    # monotonic / monotonic_jax (Algorithm 1)
    "RAResult", "solve_pairs", "fixed_ra", "grid_oracle",
    "solve_pairs_jit", "solve_pairs_fused", "precompute_gamma",
    # selection (Algorithm 3 + Sec.-VI benchmark schemes)
    "SelectionOutcome", "priority_list", "select_aou_alg3", "select_topk",
    "select_random", "select_cluster", "select_fixed",
    # stackelberg (round orchestration + policy grids)
    "RoundPolicy", "RoundPlan", "RoundRandomness", "plan_round",
    "make_clusters", "policy_grid",
    "DS_SCHEMES", "RA_SCHEMES", "SA_SCHEMES", "PAPER_BASELINE_DS",
    # wireless (system model, eqs. 1-10)
    "Topology", "WirelessConfig", "sample_topology", "sample_channel_gains",
    "comm_rate", "comm_time", "comm_energy", "compute_time",
    "compute_energy", "total_time", "total_energy",
]
