"""Device-resident Algorithm 1: jitted, batched polyblock outer approximation.

Port of `core.monotonic.solve_pairs` to JAX (DESIGN.md §6).  The host
implementation re-enters Python for every polyblock iteration of every
planning round; this one solves an arbitrary batch — including the
whole-horizon (rounds x K x N) Γ tensor, which `stackelberg.plan_round`
notes is selection-independent — as a sequence of jitted steps over
fixed-shape device arrays:

  verts/vproj : (rows, m, 2)  vertex set + boundary projections per pair
  vfval       : (rows, m)     f of eq. (21) at each projection
  valid/active: bool masks replacing the host path's ragged retirement

Structural optimizations over a naive port (all result-preserving — the
iteration trajectory replays the host algorithm's structure exactly, so
`iterations` matches the reference pair-for-pair):

  * feasibility pre-filter — Proposition-1 infeasible pairs (the majority at
    realistic radii) never enter the vertex store at all;
  * phase-split steps with active-set compaction — pairs retire after very
    few iterations (the empirical distribution is p50 ~ 2, max ~ 24 at
    Table-I settings), so the driver runs the cheap selection half-step,
    syncs the active mask, compacts surviving pairs into a smaller bucket,
    and only then pays for the expensive child projections.  Bucket sizes
    come from the {1, 1.25, 1.5, 1.75} x 2^k ladder so padding slack stays
    under 25% while jit caches stay warm across calls;
  * lazy vertex store — the store starts at 8 columns and doubles toward
    max_iter + 3 only for the rare stragglers, by which point compaction
    has shrunk the row count, so eq. (24)'s per-pair vertex replacement is
    a fully vectorized masked select over a narrow store (XLA CPU would
    execute a row scatter as a serial loop).

The projection (eqs. 27-29) dispatches through `kernels.polyblock_project`:
warm-started safeguarded log-space Newton ("newton", default — same root as
the reference 60-step bisection to ~1e-9 relative with 4x fewer
transcendental evaluations), exact mirrored bisection ("bisect"), or the
Pallas kernel ("pallas", default on TPU).  Everything runs float64 under a
scoped `jax.experimental.enable_x64`, so results match the NumPy path to
~1e-7 relative (1e-6 contract, tests/test_monotonic_jax.py) without
enabling x64 globally for the learning plane.

At the acceptance scale (100 rounds x K=4 x N=512 on a 2-core CPU
container) the whole-horizon solve is ~11x faster than the per-round host
loop; benchmarks/control_plane.py records the trajectory in
BENCH_control_plane.json.
"""
from __future__ import annotations

import warnings
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

# The masked-select store rewrite intentionally produces fresh buffers for
# the four (rows, m, ...) store arrays, so XLA cannot reuse their donated
# inputs and warns once per compiled bucket shape. Expected; silence it so
# every simulation run doesn't print compiler noise.
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable")

from ..kernels.polyblock_project.ops import polyblock_project
from .feasibility import is_infeasible
from .monotonic import RAResult
from .wireless import WirelessConfig, total_energy, total_time

__all__ = ["solve_pairs_jit", "precompute_gamma"]

# State tuple layout for one bucket of pairs (rows = bucket size, m = the
# current lazy vertex-slot capacity).
_BETA, _H2, _EMAX, _VERTS, _VPROJ, _VFVAL, _VALID, _ACTIVE = range(8)
_PREV, _BESTF, _BESTP, _ITERS, _NVALID, _IDX = range(8, 14)


def _bucket(n: int, lo: int = 128) -> int:
    """Smallest size in the {1, 1.25, 1.5, 1.75} x 2^k ladder that fits n:
    bounded padding slack (<= 25%), bounded number of distinct shapes for
    the jit cache."""
    b = lo
    while True:
        for quarters in (4, 5, 6, 7):
            s = (b * quarters) >> 2
            if n <= s:
                return s
        b <<= 1


def _project(v, beta, h2, e_max, cfg, backend, n_bisect):
    return polyblock_project(v, beta, h2, e_max, cfg,
                             n_bisect=n_bisect, backend=backend)


@partial(jax.jit, static_argnames=("cfg", "m", "backend", "n_bisect"))
def _init_state(beta, h2, e_max, n_real, *, cfg, m, backend, n_bisect):
    b = beta.shape[0]
    active = jnp.arange(b) < n_real
    v0 = jnp.ones((b, 2), h2.dtype)
    pj0 = _project(v0, beta, h2, e_max, cfg, backend, n_bisect)
    f0 = -total_time(pj0[:, 0], pj0[:, 1], beta, h2, cfg)
    verts = jnp.zeros((b, m, 2), h2.dtype).at[:, 0].set(v0)
    vproj = jnp.zeros((b, m, 2), h2.dtype).at[:, 0].set(pj0)
    vfval = jnp.full((b, m), -jnp.inf, h2.dtype).at[:, 0].set(f0)
    valid = jnp.zeros((b, m), bool).at[:, 0].set(True)
    return (beta, h2, e_max, verts, vproj, vfval, valid, active,
            jnp.full(b, jnp.inf, h2.dtype), f0, pj0,
            jnp.zeros(b, jnp.int32), jnp.ones(b, jnp.int32),
            jnp.zeros(b, jnp.int32))


def _select_impl(state, eps):
    """Polyblock selection half-step (paper steps 9-10): pick each pair's
    best vertex, update the incumbent, retire pairs that meet eq. (26).
    Split from the projection half so the driver can compact the active set
    *before* paying for child projections."""
    (beta, h2, e_max, verts, vproj, vfval, valid, active,
     prev_best, best_f, best_proj, iters, nvalid, _) = state

    fv = jnp.where(valid, vfval, -jnp.inf)
    idx = jnp.argmax(fv, axis=1).astype(jnp.int32)      # paper step 9
    fbest = jnp.take_along_axis(fv, idx[:, None].astype(jnp.int64), 1)[:, 0]

    improved = fbest > best_f
    sel_proj = jnp.take_along_axis(
        vproj, idx[:, None, None].astype(jnp.int64), 1)[:, 0]
    best_f = jnp.where(improved, fbest, best_f)
    best_proj = jnp.where(improved[:, None], sel_proj, best_proj)

    done = jnp.abs(fbest - prev_best) <= eps            # eq. (26)
    prev_best = fbest
    active = active & ~done
    iters = iters + active.astype(jnp.int32)

    return (beta, h2, e_max, verts, vproj, vfval, valid, active,
            prev_best, best_f, best_proj, iters, nvalid, idx)


def _children_impl(state, cfg, backend, n_bisect):
    """Polyblock refinement half-step (paper steps 11-13): split the chosen
    vertex into its two children (eq. 23), project both in one batch, and
    write them into the store (eq. 24)."""
    (beta, h2, e_max, verts, vproj, vfval, valid, active,
     prev_best, best_f, best_proj, iters, nvalid, idx) = state
    b, m = vfval.shape

    v = jnp.take_along_axis(verts, idx[:, None, None].astype(jnp.int64), 1)[:, 0]
    phi = jnp.take_along_axis(vproj, idx[:, None, None].astype(jnp.int64), 1)[:, 0]
    # Children (eq. 23): v - (v_i - phi_i) e_i, both projected in one batch.
    child1 = jnp.stack([phi[:, 0], v[:, 1]], axis=-1)
    child2 = jnp.stack([v[:, 0], phi[:, 1]], axis=-1)
    ch = jnp.concatenate([child1, child2], axis=0)
    beta2 = jnp.concatenate([beta, beta])
    h2x2 = jnp.concatenate([h2, h2])
    pj = _project(ch, beta2, h2x2, jnp.concatenate([e_max, e_max]),
                  cfg, backend, n_bisect)
    fj = -total_time(pj[:, 0], pj[:, 1], beta2, h2x2, cfg)
    pj1, pj2 = pj[:b], pj[b:]
    f1, f2 = fj[:b], fj[b:]

    # Eq. (24): child1 replaces the split vertex, child2 takes the next free
    # slot, retired rows keep their store.  Written as two masked one-hot
    # selects rather than a row scatter: XLA CPU executes scatters as a
    # serial per-row loop, while the selects fuse into one vectorized pass
    # over the store — and the store is narrow (lazy m), so the pass is
    # cheap.  The two masks are disjoint (slot idx is already valid;
    # slot nvalid is the first free one).
    cols = jnp.arange(m)
    mask1 = (cols[None, :] == idx[:, None]) & active[:, None]
    mask2 = (cols[None, :] == nvalid[:, None]) & active[:, None]
    verts = jnp.where(mask1[..., None], child1[:, None, :],
                      jnp.where(mask2[..., None], child2[:, None, :], verts))
    vproj = jnp.where(mask1[..., None], pj1[:, None, :],
                      jnp.where(mask2[..., None], pj2[:, None, :], vproj))
    vfval = jnp.where(mask1, f1[:, None],
                      jnp.where(mask2, f2[:, None], vfval))
    valid = valid | mask2
    nvalid = nvalid + active.astype(jnp.int32)

    return (beta, h2, e_max, verts, vproj, vfval, valid, active,
            prev_best, best_f, best_proj, iters, nvalid, idx)


@partial(jax.jit, static_argnames=("eps",), donate_argnums=(0,))
def _step_select(state, *, eps):
    return _select_impl(state, eps)


@partial(jax.jit, static_argnames=("cfg", "backend", "n_bisect"),
         donate_argnums=(0,))
def _step_children(state, *, cfg, backend, n_bisect):
    return _children_impl(state, cfg, backend, n_bisect)


@jax.jit
def _gather(state, idx, n_real):
    """Compact a bucket: keep rows `idx` (padded), mark padding inactive."""
    out = tuple(a[idx] for a in state)
    active = out[_ACTIVE] & (jnp.arange(idx.shape[0]) < n_real)
    return out[:_ACTIVE] + (active,) + out[_ACTIVE + 1:]


@partial(jax.jit, static_argnames=("new_m",), donate_argnums=(0,))
def _grow(state, *, new_m):
    """Append vertex-store columns (lazy capacity: the store starts at 8
    columns because pairs empirically retire after a handful of iterations,
    and grows toward max_iter + 3 only for the rare stragglers — by which
    point compaction has shrunk the row count, so the wide store is never
    paid for at full batch).  New columns carry valid=False / fval=-inf, so
    they are inert until a child is written into them."""
    (beta, h2, e_max, verts, vproj, vfval, valid, active,
     prev_best, best_f, best_proj, iters, nvalid, idx) = state
    b, m = vfval.shape
    pad = new_m - m
    verts = jnp.concatenate([verts, jnp.zeros((b, pad, 2), verts.dtype)], 1)
    vproj = jnp.concatenate([vproj, jnp.zeros((b, pad, 2), vproj.dtype)], 1)
    vfval = jnp.concatenate([vfval, jnp.full((b, pad), -jnp.inf, vfval.dtype)], 1)
    valid = jnp.concatenate([valid, jnp.zeros((b, pad), bool)], 1)
    return (beta, h2, e_max, verts, vproj, vfval, valid, active,
            prev_best, best_f, best_proj, iters, nvalid, idx)


def solve_pairs_jit(
    beta,
    h2,
    cfg: WirelessConfig,
    e_max=None,
    *,
    eps: float | None = None,
    max_iter: int = 64,
    backend: str | None = None,
    n_bisect: int = 60,
) -> RAResult:
    """Batched jitted Algorithm 1 over pairs of any shape.

    Drop-in for `monotonic.solve_pairs` (same arguments and RAResult contract,
    host numpy outputs); pass the whole-horizon (rounds x K x N) channel
    tensor to amortize a single solve over the training horizon.  backend:
    None (auto: "pallas" on TPU else "newton"), "newton", "bisect" (exact
    mirror of the host bisection), "jnp" (alias of "bisect"), or "pallas".
    n_bisect sets the bisection step count of the "bisect"/"pallas"
    projections; the "newton" backend converges by a different rule and has
    its own fixed step budget (`project_newton`'s n_steps).
    """
    h2 = np.asarray(h2, dtype=np.float64)
    shape = h2.shape
    e_max = cfg.e_max_j if e_max is None else e_max
    eps = 0.01 if eps is None else float(eps)
    if backend is None:
        backend = "pallas" if jax.default_backend() == "tpu" else "newton"
    if backend == "jnp":
        backend = "bisect"

    beta_f = np.broadcast_to(np.asarray(beta, np.float64), shape).reshape(-1)
    h2f = h2.reshape(-1)
    e_f = np.broadcast_to(np.asarray(e_max, np.float64), shape).reshape(-1)
    n = h2f.shape[0]

    feas = ~is_infeasible(h2f, cfg, e_f)
    tau = np.full(n, np.nan)
    p = np.full(n, np.nan)
    time_s = np.full(n, np.inf)
    energy = np.full(n, np.nan)
    iters_out = np.zeros(n, dtype=np.int64)

    def flush(rows_mask, row_orig, bp, bf, it):
        rows = np.where(rows_mask & (row_orig >= 0))[0]
        if rows.size == 0:
            return
        orig = row_orig[rows]
        tau[orig] = bp[rows, 0]
        p[orig] = bp[rows, 1]
        time_s[orig] = -bf[rows]
        energy[orig] = total_energy(bp[rows, 0], bp[rows, 1],
                                    beta_f[orig], h2f[orig], cfg)
        iters_out[orig] = it[rows]

    work = np.where(feas)[0]
    if work.size:
        m_full = max_iter + 3                  # all slots + one spare column
        m = min(8, m_full)                     # lazy store, grown on demand
        b = _bucket(work.size)
        pad = b - work.size
        row_orig = np.concatenate([work, np.full(pad, -1, np.int64)])
        with enable_x64():
            state = _init_state(
                jnp.asarray(np.concatenate([beta_f[work], np.ones(pad)])),
                jnp.asarray(np.concatenate([h2f[work], np.ones(pad)])),
                jnp.asarray(np.concatenate([e_f[work], np.full(pad, np.inf)])),
                jnp.int32(work.size),
                cfg=cfg, m=m, backend=backend, n_bisect=n_bisect)
            t = 0
            while t < max_iter:
                state = _step_select(state, eps=eps)
                act = np.asarray(state[_ACTIVE])
                na = int(act.sum())
                if na == 0:
                    break
                nb = _bucket(na)
                if nb < b:                     # compact BEFORE projecting
                    bp, bf, it = (np.asarray(state[_BESTP]),
                                  np.asarray(state[_BESTF]),
                                  np.asarray(state[_ITERS]))
                    flush(~act, row_orig, bp, bf, it)
                    keep = np.where(act)[0]
                    idx = np.concatenate([keep, np.zeros(nb - na, np.int64)])
                    state = _gather(state, jnp.asarray(idx), jnp.int32(na))
                    row_orig = np.concatenate(
                        [row_orig[keep], np.full(nb - na, -1, np.int64)])
                    b = nb
                if m < t + 3:                  # step t writes slot <= t+1
                    m = min(2 * m, m_full)
                    state = _grow(state, new_m=m)
                state = _step_children(state, cfg=cfg, backend=backend,
                                       n_bisect=n_bisect)
                t += 1
            bp, bf, it = (np.asarray(state[_BESTP]),
                          np.asarray(state[_BESTF]),
                          np.asarray(state[_ITERS]))
            flush(np.ones(b, bool), row_orig, bp, bf, it)

    return RAResult(
        tau=tau.reshape(shape),
        p=p.reshape(shape),
        time_s=time_s.reshape(shape),
        energy_j=energy.reshape(shape),
        feasible=feas.reshape(shape),
        iterations=iters_out.reshape(shape),
    )


def precompute_gamma(
    beta,
    h2_all,
    cfg: WirelessConfig,
    e_max=None,
    **kw,
) -> RAResult:
    """Whole-horizon Γ: solve all (round, sub-channel, device) pairs at once.

    h2_all has shape (rounds, K, N); beta broadcasts as (N,).  Returns an
    RAResult whose fields are (rounds, K, N) — Γ is `time_s`, the
    Proposition-1 mask is `feasible`.  One batched solve replaces `rounds`
    host solver invocations (speedup tracked in BENCH_control_plane.json,
    benchmarks/control_plane.py).
    """
    h2_all = np.asarray(h2_all, np.float64)
    return solve_pairs_jit(np.asarray(beta, np.float64)[None, None, :],
                           h2_all, cfg, e_max, **kw)
