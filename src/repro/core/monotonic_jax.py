"""Device-resident Algorithm 1: jitted, batched polyblock outer approximation.

Port of `core.monotonic.solve_pairs` to JAX (DESIGN.md §6).  The host
implementation re-enters Python for every polyblock iteration of every
planning round; this one solves an arbitrary batch — including the
whole-horizon (rounds x K x N) Γ tensor, which `stackelberg.plan_round`
notes is selection-independent — as a sequence of jitted steps over
fixed-shape device arrays:

  verts/vproj : (rows, m, 2)  vertex set + boundary projections per pair
  vfval       : (rows, m)     f of eq. (21) at each projection
  valid/active: bool masks replacing the host path's ragged retirement

Structural optimizations over a naive port (all result-preserving — the
iteration trajectory replays the host algorithm's structure exactly, so
`iterations` matches the reference pair-for-pair):

  * feasibility pre-filter — Proposition-1 infeasible pairs (the majority at
    realistic radii) never enter the vertex store at all;
  * phase-split steps with active-set compaction — pairs retire after very
    few iterations (the empirical distribution is p50 ~ 2, max ~ 24 at
    Table-I settings), so the driver runs the cheap selection half-step,
    syncs the active mask, compacts surviving pairs into a smaller bucket,
    and only then pays for the expensive child projections.  Bucket sizes
    come from the {1, 1.25, 1.5, 1.75} x 2^k ladder so padding slack stays
    under 25% while jit caches stay warm across calls;
  * lazy vertex store — the store starts at 8 columns and doubles toward
    max_iter + 3 only for the rare stragglers, by which point compaction
    has shrunk the row count, so eq. (24)'s per-pair vertex replacement is
    a fully vectorized masked select over a narrow store (XLA CPU would
    execute a row scatter as a serial loop).

The projection (eqs. 27-29) dispatches through `kernels.polyblock_project`:
warm-started safeguarded log-space Newton ("newton", default — same root as
the reference 60-step bisection to ~1e-9 relative with 4x fewer
transcendental evaluations), exact mirrored bisection ("bisect"), or the
Pallas kernel ("pallas", default on TPU).  Everything runs float64 under a
scoped `jax.experimental.enable_x64`, so results match the NumPy path to
~1e-7 relative (1e-6 contract, tests/test_monotonic_jax.py) without
enabling x64 globally for the learning plane.

At the acceptance scale (100 rounds x K=4 x N=512 on a 2-core CPU
container) the whole-horizon solve is ~11x faster than the per-round host
loop; benchmarks/control_plane.py records the trajectory in
BENCH_control_plane.json.
"""
from __future__ import annotations

import warnings
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

# The masked-select store rewrite intentionally produces fresh buffers for
# the four (rows, m, ...) store arrays, so XLA cannot reuse their donated
# inputs and warns once per compiled bucket shape. Expected; silence it so
# every simulation run doesn't print compiler noise.
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable")

from ..kernels.polyblock_project.ops import (polyblock_project,
                                             project_newton_mixed)
from .feasibility import is_infeasible
from .monotonic import RAResult
from .wireless import WirelessConfig, total_energy, total_time

__all__ = ["solve_pairs_jit", "solve_pairs_fused", "precompute_gamma"]

# State tuple layout for one bucket of pairs (rows = bucket size, m = the
# current lazy vertex-slot capacity).
_BETA, _H2, _EMAX, _VERTS, _VPROJ, _VFVAL, _VALID, _ACTIVE = range(8)
_PREV, _BESTF, _BESTP, _ITERS, _NVALID, _IDX = range(8, 14)


def _bucket(n: int, lo: int = 128) -> int:
    """Smallest size in the {1, 1.25, 1.5, 1.75} x 2^k ladder that fits n:
    bounded padding slack (<= 25%), bounded number of distinct shapes for
    the jit cache."""
    b = lo
    while True:
        for quarters in (4, 5, 6, 7):
            s = (b * quarters) >> 2
            if n <= s:
                return s
        b <<= 1


def _project(v, beta, h2, e_max, cfg, backend, n_bisect):
    return polyblock_project(v, beta, h2, e_max, cfg,
                             n_bisect=n_bisect, backend=backend)


@partial(jax.jit, static_argnames=("cfg", "m", "backend", "n_bisect"))
def _init_state(beta, h2, e_max, n_real, *, cfg, m, backend, n_bisect):
    b = beta.shape[0]
    active = jnp.arange(b) < n_real
    v0 = jnp.ones((b, 2), h2.dtype)
    if backend == "mixed":
        # Cold start (no parent hint yet), but the regime-split warm start
        # in project_newton_mixed already lands near-exact on the rows that
        # used to need 6 contraction steps.
        pj0 = project_newton_mixed(v0, beta, h2, e_max, cfg, n_f32=4)
    else:
        pj0 = _project(v0, beta, h2, e_max, cfg, backend, n_bisect)
    f0 = -total_time(pj0[:, 0], pj0[:, 1], beta, h2, cfg)
    verts = jnp.zeros((b, m, 2), h2.dtype).at[:, 0].set(v0)
    vproj = jnp.zeros((b, m, 2), h2.dtype).at[:, 0].set(pj0)
    vfval = jnp.full((b, m), -jnp.inf, h2.dtype).at[:, 0].set(f0)
    valid = jnp.zeros((b, m), bool).at[:, 0].set(True)
    return (beta, h2, e_max, verts, vproj, vfval, valid, active,
            jnp.full(b, jnp.inf, h2.dtype), f0, pj0,
            jnp.zeros(b, jnp.int32), jnp.ones(b, jnp.int32),
            jnp.zeros(b, jnp.int32))


def _select_impl(state, eps):
    """Polyblock selection half-step (paper steps 9-10): pick each pair's
    best vertex, update the incumbent, retire pairs that meet eq. (26).
    Split from the projection half so the driver can compact the active set
    *before* paying for child projections."""
    (beta, h2, e_max, verts, vproj, vfval, valid, active,
     prev_best, best_f, best_proj, iters, nvalid, _) = state

    fv = jnp.where(valid, vfval, -jnp.inf)
    idx = jnp.argmax(fv, axis=1).astype(jnp.int32)      # paper step 9
    fbest = jnp.take_along_axis(fv, idx[:, None].astype(jnp.int64), 1)[:, 0]

    improved = fbest > best_f
    sel_proj = jnp.take_along_axis(
        vproj, idx[:, None, None].astype(jnp.int64), 1)[:, 0]
    best_f = jnp.where(improved, fbest, best_f)
    best_proj = jnp.where(improved[:, None], sel_proj, best_proj)

    done = jnp.abs(fbest - prev_best) <= eps            # eq. (26)
    prev_best = fbest
    active = active & ~done
    iters = iters + active.astype(jnp.int32)

    return (beta, h2, e_max, verts, vproj, vfval, valid, active,
            prev_best, best_f, best_proj, iters, nvalid, idx)


def _children_impl(state, cfg, backend, n_bisect):
    """Polyblock refinement half-step (paper steps 11-13): split the chosen
    vertex into its two children (eq. 23), project both in one batch, and
    write them into the store (eq. 24)."""
    (beta, h2, e_max, verts, vproj, vfval, valid, active,
     prev_best, best_f, best_proj, iters, nvalid, idx) = state
    b, m = vfval.shape

    v = jnp.take_along_axis(verts, idx[:, None, None].astype(jnp.int64), 1)[:, 0]
    phi = jnp.take_along_axis(vproj, idx[:, None, None].astype(jnp.int64), 1)[:, 0]
    # Children (eq. 23): v - (v_i - phi_i) e_i, both projected in one batch.
    child1 = jnp.stack([phi[:, 0], v[:, 1]], axis=-1)
    child2 = jnp.stack([v[:, 0], phi[:, 1]], axis=-1)
    ch = jnp.concatenate([child1, child2], axis=0)
    beta2 = jnp.concatenate([beta, beta])
    h2x2 = jnp.concatenate([h2, h2])
    if backend == "mixed":
        # The parent's projection ratio zeta = phi/v is a lower bound on
        # both children's roots (energy is increasing in tau and p), so it
        # warm-starts the fp32 bulk — which then needs only 2 contraction
        # steps plus a single fp64 Halley polish, vs the cold call's 4+2
        # (see project_newton_mixed; only _init_state's projection of
        # (1, 1) runs cold).
        zeta = phi[:, 0] / jnp.maximum(v[:, 0], 1e-300)
        pj = project_newton_mixed(
            ch, beta2, h2x2, jnp.concatenate([e_max, e_max]), cfg,
            n_f32=2, n_f64=1, x0_hint=jnp.concatenate([zeta, zeta]))
    else:
        pj = _project(ch, beta2, h2x2, jnp.concatenate([e_max, e_max]),
                      cfg, backend, n_bisect)
    fj = -total_time(pj[:, 0], pj[:, 1], beta2, h2x2, cfg)
    pj1, pj2 = pj[:b], pj[b:]
    f1, f2 = fj[:b], fj[b:]

    # Eq. (24): child1 replaces the split vertex, child2 takes the next free
    # slot, retired rows keep their store.  Written as two masked one-hot
    # selects rather than a row scatter: XLA CPU executes scatters as a
    # serial per-row loop, while the selects fuse into one vectorized pass
    # over the store — and the store is narrow (lazy m), so the pass is
    # cheap.  The two masks are disjoint (slot idx is already valid;
    # slot nvalid is the first free one).
    cols = jnp.arange(m)
    mask1 = (cols[None, :] == idx[:, None]) & active[:, None]
    mask2 = (cols[None, :] == nvalid[:, None]) & active[:, None]
    verts = jnp.where(mask1[..., None], child1[:, None, :],
                      jnp.where(mask2[..., None], child2[:, None, :], verts))
    vproj = jnp.where(mask1[..., None], pj1[:, None, :],
                      jnp.where(mask2[..., None], pj2[:, None, :], vproj))
    vfval = jnp.where(mask1, f1[:, None],
                      jnp.where(mask2, f2[:, None], vfval))
    valid = valid | mask2
    nvalid = nvalid + active.astype(jnp.int32)

    return (beta, h2, e_max, verts, vproj, vfval, valid, active,
            prev_best, best_f, best_proj, iters, nvalid, idx)


@partial(jax.jit, static_argnames=("eps",), donate_argnums=(0,))
def _step_select(state, *, eps):
    return _select_impl(state, eps)


@partial(jax.jit, static_argnames=("cfg", "backend", "n_bisect"),
         donate_argnums=(0,))
def _step_children(state, *, cfg, backend, n_bisect):
    return _children_impl(state, cfg, backend, n_bisect)


@jax.jit
def _gather(state, idx, n_real):
    """Compact a bucket: keep rows `idx` (padded), mark padding inactive."""
    out = tuple(a[idx] for a in state)
    active = out[_ACTIVE] & (jnp.arange(idx.shape[0]) < n_real)
    return out[:_ACTIVE] + (active,) + out[_ACTIVE + 1:]


@partial(jax.jit, static_argnames=("new_m",), donate_argnums=(0,))
def _grow(state, *, new_m):
    """Append vertex-store columns (lazy capacity: the store starts at 8
    columns because pairs empirically retire after a handful of iterations,
    and grows toward max_iter + 3 only for the rare stragglers — by which
    point compaction has shrunk the row count, so the wide store is never
    paid for at full batch).  New columns carry valid=False / fval=-inf, so
    they are inert until a child is written into them."""
    (beta, h2, e_max, verts, vproj, vfval, valid, active,
     prev_best, best_f, best_proj, iters, nvalid, idx) = state
    b, m = vfval.shape
    pad = new_m - m
    verts = jnp.concatenate([verts, jnp.zeros((b, pad, 2), verts.dtype)], 1)
    vproj = jnp.concatenate([vproj, jnp.zeros((b, pad, 2), vproj.dtype)], 1)
    vfval = jnp.concatenate([vfval, jnp.full((b, pad), -jnp.inf, vfval.dtype)], 1)
    valid = jnp.concatenate([valid, jnp.zeros((b, pad), bool)], 1)
    return (beta, h2, e_max, verts, vproj, vfval, valid, active,
            prev_best, best_f, best_proj, iters, nvalid, idx)


def _fused_stage_impl(state, cfg, backend, n_bisect, eps, t_start, t_end):
    """One fused stage of the polyblock loop: iterations t_start..t_end-1 as
    a single `lax.while_loop`, with no host sync inside.  The body replays
    the step driver's trajectory exactly — selection half-step, then the
    child projections only while any row is still active — so per-row
    results (and `iterations`) are bit-equal to the phase-split path; only
    the *synchronization schedule* differs (the step driver syncs the active
    mask every iteration, this stage never does)."""

    def cond(carry):
        t, st = carry
        return (t < t_end) & st[_ACTIVE].any()

    def body(carry):
        t, st = carry
        st = _select_impl(st, eps)
        # No guard on the children half-step: every write in _children_impl
        # is masked by `active`, so running it after a select that retired
        # the last row is a bit-exact no-op — cheaper than a lax.cond per
        # iteration, and the trajectory still replays the step driver
        # (which never runs children after its final select) exactly.
        st = _children_impl(st, cfg, backend, n_bisect)
        return t + 1, st

    _, state = jax.lax.while_loop(cond, body, (jnp.int32(t_start), state))
    return state


@partial(jax.jit,
         static_argnames=("cfg", "backend", "n_bisect", "eps",
                          "t_start", "t_end"),
         donate_argnums=(0,))
def _fused_stage(state, *, cfg, backend, n_bisect, eps, t_start, t_end):
    return _fused_stage_impl(state, cfg, backend, n_bisect, eps,
                             t_start, t_end)


@partial(jax.jit,
         static_argnames=("cfg", "backend", "n_bisect", "eps",
                          "t_start", "t_end"),
         donate_argnums=(0,))
def _fused_stage_sharded(state, *, cfg, backend, n_bisect, eps,
                         t_start, t_end):
    """Device-axis sharded stage: every state leaf has leading dim rows, so
    row sharding is collective-free (each pair's polyblock loop is
    independent).  Same pad-and-drop pattern as `fl.sim._dispatch_group`;
    per-shard early exit is safe because retired rows are frozen (the
    selection half-step is a no-op on a fully-retired shard), so results
    stay bit-identical to the unsharded path."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec

    mesh = Mesh(np.array(jax.local_devices()), ("rows",))
    spec = PartitionSpec("rows")
    fn = shard_map(
        lambda st: _fused_stage_impl(st, cfg, backend, n_bisect, eps,
                                     t_start, t_end),
        mesh=mesh, in_specs=(spec,), out_specs=spec, check_rep=False)
    return fn(state)


def _roundup(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


def solve_pairs_fused(
    beta,
    h2,
    cfg: WirelessConfig,
    e_max=None,
    *,
    eps: float | None = None,
    max_iter: int = 64,
    backend: str | None = None,
    n_bisect: int = 60,
    shard: bool | None = None,
) -> RAResult:
    """Fused-stage Algorithm 1: the whole polyblock loop as (at most) three
    jitted `while_loop` stages instead of ~2 dispatches + 1 host sync per
    iteration.

    Drop-in for `solve_pairs_jit` (same arguments and RAResult contract).
    Two overheads of the step driver are removed at once:

      * host syncs — the iteration tail runs as jitted `while_loop` stages
        with no host round-trip inside.  The sync *schedule* follows the
        empirical retirement curve at Table-I physics (the active set
        collapses ~4096 -> 2980 -> 1208 -> 346 over iterations 2-4): the
        driver still syncs-and-compacts after each of the wide iterations
        2, 3, 4 — where compaction pays for the sync many times over — and
        then fuses the long narrow tail in one stage per store width
        (8 -> 24 -> max_iter + 3 slots; an m-slot store covers through
        iteration m - 3, since step t writes slot <= t + 1).  ~19 syncs
        become <= 6, and none happen where the batch is already narrow;

      * transcendental volume — with backend "mixed" (the CPU default
        here), the child projections run the fp32-bulk/fp64-polish Newton
        (`kernels.polyblock_project.project_newton_mixed`): same safeguarded
        loop, ~2x the SIMD width for the bracket contraction, fp64 polish
        pinned to the f64 Newton root at ~1e-12 relative (the
        fp32-accumulation study, DESIGN.md §13).

    backend: as in `solve_pairs_jit`, plus "mixed", and "pallas" here means
    the *fully fused* single-kernel solve (`kernels.polyblock_fused`) —
    vertex store, selection, and the 60-step bisection projection in one
    VMEM-resident pass per (pair-tile, 128-lane) block — rather than a
    Pallas projection inside the jnp loop.  With backend "newton"/"bisect"
    the trajectory replays `solve_pairs_jit` bit-for-bit (including
    `iterations`); with "mixed" the roots agree to ~1e-12, which is
    indistinguishable at the eq. (26) retirement tolerance on the
    differential grid (<= 1e-6 contract, tests/test_fused_solver.py).

    shard: None (auto: shard the row axis over local devices when more than
    one is visible), True, or False.  Sharded and unsharded paths are
    bit-identical (tests/test_sharding_and_launch.py).
    """
    h2 = np.asarray(h2, dtype=np.float64)
    shape = h2.shape
    e_max = cfg.e_max_j if e_max is None else e_max
    eps = 0.01 if eps is None else float(eps)
    if backend is None:
        backend = "pallas" if jax.default_backend() == "tpu" else "mixed"
    if backend == "jnp":
        backend = "bisect"

    beta_f = np.broadcast_to(np.asarray(beta, np.float64), shape).reshape(-1)
    h2f = h2.reshape(-1)
    e_f = np.broadcast_to(np.asarray(e_max, np.float64), shape).reshape(-1)
    n = h2f.shape[0]

    feas = ~is_infeasible(h2f, cfg, e_f)
    tau = np.full(n, np.nan)
    p = np.full(n, np.nan)
    time_s = np.full(n, np.inf)
    energy = np.full(n, np.nan)
    iters_out = np.zeros(n, dtype=np.int64)

    def flush(rows_mask, row_orig, bp, bf, it):
        rows = np.where(rows_mask & (row_orig >= 0))[0]
        if rows.size == 0:
            return
        orig = row_orig[rows]
        tau[orig] = bp[rows, 0]
        p[orig] = bp[rows, 1]
        time_s[orig] = -bf[rows]
        energy[orig] = total_energy(bp[rows, 0], bp[rows, 1],
                                    beta_f[orig], h2f[orig], cfg)
        iters_out[orig] = it[rows]

    work = np.where(feas)[0]
    if work.size and backend == "pallas":
        from ..kernels.polyblock_fused.ops import polyblock_solve_fused

        interpret = jax.default_backend() != "tpu"
        with enable_x64():
            k_tau, k_p, k_time, k_it = polyblock_solve_fused(
                beta_f[work], h2f[work], e_f[work], cfg,
                eps=eps, max_iter=max_iter, n_bisect=n_bisect,
                interpret=interpret,
                dtype=np.float64 if interpret else np.float32)
        tau[work] = np.asarray(k_tau, np.float64)
        p[work] = np.asarray(k_p, np.float64)
        time_s[work] = np.asarray(k_time, np.float64)
        energy[work] = total_energy(tau[work], p[work],
                                    beta_f[work], h2f[work], cfg)
        iters_out[work] = np.asarray(k_it, np.int64)
    elif work.size:
        ndev = jax.local_device_count()
        use_shard = (ndev > 1) if shard is None else bool(shard)
        if use_shard and ndev == 1:
            use_shard = False
        m_full = max_iter + 3
        # Iteration t writes child2 into slot t + 1, so an m-slot store
        # covers through t_end = m - 2: starting at 5 slots carries the
        # full-width iterations 0-3 with the narrowest store that fits
        # them, and the grow ladder below widens in small steps (the wide
        # passes are long gone by the time the store is).
        m = min(5, m_full)
        b = _bucket(work.size)
        if use_shard:
            b = _roundup(b, ndev)
        pad = b - work.size
        row_orig = np.concatenate([work, np.full(pad, -1, np.int64)])
        stage = _fused_stage_sharded if use_shard else _fused_stage
        # Stage boundaries: sync after each of the wide iterations 2-6 (the
        # retirement knee spans t=2..5 at Table-I physics; a sync is ~50us
        # while a mistimed full-width stage costs milliseconds, and the
        # gather-if-half rule below decides whether a sync actually pays
        # for a copy), then one fused stage per store width.
        bounds = [tb for tb in (2, 3, 4, 5, 6) if tb < max_iter]
        mm = 24
        while True:
            te = min(mm - 2, max_iter)
            bounds.append(te)
            if te >= max_iter:
                break
            mm = min(3 * mm, m_full)
        bounds = sorted(set(bounds))
        with enable_x64():
            state = _init_state(
                jnp.asarray(np.concatenate([beta_f[work], np.ones(pad)])),
                jnp.asarray(np.concatenate([h2f[work], np.ones(pad)])),
                jnp.asarray(np.concatenate([e_f[work], np.full(pad, np.inf)])),
                jnp.int32(work.size),
                cfg=cfg, m=m, backend=backend, n_bisect=n_bisect)
            t = 0
            for t_end in bounds:
                while m - 2 < t_end and m < m_full:  # widen the store first
                    new_m = min(max(m + (m >> 1), t_end + 2), m_full)
                    state = _grow(state, new_m=new_m)
                    m = new_m
                state = stage(state, cfg=cfg, backend=backend,
                              n_bisect=n_bisect, eps=eps,
                              t_start=t, t_end=t_end)
                t = t_end
                act = np.asarray(state[_ACTIVE])
                na = int(act.sum())
                if na == 0 or t >= max_iter:
                    break
                nb = _bucket(na)
                if use_shard:
                    nb = _roundup(nb, ndev)
                # Compact only when the bucket at least halves: a gather
                # copies the whole state, so a 25% trim costs more than the
                # width it saves in the next stage.
                if nb <= b // 2:
                    bp, bf, it = (np.asarray(state[_BESTP]),
                                  np.asarray(state[_BESTF]),
                                  np.asarray(state[_ITERS]))
                    flush(~act, row_orig, bp, bf, it)
                    keep = np.where(act)[0]
                    idx = np.concatenate(
                        [keep, np.zeros(nb - na, np.int64)]).astype(np.int32)
                    state = _gather(state, jnp.asarray(idx), jnp.int32(na))
                    row_orig = np.concatenate(
                        [row_orig[keep], np.full(nb - na, -1, np.int64)])
                    b = nb
            bp, bf, it = (np.asarray(state[_BESTP]),
                          np.asarray(state[_BESTF]),
                          np.asarray(state[_ITERS]))
            flush(np.ones(b, bool), row_orig, bp, bf, it)

    return RAResult(
        tau=tau.reshape(shape),
        p=p.reshape(shape),
        time_s=time_s.reshape(shape),
        energy_j=energy.reshape(shape),
        feasible=feas.reshape(shape),
        iterations=iters_out.reshape(shape),
    )


def solve_pairs_jit(
    beta,
    h2,
    cfg: WirelessConfig,
    e_max=None,
    *,
    eps: float | None = None,
    max_iter: int = 64,
    backend: str | None = None,
    n_bisect: int = 60,
) -> RAResult:
    """Batched jitted Algorithm 1 over pairs of any shape.

    Drop-in for `monotonic.solve_pairs` (same arguments and RAResult contract,
    host numpy outputs); pass the whole-horizon (rounds x K x N) channel
    tensor to amortize a single solve over the training horizon.  backend:
    None (auto: "pallas" on TPU else "newton"), "newton", "bisect" (exact
    mirror of the host bisection), "jnp" (alias of "bisect"), or "pallas".
    n_bisect sets the bisection step count of the "bisect"/"pallas"
    projections; the "newton" backend converges by a different rule and has
    its own fixed step budget (`project_newton`'s n_steps).
    """
    h2 = np.asarray(h2, dtype=np.float64)
    shape = h2.shape
    e_max = cfg.e_max_j if e_max is None else e_max
    eps = 0.01 if eps is None else float(eps)
    if backend is None:
        backend = "pallas" if jax.default_backend() == "tpu" else "newton"
    if backend == "jnp":
        backend = "bisect"

    beta_f = np.broadcast_to(np.asarray(beta, np.float64), shape).reshape(-1)
    h2f = h2.reshape(-1)
    e_f = np.broadcast_to(np.asarray(e_max, np.float64), shape).reshape(-1)
    n = h2f.shape[0]

    feas = ~is_infeasible(h2f, cfg, e_f)
    tau = np.full(n, np.nan)
    p = np.full(n, np.nan)
    time_s = np.full(n, np.inf)
    energy = np.full(n, np.nan)
    iters_out = np.zeros(n, dtype=np.int64)

    def flush(rows_mask, row_orig, bp, bf, it):
        rows = np.where(rows_mask & (row_orig >= 0))[0]
        if rows.size == 0:
            return
        orig = row_orig[rows]
        tau[orig] = bp[rows, 0]
        p[orig] = bp[rows, 1]
        time_s[orig] = -bf[rows]
        energy[orig] = total_energy(bp[rows, 0], bp[rows, 1],
                                    beta_f[orig], h2f[orig], cfg)
        iters_out[orig] = it[rows]

    work = np.where(feas)[0]
    if work.size:
        m_full = max_iter + 3                  # all slots + one spare column
        m = min(8, m_full)                     # lazy store, grown on demand
        b = _bucket(work.size)
        pad = b - work.size
        row_orig = np.concatenate([work, np.full(pad, -1, np.int64)])
        with enable_x64():
            state = _init_state(
                jnp.asarray(np.concatenate([beta_f[work], np.ones(pad)])),
                jnp.asarray(np.concatenate([h2f[work], np.ones(pad)])),
                jnp.asarray(np.concatenate([e_f[work], np.full(pad, np.inf)])),
                jnp.int32(work.size),
                cfg=cfg, m=m, backend=backend, n_bisect=n_bisect)
            t = 0
            while t < max_iter:
                state = _step_select(state, eps=eps)
                act = np.asarray(state[_ACTIVE])
                na = int(act.sum())
                if na == 0:
                    break
                nb = _bucket(na)
                if nb < b:                     # compact BEFORE projecting
                    bp, bf, it = (np.asarray(state[_BESTP]),
                                  np.asarray(state[_BESTF]),
                                  np.asarray(state[_ITERS]))
                    flush(~act, row_orig, bp, bf, it)
                    keep = np.where(act)[0]
                    idx = np.concatenate([keep, np.zeros(nb - na, np.int64)])
                    state = _gather(state, jnp.asarray(idx), jnp.int32(na))
                    row_orig = np.concatenate(
                        [row_orig[keep], np.full(nb - na, -1, np.int64)])
                    b = nb
                if m < t + 3:                  # step t writes slot <= t+1
                    m = min(2 * m, m_full)
                    state = _grow(state, new_m=m)
                state = _step_children(state, cfg=cfg, backend=backend,
                                       n_bisect=n_bisect)
                t += 1
            bp, bf, it = (np.asarray(state[_BESTP]),
                          np.asarray(state[_BESTF]),
                          np.asarray(state[_ITERS]))
            flush(np.ones(b, bool), row_orig, bp, bf, it)

    return RAResult(
        tau=tau.reshape(shape),
        p=p.reshape(shape),
        time_s=time_s.reshape(shape),
        energy_j=energy.reshape(shape),
        feasible=feas.reshape(shape),
        iterations=iters_out.reshape(shape),
    )


def precompute_gamma(
    beta,
    h2_all,
    cfg: WirelessConfig,
    e_max=None,
    **kw,
) -> RAResult:
    """Whole-horizon Γ: solve all (round, sub-channel, device) pairs at once.

    h2_all has shape (rounds, K, N); beta broadcasts as (N,).  Returns an
    RAResult whose fields are (rounds, K, N) — Γ is `time_s`, the
    Proposition-1 mask is `feasible`.  One batched solve replaces `rounds`
    host solver invocations (speedup tracked in BENCH_control_plane.json,
    benchmarks/control_plane.py).

    solver: "fused" (default — `solve_pairs_fused`, staged whole-loop jit)
    or "step" (`solve_pairs_jit`, per-iteration phase-split driver).  Both
    produce bit-identical results; "fused" amortizes dispatch and host-sync
    overhead over the whole horizon.
    """
    h2_all = np.asarray(h2_all, np.float64)
    solver = kw.pop("solver", "fused")
    solve = solve_pairs_fused if solver == "fused" else solve_pairs_jit
    return solve(np.asarray(beta, np.float64)[None, None, :],
                 h2_all, cfg, e_max, **kw)
