"""ShapeDtypeStruct stand-ins for every model input — the dry-run lowers
against these (weak-type-correct, shardable, zero allocation).

Per the assignment, modality frontends are stubs: audio provides precomputed
conv-frontend frame embeddings, vlm provides patch embeddings + 3-D M-RoPE
position ids.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, InputShape

__all__ = ["input_specs", "decode_input_specs", "cache_specs"]

SDS = jax.ShapeDtypeStruct


def _family_extras(cfg: ArchConfig, batch: int, seq: int) -> dict:
    ex = {}
    if cfg.family == "audio":
        ex["enc_frames"] = SDS((batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        ex["image_embeds"] = SDS((batch, cfg.n_patches, cfg.d_model), jnp.bfloat16)
        ex["mrope_pos"] = SDS((batch, seq, 3), jnp.int32)
    return ex


def input_specs(cfg: ArchConfig, shape: InputShape) -> dict:
    """Inputs for train_step / prefill_step: the full-sequence batch.

    fl_weights carries the paper's per-cohort selection weights
    (alpha_n * beta_n * S_n * psi_n) — see DESIGN.md §2.
    """
    b, s = shape.global_batch, shape.seq_len
    specs = {
        "tokens": SDS((b, s), jnp.int32),
        **_family_extras(cfg, b, s),
    }
    if shape.kind == "train":
        specs["labels"] = SDS((b, s), jnp.int32)
        specs["fl_weights"] = SDS((b,), jnp.float32)
    return specs


def decode_input_specs(cfg: ArchConfig, shape: InputShape) -> dict:
    """Inputs for serve_step: ONE new token against a seq_len-deep cache."""
    b = shape.global_batch
    specs = {
        "token": SDS((b, 1), jnp.int32),
        "pos": SDS((), jnp.int32),
    }
    if cfg.family == "vlm":
        specs["mrope_pos"] = SDS((b, 1, 3), jnp.int32)
    return specs


def cache_specs(cfg: ArchConfig, shape: InputShape):
    """Decode-cache ShapeDtypeStructs via eval_shape (no allocation)."""
    from ..models.transformer import init_cache

    b, s = shape.global_batch, shape.seq_len

    def build():
        enc_out = None
        if cfg.is_encoder_decoder:
            enc_out = jnp.zeros((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        return init_cache(cfg, b, s, enc_out=enc_out)

    return jax.eval_shape(build)
