"""Post-SPMD HLO analysis for the roofline.

Two facts make raw `compiled.cost_analysis()` insufficient on scanned models:
  1. XLA's static cost analysis counts a while-loop BODY once, not
     body x trip-count — scan-over-layers models under-report by ~n_layers.
  2. cost_analysis has no collective statistics at all.

This module parses `compiled.as_text()` (per-device program; shapes are
per-shard) into computations, discovers `while` ops, extracts their trip
counts from the loop-condition constants, and attributes collective-op bytes
to computations scaled by the product of enclosing trip counts.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["collective_stats", "parse_computations", "while_trip_counts"]

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# Header params may nest parens (tuple types) — match greedily to the arrow.
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"%?[\w.\-]+\s*=\s*((?:\([^=]*?\)|[^=(]*?))\s*([\w\-]+)\(")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)", re.DOTALL
)
_CONST_RE = re.compile(r"constant\((\d+)\)")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def parse_computations(text: str) -> dict[str, str]:
    """Split an HLO module dump into {computation_name: body_text}."""
    comps: dict[str, str] = {}
    cur_name, cur_lines = None, []
    for line in text.splitlines():
        if cur_name is None:
            m = _COMP_HDR.match(line.strip()) if "{" in line and "->" in line else None
            if m:
                cur_name = m.group(1)
                cur_lines = []
        else:
            if line.strip() == "}":
                comps[cur_name] = "\n".join(cur_lines)
                cur_name = None
            else:
                cur_lines.append(line)
    return comps


def while_trip_counts(comps: dict[str, str]) -> dict[str, int]:
    """body-computation name -> trip count (max constant in its condition)."""
    trips: dict[str, int] = {}
    for body_text in comps.values():
        for m in _WHILE_RE.finditer(body_text):
            cond, body = m.group(1), m.group(2)
            consts = [int(c) for c in _CONST_RE.findall(comps.get(cond, ""))]
            trips[body] = max(consts) if consts else 1
    return trips


def _multipliers(comps: dict[str, str], trips: dict[str, int]) -> dict[str, int]:
    """Total execution multiplier per computation (product over enclosing
    while loops, handling scan-in-scan nesting)."""
    # parent body -> child bodies found inside it
    children: dict[str, list[str]] = {}
    for name, text in comps.items():
        children[name] = [m.group(2) for m in _WHILE_RE.finditer(text)]

    mult: dict[str, int] = {}

    def visit(name: str, factor: int):
        if name in mult and mult[name] >= factor:
            return
        mult[name] = max(mult.get(name, 0), factor)
        for child in children.get(name, []):
            visit(child, factor * trips.get(child, 1))

    # Roots: computations never used as a while body.
    bodies = set(trips)
    for name in comps:
        if name not in bodies:
            visit(name, 1)
    # Any body never reached from a root (defensive): multiplier = trip count.
    for b in bodies:
        if b not in mult:
            visit(b, trips.get(b, 1))
    return mult


def collective_stats(text: str, *, detail: bool = False) -> dict:
    """Per-device collective bytes, corrected for while-loop trip counts.

    Returns {'all-reduce': bytes, ..., 'total': ..., 'count': n,
             'raw_total': uncorrected}; with detail=True adds 'top': the 15
    largest individual collectives as (op, bytes, xtrips, computation).
    """
    comps = parse_computations(text)
    trips = while_trip_counts(comps)
    mult = _multipliers(comps, trips)

    out = {k: 0 for k in COLLECTIVES}
    raw = 0
    count = 0
    items = []
    for name, body in comps.items():
        factor = mult.get(name, 1)
        for line in body.splitlines():
            m = _OP_RE.match(line.strip())
            if not m:
                continue
            op = m.group(2)
            base = op.replace("-start", "")
            if base in COLLECTIVES and not op.endswith("-done"):
                b = _shape_bytes(m.group(1))
                out[base] += b * factor
                raw += b
                count += 1
                if detail:
                    items.append((base, b * factor, factor, name))
    out["total"] = sum(out[k] for k in COLLECTIVES)
    out["raw_total"] = raw
    out["count"] = count
    if detail:
        out["top"] = sorted(items, key=lambda t: -t[1])[:15]
    return out
