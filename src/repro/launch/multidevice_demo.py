import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
"""Multi-device EXECUTION demo (not just compile): run real FL-weighted
train steps for a reduced architecture on a (data=4, model=2) mesh of 8
forced host devices, with the paper's Stackelberg planner producing the
per-cohort weights each step.

Proves end-to-end that the sharded train_step (params sharded per
repro.sharding rules, MoE expert-parallel shard_map, eq.-34 weighted loss)
EXECUTES and optimizes, and that per-cohort selection changes which data
influences the model.

  PYTHONPATH=src python -m repro.launch.multidevice_demo --arch granite-moe-3b-a800m-smoke
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..core import RoundPolicy, WirelessConfig, init_aou, sample_topology
from ..data.pipeline import synthetic_lm_stream
from ..models.moe import ShardCtx
from ..models.transformer import init_params, param_count
from ..sharding.partition import batch_shardings, param_shardings, opt_state_shardings
from ..train.optimizer import make_optimizer
from ..train.train_step import make_train_step
from .mesh import dp_axes_of
from .train import fl_round_weights


def run(arch: str = "granite-moe-3b-a800m-smoke", steps: int = 8,
        batch: int = 8, seq: int = 64, data: int = 4, model: int = 2,
        seed: int = 0) -> list[float]:
    assert jax.device_count() >= data * model, (
        f"need {data*model} devices, have {jax.device_count()} "
        "(set XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    mesh = jax.make_mesh((data, model), ("data", "model"))
    ctx = ShardCtx(mesh=mesh, dp_axes=("data",))
    cfg = get_config(arch)

    params = init_params(cfg, jax.random.PRNGKey(seed), ep_size=model)
    p_sh = param_shardings(jax.eval_shape(lambda: params), mesh)
    params = jax.device_put(params, p_sh)
    print(f"{cfg.name}: {param_count(params)/1e6:.2f}M params on "
          f"{data}x{model} mesh ({jax.device_count()} devices)")

    opt = make_optimizer("adamw", 1e-3)
    opt_state = opt.init(params)
    o_sh = opt_state_shardings(jax.eval_shape(lambda: opt_state), p_sh, mesh)
    opt_state = jax.device_put(opt_state, o_sh)

    step_fn = jax.jit(make_train_step(cfg, opt, ctx, remat=False),
                      donate_argnums=(0, 1))

    # Stackelberg planner drives per-cohort weights (cohort = batch row).
    rng = np.random.default_rng(seed)
    wcfg = WirelessConfig(n_devices=batch, n_subchannels=max(2, batch // 2))
    fl_state = {"topo": sample_topology(rng, wcfg), "aou": init_aou(batch)}
    beta = rng.integers(10, 50, batch).astype(np.float64)
    stream = synthetic_lm_stream(seed, batch, seq, cfg.vocab)

    losses = []
    t0 = time.time()
    for step in range(steps):
        b = next(stream)
        w, plan, lat = fl_round_weights(fl_state, beta, wcfg, rng, RoundPolicy())
        if w.sum() == 0:
            w = np.ones(batch)
        ex = {
            "tokens": jnp.asarray(b["tokens"]),
            "labels": jnp.asarray(b["labels"]),
            "fl_weights": jnp.asarray(w, jnp.float32),
        }
        ex = jax.device_put(ex, batch_shardings(jax.eval_shape(lambda: ex), mesh, ("data",)))
        params, opt_state, m = step_fn(params, opt_state, ex)
        losses.append(float(m["loss"]))
        print(f"step {step} loss {losses[-1]:.4f} "
              f"tx={int(plan.transmitted.sum())}/{batch} latency={lat:.2f}s")
    print(f"{steps} sharded steps in {time.time()-t0:.1f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "loss must decrease"
    return losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-3b-a800m-smoke")
    ap.add_argument("--steps", type=int, default=8)
    a = ap.parse_args(argv)
    run(a.arch, steps=a.steps)


if __name__ == "__main__":
    main()
