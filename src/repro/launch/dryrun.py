import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: prove the distribution config is coherent for every
(architecture x input shape x mesh) without real hardware.

For each combination this driver:
  1. builds the production mesh (16x16 single-pod or 2x16x16 multi-pod),
  2. lowers + compiles the appropriate step (train_step for train shapes,
     prefill_step for prefill, serve_step for decode) against
     ShapeDtypeStruct inputs with explicit NamedShardings,
  3. prints compiled.memory_analysis() (fits-in-HBM evidence) and
     cost_analysis() (FLOPs / bytes for the roofline),
  4. parses the post-SPMD HLO for collective ops and sums their bytes
     (all-gather / all-reduce / reduce-scatter / all-to-all /
     collective-permute) — cost_analysis does not report these.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--json out.json]
"""
import argparse
import json
import sys
import time
from functools import partial

import jax
import jax.numpy as jnp

from ..configs import ARCHS, INPUT_SHAPES, get_config
from ..models.moe import ShardCtx
from ..models.transformer import init_params
from ..sharding.partition import (
    batch_shardings,
    cache_shardings,
    opt_state_shardings,
    param_shardings,
)
from ..train.optimizer import make_optimizer
from ..train.train_step import make_prefill_step, make_serve_step, make_train_step
from .analytic import HW, analytic_cost
from .hlo_analysis import collective_stats
from .mesh import dp_axes_of, make_production_mesh
from .specs import cache_specs, decode_input_specs, input_specs


def build_step(cfg, shape, mesh, ctx):
    """Returns (jitted fn, example args as ShapeDtypeStructs w/ shardings)."""
    dp = dp_axes_of(mesh)
    ep = mesh.shape["model"]

    param_shapes = jax.eval_shape(
        partial(init_params, cfg, ep_size=ep), jax.random.PRNGKey(0)
    )
    p_sh = param_shardings(param_shapes, mesh)

    if shape.kind == "train":
        opt = make_optimizer(cfg.optimizer, 1e-4)
        opt_shapes = jax.eval_shape(opt.init, param_shapes)
        o_sh = opt_state_shardings(opt_shapes, p_sh, mesh)
        batch = input_specs(cfg, shape)
        b_sh = batch_shardings(batch, mesh, dp)
        fn = make_train_step(cfg, opt, ctx)
        jitted = jax.jit(
            fn,
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1),
        )
        args = (param_shapes, opt_shapes, batch)
    elif shape.kind == "prefill":
        batch = input_specs(cfg, shape)
        b_sh = batch_shardings(batch, mesh, dp)
        fn = make_prefill_step(cfg, ctx)
        jitted = jax.jit(fn, in_shardings=(p_sh, b_sh))
        args = (param_shapes, batch)
    else:  # decode
        batch = decode_input_specs(cfg, shape)
        b_sh = batch_shardings(batch, mesh, dp)
        cache = cache_specs(cfg, shape)
        c_sh = cache_shardings(cache, mesh, dp)
        fn = make_serve_step(cfg, ctx)
        jitted = jax.jit(
            fn,
            in_shardings=(p_sh, b_sh, c_sh),
            out_shardings=(None, None, c_sh),
            donate_argnums=(2,),
        )
        args = (param_shapes, batch, cache)
    return jitted, args


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               verbose: bool = True, overrides: dict | None = None,
               detail: bool = False, attn_shard: str = "auto") -> dict:
    import dataclasses

    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    cfg = cfg.for_shape(shape)  # long_500k -> sliding-window variant
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = ShardCtx(mesh=mesh, dp_axes=dp_axes_of(mesh), attn_shard=attn_shard)

    t0 = time.time()
    jitted, args = build_step(cfg, shape, mesh, ctx)
    lowered = jitted.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = collective_stats(compiled.as_text(), detail=detail)
    roof = analytic_cost(
        cfg, shape, HW(chips=mesh.size), collective_bytes_per_dev=coll["total"]
    )

    n_dev = mesh.size
    res = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "devices": n_dev,
        "hlo_flops_static": float(cost.get("flops", 0.0)),
        "hlo_bytes_static": float(cost.get("bytes accessed", 0.0)),
        "collectives": coll,
        "roofline": roof,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
    }
    for attr in ("output_size_in_bytes", "temp_size_in_bytes",
                 "argument_size_in_bytes", "generated_code_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            res[attr] = int(v)
    if verbose:
        print(f"== {arch} x {shape_name} on {res['mesh']} "
              f"({n_dev} devices) ==")
        print(f"   lower {t_lower:.1f}s  compile {t_compile:.1f}s")
        print(f"   per-device args {res.get('argument_size_in_bytes', 0)/2**30:.2f} GiB, "
              f"temp {res.get('temp_size_in_bytes', 0)/2**30:.2f} GiB")
        print(f"   hlo(static): flops={res['hlo_flops_static']:.3e} "
              f"bytes={res['hlo_bytes_static']:.3e}")
        print(f"   collectives/dev (loop-corrected): "
              f"{ {k: f'{v/2**20:.1f}MiB' for k, v in coll.items() if v and k in ('all-gather','all-reduce','reduce-scatter','all-to-all','collective-permute','total')} }")
        print(f"   roofline: compute={roof['compute_s']*1e3:.2f}ms "
              f"memory={roof['memory_s']*1e3:.2f}ms "
              f"collective={roof['collective_s']*1e3:.2f}ms "
              f"-> dominant={roof['dominant']} "
              f"useful={roof['useful_ratio']:.2f}")
        if detail and coll.get("top"):
            print("   top collectives (op, MiB total, xtrips, computation):")
            for op, b, f, comp in coll["top"]:
                print(f"     {op:20s} {b/2**20:10.1f}  x{f:<4d} {comp[:60]}")
    return res


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(INPUT_SHAPES), default=None)
    ap.add_argument("--all", action="store_true", help="all 10 x 4 combos")
    ap.add_argument("--multi-pod", action="store_true", help="2x16x16 mesh")
    ap.add_argument("--json", default=None, help="write results to this file")
    ap.add_argument("--override", action="append", default=[],
                    help="config override key=value (repeatable), e.g. "
                         "--override mla_absorb=True")
    ap.add_argument("--detail", action="store_true",
                    help="print the largest individual collectives")
    ap.add_argument("--attn-shard", choices=("auto", "explicit"), default="auto",
                    help="explicit = shard_map head-/sequence-parallel "
                         "attention (§Perf optimization)")
    args = ap.parse_args(argv)

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            overrides[k] = eval(v, {}, {})  # noqa: S307 - CLI literals
        except Exception:
            overrides[k] = v

    combos = []
    if args.all:
        combos = [(a, s) for a in ARCHS for s in INPUT_SHAPES]
    elif args.arch and args.shape:
        combos = [(args.arch, args.shape)]
    else:
        ap.error("need --all or both --arch and --shape")

    results, failures = [], []
    for arch, shp in combos:
        try:
            results.append(dryrun_one(arch, shp, multi_pod=args.multi_pod,
                                      overrides=overrides, detail=args.detail,
                                      attn_shard=args.attn_shard))
        except Exception as e:  # noqa: BLE001 - report and continue
            print(f"!! FAILED {arch} x {shp}: {type(e).__name__}: {e}")
            failures.append((arch, shp, str(e)))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"results": results, "failures": failures}, f, indent=1)
    print(f"\n{len(results)} passed, {len(failures)} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
