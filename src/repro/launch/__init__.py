"""Launchers: production mesh factory, multi-pod dry-run, training driver."""
