"""Analytic FLOP / byte model for the roofline (documented formulas).

XLA's static cost analysis counts scan bodies once (see hlo_analysis), so
compiled numbers under-report deep models; the roofline's compute and memory
terms are therefore derived analytically from the architecture config and
input shape, with compiled numbers reported alongside as a cross-check.

Conventions:
  * 1 matmul MAC = 2 FLOPs; backward pass = 2x forward (dgrad + wgrad);
  * attention scores/AV: causal halves the window on train/prefill;
  * MoE: routed tokens = T x top_k x capacity_factor (+ shared experts);
  * memory term counts per-step HBM traffic: params (+opt state for train,
    x3 params for grads/updates), decode KV/state cache read+write, and
    activation traffic approximated as ACT_IO x T x d x n_layers x 2 bytes
    (remat-adjusted).

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.
"""
from __future__ import annotations

import dataclasses

from ..configs.base import ArchConfig, InputShape
from ..models.moe import CAPACITY_FACTOR

__all__ = ["HW", "analytic_cost", "model_flops", "param_counts",
           "OpCount", "CpuHW", "CPU_HW", "g_eval_ops", "projection_ops",
           "polyblock_solve_cost", "roofline_pct"]

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
ACT_IO = 20          # activation tensors touched per token per layer (approx)


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    link_bw: float = LINK_BW
    chips: int = 256


# --------------------------------------------------------------------------
# Parameter counts per sublayer kind (matmul weights only, analytic).
# --------------------------------------------------------------------------

def _attn_params(cfg: ArchConfig) -> int:
    dh = cfg.head_dim
    return cfg.d_model * (cfg.n_heads * dh + 2 * cfg.n_kv_heads * dh) + cfg.n_heads * dh * cfg.d_model


def _mla_params(cfg: ArchConfig) -> int:
    h = cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    return (
        cfg.d_model * cfg.q_lora_rank
        + cfg.q_lora_rank * h * qk
        + cfg.d_model * (cfg.kv_lora_rank + cfg.qk_rope_dim)
        + cfg.kv_lora_rank * h * (cfg.qk_nope_dim + cfg.v_head_dim)
        + h * cfg.v_head_dim * cfg.d_model
    )


def _dense_ffn_params(cfg: ArchConfig) -> int:
    return 3 * cfg.d_model * cfg.ffn_dense


def _moe_params(cfg: ArchConfig) -> tuple[int, int]:
    """(total expert bank, active per token incl. shared + router)."""
    per_expert = 3 * cfg.d_model * cfg.ffn_expert
    total = cfg.n_experts * per_expert + cfg.n_shared_experts * per_expert
    active = (
        cfg.top_k * CAPACITY_FACTOR * per_expert
        + cfg.n_shared_experts * per_expert
        + cfg.d_model * cfg.n_experts  # router
    )
    return total, int(active)


def _rwkv_params(cfg: ArchConfig) -> int:
    d = cfg.d_model
    return 5 * d * d + d * 64 + 64 * d + d * cfg.d_ff + cfg.d_ff * d + d * d


def _mamba_params(cfg: ArchConfig) -> int:
    d, di, n = cfg.d_model, cfg.mamba_d_inner, cfg.mamba_d_state
    dtr = max(d // 16, 1)
    return d * 2 * di + cfg.mamba_d_conv * di + di * (dtr + 2 * n) + dtr * di + di * d


def param_counts(cfg: ArchConfig) -> dict:
    """Analytic totals: {'total': N, 'active': N_active} (matmul weights +
    embeddings)."""
    from ..models.transformer import stage_plan

    total = active = 0
    for st in stage_plan(cfg):
        for kind in st.pattern:
            if kind.mixer == "attn":
                t = a = _attn_params(cfg)
            elif kind.mixer == "mla":
                t = a = _mla_params(cfg)
            elif kind.mixer == "rwkv":
                t = a = _rwkv_params(cfg)
            else:
                t = a = _mamba_params(cfg)
            if kind.cross:
                t += _attn_params(cfg); a += _attn_params(cfg)
            if kind.ffn == "dense":
                t += _dense_ffn_params(cfg); a += _dense_ffn_params(cfg)
            elif kind.ffn == "moe":
                mt, ma = _moe_params(cfg)
                t += mt; a += ma
            total += t * st.repeats
            active += a * st.repeats
    if cfg.is_encoder_decoder:
        enc = (_attn_params(cfg) + _dense_ffn_params(cfg)) * cfg.n_encoder_layers
        total += enc; active += enc
    emb = 2 * cfg.vocab * cfg.d_model  # embed + lm_head
    total += emb; active += emb
    return {"total": total, "active": active}


# --------------------------------------------------------------------------
# FLOPs
# --------------------------------------------------------------------------

def _attn_score_flops(cfg: ArchConfig, b: int, sq: int, skv: float,
                      *, decode: bool = False) -> float:
    if cfg.use_mla:
        if decode and not cfg.mla_absorb:
            # Naive MLA decode re-up-projects the ENTIRE latent cache to
            # per-head K/V every step — the dominant decode cost the
            # mla_absorb variant removes (§Perf pair 3).
            up = 2.0 * b * skv * cfg.kv_lora_rank * cfg.n_heads * (
                cfg.qk_nope_dim + cfg.v_head_dim)
            sc = 2.0 * b * cfg.n_heads * sq * skv * (
                cfg.qk_nope_dim + cfg.qk_rope_dim + cfg.v_head_dim)
            return up + sc
        if decode and cfg.mla_absorb:
            # Scores + AV run in the latent space (kv_r + rope dims).
            return 2.0 * b * cfg.n_heads * sq * skv * 2 * (
                cfg.kv_lora_rank + cfg.qk_rope_dim)
        dh = cfg.qk_nope_dim + cfg.qk_rope_dim
        dv = cfg.v_head_dim
        return 2.0 * b * cfg.n_heads * sq * skv * (dh + dv)
    dh = cfg.head_dim
    return 2.0 * b * cfg.n_heads * sq * skv * (dh + dh)


def _seq_mixer_state_flops(cfg: ArchConfig, b: int, s: int) -> float:
    if cfg.family == "ssm":  # rwkv: per token per head ~4*hs^2 ops
        return 4.0 * b * s * cfg.d_model * cfg.rwkv_head_size
    return 0.0


def _mamba_state_flops(cfg: ArchConfig, b: int, s: int) -> float:
    return 6.0 * b * s * cfg.mamba_d_inner * cfg.mamba_d_state


def model_flops(cfg: ArchConfig, shape: InputShape) -> dict:
    """Forward FLOPs (global); 'train_total' = 3x forward. Also the 6ND
    reference (N = active params)."""
    from ..models.transformer import stage_plan

    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        sq, tokens = 1, b
        skv_full = float(min(s, cfg.sliding_window or s))
    else:
        sq, tokens = s, b * s
        w = cfg.sliding_window or s
        # causal average kv length
        skv_full = (s / 2.0) if w >= s else (w - (w * w) / (2.0 * s))

    flops = 0.0
    for st in stage_plan(cfg):
        for kind in st.pattern:
            if kind.mixer == "attn":
                flops += st.repeats * (2.0 * tokens * _attn_params(cfg)
                                       + _attn_score_flops(cfg, b, sq, skv_full,
                                                           decode=shape.kind == "decode"))
            elif kind.mixer == "mla":
                flops += st.repeats * (2.0 * tokens * _mla_params(cfg)
                                       + _attn_score_flops(cfg, b, sq, skv_full,
                                                           decode=shape.kind == "decode"))
            elif kind.mixer == "rwkv":
                flops += st.repeats * (2.0 * tokens * _rwkv_params(cfg)
                                       + _seq_mixer_state_flops(cfg, b, sq))
            else:
                flops += st.repeats * (2.0 * tokens * _mamba_params(cfg)
                                       + _mamba_state_flops(cfg, b, sq))
            if kind.cross:
                flops += st.repeats * (2.0 * tokens * _attn_params(cfg)
                                       + 2.0 * b * cfg.n_heads * sq * cfg.encoder_seq
                                       * 2 * cfg.head_dim)
            if kind.ffn == "dense":
                flops += st.repeats * 2.0 * tokens * _dense_ffn_params(cfg)
            elif kind.ffn == "moe":
                _, active = _moe_params(cfg)
                flops += st.repeats * 2.0 * tokens * active
    if cfg.is_encoder_decoder and shape.kind != "decode":
        se = cfg.encoder_seq
        enc_tok = b * se
        per = 2.0 * enc_tok * (_attn_params(cfg) + _dense_ffn_params(cfg)) \
            + 2.0 * b * cfg.n_heads * se * se * 2 * cfg.head_dim
        flops += cfg.n_encoder_layers * per
    flops += 2.0 * tokens * cfg.vocab * cfg.d_model  # lm head
    if cfg.mtp and shape.kind == "train":
        flops += 2.0 * tokens * cfg.vocab * cfg.d_model

    pc = param_counts(cfg)
    return {
        "forward": flops,
        "train_total": 3.0 * flops,
        "six_nd_active": 6.0 * pc["active"] * tokens,
        "six_nd_total": 6.0 * pc["total"] * tokens,
        "tokens": tokens,
    }


# --------------------------------------------------------------------------
# Bytes + roofline terms
# --------------------------------------------------------------------------

def _param_bytes(cfg: ArchConfig) -> float:
    return 2.0 * param_counts(cfg)["total"]  # bf16


def _opt_bytes(cfg: ArchConfig) -> float:
    n = param_counts(cfg)["total"]
    if cfg.optimizer in ("adam", "adamw"):
        return 8.0 * n  # two f32 moments
    if cfg.optimizer == "adafactor":
        return 0.1 * n  # factored (rows+cols) -- small
    return 4.0 * n


def _cache_bytes(cfg: ArchConfig, shape: InputShape) -> float:
    from ..models.transformer import cache_len_for, stage_plan

    b = shape.global_batch
    clen = cache_len_for(cfg, shape.seq_len)
    total = 0.0
    for st in stage_plan(cfg):
        for kind in st.pattern:
            if kind.mixer == "attn":
                per = 2 * clen * cfg.n_kv_heads * cfg.head_dim * 2
            elif kind.mixer == "mla":
                per = clen * (cfg.kv_lora_rank + cfg.qk_rope_dim) * 2
            elif kind.mixer == "rwkv":
                per = cfg.n_rwkv_heads * cfg.rwkv_head_size**2 * 4 + 2 * cfg.d_model * 2
            else:
                per = cfg.mamba_d_inner * (cfg.mamba_d_state * 4 + (cfg.mamba_d_conv - 1) * 2)
            total += st.repeats * per * b
    return total


def analytic_cost(cfg: ArchConfig, shape: InputShape, hw: HW = HW(),
                  collective_bytes_per_dev: float = 0.0) -> dict:
    """The three roofline terms (seconds) + supporting numbers."""
    mf = model_flops(cfg, shape)
    flops = mf["train_total"] if shape.kind == "train" else mf["forward"]

    b, s = shape.global_batch, shape.seq_len
    tokens = mf["tokens"]
    pbytes = _param_bytes(cfg)
    act = ACT_IO * tokens * cfg.d_model * cfg.n_layers * 2.0
    if shape.kind == "train":
        hbm = 3.0 * pbytes + 2.0 * _opt_bytes(cfg) + act * 2.0  # fwd+bwd traffic
    elif shape.kind == "prefill":
        hbm = pbytes + act
    else:
        hbm = pbytes + 2.0 * _cache_bytes(cfg, shape) + act

    compute_s = flops / (hw.chips * hw.peak_flops)
    memory_s = hbm / (hw.chips * hw.hbm_bw)
    collective_s = collective_bytes_per_dev / hw.link_bw

    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    return {
        **terms,
        "dominant": dominant,
        "flops_global": flops,
        "hbm_bytes_global": hbm,
        # 6ND counts fwd+bwd (train); inference forward is 2ND = 6ND / 3.
        "model_flops_6nd": mf["six_nd_active"] * (1.0 if shape.kind == "train" else 1 / 3),
        "useful_ratio": (mf["six_nd_active"] * (1.0 if shape.kind == "train" else 1 / 3))
        / max(flops, 1.0),
        "params_total": param_counts(cfg)["total"],
        "params_active": param_counts(cfg)["active"],
    }


# --------------------------------------------------------------------------
# Control plane: analytic op/byte model of the Algorithm-1 solvers.
#
# The learning-plane model above prices matmuls against a TPU; the control
# plane is branchy elementwise math on a small CPU box, so its roofline
# needs a different op taxonomy (transcendentals and divides dominate, not
# MACs) and CPU hardware constants.  `benchmarks/control_plane.py` turns
# these predictions into "% of roofline" gates for BENCH_control_plane.json:
# a percentage against a fixed analytic bound is an *absolute* regression
# tripwire, where a wall-clock ratio of two measured runs on a noisy 2-core
# container moves with every scheduling hiccup.
#
# Conventions (documented, deliberately round):
#   * costs are in ADD-EQUIVALENTS per element at full SIMD width — weights
#     are x86 AVX2 reciprocal throughputs relative to a vector add:
#     add/mul/fma-half/select/compare/min/max = 1, divide/sqrt = 4,
#     vectorized log1p = 12, vectorized exp = 10 (SVML/sleef-class);
#   * f32 runs at twice the f64 SIMD width, priced via `CpuHW.flops_f32`;
#   * memory traffic counts the state actually streamed per polyblock
#     iteration (the five vertex-store leaves, read + write, plus the
#     wireless operands), not allocator churn.
# --------------------------------------------------------------------------

OP_WEIGHTS = {"adds": 1.0, "muls": 1.0, "cmps": 1.0, "selects": 1.0,
              "minmax": 1.0, "divs": 4.0, "sqrts": 4.0,
              "log1ps": 12.0, "exps": 10.0}


@dataclasses.dataclass(frozen=True)
class OpCount:
    """Typed op tally for one element (one (pair, vertex) lane)."""

    adds: float = 0.0
    muls: float = 0.0
    divs: float = 0.0
    sqrts: float = 0.0
    minmax: float = 0.0
    cmps: float = 0.0
    selects: float = 0.0
    log1ps: float = 0.0
    exps: float = 0.0

    def __add__(self, o: "OpCount") -> "OpCount":
        return OpCount(**{f.name: getattr(self, f.name) + getattr(o, f.name)
                          for f in dataclasses.fields(self)})

    def __mul__(self, k: float) -> "OpCount":
        return OpCount(**{f.name: getattr(self, f.name) * k
                          for f in dataclasses.fields(self)})

    __rmul__ = __mul__

    def weighted(self) -> float:
        """Total cost in add-equivalents (see OP_WEIGHTS)."""
        return sum(OP_WEIGHTS[f.name] * getattr(self, f.name)
                   for f in dataclasses.fields(self))

    def to_dict(self) -> dict:
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self)}


@dataclasses.dataclass(frozen=True)
class CpuHW:
    """The benchmark container: 2 cores of an AVX2-class x86 server part.

    peak = cores x (256-bit lanes) x 2 (FMA) x ports x clock; the control
    plane's op mix has few fuseable MACs, so `flops_*` deliberately prices
    ONE port (the second FMA port is idle on select/compare chains).  The
    constants are round numbers, not a measured machine: the roofline gate
    compares runs of the SAME model over time, so only consistency matters.
    """

    cores: int = 2
    ghz: float = 3.0
    simd_f64: int = 4          # AVX2 256-bit lanes
    mem_gbps: float = 16.0     # container-visible stream bandwidth

    @property
    def flops_f64(self) -> float:
        return self.cores * self.simd_f64 * self.ghz * 1e9

    @property
    def flops_f32(self) -> float:
        return 2.0 * self.flops_f64


CPU_HW = CpuHW()


def g_eval_ops() -> OpCount:
    """One evaluation of the energy constraint g of eq. (22), as spelled in
    `wireless.total_energy` / the kernels: u = p|h|^2 (1 mul), log1p, rate
    (2 muls), floor max, D/rate (1 div), E^cp (4 muls), E^cm (2 muls), the
    final adds."""
    return OpCount(adds=2, muls=9, divs=1, minmax=1, log1ps=1)


def _f_eval_ops() -> OpCount:
    """One evaluation of f = -T of eq. (8) (`wireless.total_time`)."""
    return OpCount(adds=2, muls=4, divs=2, minmax=2, log1ps=1)


def projection_ops(kind: str = "bisect", *, n_bisect: int = 60,
                   n_f32: int = 2, n_f64: int = 1) -> OpCount:
    """Ops for ONE projection (eqs. 27-29) of one vertex.

    kind: "bisect" (the reference 60-step halving), "newton" (the 14-step
    safeguarded log-space Newton of `project_newton`), or "mixed" (the
    fp32-bulk/fp64-polish Halley of `project_newton_mixed`; pass the
    driver's n_f32/n_f64 — f32 steps are priced at half cost via the
    doubled SIMD width, folded in here as x0.5).
    """
    need_root = g_eval_ops() + OpCount(cmps=1)
    step_bk = OpCount(cmps=1, selects=2)                 # bracket update
    if kind == "bisect":
        step = OpCount(adds=1, muls=3) + g_eval_ops() + step_bk
        return need_root + n_bisect * step + OpCount(selects=1, muls=2)
    gp_extra = OpCount(adds=3, muls=5, divs=2)           # g' sharing the log1p
    if kind == "newton":
        step = (g_eval_ops() + gp_extra + step_bk
                + OpCount(muls=2, divs=1, exps=1, selects=1))
        warm = OpCount(adds=1, muls=2, divs=2, sqrts=1, minmax=3)
        return need_root + warm + 14 * step + OpCount(selects=1, muls=2, minmax=2)
    if kind == "mixed":
        g2_extra = OpCount(adds=4, muls=8, divs=2)       # Halley's g''
        f32_step = (g_eval_ops() + gp_extra + step_bk
                    + OpCount(muls=2, divs=1, exps=1, selects=1))
        f64_step = (g_eval_ops() + gp_extra + g2_extra + step_bk
                    + OpCount(adds=2, muls=4, divs=1, selects=1))
        warm = OpCount(adds=2, muls=6, divs=2, sqrts=2, minmax=5, cmps=1,
                       selects=2)
        return (need_root + 0.5 * (warm + n_f32 * f32_step)
                + n_f64 * f64_step + OpCount(selects=1, muls=2, minmax=2))
    raise ValueError(f"unknown projection kind: {kind}")


def polyblock_solve_cost(n_pairs: int, *, solver: str = "fused",
                         feasible_frac: float = 0.45,
                         mean_iters: float = 2.9, store_slots: float = 6.0,
                         pad_slack: float = 1.6, itemsize: int = 8,
                         hw: CpuHW = CPU_HW) -> dict:
    """Analytic compute/memory bound for one whole-horizon Γ solve.

    Stage model of the drivers in `core.monotonic_jax` (and the fused
    kernel, which runs the same trajectory):

      init      — Prop-1 filter + one cold projection of (1, 1) per
                  feasible pair;
      select    — per iteration: masked argmax over the `store_slots`-wide
                  store + incumbent/retirement bookkeeping;
      children  — per iteration: two child projections + f at both + the
                  masked one-hot store write (the store is re-streamed, so
                  this is also where the memory term lives).

    mean_iters is the empirical mean polyblock iteration count per feasible
    pair at Table-I physics (retirement histogram: p50 = 2, mean ~2.9,
    max ~16-24); pad_slack covers bucket padding plus the not-yet-compacted
    retired rows that the wide stages still carry (the {1,1.25,1.5,1.75}
    x 2^k ladder bounds pure padding at 25%, compaction lag adds the rest).

    solver: "step" (`solve_pairs_jit`, newton projections), "fused"
    (`solve_pairs_fused`, mixed projections), or "pallas" (the single
    fused kernel: bisection projections, but the store never round-trips
    through HBM — only the operands and results do).

    Returns compute_s / memory_s / bound_s (their max), the raw op and
    byte tallies, and the per-stage compute split.
    """
    if solver == "step":
        proj = projection_ops("newton")
        flops_rate = hw.flops_f64
    elif solver == "fused":
        proj = projection_ops("mixed")
        flops_rate = hw.flops_f64
    elif solver == "pallas":
        proj = projection_ops("bisect")
        flops_rate = hw.flops_f64 if itemsize == 8 else hw.flops_f32
    else:
        raise ValueError(f"unknown solver: {solver}")

    rows = n_pairs * feasible_frac * pad_slack
    iters = rows * mean_iters

    select = store_slots * OpCount(cmps=2, selects=2) + OpCount(
        adds=2, cmps=3, selects=6, minmax=1)
    write = store_slots * OpCount(cmps=2, selects=5) * 2.0
    init_ops = rows * (proj + _f_eval_ops()).weighted() \
        + n_pairs * g_eval_ops().weighted()              # Prop-1 filter
    select_ops = iters * select.weighted()
    children_ops = iters * (2.0 * (proj + _f_eval_ops()).weighted()
                            + write.weighted())
    flops = init_ops + select_ops + children_ops

    # Memory: the five store leaves (verts 2 + vproj 2 + vfval 1, plus the
    # valid bitmask) stream read+write each iteration in the jnp drivers;
    # the fused kernel keeps the store VMEM/register-resident and streams
    # only operands in and results out.
    leaf_floats = 5.125
    if solver == "pallas":
        bytes_ = n_pairs * (3 + 4) * itemsize
    else:
        bytes_ = (iters * store_slots * leaf_floats * itemsize * 2.0
                  + iters * 3 * itemsize + n_pairs * 7 * itemsize)

    compute_s = flops / flops_rate
    memory_s = bytes_ / (hw.mem_gbps * 1e9)
    return {
        "solver": solver,
        "n_pairs": n_pairs,
        "flops_add_equiv": flops,
        "bytes": bytes_,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "bound_s": max(compute_s, memory_s),
        "dominant": "compute_s" if compute_s >= memory_s else "memory_s",
        "stage_compute": {
            "init": init_ops / flops_rate,
            "select": select_ops / flops_rate,
            "children": children_ops / flops_rate,
        },
    }


def roofline_pct(measured_s: float, cost: dict) -> float:
    """Percent of the analytic roofline achieved by a measured solve."""
    return 100.0 * cost["bound_s"] / max(measured_s, 1e-12)
