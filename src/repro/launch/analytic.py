"""Analytic FLOP / byte model for the roofline (documented formulas).

XLA's static cost analysis counts scan bodies once (see hlo_analysis), so
compiled numbers under-report deep models; the roofline's compute and memory
terms are therefore derived analytically from the architecture config and
input shape, with compiled numbers reported alongside as a cross-check.

Conventions:
  * 1 matmul MAC = 2 FLOPs; backward pass = 2x forward (dgrad + wgrad);
  * attention scores/AV: causal halves the window on train/prefill;
  * MoE: routed tokens = T x top_k x capacity_factor (+ shared experts);
  * memory term counts per-step HBM traffic: params (+opt state for train,
    x3 params for grads/updates), decode KV/state cache read+write, and
    activation traffic approximated as ACT_IO x T x d x n_layers x 2 bytes
    (remat-adjusted).

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.
"""
from __future__ import annotations

import dataclasses

from ..configs.base import ArchConfig, InputShape
from ..models.moe import CAPACITY_FACTOR

__all__ = ["HW", "analytic_cost", "model_flops", "param_counts"]

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
ACT_IO = 20          # activation tensors touched per token per layer (approx)


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    link_bw: float = LINK_BW
    chips: int = 256


# --------------------------------------------------------------------------
# Parameter counts per sublayer kind (matmul weights only, analytic).
# --------------------------------------------------------------------------

def _attn_params(cfg: ArchConfig) -> int:
    dh = cfg.head_dim
    return cfg.d_model * (cfg.n_heads * dh + 2 * cfg.n_kv_heads * dh) + cfg.n_heads * dh * cfg.d_model


def _mla_params(cfg: ArchConfig) -> int:
    h = cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    return (
        cfg.d_model * cfg.q_lora_rank
        + cfg.q_lora_rank * h * qk
        + cfg.d_model * (cfg.kv_lora_rank + cfg.qk_rope_dim)
        + cfg.kv_lora_rank * h * (cfg.qk_nope_dim + cfg.v_head_dim)
        + h * cfg.v_head_dim * cfg.d_model
    )


def _dense_ffn_params(cfg: ArchConfig) -> int:
    return 3 * cfg.d_model * cfg.ffn_dense


def _moe_params(cfg: ArchConfig) -> tuple[int, int]:
    """(total expert bank, active per token incl. shared + router)."""
    per_expert = 3 * cfg.d_model * cfg.ffn_expert
    total = cfg.n_experts * per_expert + cfg.n_shared_experts * per_expert
    active = (
        cfg.top_k * CAPACITY_FACTOR * per_expert
        + cfg.n_shared_experts * per_expert
        + cfg.d_model * cfg.n_experts  # router
    )
    return total, int(active)


def _rwkv_params(cfg: ArchConfig) -> int:
    d = cfg.d_model
    return 5 * d * d + d * 64 + 64 * d + d * cfg.d_ff + cfg.d_ff * d + d * d


def _mamba_params(cfg: ArchConfig) -> int:
    d, di, n = cfg.d_model, cfg.mamba_d_inner, cfg.mamba_d_state
    dtr = max(d // 16, 1)
    return d * 2 * di + cfg.mamba_d_conv * di + di * (dtr + 2 * n) + dtr * di + di * d


def param_counts(cfg: ArchConfig) -> dict:
    """Analytic totals: {'total': N, 'active': N_active} (matmul weights +
    embeddings)."""
    from ..models.transformer import stage_plan

    total = active = 0
    for st in stage_plan(cfg):
        for kind in st.pattern:
            if kind.mixer == "attn":
                t = a = _attn_params(cfg)
            elif kind.mixer == "mla":
                t = a = _mla_params(cfg)
            elif kind.mixer == "rwkv":
                t = a = _rwkv_params(cfg)
            else:
                t = a = _mamba_params(cfg)
            if kind.cross:
                t += _attn_params(cfg); a += _attn_params(cfg)
            if kind.ffn == "dense":
                t += _dense_ffn_params(cfg); a += _dense_ffn_params(cfg)
            elif kind.ffn == "moe":
                mt, ma = _moe_params(cfg)
                t += mt; a += ma
            total += t * st.repeats
            active += a * st.repeats
    if cfg.is_encoder_decoder:
        enc = (_attn_params(cfg) + _dense_ffn_params(cfg)) * cfg.n_encoder_layers
        total += enc; active += enc
    emb = 2 * cfg.vocab * cfg.d_model  # embed + lm_head
    total += emb; active += emb
    return {"total": total, "active": active}


# --------------------------------------------------------------------------
# FLOPs
# --------------------------------------------------------------------------

def _attn_score_flops(cfg: ArchConfig, b: int, sq: int, skv: float,
                      *, decode: bool = False) -> float:
    if cfg.use_mla:
        if decode and not cfg.mla_absorb:
            # Naive MLA decode re-up-projects the ENTIRE latent cache to
            # per-head K/V every step — the dominant decode cost the
            # mla_absorb variant removes (§Perf pair 3).
            up = 2.0 * b * skv * cfg.kv_lora_rank * cfg.n_heads * (
                cfg.qk_nope_dim + cfg.v_head_dim)
            sc = 2.0 * b * cfg.n_heads * sq * skv * (
                cfg.qk_nope_dim + cfg.qk_rope_dim + cfg.v_head_dim)
            return up + sc
        if decode and cfg.mla_absorb:
            # Scores + AV run in the latent space (kv_r + rope dims).
            return 2.0 * b * cfg.n_heads * sq * skv * 2 * (
                cfg.kv_lora_rank + cfg.qk_rope_dim)
        dh = cfg.qk_nope_dim + cfg.qk_rope_dim
        dv = cfg.v_head_dim
        return 2.0 * b * cfg.n_heads * sq * skv * (dh + dv)
    dh = cfg.head_dim
    return 2.0 * b * cfg.n_heads * sq * skv * (dh + dh)


def _seq_mixer_state_flops(cfg: ArchConfig, b: int, s: int) -> float:
    if cfg.family == "ssm":  # rwkv: per token per head ~4*hs^2 ops
        return 4.0 * b * s * cfg.d_model * cfg.rwkv_head_size
    return 0.0


def _mamba_state_flops(cfg: ArchConfig, b: int, s: int) -> float:
    return 6.0 * b * s * cfg.mamba_d_inner * cfg.mamba_d_state


def model_flops(cfg: ArchConfig, shape: InputShape) -> dict:
    """Forward FLOPs (global); 'train_total' = 3x forward. Also the 6ND
    reference (N = active params)."""
    from ..models.transformer import stage_plan

    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        sq, tokens = 1, b
        skv_full = float(min(s, cfg.sliding_window or s))
    else:
        sq, tokens = s, b * s
        w = cfg.sliding_window or s
        # causal average kv length
        skv_full = (s / 2.0) if w >= s else (w - (w * w) / (2.0 * s))

    flops = 0.0
    for st in stage_plan(cfg):
        for kind in st.pattern:
            if kind.mixer == "attn":
                flops += st.repeats * (2.0 * tokens * _attn_params(cfg)
                                       + _attn_score_flops(cfg, b, sq, skv_full,
                                                           decode=shape.kind == "decode"))
            elif kind.mixer == "mla":
                flops += st.repeats * (2.0 * tokens * _mla_params(cfg)
                                       + _attn_score_flops(cfg, b, sq, skv_full,
                                                           decode=shape.kind == "decode"))
            elif kind.mixer == "rwkv":
                flops += st.repeats * (2.0 * tokens * _rwkv_params(cfg)
                                       + _seq_mixer_state_flops(cfg, b, sq))
            else:
                flops += st.repeats * (2.0 * tokens * _mamba_params(cfg)
                                       + _mamba_state_flops(cfg, b, sq))
            if kind.cross:
                flops += st.repeats * (2.0 * tokens * _attn_params(cfg)
                                       + 2.0 * b * cfg.n_heads * sq * cfg.encoder_seq
                                       * 2 * cfg.head_dim)
            if kind.ffn == "dense":
                flops += st.repeats * 2.0 * tokens * _dense_ffn_params(cfg)
            elif kind.ffn == "moe":
                _, active = _moe_params(cfg)
                flops += st.repeats * 2.0 * tokens * active
    if cfg.is_encoder_decoder and shape.kind != "decode":
        se = cfg.encoder_seq
        enc_tok = b * se
        per = 2.0 * enc_tok * (_attn_params(cfg) + _dense_ffn_params(cfg)) \
            + 2.0 * b * cfg.n_heads * se * se * 2 * cfg.head_dim
        flops += cfg.n_encoder_layers * per
    flops += 2.0 * tokens * cfg.vocab * cfg.d_model  # lm head
    if cfg.mtp and shape.kind == "train":
        flops += 2.0 * tokens * cfg.vocab * cfg.d_model

    pc = param_counts(cfg)
    return {
        "forward": flops,
        "train_total": 3.0 * flops,
        "six_nd_active": 6.0 * pc["active"] * tokens,
        "six_nd_total": 6.0 * pc["total"] * tokens,
        "tokens": tokens,
    }


# --------------------------------------------------------------------------
# Bytes + roofline terms
# --------------------------------------------------------------------------

def _param_bytes(cfg: ArchConfig) -> float:
    return 2.0 * param_counts(cfg)["total"]  # bf16


def _opt_bytes(cfg: ArchConfig) -> float:
    n = param_counts(cfg)["total"]
    if cfg.optimizer in ("adam", "adamw"):
        return 8.0 * n  # two f32 moments
    if cfg.optimizer == "adafactor":
        return 0.1 * n  # factored (rows+cols) -- small
    return 4.0 * n


def _cache_bytes(cfg: ArchConfig, shape: InputShape) -> float:
    from ..models.transformer import cache_len_for, stage_plan

    b = shape.global_batch
    clen = cache_len_for(cfg, shape.seq_len)
    total = 0.0
    for st in stage_plan(cfg):
        for kind in st.pattern:
            if kind.mixer == "attn":
                per = 2 * clen * cfg.n_kv_heads * cfg.head_dim * 2
            elif kind.mixer == "mla":
                per = clen * (cfg.kv_lora_rank + cfg.qk_rope_dim) * 2
            elif kind.mixer == "rwkv":
                per = cfg.n_rwkv_heads * cfg.rwkv_head_size**2 * 4 + 2 * cfg.d_model * 2
            else:
                per = cfg.mamba_d_inner * (cfg.mamba_d_state * 4 + (cfg.mamba_d_conv - 1) * 2)
            total += st.repeats * per * b
    return total


def analytic_cost(cfg: ArchConfig, shape: InputShape, hw: HW = HW(),
                  collective_bytes_per_dev: float = 0.0) -> dict:
    """The three roofline terms (seconds) + supporting numbers."""
    mf = model_flops(cfg, shape)
    flops = mf["train_total"] if shape.kind == "train" else mf["forward"]

    b, s = shape.global_batch, shape.seq_len
    tokens = mf["tokens"]
    pbytes = _param_bytes(cfg)
    act = ACT_IO * tokens * cfg.d_model * cfg.n_layers * 2.0
    if shape.kind == "train":
        hbm = 3.0 * pbytes + 2.0 * _opt_bytes(cfg) + act * 2.0  # fwd+bwd traffic
    elif shape.kind == "prefill":
        hbm = pbytes + act
    else:
        hbm = pbytes + 2.0 * _cache_bytes(cfg, shape) + act

    compute_s = flops / (hw.chips * hw.peak_flops)
    memory_s = hbm / (hw.chips * hw.hbm_bw)
    collective_s = collective_bytes_per_dev / hw.link_bw

    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    return {
        **terms,
        "dominant": dominant,
        "flops_global": flops,
        "hbm_bytes_global": hbm,
        # 6ND counts fwd+bwd (train); inference forward is 2ND = 6ND / 3.
        "model_flops_6nd": mf["six_nd_active"] * (1.0 if shape.kind == "train" else 1 / 3),
        "useful_ratio": (mf["six_nd_active"] * (1.0 if shape.kind == "train" else 1 / 3))
        / max(flops, 1.0),
        "params_total": param_counts(cfg)["total"],
        "params_active": param_counts(cfg)["active"],
    }
