"""Production mesh factory.

Single pod : (data=16, model=16)            = 256 chips (TPU v5e pod)
Multi-pod  : (pod=2, data=16, model=16)     = 512 chips

Defined as a FUNCTION so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512
*before* any jax import (see dryrun.py), smoke tests see 1 device.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "dp_axes_of", "smoke_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes_of(mesh) -> tuple:
    """The activation-batch (data-parallel) axes of a mesh."""
    return tuple(a for a in mesh.axis_names if a != "model")


def smoke_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many devices the test host has."""
    return jax.make_mesh((data, model), ("data", "model"))
