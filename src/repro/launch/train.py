"""Runnable training driver (single host; the examples use this to train a
~100M-param model end-to-end on synthetic data).

This is the same train_step the dry-run lowers for the production mesh —
here it runs on however many devices the host has (a 1x1 mesh on CPU), with
the paper's FL selection weights driving the per-cohort gradient weighting.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b-smoke --steps 20
  PYTHONPATH=src python -m repro.launch.train --arch yi-6b-smoke --fl --steps 50
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..core import (
    RoundPolicy,
    WirelessConfig,
    init_aou,
    plan_round,
    sample_channel_gains,
    sample_topology,
)
from ..data.pipeline import synthetic_lm_stream
from ..models.moe import ShardCtx
from ..models.transformer import init_params, param_count
from ..train.optimizer import make_optimizer
from ..train.train_step import make_train_step

__all__ = ["train_loop", "main"]


def fl_round_weights(state, beta, wcfg, rng, policy) -> tuple[np.ndarray, object, float]:
    """One Stackelberg round -> per-cohort weights alpha*beta*S*psi (eq. 42)."""
    topo, aou = state["topo"], state["aou"]
    h2 = sample_channel_gains(rng, wcfg, topo)
    plan = plan_round(aou, beta, h2, wcfg, rng, policy=policy)
    state["aou"] = plan.aou_next
    alpha = aou.weights
    w = alpha * beta * plan.transmitted.astype(np.float64)
    return w, plan, plan.latency_s


def train_loop(arch: str, *, steps: int = 20, batch: int = 8, seq: int = 128,
               lr: float = 3e-4, fl: bool = False, n_cohorts: int = 8,
               seed: int = 0, log_every: int = 1):
    cfg = get_config(arch)
    key = jax.random.PRNGKey(seed)
    params = init_params(cfg, key)
    n_params = param_count(params)
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M")

    opt = make_optimizer("adamw" if cfg.optimizer == "adafactor" else cfg.optimizer, lr)
    opt_state = opt.init(params)
    ctx = ShardCtx()
    step_fn = jax.jit(make_train_step(cfg, opt, ctx, remat=False))

    rng = np.random.default_rng(seed)
    stream = synthetic_lm_stream(seed, batch, seq, cfg.vocab)

    fl_state = None
    if fl:
        wcfg = WirelessConfig(n_devices=n_cohorts, n_subchannels=max(2, n_cohorts // 4))
        fl_state = {
            "topo": sample_topology(rng, wcfg),
            "aou": init_aou(n_cohorts),
        }
        beta = rng.integers(10, 50, n_cohorts).astype(np.float64)
        policy = RoundPolicy()

    losses, wall = [], time.time()
    total_latency = 0.0
    for step in range(steps):
        b = next(stream)
        example = {
            "tokens": jnp.asarray(b["tokens"]),
            "labels": jnp.asarray(b["labels"]),
        }
        if cfg.family == "vlm":
            example["image_embeds"] = jnp.zeros((batch, cfg.n_patches, cfg.d_model), jnp.bfloat16)
            example["mrope_pos"] = jnp.broadcast_to(
                jnp.arange(seq, dtype=jnp.int32)[None, :, None], (batch, seq, 3))
        if cfg.family == "audio":
            example["enc_frames"] = jnp.zeros((batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)

        if fl:
            w, plan, lat = fl_round_weights(fl_state, beta, wcfg, rng, policy)
            total_latency += lat
            # cohorts -> batch rows (round-robin)
            row_w = w[np.arange(batch) % n_cohorts]
            if row_w.sum() == 0:
                row_w = np.ones(batch)
            example["fl_weights"] = jnp.asarray(row_w, jnp.float32)
        else:
            example["fl_weights"] = jnp.ones((batch,), jnp.float32)

        params, opt_state, metrics = step_fn(params, opt_state, example)
        losses.append(float(metrics["loss"]))
        if step % log_every == 0:
            msg = f"step {step:4d} loss {losses[-1]:.4f} gnorm {float(metrics['grad_norm']):.3f}"
            if fl:
                msg += f" round_latency {lat:.2f}s tx={int(plan.transmitted.sum())}"
            print(msg)
    dt = time.time() - wall
    print(f"done: {steps} steps in {dt:.1f}s; loss {losses[0]:.4f} -> {losses[-1]:.4f}"
          + (f"; simulated wireless latency {total_latency:.1f}s" if fl else ""))
    return losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b-smoke")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--fl", action="store_true",
                    help="drive per-cohort weights from the Stackelberg round planner")
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args(argv)
    train_loop(a.arch, steps=a.steps, batch=a.batch, seq=a.seq, lr=a.lr,
               fl=a.fl, seed=a.seed)


if __name__ == "__main__":
    main()
