"""Serving driver: batched prefill + decode loop over the ring caches —
the runnable counterpart of the decode-shape dry-runs.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b-smoke \
      --batch 4 --prompt-len 64 --new-tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from functools import lru_cache

from ..configs import get_config
from ..data.pipeline import synthetic_token_batch
from ..models.moe import ShardCtx
from ..models.transformer import forward, init_params, param_count
from ..train.train_step import make_serve_step

__all__ = ["serve_loop", "main"]


@lru_cache(maxsize=None)
def _jitted_steps(cfg, cache_headroom: int, ctx: ShardCtx = ShardCtx()):
    """ONE jitted (prefill, serve) pair per (arch config, headroom).

    The old driver rebuilt `jax.jit(make_prefill_step(...))` inside every
    `serve_loop` call (and then didn't even use it — prefill went through
    an eager `forward`), so repeated dispatches of the same arch retraced
    and recompiled from scratch.  Hoisting the closures behind an lru_cache
    keyed on the static arguments (ArchConfig and ShardCtx are frozen
    dataclasses; headroom is baked into the prefill cache shape) makes the
    second and every later dispatch reuse jax's compile cache — what the
    sustained-service harness needs (ROADMAP).
    """

    def prefill_step(params, batch):
        logits, _, cache = forward(cfg, params, batch, ctx, mode="prefill",
                                   cache_headroom=cache_headroom)
        return logits[:, -1:], cache

    return jax.jit(prefill_step), jax.jit(make_serve_step(cfg, ctx))


def serve_loop(arch: str, *, batch: int = 4, prompt_len: int = 64,
               new_tokens: int = 32, seed: int = 0, log_every: int = 8):
    cfg = get_config(arch)
    key = jax.random.PRNGKey(seed)
    params = init_params(cfg, key)
    print(f"arch={cfg.name} family={cfg.family} "
          f"params={param_count(params)/1e6:.1f}M")

    ctx = ShardCtx()
    rng = np.random.default_rng(seed)
    toks = synthetic_token_batch(rng, batch, prompt_len, cfg.vocab)["tokens"]
    req = {"tokens": jnp.asarray(toks)}
    if cfg.family == "vlm":
        req["image_embeds"] = jnp.zeros((batch, cfg.n_patches, cfg.d_model), jnp.bfloat16)
        req["mrope_pos"] = jnp.broadcast_to(
            jnp.arange(prompt_len, dtype=jnp.int32)[None, :, None],
            (batch, prompt_len, 3))
    if cfg.family == "audio":
        req["enc_frames"] = jnp.zeros((batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)

    prefill, serve = _jitted_steps(cfg, new_tokens, ctx)

    t0 = time.perf_counter()
    logits, cache = prefill(params, req)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    jax.block_until_ready(tok)
    t_prefill = time.perf_counter() - t0
    print(f"prefill {batch}x{prompt_len}: {t_prefill:.2f}s "
          f"({batch*prompt_len/t_prefill:.0f} tok/s)")

    def decode_batch(d):
        db = {"token": tok, "pos": jnp.asarray(prompt_len + d, jnp.int32)}
        if cfg.family == "vlm":
            db["mrope_pos"] = jnp.full((batch, 1, 3), prompt_len + d, jnp.int32)
        return db

    # Warm-up: one DISCARDED decode step triggers the serve compile, so
    # the timed loop below measures steady-state decode only.  Outputs
    # are not donated, so discarding them cannot disturb tok/cache.
    jax.block_until_ready(serve(params, decode_batch(0), cache))

    # Tokens stay on device inside the loop — a `np.asarray(tok)` per
    # step (the old driver) forces a device->host sync every iteration
    # and serializes the dispatch pipeline; everything is pulled once
    # after the loop drains.
    generated = [tok]
    t0 = time.perf_counter()
    for d in range(new_tokens):
        tok, logits, cache = serve(params, decode_batch(d), cache)
        generated.append(tok)
        if d % log_every == 0:
            print(f"  step {d:3d}/{new_tokens} dispatched")
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    print(f"decoded {new_tokens} tokens x {batch}: {dt:.2f}s "
          f"({batch*new_tokens/dt:.1f} tok/s steady-state decode)")
    return np.asarray(jnp.concatenate(generated, axis=1))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args(argv)
    serve_loop(a.arch, batch=a.batch, prompt_len=a.prompt_len,
               new_tokens=a.new_tokens, seed=a.seed)


if __name__ == "__main__":
    main()
