"""whisper-base [audio] — enc-dec, conv/mel frontend STUBBED.
[arXiv:2212.04356]

Assigned spec: 6L d_model=512 8H (kv=8) d_ff=2048 vocab=51865.  The
mel-spectrogram + conv feature extractor is a stub: input_specs() provides
precomputed frame embeddings (B, 1500, 512).  6 encoder + 6 decoder layers;
decoder layers carry cross-attention to the encoder output.  Backbone uses
RoPE in place of whisper's learned absolute positions (DESIGN.md §5).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51865,
    rope_theta=1e4,
    is_encoder_decoder=True,
    n_encoder_layers=6,
    encoder_seq=1500,
    long_context="long_500k via SWA variant (long_window=8192)",
    optimizer="adamw",
)
