"""granite-moe-3b-a800m [moe] — 40 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base family]

Assigned spec: 32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155,
MoE 40e top-8.  40 experts are zero-padded to 48 on a 16-way
expert-parallel axis (repro.models.moe.pad_experts).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    moe_d_ff=512,
    vocab=49155,
    rope_theta=1e4,
    n_experts=40,
    top_k=8,
    moe_every=1,
    long_context="long_500k via SWA variant (long_window=8192)",
    optimizer="adamw",
)
