"""jamba-v0.1-52b [hybrid] — Mamba + attention 1:7 interleave, MoE 16e top-2.
[arXiv:2403.19887]

Assigned spec: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536,
MoE 16e top-2.  Period-8 blocks: attention at in-block index 4 (1 attn : 7
mamba), MoE FFN on odd layers (every other), dense SwiGLU on even.
long_500k is natively servable: mamba state is O(1), the 4 attention layers
use the GQA KV cache (full 32k cache for decode_32k; the hybrid's attention
memory is 8x smaller than a pure transformer already).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    moe_d_ff=14336,
    vocab=65536,
    rope_theta=1e4,
    n_experts=16,
    top_k=2,
    moe_every=2,
    moe_offset=1,
    attn_every=8,
    attn_offset=4,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    long_context="native (mamba state + 4 full-attn layers, B=1 cache)",
    optimizer="adafactor",
)
