"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution; ViT frontend STUBBED.
[arXiv:2409.12191]

Assigned spec: 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
input_specs() provides precomputed patch embeddings (B, 256, 1536) spliced
over the first 256 token positions, plus (B, S, 3) M-RoPE position ids
(temporal / height / width streams).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    qkv_bias=True,
    rope_theta=1e6,
    use_mrope=True,
    n_patches=256,
    long_context="long_500k via SWA variant (long_window=8192)",
    optimizer="adamw",
)
