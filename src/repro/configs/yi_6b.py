"""yi-6b [dense] — llama-arch GQA kv=4. [arXiv:2403.04652]

Assigned spec: 32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="yi-6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab=64000,
    rope_theta=5e6,
    long_context="long_500k via SWA variant (long_window=8192)",
    optimizer="adamw",
)
