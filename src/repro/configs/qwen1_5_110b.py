"""qwen1.5-110b [dense] — GQA kv=8, QKV bias. [hf:Qwen/Qwen1.5 family]

Assigned spec: 80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1e6,

    long_context="long_500k via SWA variant (long_window=8192)",
    optimizer="adafactor",         # 110B: Adam states exceed v5e HBM
)
