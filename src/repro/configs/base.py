"""Architecture configuration schema for the model zoo.

Every assigned architecture is a frozen `ArchConfig`; reduced smoke variants
(2 layers, d_model <= 512, <= 4 experts) are derived via `reduced()` so smoke
tests exercise the *same* code paths as the full configs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["ArchConfig", "InputShape", "INPUT_SHAPES"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                    # query heads (0 for attention-free)
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                 # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    attn_impl: str = "ref"          # "ref" (jnp sdpa) | "pallas" (flash kernel)

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0               # per-expert hidden (0 -> d_ff)
    n_shared_experts: int = 0
    moe_every: int = 1              # MoE FFN on layers where i % moe_every == moe_offset
    moe_offset: int = 0
    n_dense_layers: int = 0         # leading dense layers (deepseek-v3: 3)
    dense_d_ff: int = 0             # FFN width of the dense layers (0 -> d_ff)
    router_aux_coef: float = 0.001

    # --- MLA (deepseek) ------------------------------------------------------
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    mla_absorb: bool = False        # decode in latent space (§Perf optimization)

    # --- SSM / hybrid --------------------------------------------------------
    ssm_type: str = ""              # "rwkv6" | "mamba"
    rwkv_wkv_impl: str = "ref"      # "ref" (lax.scan) | "pallas" (TPU kernel)
    rwkv_head_size: int = 64
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    attn_every: int = 0             # jamba: 1 attention layer per this many (period)
    attn_offset: int = 0            # index of the attn layer within the period

    # --- encoder-decoder (whisper) -------------------------------------------
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 1500         # stubbed conv/mel frontend output length

    # --- VLM (qwen2-vl) -------------------------------------------------------
    use_mrope: bool = False
    n_patches: int = 256            # stubbed ViT frontend output length

    # --- long-context / serving ----------------------------------------------
    sliding_window: int = 0         # 0 -> full attention; >0 -> SWA window
    long_window: int = 8192         # SWA window applied ONLY for long_500k
    long_context: str = ""          # note for DESIGN: how long_500k is served

    def for_shape(self, shape: "InputShape") -> "ArchConfig":
        """Shape-specific variant: long-context decode on attention archs
        switches to the sliding-window variant (long_window); SSM/hybrid are
        natively sub-quadratic and unchanged."""
        if (shape.kind == "decode" and shape.seq_len > 65536
                and self.n_heads > 0 and self.family not in ("ssm", "hybrid")
                and self.sliding_window == 0):
            return dataclasses.replace(self, sliding_window=self.long_window)
        return self

    # --- extras ----------------------------------------------------------------
    mtp: bool = False               # deepseek multi-token prediction head
    mtp_weight: float = 0.3

    # --- training -----------------------------------------------------------
    optimizer: str = "adamw"        # dry-run optimizer (adafactor for >=100B)

    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // max(self.n_heads, 1)

    @property
    def ffn_dense(self) -> int:
        return self.dense_d_ff or self.d_ff

    @property
    def ffn_expert(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def n_rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_size

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    def is_moe_layer(self, i: int) -> bool:
        if self.n_experts == 0 or i < self.n_dense_layers:
            return False
        return (i - self.n_dense_layers) % self.moe_every == self.moe_offset

    def is_attn_layer(self, i: int) -> bool:
        """hybrid: which layers are attention (the rest are SSM)."""
        if self.family != "hybrid":
            return self.n_heads > 0
        return i % self.attn_every == self.attn_offset

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: same family/code path, toy dimensions."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4) if self.n_heads else 0
        n_kv = min(self.n_kv_heads, max(1, n_heads // 2)) if n_heads else 0
        n_layers = max(2, self.attn_every or 2) if self.family == "hybrid" else 2
        kw = dict(
            name=self.name + "-smoke",
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            d_head=(d_model // n_heads if n_heads else 0),
            d_ff=min(self.d_ff, 512),
            vocab=min(self.vocab, 1024),
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            moe_d_ff=min(self.ffn_expert, 128) if self.n_experts else 0,
            dense_d_ff=min(self.ffn_dense, 512),
            n_dense_layers=min(self.n_dense_layers, 1),
            q_lora_rank=min(self.q_lora_rank, 64),
            kv_lora_rank=min(self.kv_lora_rank, 32),
            qk_nope_dim=min(self.qk_nope_dim, 32),
            qk_rope_dim=min(self.qk_rope_dim, 16),
            v_head_dim=min(self.v_head_dim, 32),
            rwkv_head_size=min(self.rwkv_head_size, 32),
            n_encoder_layers=min(self.n_encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 64),
            n_patches=min(self.n_patches, 16),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
        )
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
