"""stablelm-3b [dense] — MHA (kv = heads). [hf:stabilityai/stablelm family]

Assigned spec: 32L d_model=2560 32H (GQA kv=32) d_ff=6912 vocab=50304.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab=50304,
    rope_theta=1e4,
    long_context="long_500k via SWA variant (long_window=8192)",
    optimizer="adamw",
)
