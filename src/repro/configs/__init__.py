"""Config registry: the 10 assigned architectures (+ reduced smoke variants
via ArchConfig.reduced()) and the paper's own simulation settings."""
from .base import INPUT_SHAPES, ArchConfig, InputShape
from .deepseek_v3_671b import CONFIG as deepseek_v3_671b
from .granite_moe_3b_a800m import CONFIG as granite_moe_3b_a800m
from .qwen1_5_110b import CONFIG as qwen1_5_110b
from .whisper_base import CONFIG as whisper_base
from .stablelm_3b import CONFIG as stablelm_3b
from .yi_6b import CONFIG as yi_6b
from .jamba_v0_1_52b import CONFIG as jamba_v0_1_52b
from .rwkv6_7b import CONFIG as rwkv6_7b
from .qwen2_7b import CONFIG as qwen2_7b
from .qwen2_vl_2b import CONFIG as qwen2_vl_2b

ARCHS: dict[str, ArchConfig] = {
    c.name: c
    for c in (
        deepseek_v3_671b,
        granite_moe_3b_a800m,
        qwen1_5_110b,
        whisper_base,
        stablelm_3b,
        yi_6b,
        jamba_v0_1_52b,
        rwkv6_7b,
        qwen2_7b,
        qwen2_vl_2b,
    )
}


def get_config(name: str) -> ArchConfig:
    if name.endswith("-smoke"):
        return get_config(name[: -len("-smoke")]).reduced()
    try:
        return ARCHS[name]
    except KeyError:
        raise ValueError(f"unknown arch {name!r}; choose from {sorted(ARCHS)}")


__all__ = ["ArchConfig", "InputShape", "INPUT_SHAPES", "ARCHS", "get_config"]
