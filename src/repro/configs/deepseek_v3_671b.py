"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP.
[arXiv:2412.19437]

Assigned spec: 61L d_model=7168 128H (kv=128 -> MLA latent) d_ff=2048
vocab=129280, MoE 256e top-8.  d_ff=2048 is the routed-expert hidden; the
3 leading dense layers use 18432 (= 9 x 2048, the DS-V3 paper value).
MLA makes the effective kv "heads" a 512-dim latent + 64-dim rope key.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_head=128,
    d_ff=2048,
    dense_d_ff=18432,
    moe_d_ff=2048,
    vocab=129280,
    rope_theta=1e4,
    # MoE
    n_experts=256,
    top_k=8,
    n_shared_experts=1,
    moe_every=1,
    n_dense_layers=3,
    # MLA
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    # long_500k served via MLA latent cache + sliding window
    long_context="long_500k via SWA variant (long_window=8192)",
    mtp=True,
    optimizer="adafactor",  # Adam states (~14 B/param) exceed v5e HBM at 671B
)
