"""rwkv6-7b [ssm] — "Finch", attention-free, data-dependent decay.
[arXiv:2404.05892]

Assigned spec: 32L d_model=4096 (attn-free) d_ff=14336 vocab=65536.
head_size 64 -> 64 WKV heads; per-layer state is (B, 64, 64, 64) fp32.
long_500k is natively servable: the recurrent state is O(1) in sequence
length — this arch is the paper's best case for the long-context shape.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=14336,
    vocab=65536,
    ssm_type="rwkv6",
    rwkv_head_size=64,
    long_context="native (constant-size WKV state)",
    optimizer="adamw",
)
