"""qwen2-7b [dense] — GQA kv=4, QKV bias. [arXiv:2407.10671]

Assigned spec: 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1e6,
    long_context="long_500k via SWA variant (long_window=8192)",
    optimizer="adamw",
)
