"""WKV6 recurrence (RWKV-6 "Finch" time-mix) as a Pallas TPU kernel.

    S_t[i,j] = w_t[i] S_{t-1}[i,j] + k_t[i] v_t[j]
    y_t[j]   = sum_i r_t[i] (S_{t-1}[i,j] + u[i] k_t[i] v_t[j])

TPU adaptation: the recurrence is O(hs^2) per head-step and strictly
sequential in time, so the kernel tiles (batch*head) over the parallel grid
axis and streams the time axis in VMEM blocks of `bt` steps; the (hs, hs)
state lives in VMEM scratch and persists across the sequential time-grid
steps. Each time step is an outer-product + reduction on (hs, hs) = (64, 64)
tiles — VPU-friendly, no HBM round-trips for the state (the CUDA reference
keeps state in registers/shared memory; VMEM scratch is the TPU analogue).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["wkv6_call"]


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                 y_ref, sout_ref, state, *, bt, nt):
    it = pl.program_id(1)

    @pl.when(it == 0)
    def _init():
        state[...] = s0_ref[...][0].astype(jnp.float32)

    def step(t, _):
        r = r_ref[0, t].astype(jnp.float32)   # (hs,)
        k = k_ref[0, t].astype(jnp.float32)
        v = v_ref[0, t].astype(jnp.float32)
        w = w_ref[0, t].astype(jnp.float32)
        u = u_ref[0].astype(jnp.float32)
        kv = k[:, None] * v[None, :]          # (hs, hs)
        y = ((state[...] + u[:, None] * kv) * r[:, None]).sum(axis=0)
        pl.store(y_ref, (pl.dslice(0, 1), pl.dslice(t, 1), slice(None)),
                 y[None, None].astype(y_ref.dtype))
        state[...] = w[:, None] * state[...] + kv
        return 0

    jax.lax.fori_loop(0, bt, step, 0)

    @pl.when(it == nt - 1)
    def _finish():
        # Full-block store: integer-indexed ref writes hit a discharge bug
        # in interpret mode on this jax version.
        sout_ref[...] = state[...][None].astype(sout_ref.dtype)


def wkv6_call(r, k, v, w, u, s0, *, bt: int = 128, interpret: bool = False):
    """r,k,v,w: (BH, T, hs); u: (BH, hs); s0: (BH, hs, hs).
    Returns (y (BH, T, hs), s_final (BH, hs, hs))."""
    bh, t, hs = r.shape
    bt = min(bt, t)
    assert t % bt == 0, (t, bt)
    nt = t // bt

    kernel = functools.partial(_wkv6_kernel, bt=bt, nt=nt)
    return pl.pallas_call(
        kernel,
        grid=(bh, nt),
        in_specs=[
            pl.BlockSpec((1, bt, hs), lambda b, i: (b, i, 0)),  # r
            pl.BlockSpec((1, bt, hs), lambda b, i: (b, i, 0)),  # k
            pl.BlockSpec((1, bt, hs), lambda b, i: (b, i, 0)),  # v
            pl.BlockSpec((1, bt, hs), lambda b, i: (b, i, 0)),  # w
            pl.BlockSpec((1, hs), lambda b, i: (b, 0)),         # u
            pl.BlockSpec((1, hs, hs), lambda b, i: (b, 0, 0)),  # s0
        ],
        out_specs=[
            pl.BlockSpec((1, bt, hs), lambda b, i: (b, i, 0)),  # y
            pl.BlockSpec((1, hs, hs), lambda b, i: (b, 0, 0)),  # s_final
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, hs), r.dtype),
            jax.ShapeDtypeStruct((bh, hs, hs), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hs, hs), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
