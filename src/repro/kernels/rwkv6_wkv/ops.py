"""Jitted wrapper: model layout (B, T, H, hs) -> kernel layout (B*H, T, hs).

Drop-in replacement for repro.models.ssm.wkv6_scan_ref (pass as `wkv_impl`
to rwkv6_time_mix on TPU)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import wkv6_call

__all__ = ["wkv6_pallas"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("bt", "interpret"))
def wkv6_pallas(r, k, v, w, u, state, *, bt: int = 128, interpret: bool | None = None):
    """Same signature/semantics as wkv6_scan_ref:
    r,k,v,w (B,T,H,hs); u (H,hs); state (B,H,hs,hs) -> (y (B,T,H,hs), state)."""
    if interpret is None:
        interpret = not _on_tpu()
    b, t, h, hs = r.shape
    to_k = lambda x: x.transpose(0, 2, 1, 3).reshape(b * h, t, hs)
    uf = jnp.broadcast_to(u[None], (b, h, hs)).reshape(b * h, hs)
    s0 = state.reshape(b * h, hs, hs)
    y, s_fin = wkv6_call(to_k(r), to_k(k), to_k(v), to_k(w), uf, s0,
                         bt=bt, interpret=interpret)
    y = y.reshape(b, h, t, hs).transpose(0, 2, 1, 3)
    return y, s_fin.reshape(b, h, hs, hs)
