"""Pure-jnp oracle for the WKV6 recurrence — re-exported from the model so
the kernel is validated against exactly what the model executes."""
from ...models.ssm import wkv6_scan_ref

__all__ = ["wkv6_scan_ref"]
