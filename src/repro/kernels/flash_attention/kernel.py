"""Flash attention for TPU: pl.pallas_call with explicit BlockSpec VMEM
tiling and an online-softmax accumulator held in VMEM scratch across the
sequential KV grid dimension.

TPU adaptation (vs. the CUDA flash-attention): no warp-level primitives —
the (bq, d) accumulator + (bq,) running max/denominator live in VMEM scratch
that persists across grid steps of the innermost (KV) grid axis, which the
TPU executes sequentially per core; block shapes default to MXU-aligned
(128, 128) tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel", "flash_attention_call"]

NEG_INF = -1e30


def flash_attention_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                           *, bq, bk, nk, scale, causal, window, q_offset):
    ik = pl.program_id(2)
    iq = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                    # (bq, d)
    k = k_ref[0].astype(jnp.float32)                    # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (bq, bk)

    rows = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset
    cols = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= cols <= rows
    if window > 0:
        mask &= cols > rows - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_call(q, k, v, *, bq: int = 128, bk: int = 128,
                         causal: bool = True, window: int = 0,
                         scale: float | None = None, interpret: bool = False):
    """q, k, v: (BH, S, D) flattened batch*heads. Returns (BH, Sq, D)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    nq, nk = sq // bq, sk // bk
    scale = d**-0.5 if scale is None else scale

    kernel = functools.partial(
        flash_attention_kernel,
        bq=bq, bk=bk, nk=nk, scale=scale, causal=causal, window=window,
        q_offset=sk - sq,  # right-aligned queries (prefill continuation)
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),   # output accumulator
            pltpu.VMEM((bq,), jnp.float32),     # running max
            pltpu.VMEM((bq,), jnp.float32),     # running denominator
        ],
        interpret=interpret,
    )(q, k, v)
