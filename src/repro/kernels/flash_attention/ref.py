"""Pure-jnp oracle for the flash-attention kernel: naive causal softmax
attention with optional sliding window. Shapes (B, H, S, D)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  scale: float | None = None):
    b, h, sq, d = q.shape
    sk = k.shape[2]
    scale = d**-0.5 if scale is None else scale
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    if causal or window:
        rows = jnp.arange(sq)[:, None] + (sk - sq)  # right-aligned queries
        cols = jnp.arange(sk)[None, :]
        mask = jnp.ones((sq, sk), bool)
        if causal:
            mask &= cols <= rows
        if window > 0:
            mask &= cols > rows - window
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)
