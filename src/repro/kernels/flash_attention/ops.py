"""Jitted public wrapper for the flash-attention kernel.

Accepts model-layout tensors (B, S, H, D) with grouped KV heads (Hkv <= Hq),
flattens to the kernel's (B*H, S, D) layout, and repeats KV heads per group.
On CPU (no TPU backend) it runs the kernel body in interpret mode.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import flash_attention_call

__all__ = ["flash_attention"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128, interpret: bool | None = None):
    """q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D) -> (B, Sq, Hq, D)."""
    if interpret is None:
        interpret = not _on_tpu()
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(b * hq, sk, d)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(b * hq, sk, d)
    of = flash_attention_call(
        qf, kf, vf, bq=bq, bk=bk, causal=causal, window=window, interpret=interpret
    )
    return of.reshape(b, hq, sq, d).transpose(0, 2, 1, 3)
