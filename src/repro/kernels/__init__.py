"""Pallas TPU kernels for the framework's compute hot spots, each with a
pure-jnp oracle (ref.py) and a jitted wrapper (ops.py):

  flash_attention   -- online-softmax attention, VMEM scratch accumulator
  rwkv6_wkv         -- chunked WKV6 recurrence, state in VMEM scratch
  fedavg_agg        -- fused selection-weighted FedAvg aggregation (eq. 34)
  polyblock_project -- fused 60-step bisection projection of Algorithm 1
                       (eqs. 27-29), the control-plane hot spot (DESIGN.md §6);
                       ref.py here is NumPy (it doubles as the host solver's
                       projection), the jnp oracle lives in ops.project_jnp

On CPU the wrappers run interpret=True (kernel bodies execute in Python);
on TPU they compile to Mosaic.
"""
