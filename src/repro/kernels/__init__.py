"""Pallas TPU kernels for the framework's compute hot spots, each with a
pure-jnp oracle (ref.py) and a jitted wrapper (ops.py):

  flash_attention -- online-softmax attention, VMEM scratch accumulator
  rwkv6_wkv       -- chunked WKV6 recurrence, state in VMEM scratch
  fedavg_agg      -- fused selection-weighted FedAvg aggregation (eq. 34)

On CPU the wrappers run interpret=True (kernel bodies execute in Python);
on TPU they compile to Mosaic.
"""
