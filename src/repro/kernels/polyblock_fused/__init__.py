from .ops import polyblock_solve_fused

__all__ = ["polyblock_solve_fused"]
