"""Host wrapper for the fully fused polyblock solve kernel.

Pads the flattened feasible-pair batch to (rows, 128) tiles (padding lanes
get the same harmless dummy element the projection kernel uses: beta = 1,
|h|^2 = 1, E^max = 1e9 — g(1, 1) < 0, so they retire after two iterations
without ever projecting below zeta = 1), invokes `polyblock_solve_call`,
and strips the padding.

Callers (the `backend="pallas"` branch of `core.monotonic_jax.
solve_pairs_fused`, the differential tests, `benchmarks/control_plane.py`)
pass Proposition-1 *feasible* pairs only; infeasibility is resolved before
the kernel, exactly as in the jnp drivers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ...core.wireless import WirelessConfig
from .kernel import polyblock_solve_call

__all__ = ["polyblock_solve_fused"]


def polyblock_solve_fused(beta, h2, e_max, cfg: WirelessConfig, *,
                          eps: float = 0.01, max_iter: int = 64,
                          n_bisect: int = 60, bm: int = 8,
                          interpret: bool | None = None, dtype=None):
    """Solve a flat batch of feasible (beta, |h|^2, E^max) pairs entirely
    inside one Pallas kernel.

    Returns (tau, p, time_s, iterations) as flat arrays of the input
    length; dtype defaults to float64 in interpret mode (bit-identical to
    the jnp `backend="bisect"` solver) and float32 compiled on TPU (the
    fp32-accumulation study, <= 1e-4 relative — DESIGN.md §13).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if dtype is None:
        dtype = np.float64 if interpret else np.float32
    dtype = jnp.dtype(dtype)

    betaf = jnp.asarray(beta, dtype).reshape(-1)
    h2f = jnp.asarray(h2, dtype).reshape(-1)
    emaxf = jnp.broadcast_to(jnp.asarray(e_max, dtype), h2f.shape).reshape(-1)
    n = int(h2f.shape[0])

    tile = bm * 128
    pad = (-n) % tile
    if pad:
        ones = jnp.ones(pad, dtype)
        betaf = jnp.concatenate([betaf, ones])
        h2f = jnp.concatenate([h2f, ones])
        emaxf = jnp.concatenate([emaxf, jnp.full(pad, 1e9, dtype)])

    shape2d = (-1, 128)
    tau, p, time_s, iters = polyblock_solve_call(
        betaf.reshape(shape2d), h2f.reshape(shape2d), emaxf.reshape(shape2d),
        eps=float(eps), max_iter=int(max_iter), n_bisect=int(n_bisect),
        kappa0_mu=cfg.kappa0 * cfg.mu_cycles, mu_cycles=cfg.mu_cycles,
        cpu_hz=cfg.cpu_hz, pt_w=cfg.pt_w, model_bits=cfg.model_bits,
        bandwidth_hz=cfg.bandwidth_hz, bm=bm, interpret=interpret,
    )
    unpad = lambda x: x.reshape(-1)[:n]
    return unpad(tau), unpad(p), unpad(time_s), unpad(iters)
