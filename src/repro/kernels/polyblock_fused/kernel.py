"""Whole-horizon Algorithm 1 as ONE Pallas kernel (DESIGN.md §13).

`kernels.polyblock_project` fused the *projection* (eqs. 27-29); the
surrounding polyblock loop — vertex store, selection (paper steps 9-10),
retirement (eq. 26), child splitting (eq. 23) and the store writes
(eq. 24) — still lived in `core.monotonic_jax` as separate XLA dispatches
with a host-visible sync schedule.  This kernel moves the entire solve
inside one `pallas_call`: each (bm, 128) tile of the flattened pair axis
loads beta, |h|^2 and E^max once, keeps the whole vertex store as a
(m, bm, 128) VMEM-resident loop carry, and runs every polyblock iteration
— selection, both child bisections, store update — on the VPU without
touching HBM until the final (tau*, p*, T*, iterations) write.

Replication contract (pinned by tests/test_fused_solver.py and
tests/test_kernels.py): the arithmetic below mirrors the jnp solver
operation-for-operation —

  * the energy constraint g of eq. (22) and the objective T of eq. (8)
    are spelled exactly like `wireless.total_energy` / `total_time`
    (constants folded at compile time, same guard epsilons, same
    evaluation order);
  * the projection is the 60-step bisection of `project_jnp` (same
    `mid = (lo + hi)/2`, same `g > 0` branch sense, same TINY floor);
  * selection replicates `jnp.argmax`'s first-max tie-break via a
    min-index reduction (`idx = min(where(f == fbest, slot, m))` — a
    plain argmax lowering is not guaranteed first-match on all backends);
  * every store write is masked by the active set, so retired lanes are
    frozen bit-exactly as in the phase-split driver.

So in float64 (interpret mode off-TPU) the kernel is *bit-identical* to
`solve_pairs_fused(backend="bisect")` including the per-pair iteration
count; in float32 (TPU compiled) it is the fp32-accumulation study's
subject: pairs whose eq.-26 retirement is decided clear of fp32 noise
(all but ~1% of a random batch) keep the f64 trajectory exactly and land
at <= 1e-4 relative, and a boundary pair (|Δf| within fp32 noise of
eps = 0.01) may retire one iteration early/late but stays within the
retirement tolerance itself, |T - T_f64| <= eps (DESIGN.md §13).

Layout: the vertex store needs max_iter + 1 slots (iteration t writes
child2 into slot t + 1), carried as five (m, bm, 128) arrays — at the
default bm = 8, max_iter = 64 that is ~1.7 MB in f32, comfortably
VMEM-resident.  Lanes are independent pairs; a tile exits its while_loop
as soon as every lane has retired (eq. 26), so tiles of easy pairs cost
only their own iterations.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["polyblock_solve_call"]

_TINY = 1e-12
_LN2 = math.log(2.0)


def _solve_kernel(beta_ref, h2_ref, emax_ref,
                  tau_ref, p_ref, time_ref, it_ref,
                  *, eps: float, max_iter: int, n_bisect: int,
                  kappa0_mu: float, mu_cycles: float, cpu_hz: float,
                  pt_w: float, model_bits: float, bandwidth_hz: float):
    beta = beta_ref[...]
    h2 = h2_ref[...]
    e_max = emax_ref[...]
    dt = beta.dtype
    shape = beta.shape
    m = max_iter + 1          # slot t + 1 is written at iteration t

    def energy(tau, p):
        # eq. (10), spelled as wireless.total_energy: E^cp + p P_t T^cm.
        e_cp = kappa0_mu * beta * (tau * cpu_hz) ** 2
        rate = bandwidth_hz * jnp.log1p(p * h2) / _LN2
        t_cm = model_bits / jnp.maximum(rate, 1e-30)
        return e_cp + p * pt_w * t_cm

    def neg_time(tau, p):
        # -T of eq. (8), spelled as wireless.total_time (f of eq. 21).
        t_cp = mu_cycles * beta / jnp.maximum(tau, 1e-30) / cpu_hz
        rate = bandwidth_hz * jnp.log1p(p * h2) / _LN2
        t_cm = model_bits / jnp.maximum(rate, 1e-30)
        return -(t_cp + t_cm)

    def project(tau_v, p_v):
        # eqs. (27-29): mirror of project_jnp's 60-step bisection.
        need = energy(tau_v, p_v) - e_max > 0.0

        def body(_, carry):
            lo, hi = carry
            mid = 0.5 * (lo + hi)
            take_hi = energy(mid * tau_v, mid * p_v) - e_max > 0.0
            return jnp.where(take_hi, lo, mid), jnp.where(take_hi, mid, hi)

        lo = jnp.full_like(tau_v, _TINY)
        hi = jnp.ones_like(tau_v)
        lo, _ = jax.lax.fori_loop(0, n_bisect, body, (lo, hi))
        zeta = jnp.where(need, lo, jnp.asarray(1.0, dt))
        return zeta * tau_v, zeta * p_v

    one = jnp.ones(shape, dt)
    pj0_tau, pj0_p = project(one, one)
    f0 = neg_time(pj0_tau, pj0_p)
    neg_inf = jnp.asarray(-jnp.inf, dt)

    # Vertex store: five (m, bm, 128) carries.  fval == -inf marks an
    # unwritten slot (the jnp driver's `valid` mask): written slots always
    # carry a finite f, since tau, p >= TINY * TINY keeps T finite.
    verts_tau = jnp.zeros((m,) + shape, dt).at[0].set(one)
    verts_p = jnp.zeros((m,) + shape, dt).at[0].set(one)
    proj_tau = jnp.zeros((m,) + shape, dt).at[0].set(pj0_tau)
    proj_p = jnp.zeros((m,) + shape, dt).at[0].set(pj0_p)
    vfval = jnp.full((m,) + shape, neg_inf, dt).at[0].set(f0)

    slot = jax.lax.broadcasted_iota(jnp.int32, (m,) + shape, 0)

    def cond(carry):
        t, *_, active, _pb, _bf, _bt, _bp, _it, _nv = carry
        return (t < max_iter) & active.any()

    def body(carry):
        (t, verts_tau, verts_p, proj_tau, proj_p, vfval,
         active, prev_best, best_f, best_tau, best_p, iters, nvalid) = carry

        # Selection half-step (paper steps 9-10).  First-max tie-break as
        # a min-index reduction over the slot axis.
        fbest = jnp.max(vfval, axis=0)
        idx = jnp.min(jnp.where(vfval == fbest[None], slot, m), axis=0)
        sel = slot == idx[None]
        zero = jnp.zeros(shape, dt)
        sel_ptau = jnp.sum(jnp.where(sel, proj_tau, zero), axis=0)
        sel_pp = jnp.sum(jnp.where(sel, proj_p, zero), axis=0)
        improved = fbest > best_f
        best_f = jnp.where(improved, fbest, best_f)
        best_tau = jnp.where(improved, sel_ptau, best_tau)
        best_p = jnp.where(improved, sel_pp, best_p)
        done = jnp.abs(fbest - prev_best) <= eps        # eq. (26)
        prev_best = fbest
        active = active & ~done
        iters = iters + active.astype(jnp.int32)

        # Children half-step (paper steps 11-13, eq. 23): split the chosen
        # vertex at its projection, project both children.
        v_tau = jnp.sum(jnp.where(sel, verts_tau, zero), axis=0)
        v_p = jnp.sum(jnp.where(sel, verts_p, zero), axis=0)
        c1_tau, c1_p = project(sel_ptau, v_p)           # child1 = (phi_t, v_p)
        c2_tau, c2_p = project(v_tau, sel_pp)           # child2 = (v_t, phi_p)
        f1 = neg_time(c1_tau, c1_p)
        f2 = neg_time(c2_tau, c2_p)

        # eq. (24): child1 replaces the split slot, child2 takes the first
        # free one; both writes masked by `active` so retired lanes freeze.
        mask1 = sel & active[None]
        mask2 = (slot == nvalid[None]) & active[None]
        verts_tau = jnp.where(mask1, sel_ptau[None],
                              jnp.where(mask2, v_tau[None], verts_tau))
        verts_p = jnp.where(mask1, v_p[None],
                            jnp.where(mask2, sel_pp[None], verts_p))
        proj_tau = jnp.where(mask1, c1_tau[None],
                             jnp.where(mask2, c2_tau[None], proj_tau))
        proj_p = jnp.where(mask1, c1_p[None],
                           jnp.where(mask2, c2_p[None], proj_p))
        vfval = jnp.where(mask1, f1[None],
                          jnp.where(mask2, f2[None], vfval))
        nvalid = nvalid + active.astype(jnp.int32)

        return (t + 1, verts_tau, verts_p, proj_tau, proj_p, vfval,
                active, prev_best, best_f, best_tau, best_p, iters, nvalid)

    carry = (jnp.int32(0), verts_tau, verts_p, proj_tau, proj_p, vfval,
             jnp.ones(shape, bool), jnp.full(shape, jnp.inf, dt),
             f0, pj0_tau, pj0_p,
             jnp.zeros(shape, jnp.int32), jnp.ones(shape, jnp.int32))
    carry = jax.lax.while_loop(cond, body, carry)
    (_, _, _, _, _, _, _, _, best_f, best_tau, best_p, iters, _) = carry

    tau_ref[...] = best_tau.astype(tau_ref.dtype)
    p_ref[...] = best_p.astype(p_ref.dtype)
    time_ref[...] = (-best_f).astype(time_ref.dtype)
    it_ref[...] = iters


def polyblock_solve_call(beta, h2, e_max, *, eps: float, max_iter: int,
                         n_bisect: int, kappa0_mu: float, mu_cycles: float,
                         cpu_hz: float, pt_w: float, model_bits: float,
                         bandwidth_hz: float, bm: int = 8,
                         interpret: bool = False):
    """All operands (rows, 128), rows % bm == 0 -> (tau, p, time_s, iters)
    of the same shape (iters int32)."""
    rows, lanes = beta.shape
    assert lanes == 128 and rows % bm == 0, (beta.shape, bm)
    dt = beta.dtype
    kern = partial(
        _solve_kernel, eps=eps, max_iter=max_iter, n_bisect=n_bisect,
        kappa0_mu=kappa0_mu, mu_cycles=mu_cycles, cpu_hz=cpu_hz, pt_w=pt_w,
        model_bits=model_bits, bandwidth_hz=bandwidth_hz,
    )
    spec = pl.BlockSpec((bm, 128), lambda i: (i, 0))
    return pl.pallas_call(
        kern,
        grid=(rows // bm,),
        in_specs=[spec] * 3,
        out_specs=(spec, spec, spec, spec),
        out_shape=(
            jax.ShapeDtypeStruct((rows, 128), dt),
            jax.ShapeDtypeStruct((rows, 128), dt),
            jax.ShapeDtypeStruct((rows, 128), dt),
            jax.ShapeDtypeStruct((rows, 128), jnp.int32),
        ),
        interpret=interpret,
    )(beta, h2, e_max)
