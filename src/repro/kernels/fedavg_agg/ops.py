"""Jitted wrapper: aggregate an entire stacked parameter PYTREE in one
kernel sweep (leaves are flattened, padded to the block size, concatenated,
aggregated, and unflattened back)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import fedavg_agg_call

__all__ = ["fedavg_aggregate", "fedavg_aggregate_tree"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("bn", "interpret"))
def fedavg_aggregate(stacked, weights, *, bn: int = 2048, interpret: bool | None = None):
    """stacked (K, N), weights (K,) -> (N,)."""
    if interpret is None:
        interpret = not _on_tpu()
    k, n = stacked.shape
    pad = (-n) % bn
    if pad:
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
    out = fedavg_agg_call(stacked, weights, bn=bn, interpret=interpret)
    return out[:n]


def fedavg_aggregate_tree(client_params, weights, *, bn: int = 2048,
                          interpret: bool | None = None):
    """client_params: pytree with leading slot axis K on every leaf.
    Returns the aggregated pytree (eq. 34)."""
    leaves, treedef = jax.tree_util.tree_flatten(client_params)
    k = leaves[0].shape[0]
    sizes = [int(x.size) // k for x in leaves]
    flat = jnp.concatenate([x.reshape(k, -1).astype(jnp.float32) for x in leaves], axis=1)
    agg = fedavg_aggregate(flat, weights, bn=bn, interpret=interpret)
    out, off = [], 0
    for x, sz in zip(leaves, sizes):
        out.append(agg[off : off + sz].reshape(x.shape[1:]).astype(x.dtype))
        off += sz
    return jax.tree_util.tree_unflatten(treedef, out)
