"""Pure-jnp oracle for the selection-masked weighted FedAvg aggregation
(paper eq. 34): w_new = sum_n weight_n * theta_n / sum_n weight_n, with
weight_n = S_n * (sum_k psi_kn) * beta_n and zero-weight slots ignored."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["fedavg_agg_ref"]


def fedavg_agg_ref(stacked, weights):
    """stacked: (K, N) client tensors (flattened params); weights: (K,).
    Returns (N,) = weighted mean over the leading axis (0 if all weights 0)."""
    wsum = jnp.maximum(weights.sum(), 1e-30)
    return jnp.einsum(
        "k,kn->n", (weights / wsum).astype(jnp.float32), stacked.astype(jnp.float32)
    ).astype(stacked.dtype)
