"""Selection-weighted FedAvg aggregation (paper eq. 34) as a Pallas kernel.

The server-side aggregation touches K x |params| bytes every round — at
framework scale (K clients x 10^8..10^9 params) it is memory-bound, so the
kernel fuses the weighting, reduction and normalization into one pass over
HBM: grid tiles the flattened parameter axis; each step loads a (K, bn)
VMEM block, multiplies by the normalized weight vector and reduces.  One
read of the stacked updates, one write of the aggregate — vs. the naive
K-pass tree_map (read K times + K-1 intermediate writes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fedavg_agg_call"]


def _agg_kernel(x_ref, w_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)            # (K, bn)
    w = w_ref[...].astype(jnp.float32)            # (K,)
    wsum = jnp.maximum(w.sum(), 1e-30)
    o_ref[...] = ((w / wsum) @ x).astype(o_ref.dtype)


def fedavg_agg_call(stacked, weights, *, bn: int = 2048, interpret: bool = False):
    """stacked: (K, N); weights: (K,) -> (N,) weighted mean."""
    k, n = stacked.shape
    bn = min(bn, n)
    assert n % bn == 0, (n, bn)
    return pl.pallas_call(
        _agg_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((k, bn), lambda i: (0, i)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), stacked.dtype),
        interpret=interpret,
    )(stacked, weights)
