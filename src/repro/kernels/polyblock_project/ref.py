"""NumPy reference for the fused polyblock projection (paper eqs. 27-29).

Projection phi(v) = zeta * v of a vertex v = (tau, p) onto the upper boundary
of the feasible set G = {z : g(z) <= 0}, where g is the energy constraint of
eq. (22).  g is strictly increasing in zeta (Proposition 2), so the root of
g(zeta * v) = 0 is found by bisection: `n_bisect` halvings of (0, 1], keeping
the lo side so the returned point satisfies g <= 0 (feasible).  When the
vertex itself is feasible (g(v) <= 0), zeta = 1 — the paper's theta=1 corner
case.

This is the canonical host-side implementation: `core.monotonic._project`
delegates here, and the Pallas kernel (`kernel.py`) plus the fused jnp path
(`ops.py`) must match it (see tests/test_monotonic_jax.py).  DESIGN.md §5-6.
"""
from __future__ import annotations

import numpy as np

from ...core.wireless import WirelessConfig, total_energy

__all__ = ["project_ref", "TINY"]

TINY = 1e-12


def project_ref(v, beta, h2, e_max, cfg: WirelessConfig, *, n_bisect: int = 60):
    """Project vertices v[..., 2] = (tau, p) onto the boundary of G.

    All of beta / h2 / e_max broadcast against v[..., 0]. Returns zeta * v.
    """

    def g_con(tau, p):
        return total_energy(tau, p, beta, h2, cfg) - e_max

    tau_v, p_v = v[..., 0], v[..., 1]
    g_at_v = g_con(tau_v, p_v)
    need_root = g_at_v > 0.0

    lo = np.full_like(tau_v, TINY)
    hi = np.ones_like(tau_v)
    for _ in range(n_bisect):
        mid = 0.5 * (lo + hi)
        g_mid = g_con(mid * tau_v, mid * p_v)
        take_hi = g_mid > 0.0
        hi = np.where(take_hi, mid, hi)
        lo = np.where(take_hi, lo, mid)
    zeta = np.where(need_root, lo, 1.0)  # lo side keeps g <= 0 (feasible)
    return zeta[..., None] * v
