"""Dispatcher for the fused polyblock projection.

Four interchangeable backends (tests assert pairwise agreement):

  "ref"    — host NumPy bisection (`ref.py`), float64; also what the legacy
             `core.monotonic._project` runs.
  "bisect" — fused jax.numpy `lax.fori_loop` mirror of "ref" (same
             arithmetic in the same order), jit/vmap-safe; float64 under an
             `jax.experimental.enable_x64` scope.  Alias: "jnp".
  "newton" — safeguarded Newton-bisection hybrid: each step evaluates g of
             eq. (22) ONCE (the expensive log1p is shared between g and g'),
             takes the Newton step when it stays inside the current bracket
             and falls back to the midpoint otherwise.  Quadratic
             convergence reaches the float64 root in ~8 engaged steps, so
             `n_steps` = 16 replaces the reference's 60 bisections (~4x
             fewer constraint evaluations) while agreeing with it to
             ~1e-12 in zeta — this is the jitted solver's CPU default.
  "pallas" — the VMEM-resident kernel (`kernel.py`), float32 (TPU has no
             f64). Default on TPU; interpret-mode elsewhere.

`project_jnp` / `project_newton` are exported separately because
`core.monotonic_jax` embeds them inside its jitted solver steps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ...core.wireless import WirelessConfig, total_energy
from .kernel import polyblock_project_call
from .ref import TINY, project_ref

__all__ = ["polyblock_project", "project_jnp", "project_newton",
           "project_newton_mixed", "project_pallas"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def project_jnp(v, beta, h2, e_max, cfg: WirelessConfig, *, n_bisect: int = 60):
    """Fused jnp mirror of `ref.project_ref` (same arithmetic, same order)."""
    tau_v, p_v = v[..., 0], v[..., 1]

    def g_con(tau, p):
        return total_energy(tau, p, beta, h2, cfg) - e_max

    need_root = g_con(tau_v, p_v) > 0.0

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        take_hi = g_con(mid * tau_v, mid * p_v) > 0.0
        return jnp.where(take_hi, lo, mid), jnp.where(take_hi, mid, hi)

    lo = jnp.full_like(tau_v, TINY)
    hi = jnp.ones_like(tau_v)
    lo, _ = jax.lax.fori_loop(0, n_bisect, body, (lo, hi))
    zeta = jnp.where(need_root, lo, 1.0)
    return zeta[..., None] * v


def project_newton(v, beta, h2, e_max, cfg: WirelessConfig, *,
                   n_steps: int = 14):
    """Safeguarded log-space Newton root of g(zeta * v) = 0 on (0, 1].

    Newton in y = log(zeta) — cand = x * exp(-g / (x g')) — so convergence is
    scale-free: roots spanning many decades (they reach ~1e-4 for weak
    channels) are approached multiplicatively, where linear-space Newton
    stagnates against its bracket.  The bisection bracket [lo, hi] is kept
    for guaranteed convergence with a *geometric*-mean fallback whenever the
    candidate leaves the open bracket (NaN/inf candidates — e.g. padded rows
    with e_max = inf — fail the comparison too, keeping them harmless).  With

        g(x)  = a x^2 + b x / L(cx) - e_max,   L = log1p,
        g'(x) = 2 a x + b (L(cx) - cx/(1 + cx)) / L(cx)^2,

    where a = kappa0 mu beta (tau_v C)^2, b = p_v P_t D ln2 / B and
    c = p_v |h|^2, so g and g' share one log1p per step.

    Warm start: as zeta -> 0 the communication term flattens to its
    Proposition-1 infimum b/c, so x0 = sqrt((e_max - b/c) / a) is the exact
    root of the low-SNR limit — Newton then only corrects the rate curvature.
    14 steps reproduce the reference 60-step bisection root to ~1e-9
    relative on Prop-1 feasible pairs (tests/test_monotonic_jax.py) at ~4x
    fewer transcendental evaluations.
    """
    tau_v, p_v = v[..., 0], v[..., 1]
    a = cfg.kappa0 * cfg.mu_cycles * beta * (tau_v * cfg.cpu_hz) ** 2
    b = p_v * cfg.pt_w * cfg.model_bits * np.log(2.0) / cfg.bandwidth_hz
    c = p_v * h2

    def g_gp(x):
        u = c * x
        el = jnp.log1p(u)
        elc = jnp.maximum(el, 1e-300)
        g = a * x * x + b * x / elc - e_max
        gp = 2.0 * a * x + b * (el - u / (1.0 + u)) / (elc * elc)
        return g, gp

    need_root = g_gp(jnp.ones_like(tau_v))[0] > 0.0
    x0 = jnp.sqrt(jnp.maximum(e_max - b / jnp.maximum(c, 1e-300), 1e-300)
                  / jnp.maximum(a, 1e-300))
    x0 = jnp.clip(x0, TINY, 1.0 - 1e-9)

    def body(_, carry):
        lo, hi, x = carry
        g, gp = g_gp(x)
        pos = g > 0.0
        lo = jnp.where(pos, lo, x)
        hi = jnp.where(pos, x, hi)
        cand = x * jnp.exp(-g / (x * gp))
        ok = (cand > lo) & (cand < hi)
        return lo, hi, jnp.where(ok, cand, jnp.sqrt(lo * hi))

    lo = jnp.full_like(tau_v, TINY)
    hi = jnp.ones_like(tau_v)
    lo, hi, x = jax.lax.fori_loop(0, n_steps, body, (lo, hi, x0))
    zeta = jnp.where(need_root, jnp.clip(x, TINY, 1.0), 1.0)
    return zeta[..., None] * v


def project_newton_mixed(v, beta, h2, e_max, cfg: WirelessConfig, *,
                         n_f32: int = 6, n_f64: int = 2, x0_hint=None):
    """Mixed-precision Newton: fp32 bulk iterations + fp64 polish.

    The fp32-accumulation study behind the fused solver (DESIGN.md §13):
    the safeguarded log-space Newton loop is precision-agnostic, and on CPU
    the fp32 `log1p`/`exp` run at twice the SIMD width of fp64, so the bulk
    of the bracket contraction is done in fp32 (rel error ~1e-7 at the f32
    root), then `n_f64` safeguarded fp64 steps restart from that root —
    Newton's quadratic convergence turns 1e-7 into ~1e-14 in one engaged
    step, so the polished root matches `project_newton`'s to ~1e-12
    relative.  The `need_root` boundary test (g(v) > 0) runs in fp64:
    pairs with g(v) within fp32 noise of zero must classify exactly like
    the reference, or a spurious projection shifts the vertex by ~1e-7.

    Only sound where the fp32 loop lands inside the basin of quadratic
    convergence — the warm start (exact low-SNR root) makes that hold at
    Table-I physics; the fp64 safeguard bracket keeps stragglers convergent
    rather than wrong.  tests/test_monotonic_jax.py pins this to the f64
    backends at 1e-9.
    """
    v64 = jnp.asarray(v)
    tau_v, p_v = v64[..., 0], v64[..., 1]
    a = cfg.kappa0 * cfg.mu_cycles * beta * (tau_v * cfg.cpu_hz) ** 2
    b = p_v * cfg.pt_w * cfg.model_bits * np.log(2.0) / cfg.bandwidth_hz
    c = p_v * h2

    def g_gp(x):
        u = c * x
        el = jnp.log1p(u)
        elc = jnp.maximum(el, 1e-300)
        g = a * x * x + b * x / elc - e_max
        gp = 2.0 * a * x + b * (el - u / (1.0 + u)) / (elc * elc)
        return g, gp

    # fp32 bulk: same loop as project_newton, all operands cast down.
    f32 = jnp.float32
    a32, b32, c32 = a.astype(f32), b.astype(f32), c.astype(f32)
    e32 = jnp.asarray(e_max).astype(f32)

    def g_gp32(x):
        u = c32 * x
        el = jnp.log1p(u)
        elc = jnp.maximum(el, f32(1e-38))
        g = a32 * x * x + b32 * x / elc - e32
        gp = 2.0 * a32 * x + b32 * (el - u / (1.0 + u)) / (elc * elc)
        return g, gp

    # Warm start, regime-split.  `project_newton` starts every row at the
    # low-SNR-limit root sqrt(q / a), q = e_max - b/c — exact when the
    # quadratic compute term dominates, but near the Prop-1 feasibility
    # boundary the root drops to ~1e-3 where the *linear* comm correction
    # dominates (a x^2 << b x / 2) and the sqrt start overshoots by orders
    # of magnitude (those rows are why the cold loop needs 14 steps).  One
    # order deeper, L(u) = u (1 - u/2) + O(u^3) flattens the constraint to
    # a x^2 + (b/2) x - q = 0, whose positive root (Muller's form,
    # cancellation-free as either coefficient vanishes) is near-exact
    # precisely when its own expansion variable u = c x stays small — so
    # each row picks the quadratic start when it is self-consistent
    # (c x_quad < 1/2) and the sqrt start otherwise.
    q = jnp.maximum(e32 - b32 / jnp.maximum(c32, f32(1e-38)), f32(1e-38))
    bh = 0.5 * b32
    a_s = jnp.maximum(a32, f32(1e-38))
    x_quad = 2.0 * q / (bh + jnp.sqrt(bh * bh + 4.0 * a_s * q))
    x_sqrt = jnp.sqrt(q / a_s)
    x0 = jnp.where(c32 * x_quad < 0.5, x_quad, x_sqrt)
    if x0_hint is not None:
        # Polyblock children shrink one coordinate of their parent, and the
        # per-device energy of eq. (10) is increasing in both tau and p, so
        # g_child <= g_parent pointwise and the parent's root zeta_par is a
        # lower bound on the child's: starting at max(low-SNR root,
        # zeta_par) puts every row inside the quadratic basin (the root
        # moved *up* from a known point), where the cold start is only exact
        # in the low-SNR limit.  Non-finite hints (retired rows carry junk
        # slots) fall back to the cold start.
        h32 = jnp.asarray(x0_hint).astype(f32)
        x0 = jnp.where(jnp.isfinite(h32), jnp.maximum(x0, h32), x0)
    x0 = jnp.clip(x0, f32(TINY), f32(1.0 - 1e-7))

    def body32(_, carry):
        # Boundary-EQUAL candidates are accepted (>=): at fp32 convergence
        # g rounds to exactly 0, the bracket endpoint is set to x itself,
        # and cand == x == lo — the strict test would hand a converged root
        # to the geometric fallback, which hurls it to sqrt(root * 1).
        lo, hi, x = carry
        g, gp = g_gp32(x)
        pos = g > 0.0
        lo = jnp.where(pos, lo, x)
        hi = jnp.where(pos, x, hi)
        cand = x * jnp.exp(-g / (x * gp))
        ok = (cand >= lo) & (cand <= hi)
        return lo, hi, jnp.where(ok, cand, jnp.sqrt(lo * hi))

    lo32 = jnp.full_like(x0, f32(TINY))
    hi32 = jnp.ones_like(x0)
    _, _, x32 = jax.lax.fori_loop(0, n_f32, body32, (lo32, hi32, x0))

    # fp64 polish: fresh safeguard bracket, start at the fp32 root.  Unlike
    # the cold-start loop, the fallback *keeps x* rather than jumping to the
    # bracket's geometric mean: at exact convergence the Newton candidate
    # rounds onto a bracket endpoint (cand == x == lo or hi), and with only
    # a handful of polish steps the bracket can still be one-sided, so the
    # geometric fallback would hurl a converged root to sqrt(root * 1).
    # Boundary-equal candidates are accepted (>=); NaN/runaway candidates
    # fail the comparison and leave x unchanged.
    need_root = g_gp(jnp.ones_like(tau_v))[0] > 0.0
    x = jnp.clip(x32.astype(tau_v.dtype), TINY, 1.0 - 1e-12)

    def body64(_, carry):
        # Halley instead of Newton: g'' is algebraic once log1p(u) is in
        # hand — with F = x / L and w = u / (1 + u),
        #   F'' = c t (1 - t) / L^2 - 2 c t (L - w) / L^3,   t = 1/(1 + u),
        # so the third-order step costs the same single transcendental as a
        # Newton step (and skips the log-space exp: the fp32 bulk already
        # landed near the root, where the linear step is safe inside the
        # bracket).  Cubic convergence turns the bulk's ~1e-4 into ~1e-12
        # in ONE engaged step where Newton needs two.
        lo, hi, x = carry
        u = c * x
        el = jnp.log1p(u)
        elc = jnp.maximum(el, 1e-300)
        t1 = 1.0 / (1.0 + u)
        w = u * t1
        g = a * x * x + b * x / elc - e_max
        gp = 2.0 * a * x + b * (el - w) / (elc * elc)
        g2 = 2.0 * a + b * c * t1 * ((1.0 - t1) / (elc * elc)
                                     - 2.0 * (el - w) / (elc * elc * elc))
        pos = g > 0.0
        lo = jnp.where(pos, lo, x)
        hi = jnp.where(pos, x, hi)
        cand = x - 2.0 * g * gp / (2.0 * gp * gp - g * g2)
        ok = (cand >= lo) & (cand <= hi)
        return lo, hi, jnp.where(ok, cand, x)

    lo = jnp.full_like(tau_v, TINY)
    hi = jnp.ones_like(tau_v)
    _, _, x = jax.lax.fori_loop(0, n_f64, body64, (lo, hi, x))
    zeta = jnp.where(need_root, jnp.clip(x, TINY, 1.0), 1.0)
    return zeta[..., None] * v64


def project_pallas(v, beta, h2, e_max, cfg: WirelessConfig, *,
                   n_bisect: int = 60, bm: int = 8, interpret: bool | None = None):
    """Pad + tile the flattened batch to (rows, 128) and run the kernel."""
    if interpret is None:
        interpret = not _on_tpu()
    v = jnp.asarray(v, jnp.float32)
    shape = v.shape[:-1]
    n = int(np.prod(shape)) if shape else 1
    flat = lambda x: jnp.broadcast_to(jnp.asarray(x, jnp.float32), shape).reshape(-1)
    tau_v, p_v = v[..., 0].reshape(-1), v[..., 1].reshape(-1)
    betaf, h2f, emaxf = flat(beta), flat(h2), flat(e_max)

    tile = bm * 128
    pad = (-n) % tile
    if pad:
        # Padding lanes bisect a harmless dummy element (g(1,1) <= 0 there).
        ones = jnp.ones(pad, jnp.float32)
        tau_v, p_v = jnp.concatenate([tau_v, ones]), jnp.concatenate([p_v, ones])
        betaf = jnp.concatenate([betaf, ones])
        h2f = jnp.concatenate([h2f, ones])
        emaxf = jnp.concatenate([emaxf, jnp.full(pad, 1e9, jnp.float32)])
    shape2d = (-1, 128)
    zeta = polyblock_project_call(
        tau_v.reshape(shape2d), p_v.reshape(shape2d), betaf.reshape(shape2d),
        h2f.reshape(shape2d), emaxf.reshape(shape2d),
        n_bisect=n_bisect, kappa0_mu=cfg.kappa0 * cfg.mu_cycles,
        cpu_hz=cfg.cpu_hz, pt_w=cfg.pt_w, model_bits=cfg.model_bits,
        bandwidth_hz=cfg.bandwidth_hz, bm=bm, interpret=interpret,
    )
    zeta = zeta.reshape(-1)[:n].reshape(shape)
    return zeta[..., None] * v


def polyblock_project(v, beta, h2, e_max, cfg: WirelessConfig, *,
                      n_bisect: int = 60, backend: str | None = None,
                      interpret: bool | None = None):
    """Project a batch of vertices.

    backend: None (auto: "pallas" on TPU else "newton"), "ref", "bisect"
    (alias "jnp"), "newton", "mixed" (fp32-bulk/fp64-polish Newton, the
    fused solver's CPU default), or "pallas".
    """
    if backend is None:
        backend = "pallas" if _on_tpu() else "newton"
    if backend == "ref":
        return project_ref(v, beta, h2, e_max, cfg, n_bisect=n_bisect)
    if backend in ("bisect", "jnp"):
        return project_jnp(jnp.asarray(v), beta, h2, e_max, cfg, n_bisect=n_bisect)
    if backend == "newton":
        return project_newton(jnp.asarray(v), beta, h2, e_max, cfg)
    if backend == "mixed":
        return project_newton_mixed(jnp.asarray(v), beta, h2, e_max, cfg)
    if backend == "pallas":
        return project_pallas(v, beta, h2, e_max, cfg,
                              n_bisect=n_bisect, interpret=interpret)
    raise ValueError(f"unknown backend: {backend}")
