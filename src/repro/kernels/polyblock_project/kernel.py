"""Fused polyblock projection (eqs. 27-29) as a Pallas kernel.

The projection is the inner loop of Algorithm 1: every polyblock iteration
projects two child vertices per active pair, and each projection runs
`n_bisect` (= 60) evaluations of the energy constraint g of eq. (22).  Done
naively that is 60 round trips through HBM per (pair, vertex) batch; at
framework scale (rounds x K x N pairs solved in one whole-horizon sweep,
DESIGN.md §6) the traffic is pure overhead because the working set per
element is five scalars.

The kernel therefore fuses the entire bisection — g-evaluation at the
midpoint, interval update, and final zeta selection — into one VMEM-resident
pass: the grid tiles the flattened (pair, vertex) axis into (bm, 128) blocks;
each block loads tau, p, beta, |h|^2 and E^max once, runs all 60 halvings on
the VPU, and writes a single zeta per element.  One HBM read of 5 floats and
one write per element, independent of n_bisect.

Wireless constants enter as compile-time Python floats (they are frozen per
`WirelessConfig`), so the kernel body hard-codes eq. (22):

    g(z*tau, z*p) = kappa0*mu*beta*(z*tau*C)^2
                  + z*p*P_t*D / (B*log2(1 + z*p*|h|^2)) - E^max
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["polyblock_project_call"]

_TINY = 1e-12
_LN2 = math.log(2.0)


def _project_kernel(tau_ref, p_ref, beta_ref, h2_ref, emax_ref, zeta_ref,
                    *, n_bisect: int, kappa0_mu: float, cpu_hz: float,
                    pt_w: float, model_bits: float, bandwidth_hz: float):
    tau_v = tau_ref[...]
    p_v = p_ref[...]
    beta = beta_ref[...]
    h2 = h2_ref[...]
    e_max = emax_ref[...]

    def g_con(tau, p):
        e_cp = kappa0_mu * beta * (tau * cpu_hz) ** 2
        rate = bandwidth_hz * jnp.log1p(p * h2) / _LN2
        t_cm = model_bits / jnp.maximum(rate, 1e-30)
        return e_cp + p * pt_w * t_cm - e_max

    need_root = g_con(tau_v, p_v) > 0.0

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        take_hi = g_con(mid * tau_v, mid * p_v) > 0.0
        return jnp.where(take_hi, lo, mid), jnp.where(take_hi, mid, hi)

    lo = jnp.full_like(tau_v, _TINY)
    hi = jnp.ones_like(tau_v)
    lo, _ = jax.lax.fori_loop(0, n_bisect, body, (lo, hi))
    zeta_ref[...] = jnp.where(need_root, lo, 1.0).astype(zeta_ref.dtype)


def polyblock_project_call(tau_v, p_v, beta, h2, e_max, *, n_bisect: int = 60,
                           kappa0_mu: float, cpu_hz: float, pt_w: float,
                           model_bits: float, bandwidth_hz: float,
                           bm: int = 8, interpret: bool = False):
    """All operands (rows, 128), rows % bm == 0 -> zeta of the same shape."""
    rows, lanes = tau_v.shape
    assert lanes == 128 and rows % bm == 0, (tau_v.shape, bm)
    kern = partial(
        _project_kernel, n_bisect=n_bisect, kappa0_mu=kappa0_mu,
        cpu_hz=cpu_hz, pt_w=pt_w, model_bits=model_bits,
        bandwidth_hz=bandwidth_hz,
    )
    spec = pl.BlockSpec((bm, 128), lambda i: (i, 0))
    return pl.pallas_call(
        kern,
        grid=(rows // bm,),
        in_specs=[spec] * 5,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, 128), tau_v.dtype),
        interpret=interpret,
    )(tau_v, p_v, beta, h2, e_max)
