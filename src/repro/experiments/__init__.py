"""Paper-figure experiment harness: declarative policy x seed sweeps.

Public surface:
  SweepSpec / SweepCell -- declarative grids over the Sec.-VI comparison
                           axes (policies, datasets, N/K, scenarios,
                           server aggregation, seeds), expanded to
                           `SimConfig` cells with stable artifact ids;
  run_sweep / SweepResult -- dispatch a spec through the vmapped/sharded
                           scan engine and derive the paper metrics;
  metrics                -- rounds/time-to-target-loss, sub-channel
                           utilization, cumulative latency;
  store                  -- versioned JSON artifacts under ``results/``;
  figures / render_gallery -- SVG convergence curves, utilization bars,
                           latency CDFs, and the sync-vs-async
                           time-to-target comparison, rendered from
                           artifacts.

See DESIGN.md §10, §12 and ``examples/reproduce_figures.py`` for the
end-to-end reproduction entry points.
"""
from .metrics import (
    cumulative_latency_s,
    eval_spacing_weights,
    mean_subchannel_utilization,
    per_round_utilization,
    rounds_to_target,
    summarize_cell,
    time_to_target_s,
)
from .figures import (
    AGG_COLORS,
    Facet,
    POLICY_COLORS,
    POLICY_NAMES,
    facets,
    fig_time_to_target,
    render_gallery,
    render_service_gallery,
)
from .runner import SweepResult, group_mean_curves, run_sweep
from .spec import SweepCell, SweepSpec
from .store import latest_dir, load_latest, load_record, write_record

__all__ = [
    "SweepSpec",
    "SweepCell",
    "SweepResult",
    "run_sweep",
    "group_mean_curves",
    "rounds_to_target",
    "time_to_target_s",
    "mean_subchannel_utilization",
    "per_round_utilization",
    "eval_spacing_weights",
    "cumulative_latency_s",
    "summarize_cell",
    "latest_dir",
    "load_latest",
    "load_record",
    "write_record",
    "POLICY_COLORS",
    "POLICY_NAMES",
    "AGG_COLORS",
    "Facet",
    "facets",
    "render_gallery",
    "render_service_gallery",
    "fig_time_to_target",
]
