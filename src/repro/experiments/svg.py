"""Dependency-free SVG chart primitives for the results gallery.

The container has no matplotlib (and the repo adds no dependencies), so
the gallery renders charts as hand-built SVG: a light surface, recessive
grid, thin 2px series lines, rounded-top bars anchored to the baseline,
and a legend row whose text stays in ink (color only on the swatch).
Categorical colors are assigned per policy *entity* by the caller
(`figures.POLICY_COLORS`), never by series rank, following the validated
8-slot palette ordering documented there.
"""
from __future__ import annotations

import dataclasses
import math
from pathlib import Path
from typing import Sequence

__all__ = ["Series", "line_chart", "bar_chart"]

SURFACE = "#fcfcfb"
INK = "#0b0b0b"
INK_2 = "#52514e"
GRID = "#e9e8e4"
AXIS = "#c9c8c2"
FONT = "system-ui, -apple-system, 'Segoe UI', Helvetica, Arial, sans-serif"


@dataclasses.dataclass(frozen=True)
class Series:
    """One named polyline: x/y samples plus its entity color."""

    name: str
    x: Sequence[float]
    y: Sequence[float]
    color: str
    step: bool = False     # render as a post-step line (CDFs)


def _nice_ticks(lo: float, hi: float, n: int = 5) -> list[float]:
    """~n ticks at 1/2/2.5/5 x 10^k steps covering [lo, hi]."""
    if not math.isfinite(lo) or not math.isfinite(hi):
        return [0.0, 1.0]
    if hi <= lo:
        hi = lo + (abs(lo) or 1.0)
    raw = (hi - lo) / max(n, 1)
    mag = 10 ** math.floor(math.log10(raw))
    step = next(s * mag for s in (1, 2, 2.5, 5, 10) if s * mag >= raw)
    t0 = math.ceil(lo / step) * step
    ticks, t = [], t0
    while t <= hi + 1e-12 * step:
        ticks.append(round(t, 10))
        t += step
    return ticks or [lo, hi]


def _fmt(v: float) -> str:
    if v == 0:
        return "0"
    if abs(v) >= 1000 or abs(v) < 0.01:
        return f"{v:.1e}"
    return f"{v:g}" if abs(v) >= 1 else f"{v:.3g}"


def _esc(s: str) -> str:
    return (s.replace("&", "&amp;").replace("<", "&lt;").replace(">", "&gt;")
            .replace('"', "&quot;"))


class _Doc:
    def __init__(self, w: int, h: int, title: str):
        self.w, self.h = w, h
        self.parts = [
            f'<svg xmlns="http://www.w3.org/2000/svg" width="{w}" height="{h}"'
            f' viewBox="0 0 {w} {h}" role="img" aria-label="{_esc(title)}">',
            f'<rect width="{w}" height="{h}" fill="{SURFACE}"/>',
        ]

    def text(self, x, y, s, *, size=11, color=INK_2, anchor="start",
             weight="normal"):
        self.parts.append(
            f'<text x="{x:.1f}" y="{y:.1f}" font-family="{FONT}" '
            f'font-size="{size}" font-weight="{weight}" fill="{color}" '
            f'text-anchor="{anchor}">{_esc(s)}</text>')

    def line(self, x1, y1, x2, y2, color, width=1.0):
        self.parts.append(
            f'<line x1="{x1:.1f}" y1="{y1:.1f}" x2="{x2:.1f}" y2="{y2:.1f}" '
            f'stroke="{color}" stroke-width="{width}"/>')

    def poly(self, pts, color, width=2.0, title=None):
        d = " ".join(f"{x:.1f},{y:.1f}" for x, y in pts)
        t = f"<title>{_esc(title)}</title>" if title else ""
        self.parts.append(
            f'<polyline points="{d}" fill="none" stroke="{color}" '
            f'stroke-width="{width}" stroke-linejoin="round" '
            f'stroke-linecap="round">{t}</polyline>')

    def raw(self, s: str):
        self.parts.append(s)

    def write(self, path: str | Path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        self.parts.append("</svg>")
        path.write_text("\n".join(self.parts) + "\n")
        return path


def _frame(doc: _Doc, box, xticks, yticks, xlim, ylim, xlabel, ylabel):
    """Grid, axes, and tick labels for a plot box (x0, y0, x1, y1)."""
    x0, y0, x1, y1 = box

    def sx(v):
        return x0 + (v - xlim[0]) / (xlim[1] - xlim[0]) * (x1 - x0)

    def sy(v):
        return y1 - (v - ylim[0]) / (ylim[1] - ylim[0]) * (y1 - y0)

    for t in yticks:
        doc.line(x0, sy(t), x1, sy(t), GRID, 1)
        doc.text(x0 - 8, sy(t) + 3.5, _fmt(t), anchor="end")
    for t in xticks:
        doc.line(sx(t), y1, sx(t), y1 + 4, AXIS, 1)
        doc.text(sx(t), y1 + 16, _fmt(t), anchor="middle")
    doc.line(x0, y1, x1, y1, AXIS, 1)          # baseline
    doc.text((x0 + x1) / 2, doc.h - 8, xlabel, size=12, anchor="middle")
    doc.raw(f'<text x="14" y="{(y0 + y1) / 2:.1f}" font-family="{FONT}" '
            f'font-size="12" fill="{INK_2}" text-anchor="middle" '
            f'transform="rotate(-90 14 {(y0 + y1) / 2:.1f})">'
            f'{_esc(ylabel)}</text>')
    return sx, sy


def _legend(doc: _Doc, x0: float, y: float, entries) -> None:
    x = x0
    for name, color in entries:
        doc.raw(f'<rect x="{x:.1f}" y="{y - 9:.1f}" width="12" height="12" '
                f'rx="3" fill="{color}"/>')
        doc.text(x + 17, y + 1, name, color=INK)
        x += 17 + 7 * len(name) + 26


def line_chart(series: Sequence[Series], path: str | Path, *, title: str,
               xlabel: str, ylabel: str, w: int = 720, h: int = 430,
               ylim: tuple[float, float] | None = None) -> Path:
    """Multi-series line (or step) chart with legend; writes `path`."""
    xs = [v for s in series for v in s.x]
    ys = [v for s in series for v in s.y]
    xlim = (min(xs), max(xs) if max(xs) > min(xs) else min(xs) + 1)
    if ylim is None:
        pad = (max(ys) - min(ys)) * 0.06 or abs(max(ys)) * 0.06 or 1.0
        ylim = (min(ys) - pad, max(ys) + pad)
    doc = _Doc(w, h, title)
    doc.text(16, 26, title, size=14, color=INK, weight="600")
    _legend(doc, 16, 48, [(s.name, s.color) for s in series])
    box = (64, 64, w - 20, h - 46)
    sx, sy = _frame(doc, box, _nice_ticks(*xlim, 6), _nice_ticks(*ylim, 5),
                    xlim, ylim, xlabel, ylabel)
    for s in series:
        pts = []
        prev = None
        for x, y in zip(s.x, s.y):
            if s.step and prev is not None:
                pts.append((sx(x), prev))
            pts.append((sx(x), sy(y)))
            prev = sy(y)
        doc.poly(pts, s.color, 2.0, title=s.name)
    return doc.write(path)


def bar_chart(labels: Sequence[str], values: Sequence[float],
              colors: Sequence[str], path: str | Path, *, title: str,
              ylabel: str, w: int = 720, h: int = 430,
              value_fmt=lambda v: _fmt(v)) -> Path:
    """Rounded-top bars anchored at the baseline, direct value labels."""
    doc = _Doc(w, h, title)
    doc.text(16, 26, title, size=14, color=INK, weight="600")
    vmax = max(max(values), 0) or 1.0
    ylim = (0.0, vmax * 1.12)
    box = (64, 52, w - 20, h - 46)
    x0, y0, x1, y1 = box
    sx_w = (x1 - x0) / len(values)
    _, sy = _frame(doc, box, [], _nice_ticks(*ylim, 5), (0, 1), ylim,
                   "", ylabel)
    bar_w = min(72.0, sx_w * 0.6)
    r = 4.0
    for i, (lab, v, color) in enumerate(zip(labels, values, colors)):
        cx = x0 + (i + 0.5) * sx_w
        top, base = sy(v), y1
        bx = cx - bar_w / 2
        height = max(base - top, 0.0)
        rr = min(r, height)
        doc.raw(
            f'<path d="M {bx:.1f} {base:.1f} V {top + rr:.1f} '
            f'Q {bx:.1f} {top:.1f} {bx + rr:.1f} {top:.1f} '
            f'H {bx + bar_w - rr:.1f} '
            f'Q {bx + bar_w:.1f} {top:.1f} {bx + bar_w:.1f} {top + rr:.1f} '
            f'V {base:.1f} Z" fill="{color}">'
            f'<title>{_esc(f"{lab}: {value_fmt(v)}")}</title></path>')
        doc.text(cx, top - 6, value_fmt(v), anchor="middle", color=INK)
        doc.text(cx, y1 + 16, lab, anchor="middle")
    return doc.write(path)
