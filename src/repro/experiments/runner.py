"""Sweep execution: expand a `SweepSpec`, dispatch through the scan engine,
derive paper metrics, and persist a versioned artifact.

`run_sweep` is a thin deterministic shell around `fl.run_many`: all the
heavy lifting — world/Γ sharing across policy-only variants, grouping
same-shape cells into one compiled `lax.scan` program, policy batching via
`lax.switch`, and sharding the cell batch across local devices — lives in
the engine (DESIGN.md §10).  The runner's own contract is that cell
results are IDENTICAL to solo `run_simulation` calls (pinned by
tests/test_sweep.py), so an artifact is exactly "the paper run N times",
never a subtly different batched variant.
"""
from __future__ import annotations

import dataclasses
import platform
import time
from pathlib import Path
from typing import Sequence

import jax

from ..fl.hierarchical import HierSimConfig, run_hier_many
from ..fl.sim import SimHistory, run_many
from ..scenarios import scenario_name
from .metrics import per_round_utilization, summarize_cell
from .spec import SweepCell, SweepSpec
from .store import next_version_dir, write_record

__all__ = ["SweepResult", "run_sweep"]


@dataclasses.dataclass
class SweepResult:
    """A finished sweep: the JSON-ready record plus in-memory histories."""

    spec: SweepSpec
    record: dict
    histories: list[SimHistory]
    cells: list[SweepCell]
    out_dir: Path | None = None

    def cell(self, cell_id: str) -> dict:
        for c in self.record["cells"]:
            if c["id"] == cell_id:
                return c
        raise KeyError(cell_id)


def _cell_record(cell: SweepCell, hist: SimHistory,
                 target_loss: float | None) -> dict:
    cfg = cell.config
    lat_all = (hist.latency_all if hist.latency_all is not None
               else hist.latency_s)
    util = per_round_utilization(hist, cfg.n_subchannels)
    g_agg = getattr(cfg, "global_aggregation", "sync")
    return {
        "id": cell.cell_id,
        "dataset": cfg.dataset,
        "n_devices": cfg.n_devices,
        "n_subchannels": cfg.n_subchannels,
        "n_cells": getattr(cfg, "n_cells", 1),
        "scenario": scenario_name(cfg.scenario),
        "aggregation": (cfg.aggregation if isinstance(cfg.aggregation, str)
                        else "custom"),
        "global_aggregation": g_agg if isinstance(g_agg, str) else "custom",
        "seed": cfg.seed,
        "policy": {"ds": cfg.policy.ds, "ra": cfg.policy.ra,
                   "sa": cfg.policy.sa, "label": cfg.policy.label},
        "metrics": summarize_cell(cfg, hist, target_loss),
        "curves": {
            "round": [int(r) for r in hist.rounds],
            "global_loss": [float(v) for v in hist.global_loss],
            "accuracy": [float(v) for v in hist.accuracy],
            "cum_time_s": [float(v) for v in hist.cum_time_s],
        },
        "trace": {
            "latency_s": [float(v) for v in lat_all],
            "utilization": [float(v) for v in util],
        },
    }


def run_sweep(spec: SweepSpec, *,
              engine: str = "scan",
              shard: bool | None = None,
              ra_backend: str | None = None,
              results_root: str | Path = "results",
              write: bool = True,
              figures: bool = False) -> SweepResult:
    """Run every cell of `spec` and (optionally) persist the artifact.

    Args:
      spec: the declarative grid to run.
      engine: `fl.run_many` round-loop engine; "scan" (default) batches
        same-shape policy x seed cells into single compiled programs.
      shard: passed to `run_many` — None auto-shards the cell batch across
        local devices when more than one is visible.
      ra_backend: Γ-solver projection backend override.
      results_root: artifact root; each call writes a NEW
        ``<root>/<spec.name>/v####/`` version (see `experiments.store`).
      write: set False to skip artifact I/O (returns the record in memory).
      figures: also render the SVG gallery into ``<version>/figures/``.

    Returns a `SweepResult`; ``result.record`` is the JSON artifact.
    """
    cells = spec.cells()
    t0 = time.time()
    # Flat and hierarchical cells dispatch through their own engines
    # (run_many / run_hier_many — identical grouping disciplines), then
    # reassemble in expansion order.
    flat_idx = [i for i, c in enumerate(cells)
                if not isinstance(c.config, HierSimConfig)]
    hier_idx = [i for i, c in enumerate(cells)
                if isinstance(c.config, HierSimConfig)]
    hists: list[SimHistory | None] = [None] * len(cells)
    if flat_idx:
        for i, h in zip(flat_idx, run_many(
                [cells[i].config for i in flat_idx], engine=engine,
                shard=shard, ra_backend=ra_backend)):
            hists[i] = h
    if hier_idx:
        hier_engine = "async" if engine == "async" else "scan"
        if engine == "loop":
            raise ValueError(
                "engine='loop' cannot run hierarchical sweep cells — "
                "use 'scan' or 'async'")
        for i, h in zip(hier_idx, run_hier_many(
                [cells[i].config for i in hier_idx], engine=hier_engine,
                shard=shard, ra_backend=ra_backend)):
            hists[i] = h
    wall_s = time.time() - t0

    record = {
        "schema": 1,
        "sweep": spec.to_json(),
        "engine": engine,
        "n_cells": len(cells),
        "wall_s": wall_s,
        "env": {
            "host": platform.machine(),
            "jax_backend": jax.default_backend(),
            "local_devices": jax.local_device_count(),
        },
        "cells": [_cell_record(c, h, spec.target_loss)
                  for c, h in zip(cells, hists)],
    }

    result = SweepResult(spec=spec, record=record, histories=list(hists),
                         cells=cells)
    if write:
        out_dir = next_version_dir(results_root, spec.name)
        write_record(record, out_dir)
        result.out_dir = out_dir
        if figures:
            from .figures import render_gallery
            render_gallery(record, out_dir / "figures")
    return result


def group_mean_curves(record: dict, *, dataset: str | None = None,
                      n_devices: int | None = None,
                      n_subchannels: int | None = None,
                      scenario: str | None = None,
                      aggregation: str | None = None,
                      n_cells: int | None = None,
                      global_aggregation: str | None = None,
                      key: str = "global_loss") -> dict[str, tuple]:
    """Average a per-cell eval curve over SEEDS, per policy label.

    Returns {policy_label: (rounds, mean_curve)} for cells matching the
    given dataset / N / K / scenario / aggregation / topology (each None
    = the record's only value; raises if the record varies an unfiltered
    axis, so heterogeneous configs are never silently pooled into one
    curve).  The label is the full ds+ra+sa scheme name, so distinct
    policies never merge either.
    """
    cells = record["cells"]

    def resolve(name, value, getter):
        values = sorted({getter(c) for c in cells})
        if value is None:
            if len(values) > 1:
                raise ValueError(
                    f"record spans {name}={values}; pass {name}= to pick one")
            return values[0]
        return value

    dataset = resolve("dataset", dataset, lambda c: c["dataset"])
    n_devices = resolve("n_devices", n_devices, lambda c: c["n_devices"])
    n_subchannels = resolve("n_subchannels", n_subchannels,
                            lambda c: c["n_subchannels"])
    scenario = resolve("scenario", scenario,
                       lambda c: c.get("scenario", "static"))
    aggregation = resolve("aggregation", aggregation,
                          lambda c: c.get("aggregation", "sync"))
    n_cells = resolve("n_cells", n_cells, lambda c: c.get("n_cells", 1))
    global_aggregation = resolve(
        "global_aggregation", global_aggregation,
        lambda c: c.get("global_aggregation", "sync"))
    by_label: dict[str, list] = {}
    rounds_by_label: dict[str, Sequence[int]] = {}
    for c in cells:
        if (c["dataset"], c["n_devices"], c["n_subchannels"],
                c.get("scenario", "static"),
                c.get("aggregation", "sync"),
                c.get("n_cells", 1),
                c.get("global_aggregation", "sync")) != (
                dataset, n_devices, n_subchannels, scenario, aggregation,
                n_cells, global_aggregation):
            continue
        lab = c["policy"]["label"]
        by_label.setdefault(lab, []).append(c["curves"][key])
        rounds_by_label[lab] = c["curves"]["round"]
    import numpy as np
    return {lab: (rounds_by_label[lab],
                  np.mean(np.asarray(v, float), axis=0))
            for lab, v in by_label.items()}
