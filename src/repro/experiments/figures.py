"""Paper-figure renderers over sweep artifacts (no matplotlib required).

Reads the versioned ``sweep.json`` record (never live histories), so every
figure in the gallery can be regenerated from a committed artifact alone:

  * convergence curves — global loss vs round and vs simulated time
    (Fig. 3 / Fig. 5 style), seed-averaged per device-selection policy;
  * sub-channel utilization bars — mean fraction of the K uplink slots
    used per round (the Fig. 7 resource story);
  * per-round latency CDF — the eq.-9 latency distribution each policy
    induces (the denominator of convergence *time*).

Cells are FACETED before averaging: one figure set per distinct
(dataset, N, K, ra, sa) combination, so a sweep that crosses resource
allocation, assignment, or network-size axes renders small multiples
instead of silently pooling heterogeneous configs into one curve.  Only
seeds are averaged within a series.

Colors follow the policy ENTITY, never its rank: each ds scheme owns a
fixed slot of the validated categorical palette (order blue, orange, aqua,
yellow, magenta — adjacent-pair CVD-safe; see the dataviz palette notes),
so adding or filtering policies never repaints the survivors.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path

import numpy as np

from .svg import Series, bar_chart, line_chart

__all__ = ["POLICY_COLORS", "POLICY_NAMES", "AGG_COLORS", "Facet", "facets",
           "render_gallery", "fig_convergence", "fig_utilization",
           "fig_latency_cdf", "fig_time_to_target",
           "fig_service_latency_cdf", "fig_service_steady_state",
           "fig_service_occupancy", "render_service_gallery"]

# Fixed entity -> categorical-slot assignment (light-mode steps).
POLICY_COLORS = {
    "alg3": "#2a78d6",      # slot 1, blue   — the proposed scheme
    "random": "#eb6834",    # slot 2, orange
    "fixed": "#1baf7a",     # slot 3, aqua
    "cluster": "#eda100",   # slot 4, yellow
    "aou_topk": "#e87ba4",  # slot 5, magenta
}
POLICY_NAMES = {
    "alg3": "Alg. 3 (proposed)",
    "random": "Random DS",
    "fixed": "Fixed DS",
    "cluster": "Cluster DS",
    "aou_topk": "AoU top-K DS",
}
# Stable legend/bar order: proposed first, then the Sec.-VI baselines.
_DS_ORDER = list(POLICY_COLORS)

# Server-aggregation entity colors (DESIGN.md §12): the paper's sync
# barrier keeps the proposed-scheme blue; async commit policies own fixed
# slots of the same categorical palette.
AGG_COLORS = {
    "sync": "#2a78d6",        # slot 1, blue   — eq.-34 round barrier
    "async": "#eb6834",       # slot 2, orange — buffered, poly staleness
    "async_const": "#eda100", # slot 4, yellow — buffered, constant weights
    "async_full": "#1baf7a",  # slot 3, aqua   — full barrier (sync limit)
}


@dataclasses.dataclass(frozen=True)
class Facet:
    """One homogeneous slice of a record: everything but ds scheme and
    seed is fixed, so seed-averaging within it is meaningful.  Scenario
    and aggregation are facet keys — pooling different environments or
    server disciplines into one curve would fabricate a run that was
    never simulated."""

    dataset: str
    n_devices: int
    n_subchannels: int
    ra: str
    sa: str
    scenario: str
    aggregation: str
    n_cells: int
    global_aggregation: str
    suffix: str    # filename suffix ("mnist", "mnist-urban-async", ...)

    def matches(self, cell: dict) -> bool:
        return (cell["dataset"] == self.dataset
                and cell["n_devices"] == self.n_devices
                and cell["n_subchannels"] == self.n_subchannels
                and cell["policy"]["ra"] == self.ra
                and cell["policy"]["sa"] == self.sa
                and cell.get("scenario", "static") == self.scenario
                and cell.get("aggregation", "sync") == self.aggregation
                and cell.get("n_cells", 1) == self.n_cells
                and cell.get("global_aggregation", "sync")
                == self.global_aggregation)


def facets(record: dict) -> list[Facet]:
    """Distinct (dataset, N, K, ra, sa, scenario, aggregation, topology)
    slices, with minimal suffixes: shape/scheme/scenario/aggregation/
    cell-count parts appear only when the record actually varies them.
    (Older artifacts carry no "scenario"/"aggregation"/"n_cells" keys;
    those cells facet as static/sync/flat.)"""
    keys = sorted({(c["dataset"], c["n_devices"], c["n_subchannels"],
                    c["policy"]["ra"], c["policy"]["sa"],
                    c.get("scenario", "static"),
                    c.get("aggregation", "sync"),
                    c.get("n_cells", 1),
                    c.get("global_aggregation", "sync"))
                   for c in record["cells"]})
    many_shapes = len({(d, n, k) for d, n, k, *_ in keys}) > len(
        {d for d, *_ in keys})
    many_schemes = len({(r, s) for _, _, _, r, s, *_ in keys}) > 1
    many_scenarios = len({sc for *_, sc, _, _, _ in keys}) > 1
    many_aggs = len({ag for *_, ag, _, _ in keys}) > 1
    many_cells = len({nc for *_, nc, _ in keys}) > 1
    many_gaggs = len({g for *_, g in keys}) > 1
    out = []
    for d, n, k, r, s, sc, ag, nc, g in keys:
        suffix = d
        if many_shapes:
            suffix += f"-N{n}-K{k}"
        if many_schemes:
            suffix += f"-{r}.{s}"
        if many_cells:
            suffix += f"-C{nc}"
        if many_scenarios:
            suffix += f"-{sc}"
        if many_aggs:
            suffix += f"-{ag}"
        if many_gaggs:
            suffix += f"-g.{g}"
        out.append(Facet(d, n, k, r, s, sc, ag, nc, g, suffix))
    return out


def _by_ds(record: dict, facet: Facet) -> dict[str, list[dict]]:
    """The facet's cells grouped by ds scheme, in `_DS_ORDER`."""
    groups: dict[str, list[dict]] = {}
    for c in record["cells"]:
        if facet.matches(c):
            groups.setdefault(c["policy"]["ds"], []).append(c)
    return {ds: groups[ds] for ds in _DS_ORDER if ds in groups}


def _seed_mean(cells: list[dict], section: str, key: str) -> np.ndarray:
    return np.mean([np.asarray(c[section][key], float) for c in cells],
                   axis=0)


def fig_convergence(record: dict, facet: Facet, out_dir: Path,
                    x_axis: str = "round") -> Path:
    """Seed-averaged global-loss curves per policy (vs round or sim time)."""
    series = []
    for ds, cells in _by_ds(record, facet).items():
        y = _seed_mean(cells, "curves", "global_loss")
        x = (np.asarray(cells[0]["curves"]["round"], float) if x_axis == "round"
             else _seed_mean(cells, "curves", "cum_time_s"))
        series.append(Series(POLICY_NAMES[ds], x, y, POLICY_COLORS[ds]))
    xlabel = ("communication round" if x_axis == "round"
              else "simulated time (s, eq. 9 cumulative)")
    suffix = "rounds" if x_axis == "round" else "time"
    return line_chart(
        series, out_dir / f"convergence_{suffix}_{facet.suffix}.svg",
        title=f"Global loss vs {xlabel.split(' (')[0]} — {facet.suffix}",
        xlabel=xlabel, ylabel="global loss F(w)")


def fig_utilization(record: dict, facet: Facet, out_dir: Path) -> Path:
    """Mean sub-channel utilization per policy (seed-averaged)."""
    labels, values, colors = [], [], []
    for ds, cells in _by_ds(record, facet).items():
        labels.append(POLICY_NAMES[ds])
        values.append(float(np.mean(
            [c["metrics"]["mean_subchannel_utilization"] for c in cells])))
        colors.append(POLICY_COLORS[ds])
    return bar_chart(
        labels, values, colors, out_dir / f"utilization_{facet.suffix}.svg",
        title=f"Mean sub-channel utilization — {facet.suffix}",
        ylabel="fraction of K sub-channels used",
        value_fmt=lambda v: f"{v:.2f}")


def fig_latency_cdf(record: dict, facet: Facet, out_dir: Path) -> Path:
    """Empirical CDF of per-round latency, pooled over rounds and seeds."""
    series = []
    for ds, cells in _by_ds(record, facet).items():
        lat = np.sort(np.concatenate(
            [np.asarray(c["trace"]["latency_s"], float) for c in cells]))
        cdf = np.arange(1, lat.size + 1) / lat.size
        series.append(Series(POLICY_NAMES[ds], lat, cdf,
                             POLICY_COLORS[ds], step=True))
    return line_chart(
        series, out_dir / f"latency_cdf_{facet.suffix}.svg",
        title=f"Per-round latency CDF — {facet.suffix}",
        xlabel="round latency (s, eq. 9)",
        ylabel="P(latency ≤ x)", ylim=(0.0, 1.04))


def fig_time_to_target(record: dict, out_dir: Path,
                       ds: str | None = None) -> Path | None:
    """Simulated time-to-target per (scenario, aggregation) — the async
    engine's headline comparison (DESIGN.md §12): how fast each server
    discipline reaches the target loss in eq.-9 simulated seconds, per
    environment.  Bars are seed-averaged for ONE ds scheme (the proposed
    Algorithm 3 when present); a (scenario, aggregation) group where any
    seed misses the target renders no bar.  Returns None when the record
    fixes the aggregation axis, carries no time-to-target metric, or
    still varies dataset / N / K / ra / sa within the chosen ds — the
    no-pooling invariant of `Facet` applies here too: only seeds are
    ever averaged into a bar.
    """
    cells = record["cells"]
    aggs = sorted({(c.get("aggregation", "sync"),
                    c.get("global_aggregation", "sync")) for c in cells})
    if len(aggs) < 2:
        return None
    if ds is None:
        present = {c["policy"]["ds"] for c in cells}
        ds = "alg3" if "alg3" in present else sorted(present)[0]
    slices = {(c["dataset"], c["n_devices"], c["n_subchannels"],
               c.get("n_cells", 1), c["policy"]["ra"], c["policy"]["sa"])
              for c in cells if c["policy"]["ds"] == ds}
    if len(slices) != 1:
        return None    # heterogeneous configs: refuse, never pool
    many_gaggs = len({g for _, g in aggs}) > 1
    groups: dict[tuple[str, str, str], list] = {}
    for c in cells:
        if c["policy"]["ds"] != ds:
            continue
        key = (c.get("scenario", "static"), c.get("aggregation", "sync"),
               c.get("global_aggregation", "sync"))
        groups.setdefault(key, []).append(
            c["metrics"].get("time_to_target_s"))
    scenarios = sorted({sc for sc, _, _ in groups})
    flat_aggs = sorted({a for a, _ in aggs})
    agg_order = [a for a in AGG_COLORS if a in flat_aggs] + [
        a for a in flat_aggs if a not in AGG_COLORS]
    g_order = sorted({g for _, g in aggs})
    labels, values, colors = [], [], []
    for sc in scenarios:
        for ag in agg_order:
            for g in g_order:
                ts = groups.get((sc, ag, g))
                if not ts or any(t is None for t in ts):
                    continue
                lab = f"{sc} · {ag}"
                if many_gaggs:
                    lab += f"/g.{g}"
                labels.append(lab)
                values.append(float(np.mean(ts)))
                colors.append(AGG_COLORS.get(ag, "#8a8f98"))
    if not values:
        return None
    return bar_chart(
        labels, values, colors, out_dir / f"time_to_target_{ds}.svg",
        title=f"Simulated time to target loss — {ds}, sync vs async",
        ylabel="time to target (s, eq. 9 cumulative)",
        value_fmt=lambda v: f"{v:.1f}")


_SERVICE_COLOR = AGG_COLORS["async"]   # the service IS the async engine
_BUDGET_COLOR = "#8a8f98"              # neutral context line, never a series


def fig_service_latency_cdf(record: dict, out_dir: Path) -> Path:
    """Empirical CDF of per-event wall commit latency from a
    ``service.json`` record, with the SLO budget as a vertical context
    line — the attained fraction is where the CDF crosses it."""
    lat = np.sort(np.asarray(record["events"]["latency_s"], float))
    cdf = np.arange(1, lat.size + 1) / lat.size
    budget = float(record["summary"]["slo"]["budget_s"])
    series = [Series("commit latency", lat, cdf, _SERVICE_COLOR, step=True)]
    if lat.min() <= budget <= lat.max() * 1.5:
        series.append(Series(f"SLO budget ({budget:g}s)",
                             np.array([budget, budget]),
                             np.array([0.0, 1.0]), _BUDGET_COLOR))
    return line_chart(
        series, Path(out_dir) / "service_latency_cdf.svg",
        title="Sustained service — commit latency CDF",
        xlabel="per-event commit latency (s, wall)",
        ylabel="P(latency ≤ x)", ylim=(0.0, 1.04))


def fig_service_steady_state(record: dict, out_dir: Path) -> Path:
    """Steady-state global loss vs events served under continuous churn."""
    ss = record["steady_state"]
    x = np.asarray(ss["event"], float)
    return line_chart(
        [Series("global loss", x, np.asarray(ss["global_loss"], float),
                _SERVICE_COLOR)],
        Path(out_dir) / "service_steady_state.svg",
        title="Sustained service — steady-state loss",
        xlabel="events served (cumulative, incl. warm-up)",
        ylabel="global loss F(w)")


def fig_service_occupancy(record: dict, out_dir: Path) -> Path:
    """Server buffer occupancy and mean device AoU per measured event."""
    ev = record["events"]
    x = np.asarray(ev["event"], float)
    series = [Series("buffer occupancy", x,
                     np.asarray(ev["n_pending"], float),
                     _SERVICE_COLOR, step=True)]
    if "mean_age" in ev:
        series.append(Series("mean AoU (rounds)", x,
                             np.asarray(ev["mean_age"], float),
                             AGG_COLORS["async_const"]))
    return line_chart(
        series, Path(out_dir) / "service_occupancy.svg",
        title="Sustained service — buffer occupancy / AoU",
        xlabel="events served (cumulative, incl. warm-up)",
        ylabel="pending updates / mean AoU")


def render_service_gallery(record: dict, out_dir: str | Path) -> list[Path]:
    """All figures for one sustained-service record; returns written paths."""
    out_dir = Path(out_dir)
    return [fig_service_latency_cdf(record, out_dir),
            fig_service_steady_state(record, out_dir),
            fig_service_occupancy(record, out_dir)]


def render_gallery(record: dict, out_dir: str | Path) -> list[Path]:
    """All figures for every facet of a record; returns written paths."""
    out_dir = Path(out_dir)
    paths = []
    for facet in facets(record):
        paths.append(fig_convergence(record, facet, out_dir, "round"))
        paths.append(fig_convergence(record, facet, out_dir, "time"))
        paths.append(fig_utilization(record, facet, out_dir))
        paths.append(fig_latency_cdf(record, facet, out_dir))
    t2t = fig_time_to_target(record, out_dir)
    if t2t is not None:
        paths.append(t2t)
    return paths
