"""Versioned JSON artifact store for sweep results.

Layout (rooted at ``results/`` by default, committed or CI-uploaded):

    results/<sweep-name>/v0001/sweep.json      # the full record
    results/<sweep-name>/v0001/figures/*.svg   # rendered gallery (optional)
    results/<sweep-name>/v0002/...             # next run, never overwritten

Every `run_sweep` call writes a NEW version directory, so a results tree
is an append-only history of reproductions; `latest_dir`/`load_latest`
resolve the most recent one.  Records carry ``schema`` so future readers
can migrate old artifacts.
"""
from __future__ import annotations

import json
import re
from pathlib import Path

__all__ = [
    "SCHEMA_VERSION",
    "next_version_dir",
    "latest_dir",
    "write_record",
    "load_record",
    "load_latest",
]

SCHEMA_VERSION = 1

_V_RE = re.compile(r"^v(\d{4,})$")


def _versions(sweep_dir: Path) -> list[tuple[int, Path]]:
    if not sweep_dir.is_dir():
        return []
    out = []
    for child in sweep_dir.iterdir():
        m = _V_RE.match(child.name)
        if m and child.is_dir():
            out.append((int(m.group(1)), child))
    return sorted(out)


def next_version_dir(root: str | Path, name: str) -> Path:
    """Create and return the next ``results/<name>/v####`` directory.

    Concurrency-safe: ``mkdir`` (never the directory listing) is the
    atomic claim.  Two writers that list the same versions compute the
    same candidate, but only one ``mkdir`` can succeed — the loser sees
    FileExistsError, re-lists, and claims the next free slot instead of
    crashing (CI matrix jobs, sharded sweeps, and the long-running
    service harness all race this path).
    """
    sweep_dir = Path(root) / name
    last_err: OSError | None = None
    for _ in range(1000):     # bounded: each retry means someone claimed
        versions = _versions(sweep_dir)
        nxt = versions[-1][0] + 1 if versions else 1
        out = sweep_dir / f"v{nxt:04d}"
        try:
            out.mkdir(parents=True, exist_ok=False)
            return out
        except FileExistsError as err:
            last_err = err
    raise RuntimeError(
        f"could not claim a version directory under {sweep_dir} after "
        f"1000 attempts") from last_err


def latest_dir(root: str | Path, name: str) -> Path | None:
    """The most recent version directory of a sweep, or None."""
    versions = _versions(Path(root) / name)
    return versions[-1][1] if versions else None


def write_record(record: dict, out_dir: str | Path,
                 filename: str = "sweep.json") -> Path:
    """Write a schema-stamped JSON record into a version directory
    (``sweep.json`` for sweeps; the service harness writes
    ``service.json``)."""
    record = dict(record)
    record.setdefault("schema", SCHEMA_VERSION)
    path = Path(out_dir) / filename
    with open(path, "w") as fh:
        json.dump(record, fh, indent=1, sort_keys=False)
        fh.write("\n")
    return path


def load_record(path: str | Path, filename: str = "sweep.json") -> dict:
    """Load a record from a JSON path or its version directory."""
    p = Path(path)
    if p.is_dir():
        p = p / filename
    with open(p) as fh:
        record = json.load(fh)
    if record.get("schema") != SCHEMA_VERSION:
        raise ValueError(
            f"unsupported sweep artifact schema {record.get('schema')!r} "
            f"in {p} (reader supports {SCHEMA_VERSION})")
    return record


def load_latest(root: str | Path, name: str,
                filename: str = "sweep.json") -> dict | None:
    """Load the most recent record of a sweep, or None if never run."""
    d = latest_dir(root, name)
    return load_record(d, filename) if d else None
