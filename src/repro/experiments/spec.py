"""Declarative sweep specifications over the simulation grid.

A `SweepSpec` names a Cartesian grid over the paper's comparison axes —
device-selection / resource-allocation / sub-channel-assignment schemes
(Sec. VI policies), datasets, network sizes (N, K), environment scenarios
(`repro.scenarios` presets), and seeds — and expands it into concrete
`SimConfig` cells with stable, path-safe ids.  The expansion order is
fixed (dataset-major, then (N, K), then scenario, then the
`core.policy_grid` policy order, then seed) so cell ids and artifact
layouts are reproducible across runs and machines.

The spec is deliberately *declarative*: it never runs anything.  The
runner (`repro.experiments.runner`) feeds the expanded cells through
`fl.run_many(engine="scan")`, which shares worlds/Γ solves across
policy-only variants and batches same-shape cells into single compiled
programs (DESIGN.md §10).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Sequence

from ..core.stackelberg import RoundPolicy, policy_grid
from ..fl.hierarchical import HierSimConfig
from ..fl.server import get_aggregation
from ..fl.sim import SimConfig
from ..scenarios import Scenario, get_scenario

__all__ = ["SweepSpec", "SweepCell"]

_NAME_RE = re.compile(r"^[A-Za-z0-9][A-Za-z0-9._-]*$")

# Config fields a spec may override beyond the grid axes, per cell kind:
# flat cells expand to `SimConfig`, hierarchical cells (cell_counts > 1 or
# an async global tier) to `HierSimConfig`.  A mixed grid may only
# override the intersection.
_AXIS_FIELDS = ("dataset", "n_devices", "n_subchannels", "seed", "policy",
                "rounds", "scenario", "aggregation")
_OVERRIDABLE = frozenset(
    f.name for f in dataclasses.fields(SimConfig)
    if f.name not in _AXIS_FIELDS)
_HIER_OVERRIDABLE = frozenset(
    f.name for f in dataclasses.fields(HierSimConfig)
    if f.name not in _AXIS_FIELDS + (
        "n_cells", "devices_per_cell", "subchannels_per_cell",
        "global_aggregation"))


def _axis(v) -> tuple:
    """Normalize a grid axis: scalars become 1-tuples, sequences tuples."""
    if isinstance(v, (str, int, float)) or v is None:
        return (v,)
    return tuple(v)


@dataclasses.dataclass(frozen=True)
class SweepCell:
    """One expanded grid point: a stable id plus its concrete `SimConfig`."""

    cell_id: str
    index: int
    config: SimConfig | HierSimConfig


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """A named Cartesian grid over the paper's comparison axes.

    Args:
      name: artifact-directory name (``results/<name>/v####/``); must be
        path-safe (letters, digits, ``._-``).
      datasets: Table-I dataset names ("mnist" / "cifar10" / "sst2").
      ds / ra / sa: policy scheme axes, crossed via `core.policy_grid`
        (eq. 42-43 selection, Algorithm-1 vs FIX RA, Algorithm-2 vs R-SA).
      n_devices / n_subchannels: network-size axes (N, K), crossed.
      scenarios: environment-scenario axis, by preset/registered name
        (`repro.scenarios.PRESETS`; "static" = the paper's fixed world).
        Scenarios vary only trace data, never program shape, so a
        policy x scenario x seed grid still dispatches as ONE compiled
        scan program per shape (DESIGN.md §11).
      aggregation: server-aggregation axis, by preset name ("sync" =
        the paper's eq.-34 round barrier; "async" / "async_const" /
        "async_full" = the buffered staleness-weighted event engine,
        `fl.AGGREGATION_PRESETS`, DESIGN.md §12).  Async cells route
        through `engine="async"` automatically and SHARE the sync cells'
        sampled worlds and Γ solves, so the comparison is differential.
      cell_counts: hierarchical-topology axis: how many base-station
        cells split the N devices / K sub-channels (each must divide
        both).  1 = the flat single-server network (`SimConfig`); > 1
        expands to a `HierSimConfig` city (N/cells devices and K/cells
        sub-channels per cell) routed through `fl.run_hier_many`, whose
        two-tier grid still dispatches as one compiled program per shape
        (DESIGN.md §15).
      global_aggregation: the GLOBAL tier's commit discipline for
        hierarchical cells, by preset name ("sync" = the two-tier
        round barrier; async presets = the buffered staleness-weighted
        global server).  A non-"sync" value makes the cell hierarchical
        even at cell_counts=1.
      seeds: world seeds; cells differing only in policy or aggregation
        share one sampled world and one Γ solve (`fl.run_many` dedups
        them).
      rounds: communication rounds per cell (scalar — part of the compiled
        scan shape, so it is not a grid axis).
      target_loss: global-loss threshold used by the derived
        rounds-to-target / time-to-target metrics (None disables them).
      overrides: extra `SimConfig` fields applied to every cell, as a
        mapping or ``((field, value), ...)`` pairs — e.g.
        ``{"n_samples": 256, "eval_every": 5}``.
    """

    name: str
    datasets: Sequence[str] = ("mnist",)
    ds: Sequence[str] = ("alg3",)
    ra: Sequence[str] = ("mo",)
    sa: Sequence[str] = ("matching",)
    n_devices: Sequence[int] = (20,)
    n_subchannels: Sequence[int] = (4,)
    scenarios: Sequence[str] = ("static",)
    aggregation: Sequence[str] = ("sync",)
    cell_counts: Sequence[int] = (1,)
    global_aggregation: Sequence[str] = ("sync",)
    seeds: Sequence[int] = (0,)
    rounds: int = 100
    target_loss: float | None = None
    overrides: Any = ()

    def __post_init__(self):
        if not _NAME_RE.match(self.name):
            raise ValueError(f"sweep name not path-safe: {self.name!r}")
        # Scenario objects are welcome but normalize to their registry NAME
        # (specs must stay JSON-serializable and reproducible by name) —
        # and only if the registry entry IS that object's configuration;
        # silently substituting a same-named preset would mislabel the
        # artifact.
        def norm(s):
            if not isinstance(s, Scenario):
                return s
            try:
                registered = get_scenario(s.name)
            except ValueError:
                raise ValueError(
                    f"scenario object {s.name!r} is not registered — "
                    f"register_scenario(...) it first so the spec stays "
                    f"reproducible by name") from None
            if registered != s:
                raise ValueError(
                    f"scenario object {s.name!r} differs from the "
                    f"registered preset of that name — register it under "
                    f"a distinct name")
            return s.name

        sc_axis = self.scenarios
        if isinstance(sc_axis, (str, Scenario)):
            sc_axis = (sc_axis,)
        object.__setattr__(self, "scenarios",
                           tuple(norm(s) for s in sc_axis))
        for field in ("datasets", "ds", "ra", "sa", "n_devices",
                      "n_subchannels", "scenarios", "aggregation",
                      "cell_counts", "global_aggregation", "seeds"):
            object.__setattr__(self, field, _axis(getattr(self, field)))
        for sc in self.scenarios:   # validate eagerly: known AND path-safe
            get_scenario(sc)        # (names flow into cell ids + filenames)
            if not _NAME_RE.match(sc):
                raise ValueError(f"scenario name not path-safe: {sc!r}")
        for axis in ("aggregation", "global_aggregation"):
            for agg in getattr(self, axis):  # presets only: JSON-safe specs
                if not isinstance(agg, str):
                    raise ValueError(
                        f"{axis} axis values must be preset names, got "
                        f"{agg!r} — register custom AsyncAggregation specs "
                        f"via fl.AGGREGATION_PRESETS")
                get_aggregation(agg)
                if not _NAME_RE.match(agg):
                    raise ValueError(f"{axis} name not path-safe: {agg!r}")
        for nc in self.cell_counts:
            if not isinstance(nc, int) or nc < 1:
                raise ValueError(f"cell_counts must be positive ints, "
                                 f"got {nc!r}")
            for n in self.n_devices:
                if n % nc:
                    raise ValueError(
                        f"cell_counts={nc} does not divide n_devices={n}")
            for k in self.n_subchannels:
                if k % nc:
                    raise ValueError(
                        f"cell_counts={nc} does not divide n_subchannels={k}")
        ov = self.overrides
        ov = tuple(sorted(ov.items())) if isinstance(ov, dict) else tuple(
            (str(k), v) for k, v in ov)
        # Validate overrides against every cell KIND the grid expands to.
        allowed: frozenset = frozenset(_OVERRIDABLE | _HIER_OVERRIDABLE)
        if any(self._is_hier(nc, g) for nc in self.cell_counts
               for g in self.global_aggregation):
            allowed &= _HIER_OVERRIDABLE
        if any(not self._is_hier(nc, g) for nc in self.cell_counts
               for g in self.global_aggregation):
            allowed &= _OVERRIDABLE
        unknown = [k for k, _ in ov if k not in allowed]
        if unknown:
            raise ValueError(
                f"overrides reference fields unknown to (or not "
                f"overridable on) every cell kind in this grid: {unknown} "
                f"(allowed here: {sorted(allowed)})")
        object.__setattr__(self, "overrides", ov)
        self.policies  # validate scheme names eagerly

    @property
    def policies(self) -> list[RoundPolicy]:
        """The policy axis expanded in `core.policy_grid` order."""
        return policy_grid(ds=tuple(self.ds), ra=tuple(self.ra),
                           sa=tuple(self.sa))

    @staticmethod
    def _is_hier(n_cells: int, global_aggregation: str) -> bool:
        """A grid point is hierarchical iff it has more than one cell or
        a non-trivial global commit tier (a cells-of-one hierarchy with a
        sync global tier IS the flat network — tests pin it bit-exact —
        so it expands to the flat `SimConfig` and keeps flat cell ids)."""
        return n_cells > 1 or global_aggregation != "sync"

    @property
    def n_cells(self) -> int:
        return (len(self.datasets) * len(self.n_devices)
                * len(self.n_subchannels) * len(self.scenarios)
                * len(self.aggregation) * len(self.cell_counts)
                * len(self.global_aggregation) * len(self.policies)
                * len(self.seeds))

    def cells(self) -> list[SweepCell]:
        """Expand the grid: dataset > (N, K) > topology > scenario >
        aggregation > global aggregation > policy > seed.

        Ids are stable; the topology, scenario, and aggregation segments
        are omitted for 1 / "static" / "sync" / "sync" so pre-existing
        sweep ids (and committed artifacts) stay unchanged.
        """
        out: list[SweepCell] = []
        ov = dict(self.overrides)
        hier_ov = {k: v for k, v in ov.items() if k in _HIER_OVERRIDABLE}
        for dataset in self.datasets:
          for n in self.n_devices:
            for k in self.n_subchannels:
              for nc in self.cell_counts:
                c_part = "" if nc == 1 else f"-C{nc}"
                for sc in self.scenarios:
                    sc_part = "" if sc == "static" else f"-{sc}"
                    for agg in self.aggregation:
                        agg_part = "" if agg == "sync" else f"-{agg}"
                        for g_agg in self.global_aggregation:
                            g_part = "" if g_agg == "sync" else f"-g.{g_agg}"
                            for pol in self.policies:
                                for seed in self.seeds:
                                    if self._is_hier(nc, g_agg):
                                        cfg = HierSimConfig(
                                            dataset=dataset, n_cells=nc,
                                            devices_per_cell=n // nc,
                                            subchannels_per_cell=k // nc,
                                            rounds=self.rounds, policy=pol,
                                            seed=seed, scenario=sc,
                                            aggregation=agg,
                                            global_aggregation=g_agg,
                                            **hier_ov)
                                    else:
                                        cfg = SimConfig(
                                            dataset=dataset, n_devices=n,
                                            n_subchannels=k,
                                            rounds=self.rounds,
                                            policy=pol, seed=seed,
                                            scenario=sc, aggregation=agg,
                                            **ov)
                                    cid = (f"{dataset}-N{n}-K{k}{c_part}"
                                           f"{sc_part}{agg_part}{g_part}-"
                                           f"{pol.ds}.{pol.ra}.{pol.sa}"
                                           f"-s{seed}")
                                    out.append(SweepCell(cid, len(out), cfg))
        return out

    def to_json(self) -> dict:
        """JSON-serializable form (round-trips through `from_json`)."""
        d = dataclasses.asdict(self)
        d["overrides"] = dict(self.overrides)
        return d

    @classmethod
    def from_json(cls, d: dict) -> "SweepSpec":
        return cls(**d)
