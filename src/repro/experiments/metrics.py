"""Derived paper metrics computed from `SimHistory` traces.

These are the quantities the paper's figures compare across scheduling
policies: convergence speed (rounds / wall-clock time to a target global
loss, Figs. 3-5), sub-channel utilization (how many of the K uplink slots
carry a transmitting device each round, Fig. 7's resource story), and
cumulative latency (the eq.-9 round latencies summed over the horizon —
the x-axis of the convergence-time plots).  All metrics are pure functions
of a finished history, so artifacts can be re-derived without re-running.
"""
from __future__ import annotations

import numpy as np

from ..fl.sim import SimConfig, SimHistory

__all__ = [
    "rounds_to_target",
    "time_to_target_s",
    "per_round_utilization",
    "eval_spacing_weights",
    "mean_subchannel_utilization",
    "cumulative_latency_s",
    "summarize_cell",
]


def rounds_to_target(hist: SimHistory, target_loss: float) -> int | None:
    """Rounds elapsed until global loss first reaches `target_loss`.

    Returns the 1-based round count at the first eval point with
    ``global_loss <= target_loss`` (loss is only observed at eval rounds,
    so this is an upper bound tight to `eval_every`), or None if the
    target is never reached within the horizon.
    """
    hit = np.nonzero(hist.global_loss <= target_loss)[0]
    return int(hist.rounds[hit[0]]) + 1 if hit.size else None


def time_to_target_s(hist: SimHistory, target_loss: float) -> float | None:
    """Simulated convergence time (eq. 9 cumsum) to reach `target_loss`."""
    hit = np.nonzero(hist.global_loss <= target_loss)[0]
    return float(hist.cum_time_s[hit[0]]) if hit.size else None


def per_round_utilization(hist: SimHistory, k: int, *,
                          allow_eval_sampled: bool = False) -> np.ndarray:
    """Fraction of the K sub-channels carrying a transmitter, per round.

    With a full ``tx_trace`` this is exact, one entry per round.  Without
    one, only the eval-sampled ``n_transmitted`` exists; that array has
    one entry per EVAL round, so it is not "per round" and its plain mean
    is biased whenever ``eval_every > 1`` (the final round and round 0
    are always sampled, interior blocks are represented by one round
    each).  Callers must opt in to that coarser series explicitly with
    ``allow_eval_sampled=True`` and weight it themselves (see
    `eval_spacing_weights`); otherwise the silent sampling-grid switch
    raises.
    """
    if hist.tx_trace is not None:
        return hist.tx_trace.sum(axis=1) / k
    if not allow_eval_sampled:
        raise ValueError(
            "history has no full tx_trace: n_transmitted is sampled on the "
            "eval grid, not per round. Pass allow_eval_sampled=True to "
            "accept the eval-sampled series (weight it by "
            "eval_spacing_weights(hist.rounds) before averaging).")
    return hist.n_transmitted / k


def eval_spacing_weights(rounds: np.ndarray) -> np.ndarray:
    """Per-eval-point block sizes: eval point j stands in for the rounds
    since the previous eval point, so weights sum to the horizon length."""
    r = np.asarray(rounds, np.int64)
    return np.diff(np.concatenate(([-1], r))).astype(np.float64)


def mean_subchannel_utilization(hist: SimHistory, k: int) -> float:
    """Mean fraction of the K sub-channels carrying a transmitter per round.

    Exact when the history carries a full ``tx_trace``.  On the
    eval-sampled fallback, each sample is weighted by the number of
    rounds its eval block spans (`eval_spacing_weights`), so uneven eval
    grids (round 0 and the final round are always sampled) don't skew
    the average the way a plain mean over eval points does.
    """
    if hist.tx_trace is not None:
        return float(per_round_utilization(hist, k).mean())
    u = per_round_utilization(hist, k, allow_eval_sampled=True)
    return float(np.average(u, weights=eval_spacing_weights(hist.rounds)))


def cumulative_latency_s(hist: SimHistory) -> float:
    """Total simulated time of the run: sum of eq.-9 round latencies."""
    if hist.latency_all is not None:
        return float(hist.latency_all.sum())
    return float(hist.cum_time_s[-1])


def summarize_cell(cfg: SimConfig, hist: SimHistory,
                   target_loss: float | None = None) -> dict:
    """One cell's scalar metric row, as stored in the sweep artifact."""
    out = {
        "final_loss": float(hist.global_loss[-1]),
        "final_accuracy": float(hist.accuracy[-1]),
        "mean_subchannel_utilization":
            mean_subchannel_utilization(hist, cfg.n_subchannels),
        "cumulative_latency_s": cumulative_latency_s(hist),
        "mean_round_latency_s": float(np.mean(
            hist.latency_all if hist.latency_all is not None
            else hist.latency_s)),
        "total_energy_j": float(np.sum(
            hist.energy_all if hist.energy_all is not None
            else hist.energy_j)),
        "wall_s": float(hist.wall_s),
        "plan_wall_s": float(hist.plan_wall_s),
    }
    if target_loss is not None:
        out["target_loss"] = float(target_loss)
        out["rounds_to_target"] = rounds_to_target(hist, target_loss)
        out["time_to_target_s"] = time_to_target_s(hist, target_loss)
    return out
