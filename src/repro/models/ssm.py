"""Attention-free sequence mixers: RWKV6 ("Finch", data-dependent decay) and
Mamba-1 selective SSM (for the Jamba hybrid).

Both expose a full-sequence form (lax.scan over time — the pure-jnp oracle
for the Pallas chunked kernel in repro.kernels.rwkv6_wkv) and a single-step
decode form with constant-size recurrent state, which is what makes the
long_500k shape natively servable for these families.

Simplifications vs. the reference implementations (DESIGN.md §5):
  * RWKV6 token-shift mixing coefficients are static per channel (the
    data-dependent *decay* w_t — the defining Finch feature — is kept, via
    the low-rank `w_lora` path).
  * Mamba uses the straightforward dt/B/C projections without the conv
    channel groups; depthwise causal conv width 4 as in the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import DTYPE, dense, dense_init

__all__ = [
    "rwkv6_init",
    "rwkv6_time_mix",
    "rwkv6_channel_mix",
    "rwkv6_decode",
    "init_rwkv6_state",
    "wkv6_scan_ref",
    "mamba_init",
    "mamba_forward",
    "mamba_decode",
    "init_mamba_state",
]


# ==========================================================================
# RWKV6
# ==========================================================================

def rwkv6_init(key, cfg: ArchConfig):
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    h = cfg.n_rwkv_heads
    ks = jax.random.split(key, 10)
    lora = 64
    return {
        # time-mix (attention-replacement) --------------------------------
        "mu": (0.5 * jnp.ones((5, d))).astype(jnp.float32),  # r,k,v,g,w shifts
        "wr": dense_init(ks[0], d, d),
        "wk": dense_init(ks[1], d, d),
        "wv": dense_init(ks[2], d, d),
        "wg": dense_init(ks[3], d, d),
        "wo": dense_init(ks[4], d, d),
        "w0": jnp.full((d,), -6.0, jnp.float32),             # decay bias
        "w_lora_a": dense_init(ks[5], d, lora, scale=0.01),
        "w_lora_b": dense_init(ks[6], lora, d, scale=0.01),
        "u": (jnp.zeros((h, hs))).astype(jnp.float32),       # per-head bonus
        "ln_x": {"g": jnp.ones((d,), jnp.float32)},
        # channel-mix (FFN-replacement) ------------------------------------
        "mu_c": (0.5 * jnp.ones((2, d))).astype(jnp.float32),
        "ck": dense_init(ks[7], d, cfg.d_ff),
        "cv": dense_init(ks[8], cfg.d_ff, d),
        "cr": dense_init(ks[9], d, d),
    }


def _shift(x, prev):
    """Token shift: x_{t-1} along the sequence; prev fills t=0."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def wkv6_scan_ref(r, k, v, w, u, state):
    """The WKV6 recurrence (pure-jnp oracle for the Pallas kernel).

    r,k,v: (B, T, H, hs); w: (B, T, H, hs) decay in (0,1); u: (H, hs);
    state: (B, H, hs, hs) mapping k-dim -> v-dim.
    Returns y (B, T, H, hs), final state.

        S_t[i,j] = w_t[i] * S_{t-1}[i,j] + k_t[i] * v_t[j]
        y_t[j]   = sum_i r_t[i] * (S_{t-1}[i,j] + u[i] k_t[i] v_t[j])
    """
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                                # (B, H, hs)
        kv = jnp.einsum("bhi,bhj->bhij", k_t, v_t)              # (B, H, hs, hs)
        y = jnp.einsum("bhi,bhij->bhj", r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., None] * s + kv
        return s, y

    rs, ks_, vs, ws = (jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    state, ys = jax.lax.scan(step, state, (rs, ks_, vs, ws))
    return jnp.moveaxis(ys, 0, 1), state


def _rwkv6_mix(p, cfg: ArchConfig, x, prev_tok):
    """Shared pre-recurrence projections. Returns r,k,v,w (B,T,H,hs), g (B,T,d)."""
    b, t, d = x.shape
    h, hs = cfg.n_rwkv_heads, cfg.rwkv_head_size
    xx = _shift(x, prev_tok)
    mu = p["mu"].astype(x.dtype)
    xr, xk, xv, xg, xw = (x + (xx - x) * mu[i] for i in range(5))
    r = dense(p["wr"], xr).reshape(b, t, h, hs).astype(jnp.float32)
    k = dense(p["wk"], xk).reshape(b, t, h, hs).astype(jnp.float32)
    v = dense(p["wv"], xv).reshape(b, t, h, hs).astype(jnp.float32)
    g = jax.nn.silu(dense(p["wg"], xg))
    # Data-dependent decay (Finch): w_t = exp(-exp(w0 + lora(xw))).
    w_log = p["w0"] + dense(p["w_lora_b"], jnp.tanh(dense(p["w_lora_a"], xw))).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w_log)).reshape(b, t, h, hs)
    return r, k, v, w, g


def _rwkv6_out(p, cfg: ArchConfig, y, g, b, t):
    d = cfg.d_model
    yf = y.reshape(b, t, d).astype(jnp.float32)
    # Per-head group normalization, folded to RMS over each head's channels.
    yh = yf.reshape(b, t, cfg.n_rwkv_heads, cfg.rwkv_head_size)
    yh = yh * jax.lax.rsqrt(jnp.mean(jnp.square(yh), -1, keepdims=True) + 1e-5)
    yf = (yh.reshape(b, t, d) * p["ln_x"]["g"]).astype(g.dtype)
    return dense(p["wo"], yf * g)


def rwkv6_time_mix(p, cfg: ArchConfig, x, state, *, wkv_impl=wkv6_scan_ref):
    """Time-mix (attention replacement) over a full sequence. x: (B, T, d).

    state: {"wkv": (B,H,hs,hs), "prev_tok": (B,d)}.  Works for T == 1
    (decode) and any prefill length.
    """
    b, t, _ = x.shape
    r, k, v, w, g = _rwkv6_mix(p, cfg, x, state["prev_tok"])
    y, s_new = wkv_impl(r, k, v, w, p["u"], state["wkv"])
    out = _rwkv6_out(p, cfg, y, g, b, t)
    return out, {"wkv": s_new, "prev_tok": x[:, -1, :]}


def rwkv6_channel_mix(p, cfg: ArchConfig, x, prev_tok):
    """Channel-mix (FFN replacement). Returns (y, new prev_tok (B, d))."""
    xx = _shift(x, prev_tok)
    mu_c = p["mu_c"].astype(x.dtype)
    xk = x + (xx - x) * mu_c[0]
    xr = x + (xx - x) * mu_c[1]
    y = jax.nn.sigmoid(dense(p["cr"], xr)) * dense(
        p["cv"], jnp.square(jax.nn.relu(dense(p["ck"], xk)))
    )
    return y, x[:, -1, :]


def init_rwkv6_state(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    h, hs = cfg.n_rwkv_heads, cfg.rwkv_head_size
    return {
        "wkv": jnp.zeros((batch, h, hs, hs), dtype),
        "prev_tok": jnp.zeros((batch, cfg.d_model), DTYPE),
    }


def rwkv6_decode(p, cfg: ArchConfig, x, state):
    """Single-token time-mix: x (B, 1, d). Same math, T=1."""
    return rwkv6_time_mix(p, cfg, x, state)


# ==========================================================================
# Mamba-1 (selective SSM)
# ==========================================================================

def mamba_init(key, cfg: ArchConfig):
    d = cfg.d_model
    di = cfg.mamba_d_inner
    n = cfg.mamba_d_state
    dt_rank = max(d // 16, 1)
    ks = jax.random.split(key, 6)
    a = jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))
    return {
        "in_proj": dense_init(ks[0], d, 2 * di),
        "conv_w": (jax.random.normal(ks[1], (cfg.mamba_d_conv, di)) * 0.2).astype(DTYPE),
        "conv_b": jnp.zeros((di,), DTYPE),
        "x_proj": dense_init(ks[2], di, dt_rank + 2 * n),
        "dt_proj": dense_init(ks[3], dt_rank, di, scale=dt_rank**-0.5),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((di,), 0.01))).astype(jnp.float32),
        "a_log": jnp.log(a),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, d),
    }


def _mamba_ssm_inputs(p, cfg: ArchConfig, xc):
    """xc: conv+silu output (B, T, di). Returns dt (B,T,di), b/c (B,T,N)."""
    n = cfg.mamba_d_state
    dt_rank = p["dt_proj"]["w"].shape[0]
    dbc = dense(p["x_proj"], xc)
    dt_low, b_ssm, c_ssm = jnp.split(dbc, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(dense(p["dt_proj"], dt_low).astype(jnp.float32) + p["dt_bias"])
    return dt, b_ssm.astype(jnp.float32), c_ssm.astype(jnp.float32)


def mamba_forward(p, cfg: ArchConfig, x, state=None):
    """x: (B, T, d). Full-sequence selective scan."""
    b, t, d = x.shape
    di, n = cfg.mamba_d_inner, cfg.mamba_d_state
    kw = cfg.mamba_d_conv
    if state is None:
        state = init_mamba_state(cfg, b)

    xi, z = jnp.split(dense(p["in_proj"], x), 2, axis=-1)       # (B, T, di)
    # Depthwise causal conv along T, warm-started from the cached window.
    xpad = jnp.concatenate([state["conv"], xi], axis=1)          # (B, T+kw-1, di)
    xc = sum(xpad[:, i : i + t, :] * p["conv_w"][i] for i in range(kw)) + p["conv_b"]
    xc = jax.nn.silu(xc)

    dt, b_ssm, c_ssm = _mamba_ssm_inputs(p, cfg, xc)
    a = -jnp.exp(p["a_log"])                                     # (di, N)
    da = jnp.exp(dt[..., None] * a)                              # (B, T, di, N)
    dbx = dt[..., None] * b_ssm[:, :, None, :] * xc.astype(jnp.float32)[..., None]

    def step(h, inp):
        da_t, dbx_t, c_t = inp
        h = da_t * h + dbx_t                                     # (B, di, N)
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    h0 = state["ssm"]
    (h_fin, ys) = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(da, 1, 0), jnp.moveaxis(dbx, 1, 0), jnp.moveaxis(c_ssm, 1, 0)),
    )
    y = jnp.moveaxis(ys, 0, 1) + xc.astype(jnp.float32) * p["d_skip"]
    out = dense(p["out_proj"], (y.astype(x.dtype)) * jax.nn.silu(z))
    new_state = {"ssm": h_fin, "conv": xpad[:, -(kw - 1):, :] if kw > 1 else state["conv"]}
    return out, new_state


def init_mamba_state(cfg: ArchConfig, batch: int):
    return {
        "ssm": jnp.zeros((batch, cfg.mamba_d_inner, cfg.mamba_d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, cfg.mamba_d_inner), DTYPE),
    }


def mamba_decode(p, cfg: ArchConfig, x, state):
    return mamba_forward(p, cfg, x, state)
