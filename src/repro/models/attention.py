"""Attention blocks: GQA (+QKV bias, RoPE / M-RoPE, sliding window, chunked
softmax for long prefill), deepseek-style MLA with latent KV cache, and
whisper-style cross-attention.

Grouped heads never materialize the repeated K/V: queries are reshaped to
(B, S, Hkv, G, Dh) and contracted against (B, S, Hkv, Dh) directly, which
also keeps the head axis shardable on the `model` mesh axis.

Caches (decode path) are ring buffers:
    {"k": (B, C, Hkv, Dh), "v": (B, C, Hkv, Dh), "pos": (C,) int32 global
     positions (-1 = empty), "idx": () int32 next write slot}
K is stored *with RoPE applied at its true position*, so decode never
re-rotates the cache.  Sliding-window configs simply allocate C = window.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import DTYPE, apply_mrope, apply_rope, dense, dense_init

__all__ = [
    "gqa_init",
    "gqa_forward",
    "gqa_decode",
    "init_kv_cache",
    "mla_init",
    "mla_forward",
    "mla_decode",
    "init_mla_cache",
    "cross_attn_init",
    "cross_attn",
]

NEG_INF = -1e30


# --------------------------------------------------------------------------
# Core softmax attention on grouped heads.
# --------------------------------------------------------------------------

def _sdpa(q, k, v, mask, scale):
    """q: (B,Sq,Hkv,G,Dh); k,v: (B,Sk,Hkv,Dh); mask: (B,1,1,Sq,Sk) or None."""
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32), k.astype(jnp.float32))
    logits = logits * scale
    if mask is not None:
        logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out


def _causal_mask(sq: int, sk: int, q_offset, window: int):
    """(1,1,1,Sq,Sk) boolean; window = 0 means full causal."""
    qi = jnp.arange(sq)[:, None] + q_offset
    kj = jnp.arange(sk)[None, :]
    m = kj <= qi
    if window > 0:
        m &= kj > qi - window
    return m[None, None, None]


def _chunked_sdpa(q, k, v, scale, window: int, chunk: int, q_offset: int = 0):
    """Flash-style: scan over query chunks with a streaming softmax.

    Peak memory per step is (B,Hkv,G,chunk,Sk) instead of (...,Sq,Sk); this
    is the memory-term optimization used for the 32k-prefill shapes
    (EXPERIMENTS.md §Perf).  q_offset shifts the causal mask for
    sequence-parallel shards.
    """
    b, sq, hkv, g, dh = q.shape
    dv = v.shape[-1]  # MLA: value dim differs from the q/k dim
    sk = k.shape[1]
    n_chunks = sq // chunk
    assert sq % chunk == 0, (sq, chunk)
    qc = q.reshape(b, n_chunks, chunk, hkv, g, dh).transpose(1, 0, 2, 3, 4, 5)

    def step(_, args):
        i, qi = args
        offset = i * chunk + q_offset
        mask = _causal_mask(chunk, sk, offset, window)
        out = _sdpa(qi, k, v, mask, scale)
        return None, out

    _, outs = jax.lax.scan(step, None, (jnp.arange(n_chunks), qc))
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, hkv, g, dv)


def _full_attn(qg, k, v, scale, window: int, chunk: int, q_offset=0):
    """Dispatch: chunked scan for long sequences, one-shot otherwise."""
    s = qg.shape[1]
    if chunk and s > 2 * chunk:
        return _chunked_sdpa(qg, k, v, scale, window, chunk, q_offset)
    mask = _causal_mask(s, k.shape[1], q_offset, window)
    return _sdpa(qg, k, v, mask, scale)


def sharded_causal_attention(qg, k, v, scale, window: int, chunk: int, ctx):
    """Explicitly partitioned full-sequence causal attention (shard_map).

    Baseline GSPMD sometimes partial-sums the per-chunk score matrix over
    the model axis (an all-reduce of (B,H,chunk,Sk) PER layer PER chunk —
    the dominant collective in the baseline roofline).  This wrapper pins a
    communication-free layout instead:

      * head-parallel when Hkv %% model == 0: every mesh column owns
        Hkv/model kv-head groups for the full sequence; zero collectives
        inside attention (q/k/v arrive head-sharded from their matmuls).
      * sequence-parallel otherwise: every column owns Sq/model query rows
        and replicates K/V (one all-gather of K/V per layer, ~|K|+|V|
        bytes, vs. the baseline's per-chunk score all-reduce).

    qg: (B, S, Hkv, G, Dh); k, v: (B, S, Hkv, Dh*).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = ctx.mesh
    mp = mesh.shape["model"]
    b, s, hkv, g, dh = qg.shape
    dp = ctx.dp_axes
    b_ax = dp if b % max(1, _dp_size(ctx)) == 0 else None

    if hkv % mp == 0:
        # ---- head-parallel ------------------------------------------------
        def fn(q_l, k_l, v_l):
            return _full_attn(q_l, k_l, v_l, scale, window, chunk)

        return shard_map(
            fn, mesh=mesh,
            in_specs=(P(b_ax, None, "model", None, None),
                      P(b_ax, None, "model", None),
                      P(b_ax, None, "model", None)),
            out_specs=P(b_ax, None, "model", None, None),
            check_rep=False,
        )(qg, k, v)

    if s % mp == 0:
        # ---- sequence-parallel ---------------------------------------------
        s_loc = s // mp

        def fn(q_l, k_f, v_f):
            off = jax.lax.axis_index("model") * s_loc
            return _full_attn(q_l, k_f, v_f, scale, window,
                              min(chunk, s_loc) if chunk else 0, off)

        return shard_map(
            fn, mesh=mesh,
            in_specs=(P(b_ax, "model", None, None, None),
                      P(b_ax, None, None, None),
                      P(b_ax, None, None, None)),
            out_specs=P(b_ax, "model", None, None, None),
            check_rep=False,
        )(qg, k, v)

    # Fallback: GSPMD auto.
    return _full_attn(qg, k, v, scale, window, chunk)


def _dp_size(ctx) -> int:
    n = 1
    for a in ctx.dp_axes:
        n *= ctx.mesh.shape[a]
    return n


# --------------------------------------------------------------------------
# GQA
# --------------------------------------------------------------------------

def gqa_init(key, cfg: ArchConfig):
    dh = cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, cfg.d_model, cfg.n_heads * dh, bias=cfg.qkv_bias),
        "wk": dense_init(k2, cfg.d_model, cfg.n_kv_heads * dh, bias=cfg.qkv_bias),
        "wv": dense_init(k3, cfg.d_model, cfg.n_kv_heads * dh, bias=cfg.qkv_bias),
        "wo": dense_init(k4, cfg.n_heads * dh, cfg.d_model),
    }


def _project_qkv(p, cfg: ArchConfig, x, positions, mrope_pos):
    b, s, _ = x.shape
    dh = cfg.head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    q = dense(p["wq"], x).reshape(b, s, hq, dh)
    k = dense(p["wk"], x).reshape(b, s, hkv, dh)
    v = dense(p["wv"], x).reshape(b, s, hkv, dh)
    if cfg.use_mrope and mrope_pos is not None:
        sections = _mrope_sections(dh)
        q = apply_mrope(q, mrope_pos, cfg.rope_theta, sections)
        k = apply_mrope(k, mrope_pos, cfg.rope_theta, sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mrope_sections(dh: int):
    """Split Dh/2 frequency pairs into (t, h, w) ~ (1/4, 3/8, 3/8)."""
    half = dh // 2
    t = half // 4
    h = (half - t) // 2
    return (t, h, half - t - h)


def gqa_forward(
    p,
    cfg: ArchConfig,
    x,
    *,
    positions=None,
    mrope_pos=None,
    chunk: int = 0,
    causal: bool = True,
    return_kv: bool = False,
    ctx=None,
):
    """Training / prefill self-attention (causal, optional sliding window).

    With return_kv=True also returns the rotated (k, v) so the serving path
    can seed a decode cache from prefill.  ctx with attn_shard="explicit"
    routes through sharded_causal_attention (§Perf).
    """
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    q, k, v = _project_qkv(p, cfg, x, positions, mrope_pos)
    hkv = cfg.n_kv_heads
    g = cfg.n_heads // hkv
    dh = cfg.head_dim
    qg = q.reshape(b, s, hkv, g, dh)
    scale = dh**-0.5
    if not causal:
        out = _sdpa(qg, k, v, None, scale)
    elif cfg.attn_impl == "pallas" and (ctx is None or ctx.mesh is None):
        # Single-device flash kernel (TPU Mosaic; interpret on CPU).
        from ..kernels.flash_attention.ops import flash_attention
        out = flash_attention(q, k, v, causal=True, window=cfg.sliding_window,
                              bq=min(128, s), bk=min(128, s))
        out = out.reshape(b, s, hkv, g, dh)
    elif ctx is not None and getattr(ctx, "mesh", None) is not None \
            and getattr(ctx, "attn_shard", "auto") == "explicit":
        out = sharded_causal_attention(qg, k, v, scale, cfg.sliding_window,
                                       chunk, ctx)
    else:
        out = _full_attn(qg, k, v, scale, cfg.sliding_window, chunk)
    y = dense(p["wo"], out.reshape(b, s, cfg.n_heads * dh))
    if return_kv:
        return y, (k, v)
    return y


def init_kv_cache(cfg: ArchConfig, batch: int, cache_len: int, dtype=DTYPE):
    dh = cfg.head_dim
    return {
        "k": jnp.zeros((batch, cache_len, cfg.n_kv_heads, dh), dtype),
        "v": jnp.zeros((batch, cache_len, cfg.n_kv_heads, dh), dtype),
        "pos": jnp.full((cache_len,), -1, jnp.int32),
        "idx": jnp.zeros((), jnp.int32),
    }


def gqa_decode(p, cfg: ArchConfig, x, cache, cur_pos, *, mrope_pos=None):
    """One-token decode: x (B, 1, d); cur_pos () int32 global position."""
    b = x.shape[0]
    dh = cfg.head_dim
    hkv = cfg.n_kv_heads
    g = cfg.n_heads // hkv
    positions = jnp.full((b, 1), cur_pos, jnp.int32)
    q, k, v = _project_qkv(p, cfg, x, positions, mrope_pos)

    c = cache["k"].shape[1]
    slot = cache["idx"] % c
    new_k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    new_pos = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], jnp.full((1,), cur_pos, jnp.int32), slot, axis=0
    )

    valid = (new_pos >= 0) & (new_pos <= cur_pos)
    if cfg.sliding_window > 0:
        valid &= new_pos > cur_pos - cfg.sliding_window
    mask = valid[None, None, None, None, :]                    # (1,1,1,1,C)

    qg = q.reshape(b, 1, hkv, g, dh)
    out = _sdpa(qg, new_k, new_v, mask, dh**-0.5)
    y = dense(p["wo"], out.reshape(b, 1, cfg.n_heads * dh))
    new_cache = {"k": new_k, "v": new_v, "pos": new_pos, "idx": cache["idx"] + 1}
    return y, new_cache


# --------------------------------------------------------------------------
# MLA (deepseek-v3): low-rank latent KV, decoupled RoPE key.
# --------------------------------------------------------------------------

def mla_init(key, cfg: ArchConfig):
    ks = jax.random.split(key, 6)
    h = cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "q_down": dense_init(ks[0], cfg.d_model, cfg.q_lora_rank),
        "q_up": dense_init(ks[1], cfg.q_lora_rank, h * qk),
        "kv_down": dense_init(ks[2], cfg.d_model, cfg.kv_lora_rank + cfg.qk_rope_dim),
        "kv_up": dense_init(ks[3], cfg.kv_lora_rank, h * (cfg.qk_nope_dim + cfg.v_head_dim)),
        "wo": dense_init(ks[4], h * cfg.v_head_dim, cfg.d_model),
    }


def _mla_qkv_from_latent(p, cfg: ArchConfig, xq, c_kv, k_pe):
    """Up-project: returns q (B,Sq,H,qk), k (B,Sk,H,qk), v (B,Sk,H,dv).

    xq: query-side activations; (c_kv, k_pe) the latent cache (key side).
    """
    b, sq, _ = xq.shape
    sk = c_kv.shape[1]
    h, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q = dense(p["q_up"], dense(p["q_down"], xq)).reshape(b, sq, h, dn + dr)
    kv = dense(p["kv_up"], c_kv).reshape(b, sk, h, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_pe[:, :, None, :], (b, sk, h, dr))], -1)
    return q, k, v


def mla_forward(p, cfg: ArchConfig, x, *, positions=None, chunk: int = 0,
                return_kv: bool = False, ctx=None):
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    down = dense(p["kv_down"], x)
    c_kv, k_pe = down[..., : cfg.kv_lora_rank], down[..., cfg.kv_lora_rank :]
    k_pe = apply_rope(k_pe[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    q, k, v = _mla_qkv_from_latent(p, cfg, x, c_kv, k_pe)
    # Rotate the rope-section of q at query positions.
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_pe], -1)

    scale = (dn + dr) ** -0.5
    qg = q[:, :, :, None, :]  # Hkv = H, G = 1
    if ctx is not None and getattr(ctx, "mesh", None) is not None \
            and getattr(ctx, "attn_shard", "auto") == "explicit":
        # MLA is post-up-projection MHA (Hkv = 128) -> head-parallel path.
        out = sharded_causal_attention(qg, k, v, scale, cfg.sliding_window,
                                       chunk, ctx)
    else:
        out = _full_attn(qg, k, v, scale, cfg.sliding_window, chunk)
    out = out[:, :, :, 0, :]
    y = dense(p["wo"], out.reshape(b, s, cfg.n_heads * cfg.v_head_dim))
    if return_kv:
        return y, (c_kv, k_pe)
    return y


def init_mla_cache(cfg: ArchConfig, batch: int, cache_len: int, dtype=DTYPE):
    """The MLA decode cache stores the *latent* (kv_lora + rope) per token —
    the paper-exact memory win of MLA (5.4x smaller than GQA kv=128)."""
    return {
        "c_kv": jnp.zeros((batch, cache_len, cfg.kv_lora_rank), dtype),
        "k_pe": jnp.zeros((batch, cache_len, cfg.qk_rope_dim), dtype),
        "pos": jnp.full((cache_len,), -1, jnp.int32),
        "idx": jnp.zeros((), jnp.int32),
    }


def mla_decode(p, cfg: ArchConfig, x, cache, cur_pos):
    """MLA single-token decode. Two execution modes:

    * naive (paper-faithful baseline): up-project the ENTIRE latent cache to
      per-head K/V, then standard attention — materializes
      (B, C, H, dn+dv) every step;
    * absorbed (cfg.mla_absorb, EXPERIMENTS §Perf): fold kv_up into the
      query/output projections so attention runs in the 576-dim latent
      space — the cache is read once and no per-head K/V ever exists.
      Identical math (associativity of the matmuls).
    """
    b = x.shape[0]
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    positions = jnp.full((b, 1), cur_pos, jnp.int32)
    down = dense(p["kv_down"], x)
    c_kv_new, k_pe_new = down[..., : cfg.kv_lora_rank], down[..., cfg.kv_lora_rank :]
    k_pe_new = apply_rope(k_pe_new[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]

    c = cache["c_kv"].shape[1]
    slot = cache["idx"] % c
    c_kv = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv_new, slot, axis=1)
    k_pe = jax.lax.dynamic_update_slice_in_dim(cache["k_pe"], k_pe_new, slot, axis=1)
    pos = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], jnp.full((1,), cur_pos, jnp.int32), slot, axis=0
    )
    new_cache = {"c_kv": c_kv, "k_pe": k_pe, "pos": pos, "idx": cache["idx"] + 1}

    valid = (pos >= 0) & (pos <= cur_pos)
    if cfg.sliding_window > 0:
        valid &= pos > cur_pos - cfg.sliding_window

    if getattr(cfg, "mla_absorb", False):
        h, dv = cfg.n_heads, cfg.v_head_dim
        q = dense(p["q_up"], dense(p["q_down"], x)).reshape(b, 1, h, dn + dr)
        q_nope, q_pe = q[..., :dn], q[..., dn:]
        q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
        w_up = p["kv_up"]["w"].reshape(cfg.kv_lora_rank, h, dn + dv)
        w_k, w_v = w_up[..., :dn], w_up[..., dn:]
        # Absorb kv_up into q: scores live in latent space.
        q_abs = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32),
                           w_k.astype(jnp.float32))
        logits = jnp.einsum("bqhr,bcr->bhqc", q_abs, c_kv.astype(jnp.float32))
        logits += jnp.einsum("bqhd,bcd->bhqc", q_pe.astype(jnp.float32),
                             k_pe.astype(jnp.float32))
        logits *= (dn + dr) ** -0.5
        logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        o_lat = jnp.einsum("bhqc,bcr->bqhr", probs, c_kv.astype(jnp.float32))
        out = jnp.einsum("bqhr,rhd->bqhd", o_lat, w_v.astype(jnp.float32))
        out = out.astype(x.dtype)
    else:
        q, k, v = _mla_qkv_from_latent(p, cfg, x, c_kv, k_pe)
        q_nope, q_pe = q[..., :dn], q[..., dn:]
        q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
        q = jnp.concatenate([q_nope, q_pe], -1)
        mask = valid[None, None, None, None, :]
        out = _sdpa(q[:, :, :, None, :], k, v, mask, (dn + dr) ** -0.5)[:, :, :, 0, :]
    y = dense(p["wo"], out.reshape(b, 1, cfg.n_heads * cfg.v_head_dim))
    return y, new_cache


# --------------------------------------------------------------------------
# Cross-attention (whisper decoder -> encoder output)
# --------------------------------------------------------------------------

def cross_attn_init(key, cfg: ArchConfig):
    dh = cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, cfg.d_model, cfg.n_heads * dh, bias=cfg.qkv_bias),
        "wk": dense_init(k2, cfg.d_model, cfg.n_kv_heads * dh),
        "wv": dense_init(k3, cfg.d_model, cfg.n_kv_heads * dh),
        "wo": dense_init(k4, cfg.n_heads * dh, cfg.d_model),
    }


def cross_attn(p, cfg: ArchConfig, x, enc_out):
    """x: (B, Sq, d) decoder stream; enc_out: (B, Se, d). No mask, no RoPE
    (whisper uses learned/sinusoidal absolute positions on the encoder)."""
    b, sq, _ = x.shape
    se = enc_out.shape[1]
    dh = cfg.head_dim
    hkv = cfg.n_kv_heads
    g = cfg.n_heads // hkv
    q = dense(p["wq"], x).reshape(b, sq, cfg.n_heads, dh)
    k = dense(p["wk"], enc_out).reshape(b, se, hkv, dh)
    v = dense(p["wv"], enc_out).reshape(b, se, hkv, dh)
    out = _sdpa(q.reshape(b, sq, hkv, g, dh), k, v, None, dh**-0.5)
    return dense(p["wo"], out.reshape(b, sq, cfg.n_heads * dh))
