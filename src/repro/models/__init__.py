from .small import SmallModel, get_small_model, mnist_mlp, cifar_cnn, sst2_text
from .moe import ShardCtx
from .transformer import (
    decode_step,
    forward,
    init_cache,
    init_params,
    lm_loss,
    param_count,
    stage_plan,
)
