"""Shared model-zoo building blocks (pure JAX, dict pytrees).

Parameter convention: every weight matrix is stored (fan_in, fan_out) so the
sharding rules in repro.sharding.partition can match on path names; compute
runs in bf16 with f32 norms/softmax accumulations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "dense_init",
    "dense",
    "rmsnorm_init",
    "rmsnorm",
    "layernorm_init",
    "layernorm",
    "swiglu_init",
    "swiglu",
    "mlp_init",
    "mlp",
    "rope_freqs",
    "apply_rope",
    "apply_mrope",
]

DTYPE = jnp.bfloat16


def dense_init(key, n_in: int, n_out: int, *, bias: bool = False, scale: float | None = None):
    scale = (2.0 / (n_in + n_out)) ** 0.5 if scale is None else scale
    p = {"w": (jax.random.normal(key, (n_in, n_out)) * scale).astype(DTYPE)}
    if bias:
        p["b"] = jnp.zeros((n_out,), DTYPE)
    return p


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def rmsnorm_init(d: int):
    return {"g": jnp.ones((d,), jnp.float32)}


def rmsnorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * p["g"]).astype(x.dtype)


def layernorm_init(d: int):
    return {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def layernorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mean = xf.mean(axis=-1, keepdims=True)
    var = xf.var(axis=-1, keepdims=True)
    return ((xf - mean) * jax.lax.rsqrt(var + eps) * p["g"] + p["b"]).astype(x.dtype)


# --------------------------------------------------------------------------
# Feed-forward blocks
# --------------------------------------------------------------------------

def swiglu_init(key, d: int, ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d, ff),
        "up": dense_init(k2, d, ff),
        "down": dense_init(k3, ff, d),
    }


def swiglu(p, x):
    return dense(p["down"], jax.nn.silu(dense(p["gate"], x)) * dense(p["up"], x))


def mlp_init(key, d: int, ff: int, *, bias: bool = False):
    k1, k2 = jax.random.split(key)
    return {"fc": dense_init(k1, d, ff, bias=bias), "proj": dense_init(k2, ff, d, bias=bias)}


def mlp(p, x, act=jax.nn.gelu):
    return dense(p["proj"], act(dense(p["fc"], x)))


# --------------------------------------------------------------------------
# Rotary embeddings (RoPE + qwen2-vl M-RoPE)
# --------------------------------------------------------------------------

def rope_freqs(dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape (dim // 2,), f32."""
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def _rot(x, cos, sin):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, Dh), positions: broadcastable to (..., S), int32."""
    dh = x.shape[-1]
    inv = rope_freqs(dh, theta)                                     # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * inv            # (..., S, Dh/2)
    cos = jnp.cos(ang)[..., None, :]                                # (..., S, 1, Dh/2)
    sin = jnp.sin(ang)[..., None, :]
    return _rot(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def apply_mrope(
    x: jax.Array, positions_3d: jax.Array, theta: float, sections=(16, 24, 24)
) -> jax.Array:
    """qwen2-VL multimodal RoPE.

    The head dim's frequency slots are split into (temporal, height, width)
    sections; each section rotates by its own position stream.

    x: (B, S, H, Dh); positions_3d: (B, S, 3) int32.  `sections` are in
    *frequency pairs* and must sum to Dh // 2.
    """
    dh = x.shape[-1]
    assert sum(sections) == dh // 2, (sections, dh)
    inv = rope_freqs(dh, theta)                                     # (Dh/2,)
    # Section id per frequency slot: 0 = t, 1 = h, 2 = w.
    sec = jnp.concatenate(
        [jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)]
    )                                                                # (Dh/2,)
    pos = jnp.take_along_axis(
        positions_3d.astype(jnp.float32),                            # (B, S, 3)
        jnp.broadcast_to(sec[None, None, :], positions_3d.shape[:2] + sec.shape),
        axis=-1,
    )                                                                # (B, S, Dh/2)
    ang = pos * inv                                                  # (B, S, Dh/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    return _rot(x.astype(jnp.float32), cos, sin).astype(x.dtype)
