"""Mixture-of-Experts FFN: dropping top-k routing with sort-based capacity
dispatch, TPU expert parallelism via shard_map.

Why this formulation (DESIGN.md hardware-adaptation):
  * GShard-style one-hot dispatch einsums inflate HLO FLOPs by the dispatch
    tensor (T x E x C) — catastrophic for both memory and the roofline's
    "useful FLOPs" ratio.  Instead we sort token-copies by expert id and
    scatter them into a fixed-capacity buffer (E_local, C, d): the dispatch
    is pure data movement (gather/scatter), and the expert matmuls are dense
    (E_local, C, d) x (E_local, d, ff) einsums that map straight onto the MXU.
  * Expert parallelism: experts are sharded over the `model` mesh axis;
    activations stay sharded over the data axes and replicated over `model`.
    Each model shard dispatches only to ITS local experts and contributes a
    partial output; one psum over `model` combines (this trades the classic
    all-to-all for an all-reduce of (T, d) — on a 16-way model axis this is
    the cheaper collective whenever top_k * capacity > d_model/16, which
    holds for every assigned MoE config).
  * Experts are zero-padded to a multiple of the expert-parallel degree
    (granite's 40 experts -> 48 on a 16-way axis); padded experts receive no
    router probability mass.

Capacity: C = ceil(T * top_k / E * capacity_factor); overflowing tokens are
dropped (their copies contribute 0), standard for capacity-based TPU MoE.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.ad_checkpoint
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..configs.base import ArchConfig
from .layers import DTYPE, dense_init, swiglu, swiglu_init

__all__ = ["ShardCtx", "moe_init", "moe_apply", "pad_experts", "CAPACITY_FACTOR"]

CAPACITY_FACTOR = 1.25


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Static sharding context threaded through the model assembly.

    mesh=None -> single-device math (smoke tests / FL simulation).
    attn_shard: "auto" leaves attention partitioning to GSPMD (baseline);
    "explicit" wraps full-sequence attention in shard_map (head-parallel
    when kv-heads divide the model axis, sequence-parallel otherwise) —
    the §Perf optimization that removes GSPMD's per-chunk score all-reduce.
    """

    mesh: Any = None
    dp_axes: tuple = ("data",)      # activation batch axes
    ep_axis: str = "model"          # expert-parallel axis
    attn_shard: str = "auto"        # "auto" | "explicit"

    @property
    def ep_size(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape[self.ep_axis]


def pad_experts(n_experts: int, ep_size: int) -> int:
    return ((n_experts + ep_size - 1) // ep_size) * ep_size


def moe_init(key, cfg: ArchConfig, *, ep_size: int = 1):
    e_pad = pad_experts(cfg.n_experts, ep_size)
    ff = cfg.ffn_expert
    ks = jax.random.split(key, 5)
    scale = (2.0 / (cfg.d_model + ff)) ** 0.5
    p = {
        "router": dense_init(ks[0], cfg.d_model, cfg.n_experts, scale=0.02),
        "gate": (jax.random.normal(ks[1], (e_pad, cfg.d_model, ff)) * scale).astype(DTYPE),
        "up": (jax.random.normal(ks[2], (e_pad, cfg.d_model, ff)) * scale).astype(DTYPE),
        "down": (jax.random.normal(ks[3], (e_pad, ff, cfg.d_model)) * scale).astype(DTYPE),
    }
    if cfg.n_shared_experts > 0:
        p["shared"] = swiglu_init(ks[4], cfg.d_model, ff * cfg.n_shared_experts)
    return p


def _capacity(n_tokens: int, cfg: ArchConfig) -> int:
    """Expert capacity. Small token counts (decode steps, smoke tests) get
    C = T * top_k, i.e. *dropless* exact routing; at scale the standard
    capacity-factor bound applies and overflow tokens are dropped."""
    if n_tokens * cfg.top_k <= 256:
        return n_tokens * cfg.top_k
    c = int(n_tokens * cfg.top_k * CAPACITY_FACTOR / cfg.n_experts) + 1
    return max(c, cfg.top_k)


def _local_moe(x2d, router_w, gate, up, down, cfg: ArchConfig, capacity: int, e_offset):
    """Dispatch T tokens to the n_local experts held by this shard.

    x2d (T, d); gate/up/down (E_local, d|ff, ff|d); e_offset int32 global id
    of this shard's first expert.  Returns (y (T, d), aux_loss ()).
    """
    t, d = x2d.shape
    n_local = gate.shape[0]
    k = cfg.top_k

    logits = (x2d.astype(jnp.float32) @ router_w.astype(jnp.float32))  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                             # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # Load-balance aux (Switch-style): E * sum_e f_e * P_e.
    frac = jnp.zeros((cfg.n_experts,), jnp.float32).at[top_e.reshape(-1)].add(1.0)
    frac = frac / (t * k)
    aux = cfg.n_experts * jnp.sum(frac * probs.mean(0))

    # ---- flatten the T*k token copies and keep those routed locally. -----
    e_flat = top_e.reshape(-1) - e_offset                               # (T*k,)
    w_flat = top_p.reshape(-1)
    tok_flat = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    valid = (e_flat >= 0) & (e_flat < n_local)
    key = jnp.where(valid, e_flat, n_local)                             # invalid -> end
    order = jnp.argsort(key, stable=True)
    e_sorted = key[order]
    tok_sorted = tok_flat[order]
    w_sorted = w_flat[order]

    counts = jnp.bincount(key, length=n_local + 1)[:n_local]
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)])
    rank = jnp.arange(t * k) - starts[jnp.minimum(e_sorted, n_local)]
    keep = (e_sorted < n_local) & (rank < capacity)
    slot = jnp.where(keep, e_sorted * capacity + rank, n_local * capacity)

    # ---- scatter into the (E_local * C) buffer, run the experts. ---------
    gathered = x2d[tok_sorted] * keep[:, None].astype(x2d.dtype)
    buf = jnp.zeros((n_local * capacity + 1, d), x2d.dtype).at[slot].set(gathered)
    buf = buf[:-1].reshape(n_local, capacity, d)

    h = jnp.einsum("ecd,edf->ecf", buf, gate)
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf, up)
    out = jnp.einsum("ecf,efd->ecd", h, down)                           # (E_l, C, d)

    # ---- combine back: gather by slot, weight, scatter-add by token. ----
    out_flat = jnp.concatenate([out.reshape(n_local * capacity, d),
                                jnp.zeros((1, d), out.dtype)])
    y_sorted = out_flat[slot] * (w_sorted * keep)[:, None].astype(out.dtype)
    y = jnp.zeros((t, d), out.dtype).at[tok_sorted].add(y_sorted)
    return y, aux


def moe_apply(p, cfg: ArchConfig, x, ctx: ShardCtx):
    """x: (B, S, d) -> (y, aux_loss).  Shared experts (deepseek) are a plain
    dense SwiGLU added to the routed output."""
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)

    if ctx.mesh is None:
        cap = _capacity(b * s, cfg)
        y2d, aux = _local_moe(
            x2d, p["router"]["w"], p["gate"], p["up"], p["down"], cfg, cap,
            jnp.zeros((), jnp.int32),
        )
    else:
        ep = ctx.ep_size
        n_local = p["gate"].shape[0] // ep
        # Per-shard token count: batch is sharded over the data axes when it
        # divides; otherwise (e.g. long_500k's single decode token) tokens
        # stay replicated and only experts are sharded.
        dp = 1
        for a in ctx.dp_axes:
            dp *= ctx.mesh.shape[a]
        token_sharded = (b * s) % dp == 0
        cap = _capacity((b * s) // dp if token_sharded else b * s, cfg)
        tok_spec = P(ctx.dp_axes, None) if token_sharded else P(None, None)

        def shard_fn(x_l, rw, g_l, u_l, d_l):
            e_off = jax.lax.axis_index(ctx.ep_axis) * n_local
            y_l, aux_l = _local_moe(x_l, rw, g_l, u_l, d_l, cfg, cap, e_off)
            y_l = jax.lax.psum(y_l, ctx.ep_axis)       # combine expert shards
            aux_l = jax.lax.pmean(aux_l, ctx.ep_axis)
            return y_l, aux_l

        y2d, aux = shard_map(
            shard_fn,
            mesh=ctx.mesh,
            in_specs=(
                tok_spec,                              # tokens
                P(None, None),                         # router: replicated
                P(ctx.ep_axis, None, None),            # experts: EP-sharded
                P(ctx.ep_axis, None, None),
                P(ctx.ep_axis, None, None),
            ),
            out_specs=(tok_spec, P()),
            check_rep=False,
        )(x2d, p["router"]["w"], p["gate"], p["up"], p["down"])
        # Name the combined output so the remat policy can SAVE it: without
        # this, rematerialization re-executes the psum in the backward pass,
        # doubling the MoE collective volume (EXPERIMENTS §Perf iteration 2).
        y2d = jax.ad_checkpoint.checkpoint_name(y2d, "moe_out")

    y = y2d.reshape(b, s, d)
    if "shared" in p:
        y = y + swiglu(p["shared"], x)
    return y, aux
