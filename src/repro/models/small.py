"""The paper's three simulation models (Sec. VI footnote 6), pure JAX.

  MNIST : MLP 784 -> 128 ReLU -> 256 ReLU -> 10 softmax
  CIFAR : CNN 3x3x32 conv + 2x2 maxpool + 3x3x64 conv + 2x2 maxpool
          -> 128 ReLU -> 10 softmax
  SST-2 : embed(4000 -> 64) mean-pool -> 128 ReLU -> 1 sigmoid

Each model is (init_fn, apply_fn, loss_fn); params are plain dict pytrees.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["SmallModel", "mnist_mlp", "cifar_cnn", "sst2_text", "get_small_model", "param_count", "param_bits"]


@dataclasses.dataclass(frozen=True)
class SmallModel:
    name: str
    init: Callable[[jax.Array], Any]
    apply: Callable[[Any, jax.Array], jax.Array]       # logits
    loss: Callable[[Any, jax.Array, jax.Array], jax.Array]  # mean loss
    accuracy: Callable[[Any, jax.Array, jax.Array], jax.Array]
    loss_per_example: Callable[[Any, jax.Array, jax.Array], jax.Array] = None  # (B,)


def _dense_init(key, n_in, n_out):
    k1, _ = jax.random.split(key)
    w = jax.random.normal(k1, (n_in, n_out)) * jnp.sqrt(2.0 / n_in)
    return {"w": w.astype(jnp.float32), "b": jnp.zeros((n_out,), jnp.float32)}


def _xent_per_example(logits, y):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]


def _xent(logits, y):
    return _xent_per_example(logits, y).mean()


def _acc_multi(logits, y):
    return (jnp.argmax(logits, axis=-1) == y).mean()


def mnist_mlp() -> SmallModel:
    def init(key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "fc1": _dense_init(k1, 784, 128),
            "fc2": _dense_init(k2, 128, 256),
            "out": _dense_init(k3, 256, 10),
        }

    def apply(params, x):
        h = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
        h = jax.nn.relu(h @ params["fc2"]["w"] + params["fc2"]["b"])
        return h @ params["out"]["w"] + params["out"]["b"]

    def loss(params, x, y):
        return _xent(apply(params, x), y)

    def loss_pe(params, x, y):
        return _xent_per_example(apply(params, x), y)

    def accuracy(params, x, y):
        return _acc_multi(apply(params, x), y)

    return SmallModel("mnist_mlp", init, apply, loss, accuracy, loss_pe)


def cifar_cnn() -> SmallModel:
    def conv_init(key, kh, kw, cin, cout):
        w = jax.random.normal(key, (kh, kw, cin, cout)) * jnp.sqrt(2.0 / (kh * kw * cin))
        return {"w": w.astype(jnp.float32), "b": jnp.zeros((cout,), jnp.float32)}

    def init(key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        return {
            "conv1": conv_init(k1, 3, 3, 3, 32),
            "conv2": conv_init(k2, 3, 3, 32, 64),
            "fc": _dense_init(k3, 8 * 8 * 64, 128),
            "out": _dense_init(k4, 128, 10),
        }

    def _conv(x, p):
        y = jax.lax.conv_general_dilated(
            x, p["w"], window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        return y + p["b"]

    def _maxpool(x):
        # 2x2 max pool via reshape (identical to reduce_window, but its
        # gradient avoids XLA-CPU's scalar select-and-scatter path).
        b, h, w, c = x.shape
        return x.reshape(b, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))

    def apply(params, x):
        h = _maxpool(jax.nn.relu(_conv(x, params["conv1"])))
        h = _maxpool(jax.nn.relu(_conv(h, params["conv2"])))
        h = h.reshape(h.shape[0], -1)
        h = jax.nn.relu(h @ params["fc"]["w"] + params["fc"]["b"])
        return h @ params["out"]["w"] + params["out"]["b"]

    def loss(params, x, y):
        return _xent(apply(params, x), y)

    def loss_pe(params, x, y):
        return _xent_per_example(apply(params, x), y)

    def accuracy(params, x, y):
        return _acc_multi(apply(params, x), y)

    return SmallModel("cifar_cnn", init, apply, loss, accuracy, loss_pe)


def sst2_text(vocab: int = 4000, d_embed: int = 64) -> SmallModel:
    def init(key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "embed": jax.random.normal(k1, (vocab, d_embed)).astype(jnp.float32) * 0.1,
            "fc": _dense_init(k2, d_embed, 128),
            "out": _dense_init(k3, 128, 1),
        }

    def apply(params, x):
        emb = params["embed"][x].mean(axis=1)  # (B, d_embed) mean-pool
        h = jax.nn.relu(emb @ params["fc"]["w"] + params["fc"]["b"])
        return (h @ params["out"]["w"] + params["out"]["b"])[:, 0]  # (B,)

    def _bce_pe(params, x, y):
        logits = apply(params, x)
        yf = y.astype(jnp.float32)
        # Stable binary cross-entropy with logits, per example.
        return (jnp.maximum(logits, 0) - logits * yf
                + jnp.log1p(jnp.exp(-jnp.abs(logits))))

    def loss(params, x, y):
        return _bce_pe(params, x, y).mean()

    def accuracy(params, x, y):
        return ((apply(params, x) > 0).astype(jnp.int32) == y).mean()

    return SmallModel("sst2_text", init, apply, loss, accuracy, _bce_pe)


def get_small_model(dataset: str) -> SmallModel:
    table = {"mnist": mnist_mlp, "cifar10": cifar_cnn, "sst2": sst2_text}
    try:
        return table[dataset]()
    except KeyError:
        raise ValueError(f"no small model for dataset {dataset!r}")


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


def param_bits(params) -> float:
    """Uplink payload D(w) if the raw fp32 model were transmitted."""
    return 32.0 * param_count(params)
