"""Model-zoo assembly: one decoder implementation covering all 6 assigned
families (dense / moe / ssm / hybrid / audio / vlm).

Layer-stacking strategy (compile-time critical for the 61-80 layer configs):
layers are grouped into *stages*; each stage is a periodic pattern of
sublayer kinds scanned over its repeats with stacked parameters, so the HLO
contains ONE copy of each distinct sublayer body regardless of depth:

  deepseek-v3 : stage0 = 3 x (mla + dense-ffn), stage1 = 58 x (mla + moe)
  jamba       : stage0 = 4 x [8-layer block: 7 mamba + 1 attn, moe on odd]
  qwen1.5-110b: stage0 = 80 x (gqa + dense-ffn)
  rwkv6       : stage0 = 32 x (time-mix + channel-mix)

Modes:
  forward(..., mode="train")   -> (logits, aux)        causal LM
  forward(..., mode="prefill") -> (logits, aux, cache) also seeds KV caches
  decode_step(...)             -> (logits, cache)      one token, ring caches

Modality frontends are stubbed per the assignment: audio gets precomputed
encoder frames (B, Se, d); vlm gets patch embeddings (B, Np, d) spliced over
the first Np token positions plus 3-D M-RoPE position ids.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig
from .attention import (
    cross_attn,
    cross_attn_init,
    gqa_decode,
    gqa_forward,
    gqa_init,
    init_kv_cache,
    init_mla_cache,
    mla_decode,
    mla_forward,
    mla_init,
)
from .layers import DTYPE, dense, dense_init, rmsnorm, rmsnorm_init, swiglu, swiglu_init
from .moe import ShardCtx, moe_apply, moe_init
from .ssm import (
    init_mamba_state,
    init_rwkv6_state,
    mamba_forward,
    mamba_init,
    rwkv6_channel_mix,
    rwkv6_init,
    rwkv6_time_mix,
)

__all__ = [
    "LayerKind",
    "Stage",
    "stage_plan",
    "init_params",
    "forward",
    "decode_step",
    "init_cache",
    "lm_loss",
    "param_count",
]

ATTN_CHUNK = 1024  # query-chunked softmax kicks in above 2x this seq length


def _wkv_impl(cfg: ArchConfig):
    """Select the WKV6 recurrence implementation (ref scan vs Pallas)."""
    if cfg.rwkv_wkv_impl == "pallas":
        from ..kernels.rwkv6_wkv.ops import wkv6_pallas
        return wkv6_pallas
    from .ssm import wkv6_scan_ref
    return wkv6_scan_ref


# ==========================================================================
# Stage planning
# ==========================================================================

@dataclasses.dataclass(frozen=True)
class LayerKind:
    mixer: str        # "attn" | "mla" | "rwkv" | "mamba"
    ffn: str          # "dense" | "moe" | "rwkv_cm"
    cross: bool = False

    @property
    def tag(self) -> str:
        return f"{self.mixer}-{self.ffn}" + ("-x" if self.cross else "")


@dataclasses.dataclass(frozen=True)
class Stage:
    pattern: tuple[LayerKind, ...]
    repeats: int


def _kind_of(cfg: ArchConfig, i: int, *, decoder: bool) -> LayerKind:
    if cfg.family == "ssm":
        return LayerKind("rwkv", "rwkv_cm")
    if cfg.family == "hybrid":
        mixer = "attn" if cfg.is_attn_layer(i) else "mamba"
    elif cfg.use_mla:
        mixer = "mla"
    else:
        mixer = "attn"
    ffn = "moe" if cfg.is_moe_layer(i) else "dense"
    cross = decoder and cfg.is_encoder_decoder
    return LayerKind(mixer, ffn, cross)


def _smallest_period(kinds: list[LayerKind]) -> int:
    n = len(kinds)
    for p in range(1, n + 1):
        if n % p == 0 and all(kinds[i] == kinds[i % p] for i in range(n)):
            return p
    return n


def stage_plan(cfg: ArchConfig) -> list[Stage]:
    kinds = [_kind_of(cfg, i, decoder=True) for i in range(cfg.n_layers)]
    stages = []
    start = 0
    nd = cfg.n_dense_layers
    if nd > 0 and nd < cfg.n_layers:
        assert all(k == kinds[0] for k in kinds[:nd]), "dense prefix must be homogeneous"
        stages.append(Stage(pattern=(kinds[0],), repeats=nd))
        start = nd
    rest = kinds[start:]
    if rest:
        p = _smallest_period(rest)
        stages.append(Stage(pattern=tuple(rest[:p]), repeats=len(rest) // p))
    return stages


# ==========================================================================
# Per-sublayer init
# ==========================================================================

def _init_sublayer(key, cfg: ArchConfig, kind: LayerKind, *, ep_size: int):
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {"ln1": rmsnorm_init(cfg.d_model)}
    if kind.mixer == "attn":
        p["attn"] = gqa_init(ks[0], cfg)
    elif kind.mixer == "mla":
        p["attn"] = mla_init(ks[0], cfg)
    elif kind.mixer == "rwkv":
        p["rwkv"] = rwkv6_init(ks[0], cfg)
    elif kind.mixer == "mamba":
        p["mamba"] = mamba_init(ks[0], cfg)
    if kind.cross:
        p["ln_c"] = rmsnorm_init(cfg.d_model)
        p["cross"] = cross_attn_init(ks[1], cfg)
    p["ln2"] = rmsnorm_init(cfg.d_model)
    if kind.ffn == "dense":
        p["ffn"] = swiglu_init(ks[2], cfg.d_model, cfg.ffn_dense)
    elif kind.ffn == "moe":
        p["moe"] = moe_init(ks[2], cfg, ep_size=ep_size)
    return p


def _init_stacked(key, cfg: ArchConfig, kind: LayerKind, repeats: int, *, ep_size: int):
    keys = jax.random.split(key, repeats)
    return jax.vmap(lambda k: _init_sublayer(k, cfg, kind, ep_size=ep_size))(keys)


def init_params(cfg: ArchConfig, key, *, ep_size: int = 1):
    """Full parameter pytree. ep_size = expert-parallel degree (pads E)."""
    stages = stage_plan(cfg)
    n_groups = sum(len(s.pattern) for s in stages)
    keys = jax.random.split(key, n_groups + 6)
    ki = 0
    p: dict[str, Any] = {}
    p["embed"] = {
        "w": (jax.random.normal(keys[-1], (cfg.vocab, cfg.d_model)) * 0.02).astype(DTYPE)
    }
    p["final_ln"] = rmsnorm_init(cfg.d_model)
    p["lm_head"] = dense_init(keys[-2], cfg.d_model, cfg.vocab, scale=0.02)
    for si, st in enumerate(stages):
        for li, kind in enumerate(st.pattern):
            p[f"s{si}_l{li}"] = _init_stacked(keys[ki], cfg, kind, st.repeats, ep_size=ep_size)
            ki += 1
    if cfg.is_encoder_decoder:
        enc_kind = LayerKind("attn", "dense")
        p["encoder"] = _init_stacked(keys[ki], cfg, enc_kind, cfg.n_encoder_layers, ep_size=ep_size)
        p["enc_final_ln"] = rmsnorm_init(cfg.d_model)
        ki += 1
    if cfg.mtp:
        p["mtp_ln"] = rmsnorm_init(cfg.d_model)
        p["mtp_head"] = dense_init(keys[-3], cfg.d_model, cfg.vocab, scale=0.02)
    return p


# ==========================================================================
# Sublayer forward (full sequence)
# ==========================================================================

@dataclasses.dataclass(frozen=True)
class _Extras:
    positions: Any = None
    mrope_pos: Any = None
    enc_out: Any = None
    chunk: int = 0


def _sublayer_full(cfg, kind: LayerKind, p, x, ctx: ShardCtx, ex: _Extras, want_cache: bool):
    """Returns (x, aux, cache_contrib)."""
    aux = jnp.zeros((), jnp.float32)
    cache: Any = ()
    h_in = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind.mixer == "attn":
        if want_cache:
            h, (k_, v_) = gqa_forward(
                p["attn"], cfg, h_in, positions=ex.positions, mrope_pos=ex.mrope_pos,
                chunk=ex.chunk, return_kv=True, ctx=ctx)
            cache = {"k": k_, "v": v_}
        else:
            h = gqa_forward(p["attn"], cfg, h_in, positions=ex.positions,
                            mrope_pos=ex.mrope_pos, chunk=ex.chunk, ctx=ctx)
    elif kind.mixer == "mla":
        if want_cache:
            h, (ckv, kpe) = mla_forward(p["attn"], cfg, h_in, positions=ex.positions,
                                        chunk=ex.chunk, return_kv=True, ctx=ctx)
            cache = {"c_kv": ckv, "k_pe": kpe}
        else:
            h = mla_forward(p["attn"], cfg, h_in, positions=ex.positions,
                            chunk=ex.chunk, ctx=ctx)
    elif kind.mixer == "rwkv":
        st = init_rwkv6_state(cfg, x.shape[0])
        h, st = rwkv6_time_mix(p["rwkv"], cfg, h_in, st, wkv_impl=_wkv_impl(cfg))
        cache = {"rwkv": st} if want_cache else ()
    elif kind.mixer == "mamba":
        h, st = mamba_forward(p["mamba"], cfg, h_in)
        cache = {"mamba": st} if want_cache else ()
    else:
        raise ValueError(kind.mixer)
    x = x + h

    if kind.cross:
        x = x + cross_attn(p["cross"], cfg, rmsnorm(p["ln_c"], x, cfg.norm_eps), ex.enc_out)

    if kind.ffn == "dense":
        x = x + swiglu(p["ffn"], rmsnorm(p["ln2"], x, cfg.norm_eps))
    elif kind.ffn == "moe":
        y, a = moe_apply(p["moe"], cfg, rmsnorm(p["ln2"], x, cfg.norm_eps), ctx)
        x = x + y
        aux = aux + a
    elif kind.ffn == "rwkv_cm":
        cm_in = rmsnorm(p["ln2"], x, cfg.norm_eps)
        y, cm_prev = rwkv6_channel_mix(p["rwkv"], cfg, cm_in, jnp.zeros_like(x[:, 0]))
        x = x + y
        if want_cache:
            cache = dict(cache, cm_prev=cm_prev)
    return x, aux, cache


def _run_stage_full(cfg, st: Stage, stacked_params, x, ctx, ex, want_cache):
    """Scan the stage pattern over its repeats. stacked_params: tuple of
    stacked trees, one per pattern position."""

    def body(carry, xs):
        x, aux = carry
        caches = []
        for kind, pp in zip(st.pattern, xs):
            x, a, c = _sublayer_full(cfg, kind, pp, x, ctx, ex, want_cache)
            aux = aux + a
            caches.append(c)
        return (x, aux), tuple(caches)

    if st.repeats == 1:
        (x, aux), caches = body((x, jnp.zeros((), jnp.float32)),
                                tuple(jax.tree_util.tree_map(lambda a: a[0], sp)
                                      for sp in stacked_params))
        caches = tuple(jax.tree_util.tree_map(lambda a: a[None], c) for c in caches)
        return x, aux, caches
    (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), stacked_params)
    return x, aux, caches


# ==========================================================================
# Embedding / frontends
# ==========================================================================

def _embed(cfg: ArchConfig, params, batch, ctx: ShardCtx):
    tokens = batch["tokens"]
    h = params["embed"]["w"][tokens]  # (B, S, d)
    if cfg.family == "vlm" and "image_embeds" in batch:
        np_ = cfg.n_patches
        img = batch["image_embeds"].astype(h.dtype)             # (B, Np, d)
        pad = jnp.zeros((h.shape[0], h.shape[1] - np_, h.shape[2]), h.dtype)
        img_full = jnp.concatenate([img, pad], axis=1)
        is_patch = (jnp.arange(h.shape[1]) < np_)[None, :, None]
        h = jnp.where(is_patch, img_full, h)
    return _shard_act(h, ctx)


def _shard_act(h, ctx: ShardCtx):
    if ctx.mesh is None:
        return h
    spec = P(ctx.dp_axes, *([None] * (h.ndim - 1)))
    return jax.lax.with_sharding_constraint(h, NamedSharding(ctx.mesh, spec))


def _encode_audio(cfg: ArchConfig, params, frames, ctx: ShardCtx):
    """Whisper-style encoder over stubbed conv-frontend frames (B, Se, d)."""
    x = frames.astype(DTYPE)
    kind = LayerKind("attn", "dense")

    def body(x, pp):
        h = gqa_forward(pp["attn"], cfg, rmsnorm(pp["ln1"], x, cfg.norm_eps), causal=False)
        x = x + h
        x = x + swiglu(pp["ffn"], rmsnorm(pp["ln2"], x, cfg.norm_eps))
        return x, ()

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return rmsnorm(params["enc_final_ln"], x, cfg.norm_eps)


# ==========================================================================
# Public API: forward / loss / decode
# ==========================================================================

def forward(cfg: ArchConfig, params, batch, ctx: ShardCtx = ShardCtx(), *,
            mode="train", cache_headroom: int = 0):
    """mode: "train" -> (logits, aux); "prefill" -> (logits, aux, cache).

    cache_headroom: extra decode slots to allocate in the prefill cache
    (full-attention configs need >= the number of tokens you plan to decode;
    sliding-window/SSM configs ignore it once the window is covered)."""
    want_cache = mode == "prefill"
    h = _embed(cfg, params, batch, ctx)
    b, s, _ = h.shape
    ex = _Extras(
        positions=jnp.arange(s, dtype=jnp.int32)[None, :],
        mrope_pos=batch.get("mrope_pos"),
        enc_out=(
            _encode_audio(cfg, params, batch["enc_frames"], ctx)
            if cfg.is_encoder_decoder else None
        ),
        chunk=ATTN_CHUNK if s > 2 * ATTN_CHUNK else 0,
    )
    stages = stage_plan(cfg)
    aux = jnp.zeros((), jnp.float32)
    all_caches = []
    for si, st in enumerate(stages):
        sp = tuple(params[f"s{si}_l{li}"] for li in range(len(st.pattern)))
        h, a, caches = _run_stage_full(cfg, st, sp, h, ctx, ex, want_cache)
        h = _shard_act(h, ctx)
        aux = aux + a
        all_caches.append(caches)
    h = rmsnorm(params["final_ln"], h, cfg.norm_eps)
    logits = dense(params["lm_head"], h)
    if ctx.mesh is not None:
        logits = jax.lax.with_sharding_constraint(
            logits, NamedSharding(ctx.mesh, P(ctx.dp_axes, None, "model"))
        )
    if mode == "train":
        if cfg.mtp:
            mtp_logits = dense(params["mtp_head"], rmsnorm(params["mtp_ln"], h, cfg.norm_eps))
            return logits, aux, mtp_logits
        return logits, aux
    cache = _assemble_prefill_cache(cfg, all_caches, s, ex, cache_headroom)
    return logits, aux, cache


def lm_loss(cfg: ArchConfig, params, batch, ctx: ShardCtx = ShardCtx()):
    """Selection-weighted causal-LM loss: the FL aggregation of eq. (34)
    folded into the loss so the backward pass needs exactly ONE all-reduce.

    batch["fl_weights"] (B,) carries alpha_n * beta_n * S_n * psi_n per
    device-cohort (uniform 1s outside the FL context).
    """
    out = forward(cfg, params, batch, ctx, mode="train")
    logits, aux = out[0], out[1]
    labels = batch["labels"]
    w = batch.get("fl_weights")
    if w is None:
        w = jnp.ones((labels.shape[0],), jnp.float32)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]  # (B, S)
    wsum = jnp.maximum(w.sum(), 1e-9)
    loss = (nll.mean(axis=-1) * w).sum() / wsum
    if cfg.mtp:
        mtp_logits = out[2]
        # Predict t+2: logits[:, t] vs labels[:, t+1].
        lp2 = jax.nn.log_softmax(mtp_logits[:, :-1].astype(jnp.float32), axis=-1)
        nll2 = -jnp.take_along_axis(lp2, labels[:, 1:, None], axis=-1)[..., 0]
        loss = loss + cfg.mtp_weight * (nll2.mean(axis=-1) * w).sum() / wsum
    return loss + cfg.router_aux_coef * aux, {"aux": aux}


# --------------------------------------------------------------------------
# Decode
# --------------------------------------------------------------------------

def _empty_sublayer_cache(cfg: ArchConfig, kind: LayerKind, batch: int, cache_len: int):
    if kind.mixer == "attn":
        c = init_kv_cache(cfg, batch, cache_len)
    elif kind.mixer == "mla":
        c = init_mla_cache(cfg, batch, cache_len)
    elif kind.mixer == "rwkv":
        c = {"rwkv": init_rwkv6_state(cfg, batch)}
    elif kind.mixer == "mamba":
        c = {"mamba": init_mamba_state(cfg, batch)}
    else:
        raise ValueError(kind.mixer)
    if kind.ffn == "rwkv_cm":
        c = dict(c, cm_prev=jnp.zeros((batch, cfg.d_model), DTYPE))
    return c


def cache_len_for(cfg: ArchConfig, seq_len: int) -> int:
    """Physical cache length: sliding-window archs cap at the window."""
    if cfg.sliding_window > 0:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def init_cache(cfg: ArchConfig, batch: int, seq_len: int, *, enc_out=None):
    """Ring-buffer caches for every layer, stacked per stage pattern slot."""
    clen = cache_len_for(cfg, seq_len)
    stages = stage_plan(cfg)
    cache: dict[str, Any] = {}
    for si, st in enumerate(stages):
        for li, kind in enumerate(st.pattern):
            one = _empty_sublayer_cache(cfg, kind, batch, clen)
            cache[f"s{si}_l{li}"] = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None], (st.repeats,) + a.shape), one
            )
    if cfg.is_encoder_decoder:
        assert enc_out is not None, "enc-dec decode needs encoder output"
        cache["enc_out"] = enc_out
    return cache


def _ring_from_prefill(seq_tensor, s, clen, seq_axis):
    """Place prefill entries for positions [0, s) into a clen-slot ring so
    that position p lands at slot p % clen (matching decode's write rule)."""
    if clen >= s:
        pad_shape = list(seq_tensor.shape)
        pad_shape[seq_axis] = clen - s
        pad = jnp.zeros(pad_shape, seq_tensor.dtype)
        return jnp.concatenate([seq_tensor, pad], axis=seq_axis)
    taken = jax.lax.slice_in_dim(seq_tensor, s - clen, s, axis=seq_axis)
    return jnp.roll(taken, s % clen, axis=seq_axis)


def _ring_positions(s, clen, repeats):
    if clen >= s:
        pos = jnp.concatenate(
            [jnp.arange(s, dtype=jnp.int32), jnp.full((clen - s,), -1, jnp.int32)]
        )
    else:
        pos = jnp.roll(jnp.arange(s - clen, s, dtype=jnp.int32), s % clen)
    return jnp.broadcast_to(pos, (repeats, clen))


def _assemble_prefill_cache(cfg, all_caches, s, ex, headroom):
    """Convert prefill-collected K/V + states into decode ring caches."""
    stages = stage_plan(cfg)
    clen = cache_len_for(cfg, s + headroom)
    cache: dict[str, Any] = {}
    for si, st in enumerate(stages):
        for li, kind in enumerate(st.pattern):
            got = all_caches[si][li]
            if kind.mixer == "attn":
                c = {
                    "k": _ring_from_prefill(got["k"], s, clen, 2),
                    "v": _ring_from_prefill(got["v"], s, clen, 2),
                    "pos": _ring_positions(s, clen, st.repeats),
                    "idx": jnp.full((st.repeats,), s, jnp.int32),
                }
            elif kind.mixer == "mla":
                c = {
                    "c_kv": _ring_from_prefill(got["c_kv"], s, clen, 2),
                    "k_pe": _ring_from_prefill(got["k_pe"], s, clen, 2),
                    "pos": _ring_positions(s, clen, st.repeats),
                    "idx": jnp.full((st.repeats,), s, jnp.int32),
                }
            elif kind.mixer == "rwkv":
                c = {"rwkv": got["rwkv"]}
            else:
                c = {"mamba": got["mamba"]}
            if kind.ffn == "rwkv_cm":
                c = dict(c, cm_prev=got["cm_prev"])
            cache[f"s{si}_l{li}"] = c
    if cfg.is_encoder_decoder:
        cache["enc_out"] = ex.enc_out
    return cache


def _sublayer_decode(cfg, kind: LayerKind, p, x, c, cur_pos, ctx, ex):
    h_in = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind.mixer == "attn":
        h, c2 = gqa_decode(p["attn"], cfg, h_in, c, cur_pos, mrope_pos=ex.mrope_pos)
        new_c = c2
    elif kind.mixer == "mla":
        h, new_c = mla_decode(p["attn"], cfg, h_in, c, cur_pos)
    elif kind.mixer == "rwkv":
        h, st = rwkv6_time_mix(p["rwkv"], cfg, h_in, c["rwkv"])
        new_c = dict(c, rwkv=st)
    else:
        h, st = mamba_forward(p["mamba"], cfg, h_in, c["mamba"])
        new_c = dict(c, mamba=st)
    x = x + h
    if kind.cross:
        x = x + cross_attn(p["cross"], cfg, rmsnorm(p["ln_c"], x, cfg.norm_eps), ex.enc_out)
    if kind.ffn == "dense":
        x = x + swiglu(p["ffn"], rmsnorm(p["ln2"], x, cfg.norm_eps))
    elif kind.ffn == "moe":
        y, _ = moe_apply(p["moe"], cfg, rmsnorm(p["ln2"], x, cfg.norm_eps), ctx)
        x = x + y
    elif kind.ffn == "rwkv_cm":
        cm_in = rmsnorm(p["ln2"], x, cfg.norm_eps)
        y, prev = rwkv6_channel_mix(p["rwkv"], cfg, cm_in, c["cm_prev"])
        x = x + y
        new_c = dict(new_c, cm_prev=prev)
    return x, new_c


def decode_step(cfg: ArchConfig, params, batch, cache, ctx: ShardCtx = ShardCtx()):
    """One-token decode. batch: {"token": (B,1) int32, "pos": () int32,
    optional "mrope_pos": (B,1,3)}. Returns (logits (B,1,V), new cache)."""
    tok = batch["token"]
    cur_pos = batch["pos"]
    h = params["embed"]["w"][tok]
    h = _shard_act(h, ctx)
    ex = _Extras(mrope_pos=batch.get("mrope_pos"), enc_out=cache.get("enc_out"))
    stages = stage_plan(cfg)
    new_cache: dict[str, Any] = {}

    for si, st in enumerate(stages):
        sp = tuple(params[f"s{si}_l{li}"] for li in range(len(st.pattern)))
        sc = tuple(cache[f"s{si}_l{li}"] for li in range(len(st.pattern)))

        def body(x, xs):
            pslices, cslices = xs
            new_cs = []
            for kind, pp, cc in zip(st.pattern, pslices, cslices):
                x, nc = _sublayer_decode(cfg, kind, pp, x, cc, cur_pos, ctx, ex)
                new_cs.append(nc)
            return x, tuple(new_cs)

        if st.repeats == 1:
            sp1 = tuple(jax.tree_util.tree_map(lambda a: a[0], t) for t in sp)
            sc1 = tuple(jax.tree_util.tree_map(lambda a: a[0], t) for t in sc)
            h, ncs = body(h, (sp1, sc1))
            ncs = tuple(jax.tree_util.tree_map(lambda a: a[None], c) for c in ncs)
        else:
            h, ncs = jax.lax.scan(body, h, (sp, sc))
        for li in range(len(st.pattern)):
            new_cache[f"s{si}_l{li}"] = ncs[li]

    if cfg.is_encoder_decoder:
        new_cache["enc_out"] = cache["enc_out"]
    h = rmsnorm(params["final_ln"], h, cfg.norm_eps)
    logits = dense(params["lm_head"], h)
    return logits, new_cache


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
