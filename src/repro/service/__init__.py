"""Sustained-service harness: the async event engine as a long-running
streaming service (DESIGN.md §14).

Public surface:
  ServiceConfig / SustainedService
      -- the resumable segment engine + load generator: regenerates
         Γ/scenario traces in fixed-size segments of ONE open-ended
         seed-deterministic stream (`scenarios.ScenarioStream`) and
         chains the async scan's carry across segments — one compiled
         program per segment shape;
  observability
      -- pure per-event accounting: throughput, p50/p95/p99 commit
         latency, SLO attainment, buffer occupancy (`EventLog`,
         `summarize`).

CLI: ``PYTHONPATH=src python -m repro.service.run --smoke`` writes a
versioned ``results/<name>/v####/service.json`` artifact + figures.
"""
from .harness import ServiceConfig, SustainedService
from .observability import (
    EventLog,
    latency_percentiles,
    slo_attainment,
    summarize,
    throughput_events_per_s,
)

__all__ = [
    "ServiceConfig",
    "SustainedService",
    "EventLog",
    "latency_percentiles",
    "slo_attainment",
    "throughput_events_per_s",
    "summarize",
]
