"""Sustained-service harness: the async event engine as a streaming service.

Every number the fixed-horizon harness (`fl.sim`) reports comes from a
closed world: the whole horizon is sampled, solved, and scanned once.
This module drives the SAME buffered event engine (DESIGN.md §12) as a
long-running service instead (DESIGN.md §14):

  * the world is OPEN-ENDED — the dataset phase replays `fl.sim`'s rng
    prefix verbatim (`_sample_dataset` + clusters/fixed ids), then the
    environment continues forever through `scenarios.ScenarioStream`
    and the leader-plane permutations are drawn per round from the same
    world generator, so segment boundaries never reseed anything;
  * Γ and the scenario traces are regenerated in fixed-size segments
    (the solver is elementwise over pairs, so per-segment solves are
    bit-identical to slicing one whole-horizon solve), and the async
    scan's carry is chained across segments via
    `build_async_runner(..., segmented=True)` + `init_async_carry` —
    one `jax.jit` compile per segment shape, every later segment a
    cache hit (the per-call rebuild class of bug `launch.serve` had);
  * a load generator replays the event stream at a target rate
    (events/s, open loop) or back-to-back (closed loop), and the
    observability layer (`service.observability`) records throughput,
    p50/p95/p99 commit latency, SLO attainment against a configurable
    budget, buffer occupancy, and steady-state loss/AoU.

The segment-resume contract — S segments of length L bit-identical to
one segment of length S*L — is pinned by tests/test_service.py.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core import RAResult, make_clusters, solve_pairs_fused, solve_pairs_jit
from ..core.monotonic import fixed_ra
from ..fl.async_loop import build_async_runner, init_async_carry
from ..fl.sim import (
    SimConfig,
    _async_spec,
    _group_trainer_and_policies,
    _sample_dataset,
)
from ..scenarios import ScenarioStream, apply_dynamics, scenario_name
from . import observability as obs

__all__ = ["ServiceConfig", "SustainedService"]

SERVICE_SCHEMA = 1


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """One sustained-service deployment.

    `sim` carries the cell shape (dataset, N, K, policy, scenario,
    aggregation, seed, learning settings); its fixed-horizon fields
    `rounds` and `eval_every` are ignored — the service horizon is
    open-ended and eval cadence is `eval_every_events`.
    """

    sim: SimConfig = SimConfig(aggregation="async")
    segment_events: int = 100           # events per compiled segment
    eval_every_events: int | None = None  # None -> once per segment
    target_rate_events_per_s: float | None = None  # None -> closed loop
    latency_budget_s: float = 1.0       # SLO budget on wall commit latency
    warmup_segments: int = 1            # compile/cache warm-up, unmeasured

    def __post_init__(self):
        if self.segment_events < 1:
            raise ValueError(
                f"segment_events must be >= 1, got {self.segment_events}")
        ee = self.eval_every_events
        if ee is not None and (ee < 1 or self.segment_events % ee != 0):
            raise ValueError(
                f"eval_every_events must divide segment_events (the eval "
                f"mask is baked into the compiled segment), got {ee} vs "
                f"{self.segment_events}")
        if (self.target_rate_events_per_s is not None
                and self.target_rate_events_per_s <= 0):
            raise ValueError("target_rate_events_per_s must be positive")
        if self.latency_budget_s <= 0:
            raise ValueError("latency_budget_s must be positive")
        if self.warmup_segments < 0:
            raise ValueError("warmup_segments must be >= 0")


class SustainedService:
    """The async event engine, resumable segment by segment.

    `run_segment()` serves the next `segment_events` events of the ONE
    long stream and returns the raw per-event ys (numpy); `serve()`
    wraps it in the load generator + observability and returns the
    artifact record.  All segments run through a single jitted program
    (`t0`, buffer, staleness, and server_lr are traced operands).
    """

    def __init__(self, cfg: ServiceConfig, *, ra_backend: str | None = None,
                 ra_solver: str = "fused"):
        if ra_solver not in ("fused", "step"):
            raise ValueError(f"unknown ra_solver: {ra_solver}")
        self.cfg = cfg
        sim = cfg.sim
        self.spec = _async_spec(sim)
        self.wcfg = sim.wireless()
        self._ra_backend, self._ra_solver = ra_backend, ra_solver
        L = cfg.segment_events

        # ---- the open-ended world: fl.sim's dataset phase, then the
        # stream extension of the scenario + per-round permutations ------
        rng = np.random.default_rng(sim.seed)
        ds, part, beta, x_all, y_all, m_all = _sample_dataset(sim, rng)
        self._beta = beta
        clusters = make_clusters(sim.n_devices, sim.n_subchannels, rng)
        fixed_ids = rng.permutation(sim.n_devices)[: sim.n_subchannels]
        self._perm_rng = rng                      # continues per round
        self._stream = ScenarioStream(sim.seed, self.wcfg, sim.scenario)

        # ---- one compiled segment program + the chained carry ----------
        model, trainer, policies, _ = _group_trainer_and_policies([sim])
        ee = cfg.eval_every_events or L
        eval_mask = np.zeros(L, bool)
        eval_mask[ee - 1::ee] = True              # end of each eval block
        self._eval_offsets = np.nonzero(eval_mask)[0]
        runner = build_async_runner(
            model, trainer, policies, k=sim.n_subchannels, n=sim.n_devices,
            rounds=L, eval_mask=eval_mask,
            track_gradnorm=sim.track_gradnorm, segmented=True)
        self._scan = jax.jit(runner)
        key = jax.random.PRNGKey(sim.seed)
        key, k_init = jax.random.split(key)
        self._carry = init_async_carry(model.init(k_init), key,
                                       sim.n_devices)
        self._static = dict(
            policy_idx=jnp.int32(0),
            beta=jnp.asarray(beta, jnp.float32),
            x_all=x_all, y_all=y_all, m_all=m_all,
            x_full=jnp.asarray(ds.x), y_full=jnp.asarray(ds.y),
            clusters=jnp.asarray(clusters, jnp.int32),
            fixed_ids=jnp.asarray(fixed_ids, jnp.int32),
            buffer=jnp.int32(self.spec.resolve_buffer(sim.n_devices,
                                                      sim.n_subchannels)),
            stale_exp=jnp.float32(self.spec.stale_exponent()),
            server_lr=jnp.float32(self.spec.server_lr),
        )
        self._events_served = 0

    @property
    def events_served(self) -> int:
        return self._events_served

    # ---- per-segment pipeline -------------------------------------------

    def _check_f32_priorities(self, horizon: int) -> None:
        # fl.sim._check_f32_priorities, restated for an open-ended
        # stream: AoU ages are bounded by the events served so far plus
        # the segment about to run, and the f32 age*beta priority
        # products must stay integer-exact below 2^24.
        worst = (self._events_served + horizon + 1) * float(self._beta.max())
        if worst >= 2 ** 24:
            raise ValueError(
                f"sustained service: after {self._events_served} events the "
                f"f32 age*beta priority products may reach {worst:.3g} >= "
                f"2^24 and lose exactness — restart the stream or shrink "
                f"data sizes")

    def _solve_segment(self, tr) -> RAResult:
        """Γ for one segment.  Elementwise over pairs, so per-segment
        solves concatenate to exactly the whole-horizon solve."""
        sim = self.cfg.sim
        emax_b = np.broadcast_to(tr.e_max_j[:, None, :], tr.h2_all.shape)
        if sim.policy.ra != "mo":
            return fixed_ra(self._beta[None, None, :], tr.h2_all,
                            self.wcfg, emax_b)
        shp = tr.h2_all.shape
        beta_b = np.broadcast_to(self._beta[None, None, :], shp)
        solve = (solve_pairs_fused if self._ra_solver == "fused"
                 else solve_pairs_jit)
        kw = {"shard": False} if self._ra_solver == "fused" else {}
        flat = solve(beta_b.reshape(-1), tr.h2_all.reshape(-1), self.wcfg,
                     emax_b.reshape(-1), backend=self._ra_backend, **kw)
        return RAResult(
            tau=np.asarray(flat.tau).reshape(shp),
            p=np.asarray(flat.p).reshape(shp),
            time_s=np.asarray(flat.time_s).reshape(shp),
            energy_j=np.asarray(flat.energy_j).reshape(shp),
            feasible=np.asarray(flat.feasible).reshape(shp),
            iterations=np.asarray(flat.iterations).reshape(shp))

    def run_segment(self) -> dict:
        """Serve the next `segment_events` events; returns numpy ys."""
        sim, L = self.cfg.sim, self.cfg.segment_events
        self._check_f32_priorities(L)
        tr = self._stream.next_segment(L)
        ra = self._solve_segment(tr)
        ra = apply_dynamics(ra, tr.avail, tr.slowdown, self._beta, self.wcfg)
        # Per-ROUND interleaved draws (sel then assign), never the
        # whole-horizon blocks `_prepare` uses: the stream position of a
        # draw must depend only on how many events have been served, not
        # on the segment size, or chaining would reshuffle the leader.
        perms = [(self._perm_rng.permutation(sim.n_devices),
                  self._perm_rng.permutation(sim.n_subchannels))
                 for _ in range(L)]
        sel = np.stack([p[0] for p in perms])
        asg = np.stack([p[1] for p in perms])
        data = dict(
            self._static,
            gamma=jnp.asarray(ra.time_s, jnp.float32),
            feas=jnp.asarray(ra.feasible),
            energy=jnp.asarray(np.where(np.isfinite(ra.energy_j),
                                        ra.energy_j, 0.0), jnp.float32),
            sel_perms=jnp.asarray(sel, jnp.int32),
            assign_perms=jnp.asarray(asg, jnp.int32),
            t0=jnp.int32(self._events_served),
        )
        self._carry, ys = self._scan(data, self._carry)
        jax.block_until_ready(ys)
        self._events_served += L
        return jax.tree_util.tree_map(np.asarray, ys)

    # ---- the load generator + observability window ----------------------

    def serve(self, n_segments: int,
              progress: Callable[[str], None] | None = None) -> dict:
        """Replay `n_segments` measured segments (after the configured
        warm-up) and return the artifact record (`service.json` shape)."""
        if n_segments < 1:
            raise ValueError(f"n_segments must be >= 1, got {n_segments}")
        cfg, L = self.cfg, self.cfg.segment_events
        rate = cfg.target_rate_events_per_s

        warm_walls = []
        for _ in range(cfg.warmup_segments):
            t0 = time.perf_counter()
            self.run_segment()
            warm_walls.append(time.perf_counter() - t0)
            if progress:
                progress(f"warm-up segment: {warm_walls[-1]:.2f}s")

        served0 = self._events_served
        arrivals, completes, sim_lat, pend, mean_age = [], [], [], [], []
        losses, accs, eval_events = [], [], []
        seg_walls = []
        t_base = time.perf_counter()
        for s in range(n_segments):
            if rate is not None:
                # Open loop: event i of the window arrives at i/rate; a
                # segment may only enter the engine once its last event
                # has arrived.
                arr = np.arange(s * L, (s + 1) * L, dtype=np.float64) / rate
                wait = t_base + arr[-1] - time.perf_counter()
                if wait > 0:
                    time.sleep(wait)
            t_seg = time.perf_counter()
            ys = self.run_segment()
            t_done = time.perf_counter() - t_base
            seg_walls.append(time.perf_counter() - t_seg)
            if rate is None:
                arr = np.full(L, t_seg - t_base)
            arrivals.append(arr)
            completes.append(np.full(L, t_done))
            sim_lat.append(ys["latency"])
            pend.append(ys["n_pending"])
            mean_age.append(ys["age"].mean(axis=1))
            eval_events.append(served0 + s * L + self._eval_offsets)
            losses.append(ys["loss"][self._eval_offsets])
            accs.append(ys["acc"][self._eval_offsets])
            if progress:
                progress(f"segment {s + 1}/{n_segments}: "
                         f"{seg_walls[-1]:.2f}s "
                         f"({L / seg_walls[-1]:.1f} ev/s engine)")

        log = obs.EventLog(
            arrival_s=np.concatenate(arrivals),
            complete_s=np.concatenate(completes),
            sim_latency_s=np.concatenate(sim_lat),
            n_pending=np.concatenate(pend))
        summary = obs.summarize(log, cfg.latency_budget_s)
        summary["slo"]["target_rate_events_per_s"] = rate
        sim = cfg.sim
        return {
            "schema": SERVICE_SCHEMA,
            "kind": "sustained_service",
            "service": {
                "sim": _jsonable(dataclasses.asdict(sim)),
                "scenario": scenario_name(sim.scenario),
                "segment_events": L,
                "eval_every_events": cfg.eval_every_events or L,
                "target_rate_events_per_s": rate,
                "latency_budget_s": cfg.latency_budget_s,
                "warmup_segments": cfg.warmup_segments,
                "segments": n_segments,
                "events_measured": int(log.events),
                "events_served_total": int(self._events_served),
            },
            "summary": summary,
            "walls": {
                "warmup_s": warm_walls,
                "segment_s": seg_walls,
            },
            "events": {
                "event": (served0 + np.arange(log.events)).tolist(),
                "arrival_s": log.arrival_s.tolist(),
                "complete_s": log.complete_s.tolist(),
                "latency_s": log.latencies_s().tolist(),
                "sim_latency_s": log.sim_latency_s.tolist(),
                "n_pending": log.n_pending.tolist(),
                "mean_age": np.concatenate(mean_age).tolist(),
            },
            "steady_state": {
                "event": np.concatenate(eval_events).tolist(),
                "global_loss": np.concatenate(losses).astype(float).tolist(),
                "accuracy": np.concatenate(accs).astype(float).tolist(),
            },
        }


def _jsonable(obj):
    """Recursively coerce a config dict to JSON-serializable values."""
    if isinstance(obj, dict):
        return {k: _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    return obj
