"""CLI entry point for the sustained-service harness (DESIGN.md §14).

Replays the async event engine as a long-running streaming service and
writes a versioned artifact:

    results/<name>/v####/service.json
    results/<name>/v####/figures/service_*.svg

Quickstarts:

    # CI-sized smoke replay (tiny cell, a few segments, modest rate):
    PYTHONPATH=src python -m repro.service.run --smoke

    # the benchmarked deployment shape (N=64, K=16, closed loop):
    PYTHONPATH=src python -m repro.service.run \\
        --devices 64 --subchannels 16 --segments 4 --segment-events 100

Throughput/latency numbers are machine-dependent; the committed gate
lives in benchmarks/control_plane.py (`sustained_service` row).
"""
from __future__ import annotations

import argparse
import dataclasses
import json

from ..core import RoundPolicy
from ..experiments.figures import render_service_gallery
from ..experiments.store import next_version_dir, write_record
from ..fl.sim import SimConfig
from .harness import ServiceConfig, SustainedService

__all__ = ["build_service_config", "main"]

# One tiny deployment every environment can replay in ~a minute: the CI
# `service-smoke` job runs exactly this preset and uploads the artifact.
SMOKE = dict(devices=8, subchannels=3, samples=96, batch=16, local_steps=1,
             segment_events=20, eval_every=10, segments=3, rate=40.0,
             budget=1.0, warmup=1, scenario="churn", ra="fix")


def build_service_config(args: argparse.Namespace) -> ServiceConfig:
    sim = SimConfig(
        dataset=args.dataset,
        n_devices=args.devices,
        n_subchannels=args.subchannels,
        n_samples=args.samples,
        batch=args.batch,
        local_steps=args.local_steps,
        seed=args.seed,
        policy=RoundPolicy(ra=args.ra),
        scenario=args.scenario,
        aggregation=args.aggregation,
    )
    return ServiceConfig(
        sim=sim,
        segment_events=args.segment_events,
        eval_every_events=args.eval_every,
        target_rate_events_per_s=args.rate,
        latency_budget_s=args.budget,
        warmup_segments=args.warmup,
    )


def _parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.service.run",
        description="Run the async engine as a sustained streaming service "
                    "and write a versioned results/ artifact.")
    p.add_argument("--smoke", action="store_true",
                   help="CI-sized preset (overrides shape/load defaults; "
                        "explicit flags still win)")
    p.add_argument("--segments", type=int, default=4,
                   help="measured segments to replay (default 4)")
    p.add_argument("--segment-events", type=int, default=100,
                   help="events per compiled segment (default 100)")
    p.add_argument("--eval-every", type=int, default=None,
                   help="eval cadence in events; must divide "
                        "--segment-events (default: once per segment)")
    p.add_argument("--rate", type=float, default=None,
                   help="open-loop arrival rate in events/s "
                        "(default: closed loop, back-to-back)")
    p.add_argument("--budget", type=float, default=1.0,
                   help="SLO latency budget in seconds (default 1.0)")
    p.add_argument("--warmup", type=int, default=1,
                   help="unmeasured warm-up segments (default 1)")
    p.add_argument("--devices", type=int, default=64)
    p.add_argument("--subchannels", type=int, default=16)
    p.add_argument("--dataset", default="mnist")
    p.add_argument("--samples", type=int, default=128)
    p.add_argument("--batch", type=int, default=16)
    p.add_argument("--local-steps", type=int, default=1)
    p.add_argument("--ra", default="fix", help="resource allocation scheme "
                   "(default 'fix'; 'mo' runs the Stackelberg solver per "
                   "segment)")
    p.add_argument("--scenario", default="churn",
                   help="environment preset (default 'churn' — the "
                        "continuous-churn steady state)")
    p.add_argument("--aggregation", default="async")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--name", default="sustained_service",
                   help="artifact name under the results root")
    p.add_argument("--results-root", default="results")
    p.add_argument("--no-figures", action="store_true",
                   help="skip SVG rendering")
    return p


def main(argv: list[str] | None = None) -> int:
    p = _parser()
    args = p.parse_args(argv)
    if args.smoke:
        # Preset fills every value the user did not set explicitly.
        defaults = {a.dest: a.default for a in p._actions}
        for flag, value in SMOKE.items():
            if getattr(args, flag) == defaults[flag]:
                setattr(args, flag, value)

    cfg = build_service_config(args)
    sim = cfg.sim
    print(f"[service] {sim.dataset} N={sim.n_devices} K={sim.n_subchannels} "
          f"scenario={args.scenario} aggregation={args.aggregation} "
          f"segment={cfg.segment_events}ev x{args.segments} "
          f"rate={cfg.target_rate_events_per_s or 'closed-loop'}")
    svc = SustainedService(cfg)
    record = svc.serve(args.segments, progress=lambda m: print(f"[service] {m}"))

    out_dir = next_version_dir(args.results_root, args.name)
    path = write_record(record, out_dir, filename="service.json")
    figs = []
    if not args.no_figures:
        figs = render_service_gallery(record, out_dir / "figures")

    s = record["summary"]
    print(f"[service] wrote {path}" +
          (f" (+{len(figs)} figures)" if figs else ""))
    print(f"[service] events={s['events']} "
          f"throughput={s['throughput_events_per_s']:.1f} ev/s "
          f"p50={s['latency_s']['p50'] * 1e3:.0f}ms "
          f"p95={s['latency_s']['p95'] * 1e3:.0f}ms "
          f"p99={s['latency_s']['p99'] * 1e3:.0f}ms "
          f"slo={s['slo']['attained']:.0%} @ {s['slo']['budget_s']:g}s")
    print(json.dumps({"out_dir": str(out_dir),
                      "throughput_events_per_s":
                          s["throughput_events_per_s"],
                      "p99_latency_s": s["latency_s"]["p99"],
                      "slo_attained": s["slo"]["attained"]}))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
