"""Event-level observability for the sustained service (DESIGN.md §14).

Pure numpy over a flat per-event log — no engine, clock, or artifact
dependencies — so the SLO/percentile arithmetic is unit-testable on
hand-built traces (tests/test_service.py) and re-derivable from a
committed `service.json` artifact alone.

The wall-clock accounting model: every event i has an *arrival* time
(open loop: ``i / target_rate`` on the load generator's schedule; closed
loop: the wall time its segment entered the engine) and a *completion*
time (the wall time its segment's device results landed on the host).
Commit latency is their difference — for a batched segment engine this
charges each event the full segment residency, the honest (pessimistic)
per-event figure for a service that commits results segment-at-a-time.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "EventLog",
    "latency_percentiles",
    "slo_attainment",
    "throughput_events_per_s",
    "summarize",
]


@dataclasses.dataclass(frozen=True)
class EventLog:
    """One measured window of the service, one row per server event.

    All arrays share length E (validated at construction); times are
    seconds on the measurement clock (0 = window start).
    """

    arrival_s: np.ndarray       # (E,) load-generator arrival times
    complete_s: np.ndarray      # (E,) wall completion times
    sim_latency_s: np.ndarray   # (E,) simulated eq.-9 event latencies
    n_pending: np.ndarray       # (E,) buffer occupancy after the event

    def __post_init__(self):
        arrays = {f.name: np.asarray(getattr(self, f.name))
                  for f in dataclasses.fields(self)}
        sizes = {k: v.shape for k, v in arrays.items()}
        if any(v.ndim != 1 for v in arrays.values()) or \
                len({v.size for v in arrays.values()}) != 1:
            raise ValueError(
                f"EventLog fields must be 1-D and equal-length, got {sizes}")
        if arrays["arrival_s"].size == 0:
            raise ValueError("EventLog needs at least one event")
        for name, v in arrays.items():
            object.__setattr__(self, name, np.asarray(v, np.float64)
                               if name != "n_pending"
                               else np.asarray(v, np.int64))
        if (np.diff(self.arrival_s) < 0).any():
            raise ValueError("arrival times must be non-decreasing")
        if (self.complete_s < self.arrival_s).any():
            raise ValueError("an event cannot complete before it arrives")

    @property
    def events(self) -> int:
        return self.arrival_s.size

    def latencies_s(self) -> np.ndarray:
        """Per-event wall commit latency: completion - arrival."""
        return self.complete_s - self.arrival_s


def latency_percentiles(lat_s: np.ndarray,
                        qs: tuple[float, ...] = (50.0, 95.0, 99.0)) -> dict:
    """{"p50": ..., "p95": ..., "p99": ...} over a latency sample."""
    lat_s = np.asarray(lat_s, np.float64)
    if lat_s.size == 0:
        raise ValueError("percentiles need a non-empty latency sample")
    return {f"p{q:g}": float(np.percentile(lat_s, q)) for q in qs}


def slo_attainment(lat_s: np.ndarray, budget_s: float) -> float:
    """Fraction of events whose commit latency meets the budget."""
    if budget_s <= 0:
        raise ValueError(f"latency budget must be positive, got {budget_s}")
    lat_s = np.asarray(lat_s, np.float64)
    if lat_s.size == 0:
        raise ValueError("SLO attainment needs a non-empty latency sample")
    return float(np.mean(lat_s <= budget_s))


def throughput_events_per_s(log: EventLog) -> float:
    """Committed events per wall second over the measured window
    (first arrival to last completion)."""
    window = float(log.complete_s[-1] - log.arrival_s[0])
    if window <= 0:
        raise ValueError(f"degenerate measurement window: {window}s")
    return log.events / window


def summarize(log: EventLog, budget_s: float) -> dict:
    """The service's scalar observability row for one measured window."""
    lat = log.latencies_s()
    return {
        "events": int(log.events),
        "throughput_events_per_s": throughput_events_per_s(log),
        "latency_s": {
            **latency_percentiles(lat),
            "mean": float(lat.mean()),
            "max": float(lat.max()),
        },
        "slo": {
            "budget_s": float(budget_s),
            "attained": slo_attainment(lat, budget_s),
        },
        "buffer": {
            "mean_pending": float(log.n_pending.mean()),
            "max_pending": int(log.n_pending.max()),
        },
        "sim": {
            "total_time_s": float(log.sim_latency_s.sum()),
            "mean_event_latency_s": float(log.sim_latency_s.mean()),
        },
    }
