"""Algorithm 1 (polyblock outer approximation) vs the brute-force oracle."""
import numpy as np
import pytest
from _hyp import given, settings, st  # per-test skip without hypothesis

from repro.core import WirelessConfig, fixed_ra, grid_oracle, is_infeasible, solve_pairs
from repro.core.wireless import total_energy, total_time

CFG = WirelessConfig()


@given(
    h2=st.floats(0.05, 500.0),
    beta=st.integers(5, 80),
)
@settings(max_examples=30)
def test_polyblock_matches_oracle(h2, beta):
    res = solve_pairs(np.array([beta], float), np.array([h2]), CFG)
    oracle = grid_oracle(float(beta), h2, CFG)
    if not res.feasible[0]:
        assert oracle == np.inf
        return
    # Optimal time within 2% of (or better than) the grid oracle.
    assert res.time_s[0] <= oracle * 1.02 + 1e-6


@given(h2=st.floats(1e-4, 1e3), beta=st.integers(1, 200))
def test_energy_budget_respected(h2, beta):
    res = solve_pairs(np.array([beta], float), np.array([h2]), CFG)
    if res.feasible[0]:
        e = total_energy(res.tau[0], res.p[0], beta, h2, CFG)
        assert e <= CFG.e_max_j * (1 + 1e-6)
        assert 0 < res.tau[0] <= 1 and 0 < res.p[0] <= 1


def test_solution_on_boundary_when_constrained(rng):
    """When (1,1) violates the budget, the optimum sits on g=0 (monotonic
    optimization: f increasing => boundary optimal)."""
    h2 = 5.0
    beta = 40.0
    if total_energy(1.0, 1.0, beta, h2, CFG) <= CFG.e_max_j:
        pytest.skip("budget not active at this point")
    res = solve_pairs(np.array([beta]), np.array([h2]), CFG)
    e = total_energy(res.tau[0], res.p[0], beta, h2, CFG)
    assert e >= 0.95 * CFG.e_max_j  # active constraint


def test_unconstrained_corner():
    """Tiny payloads: (tau, p) = (1, 1) feasible => that's the optimum."""
    cfg = WirelessConfig(e_max_j=100.0)
    res = solve_pairs(np.array([10.0]), np.array([10.0]), cfg)
    assert res.feasible[0]
    assert res.tau[0] == pytest.approx(1.0)
    assert res.p[0] == pytest.approx(1.0)


def test_vectorized_grid_consistent(rng):
    """The batched solver must match per-pair solves."""
    h2 = rng.exponential(size=(4, 6)) * 2.0
    beta = rng.integers(5, 60, 6).astype(float)
    batch = solve_pairs(beta[None, :], h2, CFG)
    for k in range(4):
        for n in range(6):
            one = solve_pairs(np.array([beta[n]]), np.array([h2[k, n]]), CFG)
            if batch.feasible[k, n]:
                assert batch.time_s[k, n] == pytest.approx(one.time_s[0], rel=1e-6)


def test_fixed_ra_feasibility_semantics(rng):
    h2 = rng.exponential(size=(3, 5))
    beta = rng.integers(5, 60, 5).astype(float)
    res = fixed_ra(beta[None, :], h2, CFG)
    e = total_energy(0.5, 0.5, beta[None, :], h2, CFG)
    np.testing.assert_array_equal(res.feasible, e <= CFG.e_max_j)
    assert np.all(np.isinf(res.time_s[~res.feasible]))


def test_mo_ra_never_worse_than_fix_ra(rng):
    """MO-RA optimizes what FIX-RA fixes; wherever both are feasible the
    optimized latency must be <= the fixed one (Fig. 8/9 mechanism)."""
    h2 = rng.exponential(size=(4, 20)) * 3
    beta = rng.integers(5, 60, 20).astype(float)
    mo = solve_pairs(beta[None, :], h2, CFG)
    fx = fixed_ra(beta[None, :], h2, CFG)
    both = mo.feasible & fx.feasible
    assert np.all(mo.time_s[both] <= fx.time_s[both] * 1.001)
    # Prop-1 infeasible pairs are infeasible under ANY allocation.
    assert not np.any(~mo.feasible & fx.feasible)
