"""Jitted/batched Algorithm 1 (core.monotonic_jax + kernels.polyblock_project)
vs the host NumPy reference, plus the vectorized Algorithm 2 formulation.

No hypothesis dependency: these must run even where the property-test
modules skip."""
import numpy as np
import pytest

from repro.core import (
    WirelessConfig,
    grid_oracle,
    precompute_gamma,
    solve_pairs,
    solve_pairs_jit,
    swap_matching,
    swap_matching_loop,
)
from repro.core.matching import is_two_sided_exchange_stable, prepare_utility
from repro.core.wireless import total_energy

CFG = WirelessConfig()


def _random_batch(seed=0, k=4, n=48, scale=3.0):
    rng = np.random.default_rng(seed)
    h2 = rng.exponential(size=(k, n)) * scale
    beta = rng.integers(5, 60, n).astype(float)
    return beta, h2


def _rel(a, b):
    return np.max(np.abs(a - b) / np.maximum(np.abs(a), 1e-30))


@pytest.mark.parametrize("backend", ["newton", "bisect"])
def test_jitted_matches_numpy(backend):
    """Acceptance contract: 1e-6 relative on tau/p/time_s for feasible pairs."""
    beta, h2 = _random_batch(seed=1)
    ref = solve_pairs(beta[None, :], h2, CFG)
    jit = solve_pairs_jit(beta[None, :], h2, CFG, backend=backend)
    np.testing.assert_array_equal(ref.feasible, jit.feasible)
    np.testing.assert_array_equal(ref.iterations, jit.iterations)
    f = ref.feasible
    assert f.any()
    for field in ("tau", "p", "time_s", "energy_j"):
        assert _rel(getattr(ref, field)[f], getattr(jit, field)[f]) < 1e-6, field
    # infeasible pairs keep the sentinel contract
    assert np.all(np.isinf(jit.time_s[~f]))
    assert np.all(np.isnan(jit.tau[~f]))


def test_jitted_matches_grid_oracle():
    """Spot-check the jitted solver against the brute-force oracle."""
    rng = np.random.default_rng(7)
    h2 = rng.exponential(size=8) * 4
    beta = rng.integers(5, 60, 8).astype(float)
    res = solve_pairs_jit(beta, h2, CFG)
    for i in range(8):
        oracle = grid_oracle(float(beta[i]), float(h2[i]), CFG)
        if not res.feasible[i]:
            assert oracle == np.inf
        else:
            assert res.time_s[i] <= oracle * 1.02 + 1e-6


def test_jitted_energy_budget_and_bounds():
    beta, h2 = _random_batch(seed=2, n=64)
    res = solve_pairs_jit(beta[None, :], h2, CFG)
    f = res.feasible
    e = total_energy(res.tau[f], res.p[f], np.broadcast_to(beta, h2.shape)[f],
                     h2[f], CFG)
    assert np.all(e <= CFG.e_max_j * (1 + 1e-6))
    assert np.all((res.tau[f] > 0) & (res.tau[f] <= 1))
    assert np.all((res.p[f] > 0) & (res.p[f] <= 1))


def test_jitted_unconstrained_corner():
    """theta = 1 corner: a huge budget makes (1, 1) optimal."""
    cfg = WirelessConfig(e_max_j=100.0)
    res = solve_pairs_jit(np.array([10.0]), np.array([10.0]), cfg)
    assert res.feasible[0]
    assert res.tau[0] == pytest.approx(1.0)
    assert res.p[0] == pytest.approx(1.0)


def test_whole_horizon_precompute_matches_per_round():
    """precompute_gamma == stacking per-round host solves (the tensor is
    selection-independent, so one batched call covers the horizon)."""
    rng = np.random.default_rng(3)
    rounds, k, n = 5, 4, 12
    beta = rng.integers(5, 60, n).astype(float)
    h2_all = rng.exponential(size=(rounds, k, n)) * 3
    batch = precompute_gamma(beta, h2_all, CFG)
    assert batch.time_s.shape == (rounds, k, n)
    for t in range(rounds):
        ref = solve_pairs(beta[None, :], h2_all[t], CFG)
        np.testing.assert_array_equal(ref.feasible, batch.feasible[t])
        f = ref.feasible
        assert _rel(ref.time_s[f], batch.time_s[t][f]) < 1e-6


def test_projection_backends_agree():
    """ref (NumPy bisection) vs fused jnp vs Pallas kernel (f32, interpret
    off-TPU) on the same vertex batch."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.core.feasibility import is_infeasible
    from repro.kernels.polyblock_project.ops import polyblock_project

    rng = np.random.default_rng(11)
    n = 256
    v = np.stack([rng.uniform(0.05, 1, n), rng.uniform(0.05, 1, n)], -1)
    beta = rng.integers(5, 60, n).astype(float)
    h2 = rng.exponential(size=n) * 3
    e_max = np.full(n, CFG.e_max_j)
    keep = ~is_infeasible(h2, CFG, e_max)  # bisection-to-TINY pairs excluded
    v, beta, h2, e_max = v[keep], beta[keep], h2[keep], e_max[keep]

    ref = polyblock_project(v, beta, h2, e_max, CFG, backend="ref")
    with enable_x64():
        args = [jnp.asarray(x) for x in (v, beta, h2, e_max)]
        jit = np.asarray(polyblock_project(*args, CFG, backend="bisect"))
        newt = np.asarray(polyblock_project(*args, CFG, backend="newton"))
        mixed = np.asarray(polyblock_project(*args, CFG, backend="mixed"))
    pal = np.asarray(polyblock_project(v, beta, h2, e_max, CFG,
                                       backend="pallas", interpret=True))
    assert _rel(ref, jit) < 1e-12          # same arithmetic, same order
    assert _rel(ref, newt) < 1e-6          # Newton converges to the same root
    assert _rel(ref, mixed) < 1e-6         # f32 bulk, f64 polish (§13)
    assert _rel(newt, mixed) < 1e-9        # polish pins to the f64 Newton root
    assert _rel(ref, pal) < 1e-4           # kernel runs float32


def test_swap_matching_vectorized_equals_loop():
    """The vectorized pairwise-delta formulation replicates the reference
    proposal loop exactly: same assignment, same swap count, stable result."""
    for seed in range(40):
        rng = np.random.default_rng(seed)
        k = int(rng.integers(2, 9))
        n_sel = int(rng.integers(1, k + 1))
        gamma = rng.exponential(size=(k, n_sel)) * 5
        feas = rng.uniform(size=(k, n_sel)) > rng.uniform(0, 0.8)
        init = rng.permutation(k)[:n_sel]
        vec = swap_matching(gamma, feas, initial=init)
        ref = swap_matching_loop(gamma, feas, initial=init)
        gamma_u = prepare_utility(gamma, feas)
        assert is_two_sided_exchange_stable(gamma_u, vec.assignment)
        np.testing.assert_array_equal(vec.assignment, ref.assignment)
        assert vec.n_swaps == ref.n_swaps
        assert vec.utilities.sum() == ref.utilities.sum()


def test_swap_matching_zero_rounds_guard():
    """max_rounds=0 must return the initial matching, not crash on an
    unbound loop variable (regression)."""
    gamma = np.ones((3, 3))
    feas = np.ones((3, 3), bool)
    init = np.array([2, 0, 1])
    for fn in (swap_matching, swap_matching_loop):
        res = fn(gamma, feas, initial=init, max_rounds=0)
        np.testing.assert_array_equal(res.assignment, init)
        assert res.n_swaps == 0
        assert res.n_rounds == 0
