"""Sweep-harness tests (repro.experiments + the run_many sweep substrate).

Pins the harness's core contract — a sweep cell's trajectory is IDENTICAL
to a solo `run_simulation` call — plus the grouping machinery behind it:
policy-only variants share one prepared world and one Γ solve, different
(N, K) shapes land in different compiled-program groups, and artifacts
version monotonically.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import RoundPolicy, policy_grid
from repro.experiments import (
    SweepSpec,
    load_latest,
    load_record,
    mean_subchannel_utilization,
    rounds_to_target,
    run_sweep,
    time_to_target_s,
)
from repro.experiments.store import latest_dir, next_version_dir, write_record
from repro.fl import SimConfig, run_simulation
from repro.fl.sim import _prep_key, _scan_group_key

TINY = dict(n_samples=64, batch=8, eval_every=2, local_steps=2)


# --------------------------------------------------------------------------
# spec expansion
# --------------------------------------------------------------------------

def test_policy_grid_order_and_validation():
    grid = policy_grid(ds=("alg3", "random"), ra=("mo", "fix"))
    assert [(p.ds, p.ra) for p in grid] == [
        ("alg3", "mo"), ("alg3", "fix"), ("random", "mo"), ("random", "fix")]
    assert policy_grid(ds="cluster")[0] == RoundPolicy(ds="cluster")
    with pytest.raises(ValueError):
        policy_grid(ds="nope")


def test_spec_expansion_stable_ids():
    spec = SweepSpec(name="t", datasets="mnist", ds=("alg3", "random"),
                     seeds=(0, 1), rounds=4, n_devices=(8, 10),
                     n_subchannels=3, overrides={"n_samples": 32})
    cells = spec.cells()
    assert spec.n_cells == len(cells) == 8
    # dataset > (N, K) > policy > seed, ids stable and unique
    assert cells[0].cell_id == "mnist-N8-K3-alg3.mo.matching-s0"
    assert cells[1].cell_id == "mnist-N8-K3-alg3.mo.matching-s1"
    assert cells[2].cell_id == "mnist-N8-K3-random.mo.matching-s0"
    assert cells[4].cell_id == "mnist-N10-K3-alg3.mo.matching-s0"
    assert len({c.cell_id for c in cells}) == 8
    assert all(c.config.n_samples == 32 for c in cells)
    # round-trips through JSON
    assert SweepSpec.from_json(spec.to_json()) == spec


def test_spec_rejects_bad_inputs():
    with pytest.raises(ValueError):
        SweepSpec(name="bad/name")
    with pytest.raises(ValueError):
        SweepSpec(name="t", overrides={"n_devices": 5})   # grid axis
    with pytest.raises(ValueError):
        SweepSpec(name="t", overrides={"typo_field": 1})
    with pytest.raises(ValueError):
        SweepSpec(name="t", ds="unknown-scheme")
    with pytest.raises(ValueError):
        SweepSpec(name="t", aggregation="warp")
    with pytest.raises(ValueError):                       # grid axis too
        SweepSpec(name="t", overrides={"aggregation": "async"})


def test_spec_aggregation_axis():
    """The aggregation axis expands between scenario and policy, keeps
    "sync" ids unchanged (committed artifacts stay addressable), and
    round-trips through JSON."""
    spec = SweepSpec(name="t", ds="alg3", seeds=(0,), rounds=4,
                     n_devices=8, n_subchannels=3,
                     aggregation=("sync", "async"))
    cells = spec.cells()
    assert spec.n_cells == len(cells) == 2
    assert cells[0].cell_id == "mnist-N8-K3-alg3.mo.matching-s0"
    assert cells[1].cell_id == "mnist-N8-K3-async-alg3.mo.matching-s0"
    assert cells[0].config.aggregation == "sync"
    assert cells[1].config.aggregation == "async"
    assert SweepSpec.from_json(spec.to_json()) == spec


# --------------------------------------------------------------------------
# grouping: shapes, worlds, Γ reuse
# --------------------------------------------------------------------------

def test_scan_group_keys_mixed_shapes():
    base = SimConfig(rounds=4, **TINY)
    same = [dataclasses.replace(base, seed=1),
            dataclasses.replace(base, policy=RoundPolicy(ds="random")),
            dataclasses.replace(base, policy=RoundPolicy(ra="fix")),
            dataclasses.replace(base, radius_m=300.0)]
    for c in same:     # policy/seed/wireless-data variants share a program
        assert _scan_group_key(c) == _scan_group_key(base)
    diff = [dataclasses.replace(base, n_devices=32),
            dataclasses.replace(base, n_subchannels=8),
            dataclasses.replace(base, rounds=6),
            dataclasses.replace(base, dataset="sst2"),
            dataclasses.replace(base, eval_every=4)]
    for c in diff:     # shape changes compile separately
        assert _scan_group_key(c) != _scan_group_key(base)


def test_prep_key_shares_worlds_only_across_policies():
    base = SimConfig(rounds=4, **TINY)
    assert _prep_key(base) == _prep_key(
        dataclasses.replace(base, policy=RoundPolicy(ds="fixed", ra="fix")))
    # ... and across aggregation disciplines: sync vs async cells of one
    # seed share the sampled world and Γ solve (the differential setup).
    assert _prep_key(base) == _prep_key(
        dataclasses.replace(base, aggregation="async"))
    assert _prep_key(base) != _prep_key(dataclasses.replace(base, seed=1))
    assert _prep_key(base) != _prep_key(
        dataclasses.replace(base, n_devices=32))


def test_gamma_solved_once_per_world(monkeypatch):
    """A policy grid over one seed pays ONE Γ solve (and mixed shapes/seeds
    pay one each — no cross-world aliasing)."""
    import repro.fl.sim as sim

    calls = []
    real = sim.solve_pairs_fused    # the ra_solver="fused" default path

    def counting(beta, h2, wcfg, e_max=None, **kw):
        calls.append(np.asarray(h2).size)
        return real(beta, h2, wcfg, e_max, **kw)

    monkeypatch.setattr(sim, "solve_pairs_fused", counting)
    base = SimConfig(rounds=3, n_devices=6, n_subchannels=2, **TINY)
    cfgs = [dataclasses.replace(base, policy=RoundPolicy(ds=d))
            for d in ("alg3", "random", "cluster")]
    sim.run_many(cfgs, engine="loop")
    # One batched call, sized for ONE horizon (not 3x): policy variants
    # share the world's solve.
    assert len(calls) == 1
    assert calls[0] == 3 * 2 * 6
    calls.clear()
    sim.run_many(cfgs + [dataclasses.replace(base, seed=1)], engine="loop")
    # Still one flattened call, but now two worlds' pairs deep.
    assert len(calls) == 1 and calls[0] == 2 * (3 * 2 * 6)


# --------------------------------------------------------------------------
# cell results identical to solo runs
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_sweep_cells_bit_identical_to_solo(tmp_path):
    spec = SweepSpec(name="eq", datasets="mnist", ds=("alg3", "random"),
                     seeds=(0, 1), rounds=5, n_devices=8, n_subchannels=3,
                     target_loss=5.0, overrides=TINY)
    res = run_sweep(spec, results_root=tmp_path, figures=True)
    assert len(res.histories) == 4
    for cell, hist in zip(res.cells, res.histories):
        solo = run_simulation(cell.config, engine="scan")
        assert np.array_equal(hist.tx_trace, solo.tx_trace), cell.cell_id
        assert np.array_equal(hist.age_trace, solo.age_trace)
        assert np.array_equal(hist.global_loss, solo.global_loss)
        assert np.array_equal(hist.accuracy, solo.accuracy)
    # artifact round-trip agrees with the in-memory record
    rec = load_record(res.out_dir)
    assert rec["n_cells"] == 4
    ids = [c["id"] for c in rec["cells"]]
    assert ids == [c.cell_id for c in res.cells]
    for c in rec["cells"]:
        m = c["metrics"]
        assert 0.0 <= m["mean_subchannel_utilization"] <= 1.0
        assert m["rounds_to_target"] is None or m["rounds_to_target"] >= 1
    figs = sorted(p.name for p in (res.out_dir / "figures").iterdir())
    assert figs == ["convergence_rounds_mnist.svg", "convergence_time_mnist.svg",
                    "latency_cdf_mnist.svg", "utilization_mnist.svg"]


@pytest.mark.slow
def test_mixed_shape_grid_matches_solo():
    """Mixed N/K grids split into per-shape groups with no cross-group
    contamination: every cell still reproduces its solo trajectory."""
    spec = SweepSpec(name="mix", datasets="mnist", ds="alg3", seeds=0,
                     rounds=4, n_devices=(6, 9), n_subchannels=(2, 3),
                     overrides=TINY)
    res = run_sweep(spec, write=False)
    assert len(res.histories) == 4
    shapes = {(c.config.n_devices, c.config.n_subchannels) for c in res.cells}
    assert shapes == {(6, 2), (6, 3), (9, 2), (9, 3)}
    for cell, hist in zip(res.cells, res.histories):
        solo = run_simulation(cell.config, engine="scan")
        assert np.array_equal(hist.tx_trace, solo.tx_trace), cell.cell_id
        assert np.array_equal(hist.global_loss, solo.global_loss)


@pytest.mark.slow
def test_sharded_dispatch_matches_vmap():
    """shard=auto on 2 forced host devices == unsharded vmap, bit-for-bit
    (separate process: device count must be set before JAX initializes)."""
    code = """
import numpy as np
from repro.core import RoundPolicy
from repro.fl import SimConfig, run_many
cfgs = [SimConfig(dataset="mnist", rounds=4, n_devices=6, n_subchannels=2,
                  n_samples=48, batch=8, eval_every=2, seed=0,
                  policy=RoundPolicy(ds=d))
        for d in ("alg3", "random", "fixed")]
sh = run_many(cfgs, engine="scan", shard=True)
un = run_many(cfgs, engine="scan", shard=False)
for a, b in zip(sh, un):
    assert np.array_equal(a.tx_trace, b.tx_trace)
    assert np.array_equal(a.global_loss, b.global_loss)
print("SHARD_OK")
"""
    env = dict(os.environ,
               XLA_FLAGS=(os.environ.get("XLA_FLAGS", "") +
                          " --xla_force_host_platform_device_count=2"),
               PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "SHARD_OK" in proc.stdout


# --------------------------------------------------------------------------
# figures: faceting never pools heterogeneous configs
# --------------------------------------------------------------------------

def _toy_record(keys):
    """Minimal record with one cell per (dataset, N, K, ra, sa, ds, seed)."""
    cells = []
    for d, n, k, ra, sa, ds, seed in keys:
        cells.append({
            "id": f"{d}-N{n}-K{k}-{ds}.{ra}.{sa}-s{seed}",
            "dataset": d, "n_devices": n, "n_subchannels": k, "seed": seed,
            "policy": {"ds": ds, "ra": ra, "sa": sa,
                       "label": f"{ds}+{ra}+{sa}"},
            "metrics": {"mean_subchannel_utilization": 0.5},
            "curves": {"round": [0, 1], "global_loss": [2.0, 1.0],
                       "accuracy": [0.1, 0.2], "cum_time_s": [1.0, 2.0]},
            "trace": {"latency_s": [1.0, 1.0], "utilization": [0.5, 0.5]},
        })
    return {"schema": 1, "cells": cells}


def test_facets_split_heterogeneous_records(tmp_path):
    from repro.experiments import facets, render_gallery

    rec = _toy_record([
        ("mnist", 8, 2, "mo", "matching", "alg3", 0),
        ("mnist", 8, 2, "mo", "matching", "random", 0),
        ("mnist", 16, 4, "mo", "matching", "alg3", 0),   # second shape
        ("mnist", 8, 2, "fix", "random", "alg3", 0),     # second (ra, sa)
    ])
    fs = facets(rec)
    assert len(fs) == 3           # (8,2,mo,matching), (16,4,...), (8,2,fix,random)
    assert {f.suffix for f in fs} == {
        "mnist-N8-K2-mo.matching", "mnist-N16-K4-mo.matching",
        "mnist-N8-K2-fix.random"}
    paths = render_gallery(rec, tmp_path)
    assert len(paths) == 12       # 4 figures per facet, no pooling
    # homogeneous record keeps the short suffix (committed artifact names)
    homo = _toy_record([("mnist", 8, 2, "mo", "matching", "alg3", s)
                        for s in (0, 1)])
    assert [f.suffix for f in facets(homo)] == ["mnist"]


def test_fig_time_to_target_refuses_pooling(tmp_path):
    """The sync-vs-async headline figure averages SEEDS only: records
    varying ra/sa (or shape/dataset) within the chosen ds render nothing
    rather than pooling configurations that were never co-simulated."""
    from repro.experiments import fig_time_to_target

    def cell(agg, ra, seed, t2t):
        return {"dataset": "mnist", "n_devices": 8, "n_subchannels": 3,
                "scenario": "static", "aggregation": agg, "seed": seed,
                "policy": {"ds": "alg3", "ra": ra, "sa": "matching",
                           "label": "x"},
                "metrics": {"time_to_target_s": t2t},
                "curves": {}, "trace": {}}

    homogeneous = {"cells": [cell("sync", "mo", 0, 10.0),
                             cell("sync", "mo", 1, 12.0),
                             cell("async", "mo", 0, 2.0),
                             cell("async", "mo", 1, 3.0)]}
    assert fig_time_to_target(homogeneous, tmp_path) is not None
    mixed_ra = {"cells": homogeneous["cells"]
                + [cell("sync", "fix", 0, 99.0)]}
    assert fig_time_to_target(mixed_ra, tmp_path) is None
    sync_only = {"cells": [cell("sync", "mo", 0, 10.0)]}
    assert fig_time_to_target(sync_only, tmp_path) is None


def test_group_mean_curves_refuses_ambiguity():
    from repro.experiments import group_mean_curves

    rec = _toy_record([
        ("mnist", 8, 2, "mo", "matching", "alg3", 0),
        ("mnist", 16, 2, "mo", "matching", "alg3", 0),
    ])
    with pytest.raises(ValueError, match="n_devices"):
        group_mean_curves(rec)
    out = group_mean_curves(rec, n_devices=8)
    assert list(out) == ["alg3+mo+matching"]
    np.testing.assert_allclose(out["alg3+mo+matching"][1], [2.0, 1.0])


# --------------------------------------------------------------------------
# metrics + store
# --------------------------------------------------------------------------

def _fake_history(losses, rounds, lat):
    from repro.fl.sim import SimHistory
    ev = np.asarray(rounds)
    lat = np.asarray(lat, float)
    return SimHistory(
        label="t", rounds=ev, global_loss=np.asarray(losses, float),
        accuracy=np.zeros(len(ev)), latency_s=lat[ev],
        cum_time_s=np.cumsum(lat)[ev], n_selected=np.zeros(len(ev)),
        n_transmitted=np.zeros(len(ev)), energy_j=np.zeros(len(ev)),
        deficits=np.zeros(len(ev)), grad_sq_norms=np.zeros(len(ev)),
        beta=np.ones(4), wall_s=0.0, latency_all=lat,
        energy_all=np.zeros(len(lat)),
        tx_trace=np.array([[1, 1, 0, 0]] * len(lat), bool),
        age_trace=np.ones((len(lat), 4), np.int64))


def test_derived_metrics():
    h = _fake_history(losses=[3.0, 1.9, 1.2], rounds=[0, 2, 4],
                      lat=[2.0, 1.0, 2.0, 1.0, 4.0])
    assert rounds_to_target(h, 2.0) == 3          # eval round 2, 1-based
    assert rounds_to_target(h, 0.5) is None
    assert time_to_target_s(h, 2.0) == pytest.approx(5.0)  # cumsum at t=2
    assert mean_subchannel_utilization(h, 2) == pytest.approx(1.0)
    assert mean_subchannel_utilization(h, 4) == pytest.approx(0.5)


def test_utilization_fallback_is_explicit_and_weighted():
    """Without a full tx_trace, utilization is eval-sampled: the silent
    per-round pretence raises, and the mean weights each eval point by
    its block span instead of double-counting the always-sampled tail."""
    from repro.experiments import eval_spacing_weights, per_round_utilization

    # eval_every=5, horizon 20: eval rounds 0, 5, 10, 15, 19.
    rounds = [0, 5, 10, 15, 19]
    h = _fake_history(losses=[3.0] * 5, rounds=rounds, lat=[1.0] * 20)
    h = dataclasses.replace(h, tx_trace=None,
                            n_transmitted=np.array([0, 4, 4, 4, 4], float))
    with pytest.raises(ValueError, match="allow_eval_sampled"):
        per_round_utilization(h, 4)
    u = per_round_utilization(h, 4, allow_eval_sampled=True)
    assert np.array_equal(u, [0.0, 1.0, 1.0, 1.0, 1.0])
    w = eval_spacing_weights(h.rounds)
    assert np.array_equal(w, [1, 5, 5, 5, 4])     # blocks cover all 20 rounds
    assert w.sum() == 20
    # plain mean over eval points would be 0.8; round-0 carries a 1-round
    # block, so the block-weighted mean is 19/20.
    assert mean_subchannel_utilization(h, 4) == pytest.approx(19 / 20)
    # full-trace histories are untouched by the fallback change
    full = _fake_history(losses=[3.0] * 5, rounds=rounds, lat=[1.0] * 20)
    assert mean_subchannel_utilization(full, 2) == pytest.approx(1.0)


def test_store_versioning(tmp_path):
    d1 = next_version_dir(tmp_path, "s")
    d2 = next_version_dir(tmp_path, "s")
    assert (d1.name, d2.name) == ("v0001", "v0002")
    write_record({"schema": 1, "cells": []}, d2)
    assert latest_dir(tmp_path, "s") == d2
    assert load_latest(tmp_path, "s") == {"schema": 1, "cells": []}
    assert load_latest(tmp_path, "never-ran") is None
    bad = d1 / "sweep.json"
    bad.write_text(json.dumps({"schema": 99}))
    with pytest.raises(ValueError):
        load_record(d1)
