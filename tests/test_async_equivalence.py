"""Differential harness: the buffered async engine vs the sync engines.

The async engine's anchor (DESIGN.md §12) is its degenerate limit: with a
full buffer the server's commit barrier waits for EVERY in-flight upload,
so dispatch == commit, staleness == 0, and the event loop must reproduce
the synchronous scan engine BIT-EXACTLY — transmitted sets, AoU
trajectories, latencies, energies, and losses — for every RoundPolicy and
scenario preset.  Away from the limit, the event traces must satisfy the
buffered-server protocol exactly (replayed here through the engine's own
`commit_event` rule) and beat the synchronous barrier on simulated time
under straggler-heavy scenarios.

Set REPRO_DIFF_BACKEND=pallas to run with Γ solved by the interpret-mode
Pallas projection backend (CI's async-differential job runs the default).
"""
import os

import numpy as np
import pytest

from repro.core import RoundPolicy
from repro.fl import AsyncAggregation, SimConfig, run_many, run_simulation
from repro.fl.async_loop import commit_event

RA_BACKEND = os.environ.get("REPRO_DIFF_BACKEND") or None

_SMALL = dict(rounds=6, n_devices=8, n_subchannels=3, n_samples=96,
              batch=16, local_steps=2, eval_every=2)

# The pinned RoundPolicy x scenario matrix (>= 10 combos): the proposed
# policy across every scenario preset, plus baseline policies crossed
# with the stressful presets.
POLICY_SCENARIOS = [
    ("alg3", "mo", "matching", "static"),
    ("alg3", "mo", "matching", "corr_fading"),
    ("alg3", "mo", "matching", "mobility"),
    ("alg3", "mo", "matching", "churn"),
    ("alg3", "mo", "matching", "harvest"),
    ("alg3", "mo", "matching", "urban"),
    ("aou_topk", "mo", "matching", "churn"),
    ("random", "fix", "random", "urban"),
    ("cluster", "mo", "random", "churn"),
    ("fixed", "fix", "matching", "urban"),
    ("random", "mo", "matching", "harvest"),
    ("cluster", "fix", "matching", "corr_fading"),
]


def _cfg(**kw):
    base = dict(_SMALL, dataset="mnist")
    base.update(kw)
    return SimConfig(**base)


def _assert_bit_exact(sync, asy):
    """The degenerate-limit contract: EVERYTHING the sync engine records
    must match bit-for-bit, and every dispatch must commit at its own
    event."""
    np.testing.assert_array_equal(sync.tx_trace, asy.tx_trace)
    np.testing.assert_array_equal(sync.age_trace, asy.age_trace)
    np.testing.assert_array_equal(sync.latency_all, asy.latency_all)
    np.testing.assert_array_equal(sync.energy_all, asy.energy_all)
    np.testing.assert_array_equal(sync.global_loss, asy.global_loss)
    np.testing.assert_array_equal(sync.accuracy, asy.accuracy)
    np.testing.assert_array_equal(sync.n_selected, asy.n_selected)
    np.testing.assert_array_equal(sync.n_transmitted, asy.n_transmitted)
    np.testing.assert_array_equal(asy.commit_trace, sync.tx_trace)
    assert not asy.async_trace["overflow"].any()
    assert asy.async_trace["n_pending"].max() == 0


def _replay_protocol(hist, n, k, buffer):
    """Replay the recorded event trace through the engine's own
    `commit_event` rule and re-derive every commit decision, event
    latency, and buffer invariant from (tx, rem_dispatch) alone.

    This pins the per-device virtual clocks to the Γ latency trace: the
    engine emits each dispatch's Γ time in `rem_dispatch`, and the replay
    must reproduce `commit_trace` and `latency_all` exactly (identical
    float32 ops, so equality is bitwise).
    """
    import jax.numpy as jnp

    rem = jnp.zeros(n, jnp.float32)
    active = np.zeros(n, bool)
    rounds = hist.tx_trace.shape[0]
    for e in range(rounds):
        tx = hist.tx_trace[e]
        # Buffer overflow is structurally impossible: a device with an
        # uncommitted upload is busy and must never be re-dispatched.
        assert not (tx & active).any(), f"double dispatch at event {e}"
        active = active | tx
        rem = jnp.where(tx, jnp.float32(hist.async_trace["rem_dispatch"][e]),
                        rem)
        delta, commit = commit_event(rem, jnp.asarray(active),
                                     jnp.int32(buffer), k)
        commit = np.asarray(commit)
        assert float(delta) == hist.latency_all[e], f"latency at event {e}"
        np.testing.assert_array_equal(commit, hist.commit_trace[e],
                                      err_msg=f"commit set at event {e}")
        assert (commit <= active).all()      # commits only in-flight devices
        active = active & ~commit
        rem = jnp.where(jnp.asarray(active), rem - delta, jnp.float32(0.0))
        assert hist.async_trace["n_pending"][e] == active.sum()
        # AoU resets exactly at server commits.
        prev_age = hist.age_trace[e - 1] if e else np.ones(n, np.int64)
        np.testing.assert_array_equal(
            hist.age_trace[e], np.where(commit, 1, prev_age + 1))


@pytest.mark.parametrize("ds,ra,sa,scenario", POLICY_SCENARIOS,
                         ids=[f"{d}-{r}-{s}-{sc}"
                              for d, r, s, sc in POLICY_SCENARIOS])
def test_async_full_buffer_bit_exact_vs_scan(ds, ra, sa, scenario):
    """engine="async" with the full-buffer barrier == engine="scan",
    bit-for-bit, across the policy x scenario matrix."""
    cfg = _cfg(policy=RoundPolicy(ds=ds, ra=ra, sa=sa), scenario=scenario)
    sync = run_simulation(cfg, engine="scan", ra_backend=RA_BACKEND)
    asy = run_simulation(cfg, engine="async", ra_backend=RA_BACKEND)
    _assert_bit_exact(sync, asy)


def test_async_full_buffer_any_staleness_bit_exact():
    """With a full buffer no commit is ever stale, so the staleness
    preset cannot perturb the degenerate limit (f(0) == 1.0 exactly)."""
    cfg = _cfg(scenario="churn")
    sync = run_simulation(cfg, engine="scan", ra_backend=RA_BACKEND)
    for agg in (AsyncAggregation(buffer="full", staleness="poly"),
                AsyncAggregation(buffer="full", staleness="const",
                                 exponent=0.0),
                "async_full"):
        asy = run_simulation(
            SimConfig(**{**_SMALL, "scenario": "churn",
                         "aggregation": agg}),
            ra_backend=RA_BACKEND)
        _assert_bit_exact(sync, asy)


def test_async_routing_engine_agnostic():
    """An async-aggregation cell runs the event engine no matter which
    engine the caller asked for — the sync engines cannot express
    buffered commits, so routing must not silently change semantics."""
    cfg = _cfg(scenario="churn", aggregation="async")
    by_engine = [run_many([cfg], engine=e, ra_backend=RA_BACKEND)[0]
                 for e in ("loop", "scan", "async")]
    for other in by_engine[1:]:
        np.testing.assert_array_equal(by_engine[0].tx_trace, other.tx_trace)
        np.testing.assert_array_equal(by_engine[0].commit_trace,
                                      other.commit_trace)
        np.testing.assert_array_equal(by_engine[0].global_loss,
                                      other.global_loss)


@pytest.mark.slow
def test_async_vmap_matches_solo():
    """run_many's vmapped event engine == per-cell solo runs, bit-exact,
    across a seed x aggregation grid (one compiled program per shape)."""
    cfgs = [_cfg(seed=s, scenario="churn", aggregation=a)
            for s in (0, 1, 2) for a in ("async", "async_const")]
    vmapped = run_many(cfgs, engine="scan", ra_backend=RA_BACKEND)
    for c, v in zip(cfgs, vmapped):
        solo = run_simulation(c, ra_backend=RA_BACKEND)
        np.testing.assert_array_equal(v.tx_trace, solo.tx_trace)
        np.testing.assert_array_equal(v.commit_trace, solo.commit_trace)
        np.testing.assert_array_equal(v.age_trace, solo.age_trace)
        np.testing.assert_array_equal(v.latency_all, solo.latency_all)
        np.testing.assert_array_equal(v.global_loss, solo.global_loss)


@pytest.mark.parametrize("buffer", [1, 2, None])
def test_async_cum_time_monotonic_under_churn(buffer):
    """The buffered server never waits longer than the eq.-9 barrier:
    async cumulative simulated time <= sync, pinned under the straggler
    scenario for every commit batch size (the satellite monotonicity
    check)."""
    for seed in (0, 1):
        cfg = _cfg(rounds=10, seed=seed, scenario="churn")
        sync = run_simulation(cfg, engine="scan", ra_backend=RA_BACKEND)
        asy = run_simulation(
            SimConfig(**{**_SMALL, "rounds": 10, "seed": seed,
                         "scenario": "churn",
                         "aggregation": AsyncAggregation(buffer=buffer)}),
            ra_backend=RA_BACKEND)
        assert asy.cum_time_s[-1] <= sync.cum_time_s[-1]
        assert (asy.latency_all >= 0).all()


@pytest.mark.parametrize("buffer,scenario", [(1, "urban"), (2, "churn"),
                                             (2, "static")])
def test_async_event_protocol_replay(buffer, scenario):
    """Away from the degenerate limit, the recorded event traces must
    replay exactly through the engine's own commit rule: virtual clocks
    are driven by the Γ dispatch times, commits and latencies re-derive
    bit-for-bit, and the device-indexed buffer never overflows."""
    cfg = SimConfig(**{**_SMALL, "rounds": 12, "scenario": scenario,
                       "aggregation": AsyncAggregation(buffer=buffer)})
    hist = run_simulation(cfg, ra_backend=RA_BACKEND)
    _replay_protocol(hist, cfg.n_devices, cfg.n_subchannels, buffer)
    # Dispatch times come from Γ: positive and finite wherever dispatched.
    rd = hist.async_trace["rem_dispatch"]
    assert np.isfinite(rd).all()
    assert (rd[hist.tx_trace] > 0).all()


def test_uniform_clocks_any_buffer_degenerates_to_sync(monkeypatch):
    """With uniform per-device clocks every upload of an event ties, so
    ANY buffer size commits the whole dispatch together — the async
    engine collapses to the synchronous barrier even at buffer=1.
    Uniform clocks are forced by flattening the solved Γ to a constant
    (the world, randomness, and energies are otherwise untouched; the
    scenario must be slowdown-free — `apply_dynamics` re-stretches Γ
    per device under stragglers, which is exactly non-uniform clocks)."""
    from repro.fl import sim as sim_mod

    orig = sim_mod._solve_horizons

    def flat_gamma(preps, backend, **kw):
        ras, secs = orig(preps, backend, **kw)
        flat = []
        for ra in ras:
            t = np.where(ra.feasible, 1.0, np.inf)
            flat.append(type(ra)(tau=ra.tau, p=ra.p, time_s=t,
                                 energy_j=ra.energy_j, feasible=ra.feasible,
                                 iterations=ra.iterations))
        return flat, secs

    monkeypatch.setattr(sim_mod, "_solve_horizons", flat_gamma)
    cfg = _cfg(scenario="static")
    sync = run_simulation(cfg, engine="scan", ra_backend=RA_BACKEND)
    asy = run_simulation(
        SimConfig(**{**_SMALL, "scenario": "static",
                     "aggregation": AsyncAggregation(buffer=1)}),
        ra_backend=RA_BACKEND)
    _assert_bit_exact(sync, asy)


def test_unknown_aggregation_rejected():
    with pytest.raises(ValueError):
        run_many([_cfg(aggregation="warp")], engine="scan")
    with pytest.raises(ValueError):
        AsyncAggregation(buffer=0)
    with pytest.raises(ValueError):
        AsyncAggregation(staleness="exp")
