"""Docs reference check: README.md / RESULTS.md must not drift from the
repo.

Every repo-relative path mentioned in their markdown links or fenced code
blocks must exist, and every ``--flag`` a code block passes to
``examples/reproduce_figures.py`` (or ``benchmarks/run.py``) must appear
in that entry point's source.  Placeholders (``<name>``, ``v####``) are
exempt.  CI runs this as the `docs-check` job.
"""
from __future__ import annotations

import re
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
DOCS = [p for p in (ROOT / "README.md", ROOT / "RESULTS.md") if p.exists()]

_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)#\s]+)[^)]*\)")
_FENCE_RE = re.compile(r"```[^\n]*\n(.*?)```", re.S)
# A repo-relative file token inside a code block.
_PATH_RE = re.compile(
    r"(?<![\w/.-])((?:src|tests|examples|benchmarks|results|\.github)"
    r"/[\w./-]+\.\w+|[A-Z][\w.-]*\.(?:md|json|ini|txt))(?![\w/-])")
_FLAG_RE = re.compile(r"(--[a-z][\w-]*)")
_PLACEHOLDER = re.compile(r"[<>*#]|\{|\}|v#|XXXX")

FLAG_SOURCES = {
    "reproduce_figures.py": ROOT / "examples" / "reproduce_figures.py",
    "benchmarks.run": ROOT / "benchmarks" / "run.py",
    "multi_cell.py": ROOT / "examples" / "multi_cell.py",
    "repro.service.run": ROOT / "src" / "repro" / "service" / "run.py",
}
# Flags consumed by tools, not by our entry points.
_GENERIC_FLAGS = {"--upgrade"}


def test_docs_exist():
    assert (ROOT / "README.md").exists(), "README.md missing"
    assert (ROOT / "RESULTS.md").exists(), "RESULTS.md missing"


@pytest.mark.parametrize("doc", DOCS, ids=lambda p: p.name)
def test_markdown_links_resolve(doc: Path):
    text = doc.read_text()
    missing = []
    for target in _LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if _PLACEHOLDER.search(target):
            continue
        if not (ROOT / target).exists():
            missing.append(target)
    assert not missing, f"{doc.name} links to missing paths: {missing}"


@pytest.mark.parametrize("doc", DOCS, ids=lambda p: p.name)
def test_code_block_paths_exist(doc: Path):
    missing = []
    for block in _FENCE_RE.findall(doc.read_text()):
        for line in block.splitlines():
            if _PLACEHOLDER.search(line):
                continue
            for token in _PATH_RE.findall(line):
                if not (ROOT / token).exists():
                    missing.append(token)
    assert not missing, f"{doc.name} code blocks reference missing: {missing}"


@pytest.mark.parametrize("doc", DOCS, ids=lambda p: p.name)
def test_code_block_flags_exist(doc: Path):
    """Flags passed to our entry points must appear in their argparse/source."""
    bad = []
    for block in _FENCE_RE.findall(doc.read_text()):
        for line in block.splitlines():
            srcs = [src for key, src in FLAG_SOURCES.items() if key in line]
            if not srcs:
                continue
            src_text = "".join(s.read_text() for s in srcs)
            for flag in _FLAG_RE.findall(line):
                if flag in _GENERIC_FLAGS:
                    continue
                if flag not in src_text:
                    bad.append(f"{flag} (not in "
                               f"{'/'.join(s.name for s in srcs)})")
    assert not bad, f"{doc.name} passes unknown flags: {bad}"
