"""Shared test config. NOTE: no XLA_FLAGS here — smoke tests must see the
host's real (single) device; only the dry-run forces 512 placeholder
devices, in its own process.

Skip inventory (the ISSUE-5 triage; keep this registry current)
---------------------------------------------------------------
The suite is expected to skip tests ONLY for the reasons below.  Anything
else skipping is debt — either un-skip it with a proper per-test guard or
add it here with its reason.

* ``@given`` property tests (test_wireless, test_matching,
  test_stackelberg, test_monotonic, test_aou_selection, test_fl_substrate,
  test_property_invariants, test_scenario_properties,
  test_async_properties, test_hier_async_properties): skip PER TEST
  when `hypothesis` is not installed, via the ``tests/_hyp.py`` shim.  These modules previously
  skipped WHOLESALE through a module-level ``pytest.importorskip``,
  which also silently dropped ~30 deterministic tests sharing the files;
  the shim keeps those running everywhere.  `hypothesis` is an optional
  dev dependency (requirements-dev.txt) — CI installs it, minimal
  containers may not.
* test_monotonic.py::test_solution_on_boundary_when_constrained guards
  itself with a RUNTIME ``pytest.skip("budget not active at this
  point")``: the test is only meaningful when its pinned (h2, beta)
  point makes the energy budget bind under the current WirelessConfig
  defaults — if a config change relaxes the budget there, the test is
  vacuous, not broken.
* test_sweep.py's and test_hier_async_equivalence.py's 2-device shard
  checks and the launch dry-runs spawn subprocesses with
  ``XLA_FLAGS=--xla_force_host_platform_device_count`` and skip only if
  the subprocess environment cannot host them.

hypothesis settings: the "ci" profile (max_examples=25, no deadline)
keeps property runtime bounded on 2-core CI runners.
"""
import importlib.util

import numpy as np
import pytest

if importlib.util.find_spec("hypothesis") is not None:
    from hypothesis import settings

    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
