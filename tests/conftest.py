"""Shared test config. NOTE: no XLA_FLAGS here — smoke tests must see the
host's real (single) device; only the dry-run forces 512 placeholder
devices, in its own process.

hypothesis is optional: without it the property-based test modules skip
themselves via pytest.importorskip and the rest of the suite still runs."""
import importlib.util

import numpy as np
import pytest

if importlib.util.find_spec("hypothesis") is not None:
    from hypothesis import settings

    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
