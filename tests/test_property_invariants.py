"""Property tests for the leader plane's invariants (hypothesis-guarded):

  * AoU (eqs. 6-7): ages >= 1, reset-on-transmit, weights sum to 1 — and the
    jnp port (`core.leader_jax.step_age`) replays the host state machine
    exactly;
  * matching (Definitions 2-3): both host `swap_matching` variants AND the
    jnp while_loop port terminate two-sided exchange-stable on random
    feasibility masks, and the port replays the host trajectory exactly —
    including padded (n_sel < K) buffers.
"""
import numpy as np
import pytest

import jax.numpy as jnp
from _hyp import given, settings, st  # per-test skip without hypothesis

from repro.core import (
    aou_weights,
    init_aou,
    is_two_sided_exchange_stable,
    step_age,
    step_aou,
    swap_matching,
    swap_matching_jnp,
    swap_matching_loop,
)
from repro.core.matching import prepare_utility


# --------------------------------------------------------------------------
# AoU invariants (eqs. 6-7)
# --------------------------------------------------------------------------

@given(n=st.integers(1, 40), rounds=st.integers(1, 25), seed=st.integers(0, 9999))
def test_aou_invariants_host_and_jnp(n, rounds, seed):
    rng = np.random.default_rng(seed)
    host = init_aou(n)
    age_j = jnp.ones(n, jnp.int32)
    for _ in range(rounds):
        tx = rng.uniform(size=n) < 0.4
        prev = host.age.copy()
        host = step_aou(host, tx)
        age_j = step_age(age_j, jnp.asarray(tx))
        # ages >= 1, reset-on-transmit, +1 otherwise
        assert np.all(host.age >= 1)
        assert np.all(host.age[tx] == 1)
        assert np.all(host.age[~tx] == prev[~tx] + 1)
        # weights: a distribution, monotone in age
        w = aou_weights(host)
        assert w.sum() == pytest.approx(1.0, abs=1e-12)
        assert np.all(w > 0)
        # jnp port replays the host state machine exactly
        np.testing.assert_array_equal(np.asarray(age_j), host.age)


# --------------------------------------------------------------------------
# matching invariants (Definitions 2-3)
# --------------------------------------------------------------------------

def _instance(rng, k, n_sel, infeasible_frac):
    gamma = rng.exponential(size=(k, n_sel)) * 5
    feas = rng.uniform(size=(k, n_sel)) > infeasible_frac
    return gamma, feas


@given(k=st.integers(2, 7), seed=st.integers(0, 10_000),
       infeasible=st.floats(0.0, 0.9))
@settings(max_examples=30)
def test_both_host_variants_terminate_2es(k, seed, infeasible):
    rng = np.random.default_rng(seed)
    gamma, feas = _instance(rng, k, k, infeasible)
    init = np.random.default_rng(seed + 1).permutation(k)
    gamma_u = prepare_utility(gamma, feas)
    for fn in (swap_matching, swap_matching_loop):
        res = fn(gamma, feas, initial=init.copy())
        assert is_two_sided_exchange_stable(gamma_u, res.assignment)
        assert len(set(res.assignment.tolist())) == k        # one-to-one


@given(k=st.integers(2, 7), n_sel=st.integers(1, 7), seed=st.integers(0, 10_000),
       infeasible=st.floats(0.0, 0.9))
@settings(max_examples=40)
def test_jnp_port_replays_host_and_is_2es(k, n_sel, seed, infeasible):
    """The fixed-buffer jnp port = the host matching, slot for slot — also
    when the candidate buffer is padded (n_sel < K)."""
    n_sel = min(n_sel, k)
    rng = np.random.default_rng(seed)
    gamma, feas = _instance(rng, k, n_sel, infeasible)
    # float32 utilities on both sides: the scan engine feeds the port f32,
    # and f32 values are exact in the host's f64 comparisons.
    gamma = gamma.astype(np.float32).astype(np.float64)
    perm = np.random.default_rng(seed + 1).permutation(k)

    host = swap_matching(gamma, feas, initial=perm[:n_sel].copy())

    # Pad to a K-slot buffer the way core.leader_jax does.
    gamma_u = prepare_utility(gamma, feas)
    padded = np.full((k, k), 1e30)
    padded[:, :n_sel] = gamma_u
    valid = np.arange(k) < n_sel
    assignment, feasible, n_swaps, n_rounds = swap_matching_jnp(
        jnp.asarray(padded, jnp.float32), jnp.asarray(valid),
        jnp.asarray(perm, jnp.int32))

    np.testing.assert_array_equal(np.asarray(assignment)[:n_sel],
                                  host.assignment)
    np.testing.assert_array_equal(np.asarray(feasible)[:n_sel], host.feasible)
    assert int(n_swaps) == host.n_swaps
    assert int(n_rounds) == host.n_rounds
    assert is_two_sided_exchange_stable(gamma_u,
                                        np.asarray(assignment)[:n_sel])


@given(k=st.integers(2, 6), seed=st.integers(0, 10_000))
@settings(max_examples=20)
def test_swaps_monotonically_reduce_total_utility(k, seed):
    """Every executed swap strictly reduces total utility (the paper's
    convergence argument), so the final sum never exceeds the initial."""
    rng = np.random.default_rng(seed)
    gamma, feas = _instance(rng, k, k, 0.3)
    gamma_u = prepare_utility(gamma, feas)
    init = rng.permutation(k)
    res = swap_matching(gamma, feas, initial=init.copy())
    assert res.utilities.sum() <= gamma_u[init, np.arange(k)].sum() + 1e-9
