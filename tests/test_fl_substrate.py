"""FL substrate: datasets/partition, aggregation (eq. 34), optimizers, and a
short end-to-end simulation per dataset."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # per-test skip without hypothesis

from repro.core import RoundPolicy
from repro.data.fl_datasets import make_dataset, partition_imbalanced_iid
from repro.data.pipeline import synthetic_lm_stream
from repro.fl import SimConfig, aggregate, run_simulation
from repro.train.optimizer import (
    adafactor, adam, adamw, apply_updates, make_optimizer, momentum, sgd)


# --------------------------------------------------------------------------
# data
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name,n", [("mnist", 200), ("cifar10", 100), ("sst2", 150)])
def test_datasets_shapes(name, n, rng):
    ds = make_dataset(name, rng, n=n)
    assert ds.n == n
    assert ds.y.min() >= 0 and ds.y.max() < ds.n_classes
    if name == "mnist":
        assert ds.x.shape == (n, 784)
    elif name == "cifar10":
        assert ds.x.shape == (n, 32, 32, 3)
    else:
        assert ds.x.shape[1] == 32 and ds.x.dtype == np.int32


@given(n_samples=st.integers(50, 1000), n_devices=st.integers(2, 30),
       seed=st.integers(0, 999))
@settings(max_examples=20)
def test_partition_imbalanced_iid(n_samples, n_devices, seed):
    rng = np.random.default_rng(seed)
    part = partition_imbalanced_iid(rng, n_samples, n_devices)
    assert part.n_devices == n_devices
    assert part.beta.sum() <= n_samples
    assert np.all(part.beta >= 1)
    all_idx = np.concatenate(part.indices)
    assert len(np.unique(all_idx)) == len(all_idx)  # disjoint


def test_partition_deterministic():
    p1 = partition_imbalanced_iid(np.random.default_rng(5), 300, 10)
    p2 = partition_imbalanced_iid(np.random.default_rng(5), 300, 10)
    np.testing.assert_array_equal(p1.beta, p2.beta)


def test_lm_stream_deterministic():
    a = next(synthetic_lm_stream(1, 2, 16, 100))
    b = next(synthetic_lm_stream(1, 2, 16, 100))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


# --------------------------------------------------------------------------
# aggregation (eq. 34)
# --------------------------------------------------------------------------

def test_aggregate_weighted_mean():
    g = {"w": jnp.zeros((3,))}
    clients = {"w": jnp.asarray([[1.0, 1, 1], [3.0, 3, 3], [100.0, 100, 100]])}
    out = aggregate(g, clients, jnp.asarray([1.0, 1.0, 0.0]))
    np.testing.assert_allclose(np.asarray(out["w"]), 2.0)


def test_aggregate_zero_weights_keeps_global():
    g = {"w": jnp.full((3,), 7.0)}
    clients = {"w": jnp.ones((2, 3))}
    out = aggregate(g, clients, jnp.zeros((2,)))
    np.testing.assert_allclose(np.asarray(out["w"]), 7.0)


@given(seed=st.integers(0, 999), k=st.integers(1, 6))
@settings(max_examples=15)
def test_aggregate_convexity(seed, k):
    """Aggregate lies in the convex hull of client params (eq. 34 is a
    convex combination)."""
    rng = np.random.default_rng(seed)
    c = jnp.asarray(rng.normal(size=(k, 4)))
    w = jnp.asarray(np.abs(rng.normal(size=k)) + 0.01)
    out = aggregate({"x": jnp.zeros(4)}, {"x": c}, w)["x"]
    assert np.all(np.asarray(out) <= np.asarray(c.max(0)) + 1e-6)
    assert np.all(np.asarray(out) >= np.asarray(c.min(0)) - 1e-6)


# --------------------------------------------------------------------------
# optimizers
# --------------------------------------------------------------------------

@pytest.mark.parametrize("make", [
    lambda: sgd(0.1), lambda: momentum(0.05), lambda: adam(0.1),
    lambda: adamw(0.1, wd=0.0), lambda: adafactor(0.5),
])
def test_optimizers_descend_quadratic(make):
    opt = make()
    params = {"w": jnp.asarray([3.0, -2.0]), "m": jnp.ones((2, 2))}

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum((p["m"] - 1.0) ** 2)

    state = opt.init(params)
    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    assert float(loss(params)) < 0.2 * l0


def test_make_optimizer_unknown():
    with pytest.raises(ValueError):
        make_optimizer("lion", 1e-3)


# --------------------------------------------------------------------------
# end-to-end simulation (short)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("dataset,n", [("mnist", 300), ("sst2", 300)])
def test_sim_loss_decreases(dataset, n):
    h = run_simulation(SimConfig(dataset=dataset, rounds=25, n_samples=n,
                                 eval_every=5, local_steps=6,
                                 lr=0.05 if dataset == "sst2" else None))
    assert h.global_loss[-1] < h.global_loss[0] * 0.9
    assert np.all(h.n_transmitted <= 4)
    assert h.cum_time_s[-1] > 0


def test_sim_policies_all_run():
    for ds in ("alg3", "aou_topk", "random", "cluster", "fixed"):
        h = run_simulation(SimConfig(dataset="mnist", rounds=4, n_samples=120,
                                     policy=RoundPolicy(ds=ds), eval_every=2))
        assert np.isfinite(h.global_loss).all(), ds


def test_sim_deterministic():
    a = run_simulation(SimConfig(dataset="mnist", rounds=6, n_samples=120, eval_every=3))
    b = run_simulation(SimConfig(dataset="mnist", rounds=6, n_samples=120, eval_every=3))
    np.testing.assert_allclose(a.global_loss, b.global_loss, rtol=1e-5)


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    from repro.configs import get_config
    from repro.models import init_params

    cfg = get_config("qwen2-7b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    p = str(tmp_path / "ckpt.npz")
    save_checkpoint(p, params, step=7)
    restored, step = restore_checkpoint(p, params)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


# --------------------------------------------------------------------------
# non-IID (Dirichlet) partition extension
# --------------------------------------------------------------------------

@given(seed=st.integers(0, 500), alpha=st.floats(0.05, 2.0))
@settings(max_examples=15)
def test_partition_dirichlet(seed, alpha):
    from repro.data.fl_datasets import partition_dirichlet

    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, 400).astype(np.int32)
    part = partition_dirichlet(rng, labels, 8, alpha)
    assert part.n_devices == 8
    assert np.all(part.beta >= 1)
    all_idx = np.concatenate(part.indices)
    # near-complete coverage (only the empty-device guard can duplicate)
    assert len(all_idx) >= 395


def test_sim_dirichlet_runs():
    h = run_simulation(SimConfig(dataset="mnist", rounds=6, n_samples=200,
                                 partition="dirichlet", eval_every=3))
    assert np.isfinite(h.global_loss).all()


# --------------------------------------------------------------------------
# hierarchical (multi-cell) extension
# --------------------------------------------------------------------------

def test_hierarchical_two_cells():
    from repro.fl import HierSimConfig, run_hierarchical

    out = run_hierarchical(HierSimConfig(rounds=8, n_samples=200))
    assert out["loss"].shape == (8,)
    assert np.isfinite(out["loss"]).all()
    assert out["loss"][-1] < out["loss"][0]
    # engine matrix + full coverage lives in tests/test_hierarchical.py
    # (this module needs hypothesis; that one always runs).
