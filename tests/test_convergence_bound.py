"""Proposition 3: convergence-bound behaviour + a measured strongly-convex
FL run staying under its bound."""
import numpy as np
import pytest

from repro.core import convergence_bound, participation_deficit


def test_deficit():
    beta = np.array([10.0, 20.0, 30.0])
    assert participation_deficit(beta, np.array([1, 1, 1])) == 0.0
    assert participation_deficit(beta, np.array([0, 1, 0])) == 40.0


def test_full_participation_recovers_classic_rate():
    """With zero deficits the bound is the classic (1-mu/L)^t decay."""
    t = 20
    b = convergence_bound(
        gap0=1.0,
        grad_sq_norms=np.ones(t),
        deficits=np.zeros(t),
        beta_total=100.0,
        mu=1.0, lips=4.0, rho=1.0,
    )
    np.testing.assert_allclose(b, (1 - 0.25) ** np.arange(1, t + 1))


def test_more_participation_tightens_bound():
    t = 30
    g = np.ones(t)
    lo = convergence_bound(1.0, g, np.full(t, 10.0), 100.0, mu=1, lips=4, rho=1)
    hi = convergence_bound(1.0, g, np.full(t, 60.0), 100.0, mu=1, lips=4, rho=1)
    assert np.all(lo <= hi)


def test_bound_holds_on_quadratic_fl():
    """Distributed quadratic F(w) = mean_i 0.5||a_i^T w - y_i||^2: run FedAvg
    with partial participation at lr=1/L and check the measured gap stays
    under eq. (40)."""
    rng = np.random.default_rng(0)
    n_dev, d = 8, 5
    beta = rng.integers(5, 20, n_dev)
    data = [
        (rng.normal(size=(b, d)), rng.normal(size=(b,)))
        for b in beta
    ]
    a_all = np.concatenate([a for a, _ in data])
    y_all = np.concatenate([y for _, y in data])
    n_tot = len(y_all)

    h = a_all.T @ a_all / n_tot
    eigs = np.linalg.eigvalsh(h)
    mu, lips = max(eigs.min(), 1e-3), eigs.max()
    w_star = np.linalg.lstsq(a_all, y_all, rcond=None)[0]

    def f_global(w):
        r = a_all @ w - y_all
        return 0.5 * float(r @ r) / n_tot

    def grad_local(w, a, y):
        return a.T @ (a @ w - y) / len(y)

    # rho: max_i ||grad_i||^2 <= rho ||grad F||^2 over the trajectory -> measure.
    w = rng.normal(size=d)
    gap0 = f_global(w) - f_global(w_star)
    lr = 1.0 / lips
    t_max = 40
    gaps, gnorms, defs, rho = [], [], [], 1.0
    for t in range(t_max):
        g_full = a_all.T @ (a_all @ w - y_all) / n_tot
        gnorms.append(float(g_full @ g_full))
        tx = rng.uniform(size=n_dev) < 0.6
        if not tx.any():
            tx[rng.integers(n_dev)] = True
        defs.append(float((beta * (~tx)).sum()))
        for i in np.where(tx)[0]:
            a, y = data[i]
            for j in range(len(y)):
                gi = a[j] * (a[j] @ w - y[j])
                rho = max(rho, float(gi @ gi) / max(gnorms[-1], 1e-12))
        num = sum(beta[i] * (w - lr * grad_local(w, *data[i])) for i in np.where(tx)[0])
        w = num / beta[tx].sum()
        gaps.append(f_global(w) - f_global(w_star))

    bound = convergence_bound(gap0, np.array(gnorms), np.array(defs),
                              float(beta.sum()), mu=mu, lips=lips, rho=rho)
    assert np.all(np.array(gaps) <= bound * (1 + 1e-6) + 1e-9)
