"""Differential harness: the scan engine vs the host round loop.

Both engines consume identical pre-sampled randomness (DESIGN.md §8), so
for EVERY RoundPolicy (5 DS x 2 RA x 2 SA) they must produce identical
transmitted-device sets and AoU trajectories, latencies equal up to the
leader plane's float32 cast, and matched final loss on every dataset.

Set REPRO_DIFF_BACKEND=pallas to run the same suite with Γ solved by the
interpret-mode Pallas projection backend (CI runs both).
"""
import itertools
import os

import numpy as np
import pytest

from repro.core import RoundPolicy
from repro.fl import SimConfig, run_many, run_simulation

RA_BACKEND = os.environ.get("REPRO_DIFF_BACKEND") or None

COMBOS = list(itertools.product(
    ("alg3", "aou_topk", "random", "cluster", "fixed"),
    ("mo", "fix"),
    ("matching", "random"),
))

_SMALL = dict(rounds=6, n_devices=8, n_subchannels=3, n_samples=96,
              batch=16, local_steps=2, eval_every=2)


def _cfg(dataset="mnist", **kw):
    base = dict(_SMALL, dataset=dataset)
    base.update(kw)
    return SimConfig(**base)


def _assert_equivalent(a, b, *, loss_rtol=1e-3):
    """The differential contract (DESIGN.md §8)."""
    np.testing.assert_array_equal(a.tx_trace, b.tx_trace)
    np.testing.assert_array_equal(a.age_trace, b.age_trace)
    np.testing.assert_allclose(a.latency_all, b.latency_all,
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(a.energy_all, b.energy_all,
                               rtol=1e-5, atol=1e-9)
    np.testing.assert_allclose(a.cum_time_s, b.cum_time_s, rtol=1e-5)
    np.testing.assert_array_equal(a.n_selected, b.n_selected)
    np.testing.assert_array_equal(a.n_transmitted, b.n_transmitted)
    np.testing.assert_allclose(a.deficits, b.deficits, rtol=1e-6)
    np.testing.assert_allclose(a.global_loss, b.global_loss, rtol=loss_rtol)


@pytest.mark.parametrize("ds,ra,sa", COMBOS,
                         ids=[f"{d}-{r}-{s}" for d, r, s in COMBOS])
def test_scan_matches_loop_all_policies(ds, ra, sa):
    cfg = _cfg(policy=RoundPolicy(ds=ds, ra=ra, sa=sa))
    a = run_simulation(cfg, engine="loop", ra_backend=RA_BACKEND)
    b = run_simulation(cfg, engine="scan", ra_backend=RA_BACKEND)
    _assert_equivalent(a, b)


@pytest.mark.slow
@pytest.mark.parametrize("dataset,n,batch", [("cifar10", 64, 8), ("sst2", 96, 16)])
def test_scan_matches_loop_other_datasets(dataset, n, batch):
    cfg = _cfg(dataset=dataset, rounds=4, n_samples=n, batch=batch)
    a = run_simulation(cfg, engine="loop", ra_backend=RA_BACKEND)
    b = run_simulation(cfg, engine="scan", ra_backend=RA_BACKEND)
    _assert_equivalent(a, b)


@pytest.mark.slow
def test_scan_vmap_matches_per_seed_and_loop():
    """run_many's vmapped scan = per-seed scan runs = host loop.  Minibatch
    sampling is padding-independent (floor(u * n_valid), fl.client), so the
    group-padded vmap batch cannot perturb a seed's trajectory; only batched
    XLA kernel reassociation may move the loss, hence the tight rtol."""
    cfgs = [_cfg(rounds=5, seed=s) for s in (0, 1, 2)]
    vmapped = run_many(cfgs, engine="scan", ra_backend=RA_BACKEND)
    solo = [run_simulation(c, engine="scan", ra_backend=RA_BACKEND)
            for c in cfgs]
    loop = run_many(cfgs, engine="loop", ra_backend=RA_BACKEND)
    for v, s, l in zip(vmapped, solo, loop):
        np.testing.assert_array_equal(v.tx_trace, s.tx_trace)
        np.testing.assert_array_equal(v.tx_trace, l.tx_trace)
        np.testing.assert_array_equal(v.age_trace, l.age_trace)
        np.testing.assert_allclose(v.latency_all, l.latency_all,
                                   rtol=1e-5, atol=1e-7)
        np.testing.assert_allclose(v.global_loss, s.global_loss, rtol=1e-4)
        np.testing.assert_allclose(v.global_loss, l.global_loss, rtol=1e-4)


def test_scan_mixed_policy_sweep_partitions_into_groups():
    """A sweep mixing policies still runs (one compiled program per static
    group) and returns histories in input order."""
    cfgs = [_cfg(policy=RoundPolicy(ds="alg3"), seed=0),
            _cfg(policy=RoundPolicy(ds="random"), seed=1),
            _cfg(policy=RoundPolicy(ds="alg3", ra="fix"), seed=2)]
    hists = run_many(cfgs, engine="scan", ra_backend=RA_BACKEND)
    for c, h in zip(cfgs, hists):
        assert h.label == c.policy.label
        assert np.isfinite(h.global_loss).all()


def test_unknown_engine_rejected():
    with pytest.raises(ValueError):
        run_many([_cfg()], engine="warp")


# ---------------------------------------------------------------------------
# history sampling regression (satellite: convergence time must not drop
# unsampled rounds)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["loop", "scan"])
def test_cum_time_accumulates_unsampled_rounds(engine):
    """With eval_every=5, cum_time_s (the paper's convergence-time metric)
    must still accumulate the latency of EVERY round, not just the sampled
    ones — the pre-fix behavior silently dropped 4/5 of the rounds."""
    cfg = _cfg(rounds=10, eval_every=5)
    h = run_simulation(cfg, engine=engine, ra_backend=RA_BACKEND)
    assert h.rounds.tolist() == [0, 5, 9]
    assert h.latency_all.shape == (10,)
    np.testing.assert_allclose(
        h.cum_time_s, np.cumsum(h.latency_all)[h.rounds], rtol=1e-12)
    # Every simulated round has positive latency here, so the fixed metric
    # is strictly larger than the sum of the sampled latencies alone.
    assert (h.latency_all > 0).all()
    assert h.cum_time_s[-1] > h.latency_s.sum()
    # Sampled-round views stay consistent with the full traces.
    np.testing.assert_allclose(h.latency_s, h.latency_all[h.rounds])
    np.testing.assert_allclose(h.energy_j, h.energy_all[h.rounds])
