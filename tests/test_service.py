"""Sustained-service harness (DESIGN.md §14): segment-resume contract,
stream extension, SLO/percentile arithmetic, and the artifact-store
concurrency fix.

The load generator's wall-clock numbers are machine-dependent and never
asserted here — only the deterministic invariants are: S segments of
length L must be bit-identical to one segment of length S*L, a scenario
stream must never reposition its rng when the segmentation changes, and
the observability layer must be exact arithmetic over a hand-built log.
"""
import concurrent.futures as cf
import json

import numpy as np
import pytest

from repro.experiments.store import (
    load_latest,
    load_record,
    next_version_dir,
    write_record,
)
from repro.fl.sim import SimConfig
from repro.scenarios import ScenarioStream, generate_traces
from repro.service import (
    EventLog,
    ServiceConfig,
    SustainedService,
    latency_percentiles,
    slo_attainment,
    summarize,
    throughput_events_per_s,
)

_SIM = dict(dataset="mnist", n_devices=8, n_subchannels=3, n_samples=96,
            batch=16, local_steps=1, scenario="churn", aggregation="async")

_YS_KEYS = ("loss", "acc", "latency", "energy", "selected", "transmitted",
            "age", "committed", "n_pending", "overflow", "rem_dispatch")


def _service(segment_events, eval_every):
    return SustainedService(ServiceConfig(
        sim=SimConfig(**_SIM),
        segment_events=segment_events,
        eval_every_events=eval_every))


# ---------------------------------------------------------------------------
# stream extension: segment s continues ONE world, never a reseeded one
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("preset", ["static", "urban", "harvest"])
def test_scenario_stream_segmentation_invariant(preset):
    wcfg = SimConfig(**_SIM).wireless()
    one = ScenarioStream(7, wcfg, preset).next_segment(12)
    chained = ScenarioStream(7, wcfg, preset)
    parts = [chained.next_segment(r) for r in (3, 4, 5)]
    for field in ("h2_all", "distances_m", "avail", "slowdown", "e_max_j"):
        whole = getattr(one, field)
        cat = np.concatenate([getattr(p, field) for p in parts])
        assert np.array_equal(whole, cat), (preset, field)
    assert chained.t == 12


def test_scenario_stream_differs_from_block_order_world():
    """The stream is a different (equally valid) world than the
    fixed-horizon block sampler — drawing per round, not per process
    block, is what makes its rng position segment-size independent."""
    wcfg = SimConfig(**_SIM).wireless()
    st = ScenarioStream(7, wcfg, "urban").next_segment(12)
    block = generate_traces(7, wcfg, "urban", 12)
    assert st.h2_all.shape == block.h2_all.shape
    assert not np.array_equal(st.h2_all, block.h2_all)


# ---------------------------------------------------------------------------
# segment-resume contract: S x L  ==  1 x S*L, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_segment_chained_bit_identical_to_single_scan():
    one = _service(segment_events=12, eval_every=2)
    ys_one = one.run_segment()
    chained = _service(segment_events=4, eval_every=2)
    parts = [chained.run_segment() for _ in range(3)]
    assert chained.events_served == one.events_served == 12
    for k in _YS_KEYS:
        whole = ys_one[k]
        cat = np.concatenate([p[k] for p in parts])
        assert np.array_equal(whole, cat), k


def test_service_record_shape():
    svc = SustainedService(ServiceConfig(
        sim=SimConfig(**_SIM), segment_events=4, eval_every_events=2,
        warmup_segments=1, latency_budget_s=60.0))
    rec = svc.serve(2)
    assert rec["kind"] == "sustained_service"
    assert rec["service"]["events_measured"] == 8
    assert rec["service"]["events_served_total"] == 12   # incl. warm-up
    ev = rec["events"]
    assert len({len(v) for v in ev.values()}) == 1
    assert len(ev["event"]) == 8
    assert ev["event"][0] == 4                           # after warm-up
    s = rec["summary"]
    assert {"p50", "p95", "p99", "mean", "max"} <= s["latency_s"].keys()
    assert s["events"] == 8 and s["throughput_events_per_s"] > 0
    assert 0.0 <= s["slo"]["attained"] <= 1.0
    ss = rec["steady_state"]
    assert ss["event"] == [5, 7, 9, 11]                  # eval block ends
    assert len(ss["global_loss"]) == len(ss["accuracy"]) == 4
    json.dumps(rec)                                      # artifact-ready


def test_service_config_validation():
    with pytest.raises(ValueError, match="divide"):
        ServiceConfig(sim=SimConfig(**_SIM), segment_events=10,
                      eval_every_events=3)
    with pytest.raises(ValueError, match="positive"):
        ServiceConfig(sim=SimConfig(**_SIM), target_rate_events_per_s=0.0)
    with pytest.raises(ValueError, match="budget"):
        ServiceConfig(sim=SimConfig(**_SIM), latency_budget_s=-1.0)


# ---------------------------------------------------------------------------
# observability: exact arithmetic on hand-built traces
# ---------------------------------------------------------------------------

def _log():
    # 4 events: latencies 1, 2, 3, 4 seconds exactly.
    return EventLog(arrival_s=np.array([0.0, 1.0, 2.0, 3.0]),
                    complete_s=np.array([1.0, 3.0, 5.0, 7.0]),
                    sim_latency_s=np.array([0.5, 0.5, 1.0, 1.0]),
                    n_pending=np.array([1, 2, 3, 2]))


def test_latency_percentile_and_slo_arithmetic():
    log = _log()
    lat = log.latencies_s()
    assert np.array_equal(lat, [1.0, 2.0, 3.0, 4.0])
    p = latency_percentiles(lat)
    assert p["p50"] == pytest.approx(2.5)
    assert p["p95"] == pytest.approx(np.percentile([1, 2, 3, 4], 95))
    assert slo_attainment(lat, 2.0) == pytest.approx(0.5)
    assert slo_attainment(lat, 0.5) == 0.0
    assert slo_attainment(lat, 10.0) == 1.0
    # window = first arrival (0) to last completion (7)
    assert throughput_events_per_s(log) == pytest.approx(4 / 7)
    s = summarize(log, budget_s=2.0)
    assert s["events"] == 4
    assert s["latency_s"]["mean"] == pytest.approx(2.5)
    assert s["slo"]["attained"] == pytest.approx(0.5)
    assert s["buffer"]["mean_pending"] == pytest.approx(2.0)
    assert s["sim"]["total_time_s"] == pytest.approx(3.0)


def test_event_log_validation():
    with pytest.raises(ValueError, match="equal-length"):
        EventLog(arrival_s=np.zeros(3), complete_s=np.zeros(2),
                 sim_latency_s=np.zeros(3), n_pending=np.zeros(3))
    with pytest.raises(ValueError, match="non-decreasing"):
        EventLog(arrival_s=np.array([1.0, 0.0]),
                 complete_s=np.array([2.0, 2.0]),
                 sim_latency_s=np.zeros(2), n_pending=np.zeros(2))
    with pytest.raises(ValueError, match="complete before"):
        EventLog(arrival_s=np.array([0.0, 2.0]),
                 complete_s=np.array([1.0, 1.0]),
                 sim_latency_s=np.zeros(2), n_pending=np.zeros(2))
    with pytest.raises(ValueError, match="positive"):
        slo_attainment(np.ones(3), 0.0)
    with pytest.raises(ValueError, match="non-empty"):
        latency_percentiles(np.array([]))


# ---------------------------------------------------------------------------
# artifact store: concurrent version claims (the FileExistsError fix)
# ---------------------------------------------------------------------------

def test_next_version_dir_stale_listing_retries(tmp_path, monkeypatch):
    """A writer that lists versions just before another claims one must
    retry onto the next free slot, not crash (the pre-fix behavior)."""
    from repro.experiments import store

    (tmp_path / "s" / "v0001").mkdir(parents=True)
    real = store._versions
    stale = {"pending": True}

    def racy_versions(sweep_dir):
        if stale.pop("pending", None):
            return []          # raced: another writer claimed v0001 already
        return real(sweep_dir)

    monkeypatch.setattr(store, "_versions", racy_versions)
    out = next_version_dir(tmp_path, "s")
    assert out.name == "v0002"


def test_next_version_dir_concurrent_claims_unique(tmp_path):
    def claim(_):
        return next_version_dir(tmp_path, "s").name

    with cf.ThreadPoolExecutor(max_workers=8) as pool:
        names = list(pool.map(claim, range(24)))
    assert len(set(names)) == 24
    assert sorted(names) == [f"v{i:04d}" for i in range(1, 25)]


def test_store_filename_roundtrip(tmp_path):
    d = next_version_dir(tmp_path, "svc")
    write_record({"kind": "sustained_service"}, d, filename="service.json")
    assert (d / "service.json").exists() and not (d / "sweep.json").exists()
    rec = load_record(d, filename="service.json")
    assert rec["kind"] == "sustained_service"
    assert load_latest(tmp_path, "svc",
                       filename="service.json")["kind"] == "sustained_service"
