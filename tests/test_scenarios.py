"""Scenario-dynamics subsystem (repro.scenarios, DESIGN.md §11).

Pins the layer's two load-bearing contracts:

  * the ``static`` preset is BIT-EXACT with the pre-scenario simulator —
    `_prepare` consumes the identical world rng stream (verified against a
    hand-replicated legacy draw sequence) and both engines reproduce the
    identical trajectories;
  * every dynamic preset preserves the loop/scan/vmap differential
    equivalence (the dynamics fold into the whole-horizon RAResult before
    either engine runs, so the engines cannot diverge by construction) —
    the tests/test_scan_equivalence.py convention extended to scenarios.

Plus the plumbing: process validation, churn/harvest actually altering
behavior, `apply_dynamics` arithmetic, the `min_dist_m` clamp, the
SweepSpec scenario axis, and scenario-aware figure faceting.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    RoundPolicy,
    WirelessConfig,
    make_clusters,
    sample_channel_gains,
    sample_topology,
    solve_pairs,
)
from repro.core.wireless import compute_energy, compute_time
from repro.data.fl_datasets import make_dataset, partition_imbalanced_iid
from repro.experiments import SweepSpec, facets
from repro.fl import SimConfig, run_many, run_simulation
from repro.fl.sim import _prepare, _scan_group_key
from repro.scenarios import (
    PRESETS,
    ChurnProcess,
    EnergyProcess,
    FadingProcess,
    MobilityProcess,
    Scenario,
    apply_dynamics,
    generate_traces,
    get_scenario,
    register_scenario,
    sample_distances,
    scenario_name,
)

_SMALL = dict(rounds=5, n_devices=8, n_subchannels=3, n_samples=64,
              batch=8, local_steps=2, eval_every=2)


def _cfg(**kw):
    base = dict(_SMALL)
    base.update(kw)
    return SimConfig(**base)


# --------------------------------------------------------------------------
# registry + process validation
# --------------------------------------------------------------------------

def test_registry_presets_resolve_and_reject_unknown():
    assert set(PRESETS) >= {"static", "corr_fading", "mobility", "churn",
                            "harvest", "urban"}
    assert get_scenario("static").name == "static"
    custom = Scenario("custom-x", fading=FadingProcess("ar1", rho=0.5))
    assert get_scenario(custom) is custom      # objects pass through
    with pytest.raises(ValueError, match="unknown scenario"):
        get_scenario("nope")
    assert scenario_name("urban") == "urban" == scenario_name(PRESETS["urban"])


def test_register_scenario_roundtrip():
    scn = Scenario("test-registered", churn=ChurnProcess("markov", p_drop=0.2))
    try:
        register_scenario(scn)
        assert get_scenario("test-registered") is scn
        with pytest.raises(ValueError, match="already registered"):
            register_scenario(Scenario("test-registered"))
    finally:
        PRESETS.pop("test-registered", None)


def test_process_validation():
    with pytest.raises(ValueError):
        FadingProcess("weird")
    with pytest.raises(ValueError):
        FadingProcess("ar1", rho=1.0)          # must be < 1
    with pytest.raises(ValueError):
        MobilityProcess("waypoint", speed_mps=-1.0)
    with pytest.raises(ValueError):
        ChurnProcess("markov", p_drop=1.5)
    with pytest.raises(ValueError):
        ChurnProcess("markov", slowdown_max=0.5)   # speed-ups are forbidden
    with pytest.raises(ValueError):
        EnergyProcess("harvest", mean_frac=0.1, floor_frac=0.2)


def test_min_dist_is_config_not_hardcode():
    with pytest.raises(ValueError, match="min_dist_m"):
        WirelessConfig(min_dist_m=0.0)
    rng = np.random.default_rng(0)
    cfg = WirelessConfig(n_devices=50, radius_m=5.0, min_dist_m=20.0)
    topo = sample_topology(rng, cfg)
    assert (topo.distances_m == 20.0).all()    # clamp floor wins everywhere
    d = sample_distances(np.random.default_rng(0), cfg,
                         MobilityProcess("waypoint", speed_mps=3.0), 30)
    assert (d >= 20.0).all()                   # mobility cannot tunnel below


# --------------------------------------------------------------------------
# the static preset is bit-exact with the legacy inline sampler
# --------------------------------------------------------------------------

def test_static_prepare_replays_legacy_stream_bitwise():
    """`_prepare(scenario='static')` must consume the world rng EXACTLY as
    the pre-scenario code did (topology draw, per-round channel draws,
    permutations — in that order) and its churn/energy traces must consume
    nothing."""
    cfg = _cfg()
    prep = _prepare(cfg)

    rng = np.random.default_rng(cfg.seed)      # legacy draw sequence, by hand
    wcfg = cfg.wireless()
    ds = make_dataset(cfg.dataset, rng, n=cfg.n_samples)
    partition_imbalanced_iid(rng, ds.n, cfg.n_devices)
    topo = sample_topology(rng, wcfg)
    clusters = make_clusters(cfg.n_devices, cfg.n_subchannels, rng)
    fixed_ids = rng.permutation(cfg.n_devices)[: cfg.n_subchannels]
    h2_all = np.stack([sample_channel_gains(rng, wcfg, topo)
                       for _ in range(cfg.rounds)])
    sel = np.stack([rng.permutation(cfg.n_devices) for _ in range(cfg.rounds)])
    asg = np.stack([rng.permutation(cfg.n_subchannels)
                    for _ in range(cfg.rounds)])

    np.testing.assert_array_equal(prep.h2_all, h2_all)
    np.testing.assert_array_equal(prep.clusters, clusters)
    np.testing.assert_array_equal(prep.fixed_ids, fixed_ids)
    np.testing.assert_array_equal(prep.sel_perms, sel)
    np.testing.assert_array_equal(prep.assign_perms, asg)
    np.testing.assert_array_equal(prep.distances,
                                  np.broadcast_to(topo.distances_m,
                                                  (cfg.rounds, cfg.n_devices)))
    assert prep.avail.all() and (prep.slowdown == 1.0).all()
    assert (prep.emax_all == wcfg.e_max_j).all()


def test_static_preset_identical_across_engines_and_vmap():
    """scenario='static' trajectories: loop == scan == vmapped run_many,
    bit-identical tx/AoU (the acceptance differential)."""
    cfgs = [_cfg(seed=s, scenario="static") for s in (0, 1)]
    loop = run_many(cfgs, engine="loop")
    solo = [run_simulation(c, engine="scan") for c in cfgs]
    vmapped = run_many(cfgs, engine="scan")
    for l, s, v in zip(loop, solo, vmapped):
        np.testing.assert_array_equal(l.tx_trace, s.tx_trace)
        np.testing.assert_array_equal(l.tx_trace, v.tx_trace)
        np.testing.assert_array_equal(l.age_trace, v.age_trace)
        np.testing.assert_allclose(l.latency_all, v.latency_all,
                                   rtol=1e-5, atol=1e-7)


# --------------------------------------------------------------------------
# dynamic scenarios: engine equivalence + the dynamics actually bite
# --------------------------------------------------------------------------

@pytest.mark.parametrize("preset", ["corr_fading", "mobility", "churn",
                                    "harvest", "urban"])
def test_dynamic_presets_loop_scan_equivalent(preset):
    cfg = _cfg(scenario=preset)
    a = run_simulation(cfg, engine="loop")
    b = run_simulation(cfg, engine="scan")
    np.testing.assert_array_equal(a.tx_trace, b.tx_trace)
    np.testing.assert_array_equal(a.age_trace, b.age_trace)
    np.testing.assert_allclose(a.latency_all, b.latency_all,
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(a.energy_all, b.energy_all,
                               rtol=1e-5, atol=1e-9)


@pytest.mark.slow
def test_dynamic_scenario_vmap_matches_solo():
    cfgs = [_cfg(seed=s, scenario="urban") for s in (0, 1, 2)]
    vmapped = run_many(cfgs, engine="scan")
    for c, v in zip(cfgs, vmapped):
        s = run_simulation(c, engine="scan")
        np.testing.assert_array_equal(v.tx_trace, s.tx_trace)
        np.testing.assert_allclose(v.global_loss, s.global_loss, rtol=1e-4)


def test_churn_knocks_out_devices_and_harvest_tightens_budgets():
    base = _cfg(rounds=8)
    harsh = Scenario("harsh-churn",
                     churn=ChurnProcess("markov", p_drop=0.6, p_join=0.2))
    tight = Scenario("tight-energy",
                     energy=EnergyProcess("harvest", mean_frac=0.25,
                                          floor_frac=0.01))
    h_static = run_simulation(base)
    h_churn = run_simulation(dataclasses.replace(base, scenario=harsh))
    h_tight = run_simulation(dataclasses.replace(base, scenario=tight))
    assert h_churn.tx_trace.sum() < h_static.tx_trace.sum()
    # An unavailable device never transmits even if its channel is great.
    prep = _prepare(dataclasses.replace(base, scenario=harsh))
    assert not h_churn.tx_trace[~prep.avail].any()
    # Tighter harvested budgets strictly reduce Prop-1 feasibility odds;
    # with mean 25% of Table-I E^max some rounds must lose transmitters.
    assert h_tight.tx_trace.sum() < h_static.tx_trace.sum()


def test_apply_dynamics_arithmetic_and_identity():
    rng = np.random.default_rng(0)
    cfg = WirelessConfig(n_devices=6, n_subchannels=2)
    topo = sample_topology(rng, cfg)
    h2 = np.stack([sample_channel_gains(rng, cfg, topo) for _ in range(3)])
    beta = rng.integers(5, 40, 6).astype(float)
    ra = solve_pairs(beta[None, None], h2, cfg)

    # churn-free: the IDENTITY, not a numeric round-trip
    ones_a = np.ones((3, 6), bool)
    ones_s = np.ones((3, 6))
    assert apply_dynamics(ra, ones_a, ones_s, beta, cfg) is ra

    avail = ones_a.copy(); avail[1, 2] = False
    slow = ones_s.copy(); slow[0, :] = 2.5
    ra2 = apply_dynamics(ra, avail, slow, beta, cfg)
    # availability: all of the dropped device's pairs become infeasible
    assert not ra2.feasible[1, :, 2].any()
    assert np.isinf(ra2.time_s[1, :, 2]).all()
    # slowdown s: T' - T = (s-1) T^cp(tau*), E' - E = (1/s^2 - 1) E^cp(tau*)
    m = ra2.feasible[0]
    bb = np.broadcast_to(beta, ra.tau[0].shape)
    t_cp = compute_time(ra.tau[0], bb, cfg)
    e_cp = compute_energy(ra.tau[0], bb, cfg)
    np.testing.assert_allclose(ra2.time_s[0][m] - ra.time_s[0][m],
                               1.5 * t_cp[m], rtol=1e-12)
    np.testing.assert_allclose(ra2.energy_j[0][m] - ra.energy_j[0][m],
                               (1 / 2.5**2 - 1) * e_cp[m], rtol=1e-12)
    # DVFS at a slower clock only FREES budget — feasibility stays valid
    assert (ra2.energy_j[0][m] <= ra.energy_j[0][m] + 1e-15).all()
    # untouched rounds pass through numerically unchanged
    np.testing.assert_array_equal(ra2.time_s[2], ra.time_s[2])


def test_generate_traces_deterministic_and_shaped():
    cfg = WirelessConfig(n_devices=10, n_subchannels=3)
    a = generate_traces(7, cfg, "urban", 20)
    b = generate_traces(np.random.default_rng(7), cfg, "urban", 20)
    np.testing.assert_array_equal(a.h2_all, b.h2_all)
    np.testing.assert_array_equal(a.avail, b.avail)
    np.testing.assert_array_equal(a.e_max_j, b.e_max_j)
    assert a.h2_all.shape == (20, 3, 10)
    assert a.distances_m.shape == a.avail.shape == (20, 10)
    assert (a.h2_all > 0).all() and (a.slowdown >= 1.0).all()
    # waypoint walkers stay on the disc, move at most one step per round
    step = PRESETS["urban"].mobility.speed_mps * PRESETS["urban"].mobility.round_s
    assert (a.distances_m <= cfg.radius_m + 1e-9).all()
    assert (np.abs(np.diff(a.distances_m, axis=0)) <= step + 1e-9).all()


# --------------------------------------------------------------------------
# sweep harness: the scenario axis
# --------------------------------------------------------------------------

def test_spec_scenario_axis_ids_and_grouping():
    spec = SweepSpec(name="t", datasets="mnist", ds=("alg3", "random"),
                     scenarios=("static", "corr_fading"), seeds=(0, 1),
                     rounds=4, n_devices=8, n_subchannels=3,
                     overrides={"n_samples": 32})
    cells = spec.cells()
    assert spec.n_cells == len(cells) == 8
    # static cells keep the PRE-scenario id format (committed artifacts
    # from earlier PRs remain addressable); others gain a scenario segment
    assert cells[0].cell_id == "mnist-N8-K3-alg3.mo.matching-s0"
    assert cells[4].cell_id == "mnist-N8-K3-corr_fading-alg3.mo.matching-s0"
    assert len({c.cell_id for c in cells}) == 8
    assert {c.config.scenario for c in cells} == {"static", "corr_fading"}
    # the whole policy x scenario x seed grid is ONE compiled program
    assert len({_scan_group_key(c.config) for c in cells}) == 1
    # round-trips through JSON with the scenario axis intact
    assert SweepSpec.from_json(spec.to_json()) == spec


def test_scenario_grid_cells_bit_identical_to_solo():
    """A policy x scenario grid through grouped run_many == solo
    run_simulation per cell — exercising the shared-dataset-phase cache
    (`_prepare`'s rng branch-point replay) and the grouped dispatch."""
    cfgs = [_cfg(rounds=4, policy=RoundPolicy(ds=d), scenario=sc, seed=s)
            for sc in ("static", "corr_fading")
            for d in ("alg3", "random") for s in (0,)]
    grid = run_many(cfgs, engine="scan")
    for c, g in zip(cfgs, grid):
        solo = run_simulation(c, engine="scan")
        np.testing.assert_array_equal(g.tx_trace, solo.tx_trace)
        np.testing.assert_array_equal(g.age_trace, solo.age_trace)
        np.testing.assert_array_equal(g.global_loss, solo.global_loss)


def test_spec_rejects_bad_scenarios():
    with pytest.raises(ValueError, match="unknown scenario"):
        SweepSpec(name="t", scenarios=("static", "wat"))
    with pytest.raises(ValueError):           # scenario is an axis, not an
        SweepSpec(name="t", overrides={"scenario": "urban"})   # override


def test_facets_split_on_scenario_and_default_old_records_to_static():
    def cell(sc=None, ds="alg3"):
        c = {"dataset": "mnist", "n_devices": 8, "n_subchannels": 3,
             "policy": {"ds": ds, "ra": "mo", "sa": "matching"}}
        if sc is not None:
            c["scenario"] = sc
        return c

    rec = {"cells": [cell("static"), cell("urban"), cell(None, ds="random")]}
    fs = facets(rec)
    assert sorted(f.scenario for f in fs) == ["static", "urban"]
    by_sc = {f.scenario: f for f in fs}
    # the scenario-less legacy cell facets together with "static"
    assert by_sc["static"].matches(cell(None, ds="random"))
    assert not by_sc["urban"].matches(cell("static"))
    assert by_sc["urban"].suffix == "mnist-urban"
