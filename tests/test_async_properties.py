"""Property tests for the buffered async server (hypothesis-guarded,
following the tests/test_scenario_properties.py convention: each @given
test skips individually without hypothesis, via the tests/_hyp.py shim).

Pins the protocol contracts documented in src/repro/fl/async_loop.py and
src/repro/fl/server.py:

  * staleness weights: f(0) == 1.0 EXACTLY (the bit-exact sync anchor),
    f in (0, 1], non-increasing in staleness, constant preset == 1.0;
  * commit weights normalize to 1 inside the weighted mean whenever
    anything commits (`masked_weighted_mean` divides by the mass);
  * `commit_event`: commits only in-flight devices, at most K per event,
    never negative latency; a buffer >= the in-flight count commits
    everything at the max remaining time (the sync barrier);
  * virtual clocks: for ANY dispatch pattern and clock trace the server
    time is non-decreasing, an upload never commits before its full
    Γ-time has elapsed, and the device-indexed event buffer (one slot
    per device) cannot overflow.

The check bodies live in module-level `_check_*` helpers so they can be
driven without hypothesis too (see the deterministic tests at the end,
which run a small pinned corpus through the same helpers).
"""
import numpy as np
import pytest

from _hyp import given, settings, st  # per-test skip without hypothesis

from repro.fl import (
    AGGREGATION_PRESETS,
    AsyncAggregation,
    aggregate_buffered,
    get_aggregation,
    masked_weighted_mean,
    staleness_weight,
)
from repro.fl.async_loop import commit_event


# ---------------------------------------------------------------------------
# check bodies (hypothesis-independent)
# ---------------------------------------------------------------------------

def _check_staleness_weight(stales, exponent):
    import jax.numpy as jnp

    s = jnp.asarray(stales, jnp.int32)
    w = np.asarray(staleness_weight(s, jnp.float32(exponent)))
    assert w.dtype == np.float32
    assert (w[np.asarray(stales) == 0] == 1.0).all()      # EXACT sync anchor
    assert ((w > 0) & (w <= 1.0)).all()
    order = np.argsort(stales)
    assert (np.diff(w[order]) <= 1e-7).all()              # non-increasing
    if exponent == 0.0:                                   # "const" preset
        assert (w == 1.0).all()


def _check_weight_normalization(weights):
    import jax.numpy as jnp

    w = jnp.asarray(weights, jnp.float32)
    ones = jnp.ones((len(weights), 1), jnp.float32)
    mean = float(masked_weighted_mean(ones, w)[0])
    wsum = float(w.sum())
    if wsum >= 1e-28:
        assert abs(mean - 1.0) < 1e-5    # weights normalize to 1
    elif wsum == 0.0:
        assert mean == 0.0               # zero mass contributes nothing
    else:
        # Sub-guard mass (< the 1e-30 zero-division guard): the mean
        # shrinks toward 0 instead of amplifying noise.
        assert 0.0 <= mean <= 1.0 + 1e-5


def _check_commit_event(rem, active, buffer, k):
    import jax.numpy as jnp

    rem = jnp.asarray(rem, jnp.float32)
    active_j = jnp.asarray(active)
    delta, commit = commit_event(rem, active_j, jnp.int32(buffer), k)
    delta = float(delta)
    commit = np.asarray(commit)
    active = np.asarray(active)
    assert delta >= 0.0
    assert not (commit & ~active).any()          # commits only in flight
    assert commit.sum() <= k                     # server drains <= K/event
    if not active.any():
        assert delta == 0.0 and not commit.any()
        return
    rem_np = np.asarray(rem)
    if buffer >= active.sum():
        # Full buffer == the sync barrier: everything commits at max rem.
        assert delta == rem_np[active].max()
        assert (commit == active).all() or active.sum() > k
    # Every commit had arrived by the commit time; every arrival beyond
    # the K cap stays pending.
    assert (rem_np[commit] <= delta).all()
    uncommitted_arrived = active & ~commit & (rem_np <= delta)
    assert uncommitted_arrived.sum() == 0 or commit.sum() == k


def _check_virtual_clocks(n, k, buffer, dispatch_wants, upload_times):
    """Run an arbitrary dispatch/clock schedule through `commit_event`
    and verify the event-timeline invariants."""
    import jax.numpy as jnp

    rem = jnp.zeros(n, jnp.float32)
    active = np.zeros(n, bool)
    started = np.full(n, np.nan)
    t_len = np.full(n, np.nan)
    t_now = 0.0
    for want, times in zip(dispatch_wants, upload_times):
        # The engine gates dispatch on free-ness and has <= min(K, N)
        # transmit slots; mimic both.
        req = np.asarray(want) & ~active
        ids = np.where(req)[0][: min(k, n)]
        dispatch = np.zeros(n, bool)
        dispatch[ids] = True
        # One slot per device: a dispatch can never land on an occupied
        # slot, so the buffer structurally cannot overflow.
        assert not (dispatch & active).any()
        active |= dispatch
        assert active.sum() <= n
        started[dispatch] = t_now
        t_len[dispatch] = np.asarray(times)[dispatch]
        rem = jnp.where(jnp.asarray(dispatch),
                        jnp.asarray(times, jnp.float32), rem)
        delta, commit = commit_event(rem, jnp.asarray(active),
                                     jnp.int32(buffer), k)
        delta = float(delta)
        commit = np.asarray(commit)
        assert delta >= 0.0                       # server clock monotone
        t_now += delta
        # An upload never commits before its full Γ-time has elapsed
        # (tolerance: float32 remaining-time decrements).
        for i in np.where(commit)[0]:
            assert t_now - started[i] >= t_len[i] - 1e-3 * (1.0 + t_len[i])
        active &= ~commit
        rem = jnp.where(jnp.asarray(active), rem - delta, jnp.float32(0.0))
    return t_now


# ---------------------------------------------------------------------------
# hypothesis drivers
# ---------------------------------------------------------------------------

@given(st.lists(st.integers(0, 500), min_size=1, max_size=32),
       st.floats(0.0, 4.0))
def test_staleness_weight_properties(stales, exponent):
    _check_staleness_weight(stales, exponent)


@given(st.lists(st.floats(0.0, 100.0), min_size=1, max_size=16))
def test_commit_weights_normalize_to_one(weights):
    _check_weight_normalization(weights)


@given(st.data())
@settings(max_examples=50, deadline=None)
def test_commit_event_protocol(data):
    n = data.draw(st.integers(1, 12))
    k = data.draw(st.integers(1, 6))
    buffer = data.draw(st.integers(1, n + 3))
    rem = data.draw(st.lists(st.floats(0.001, 50.0), min_size=n, max_size=n))
    active = data.draw(st.lists(st.booleans(), min_size=n, max_size=n))
    _check_commit_event(rem, active, buffer, k)


@given(st.data())
@settings(max_examples=25, deadline=None)
def test_virtual_clocks_for_any_trace(data):
    n = data.draw(st.integers(2, 10))
    k = data.draw(st.integers(1, 4))
    buffer = data.draw(st.integers(1, n))
    rounds = data.draw(st.integers(1, 10))
    wants = data.draw(st.lists(
        st.lists(st.booleans(), min_size=n, max_size=n),
        min_size=rounds, max_size=rounds))
    times = data.draw(st.lists(
        st.lists(st.floats(0.01, 8.0), min_size=n, max_size=n),
        min_size=rounds, max_size=rounds))
    _check_virtual_clocks(n, k, buffer, wants, times)


# ---------------------------------------------------------------------------
# deterministic pinned corpus (runs with or without hypothesis)
# ---------------------------------------------------------------------------

def test_pinned_corpus_through_check_bodies(rng):
    """A small seeded corpus through the same helpers, so the protocol
    contracts stay exercised on boxes without hypothesis."""
    _check_staleness_weight([0, 1, 2, 5, 100], 0.5)
    _check_staleness_weight([0, 3, 7], 0.0)
    _check_weight_normalization([0.0, 2.5, 40.0])
    _check_weight_normalization([0.0, 0.0])
    for _ in range(25):
        n = int(rng.integers(1, 12))
        k = int(rng.integers(1, 6))
        _check_commit_event(rng.uniform(0.01, 50.0, n),
                            rng.random(n) < 0.6,
                            int(rng.integers(1, n + 3)), k)
    for _ in range(5):
        n, k = int(rng.integers(2, 10)), int(rng.integers(1, 4))
        rounds = int(rng.integers(1, 10))
        _check_virtual_clocks(
            n, k, int(rng.integers(1, n)),
            [rng.random(n) < 0.5 for _ in range(rounds)],
            [rng.uniform(0.01, 8.0, n) for _ in range(rounds)])


def test_staleness_zero_is_exactly_one():
    import jax.numpy as jnp

    w = staleness_weight(jnp.zeros(4, jnp.int32), jnp.float32(0.7))
    assert (np.asarray(w) == 1.0).all()


def test_aggregation_spec_resolution():
    assert get_aggregation("sync") is None
    assert get_aggregation("async") == AsyncAggregation()
    assert get_aggregation("async_const").stale_exponent() == 0.0
    assert get_aggregation("async_full").resolve_buffer(20, 4) == 20
    assert AsyncAggregation().resolve_buffer(20, 4) == 2       # K // 2
    assert AsyncAggregation().resolve_buffer(20, 1) == 1       # floor 1
    assert AsyncAggregation(buffer=3).resolve_buffer(20, 4) == 3
    for b in (4, 7):                  # >= K silently means "sync barrier"
        with pytest.raises(ValueError):
            AsyncAggregation(buffer=b).resolve_buffer(20, 4)
    assert AsyncAggregation(buffer=1).resolve_buffer(20, 1) == 1  # K=1 exempt
    spec = get_aggregation(AsyncAggregation(buffer=3))
    assert spec is not None and spec.buffer == 3
    assert set(AGGREGATION_PRESETS) == {"async", "async_const", "async_full"}
    with pytest.raises(ValueError):
        get_aggregation("nope")


def test_aggregate_buffered_endpoints():
    """server_lr == 1 must be bitwise eq.-34; an empty commit must be
    bitwise identity; intermediate step sizes land strictly between."""
    import jax.numpy as jnp

    from repro.fl import aggregate

    rng = np.random.default_rng(3)
    g = {"w": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)}
    c = {"w": jnp.asarray(rng.normal(size=(5, 4, 3)), jnp.float32)}
    w = jnp.asarray([1.0, 0.0, 2.0, 0.5, 0.0], jnp.float32)
    sync = aggregate(g, c, w)
    full_step = aggregate_buffered(g, c, w, jnp.float32(1.0))
    np.testing.assert_array_equal(np.asarray(sync["w"]),
                                  np.asarray(full_step["w"]))
    nothing_committed = aggregate_buffered(g, c, jnp.zeros(5, jnp.float32),
                                           jnp.float32(1.0))
    np.testing.assert_array_equal(np.asarray(g["w"]),
                                  np.asarray(nothing_committed["w"]))
    # Strictly between the endpoints the commit moves the model partway.
    mixed = aggregate_buffered(g, c, w, jnp.float32(0.4))
    assert not np.array_equal(np.asarray(mixed["w"]), np.asarray(g["w"]))
    assert not np.array_equal(np.asarray(mixed["w"]), np.asarray(sync["w"]))
    with pytest.raises(ValueError):
        AsyncAggregation(server_lr=0.0)
    with pytest.raises(ValueError):
        AsyncAggregation(server_lr=1.5)
