"""AoU state machine (eq. 6-7) + Algorithm 3 device selection."""
import numpy as np
import pytest
from _hyp import given, settings, st  # per-test skip without hypothesis

from repro.core import (
    init_aou,
    priority_list,
    select_aou_alg3,
    select_topk,
    step_aou,
)


@given(
    n=st.integers(2, 30),
    rounds=st.integers(1, 20),
    seed=st.integers(0, 9999),
)
def test_aou_invariants(n, rounds, seed):
    """Ages >= 1; transmitted resets to 1; skipped increments by exactly 1;
    age never exceeds rounds since last transmission + 1."""
    rng = np.random.default_rng(seed)
    st_ = init_aou(n)
    last_tx = np.full(n, -1)
    for t in range(rounds):
        tx = rng.uniform(size=n) < 0.3
        st_ = step_aou(st_, tx)
        last_tx[tx] = t
        # age = rounds since last transmission + 1 (never-transmitted: t+2
        # because the initial age already was 1 before round 0).
        expect = np.where(last_tx >= 0, t - last_tx + 1, t + 2)
        np.testing.assert_array_equal(st_.age, expect)
        assert np.all(st_.age >= 1)
        w = st_.weights
        assert abs(w.sum() - 1.0) < 1e-12
        assert np.all(w > 0)


def test_weights_prioritize_stale():
    st_ = init_aou(3)
    st_ = step_aou(st_, np.array([True, False, False]))   # ages 1,2,2
    st_ = step_aou(st_, np.array([True, False, True]))    # ages 1,3,1
    assert st_.age.tolist() == [1, 3, 1]
    assert np.argmax(st_.weights) == 1


@given(seed=st.integers(0, 9999))
def test_priority_list_is_sorted(seed):
    rng = np.random.default_rng(seed)
    alpha = rng.uniform(size=12)
    beta = rng.integers(1, 100, 12).astype(float)
    order = priority_list(alpha, beta)
    prio = alpha * beta
    assert np.all(np.diff(prio[order]) <= 1e-12)


def _instance(rng, k=4, n=12, frac_bad=0.5):
    gamma = rng.exponential(size=(k, n)) * 5
    feas = rng.uniform(size=(k, n)) > frac_bad
    alpha = rng.uniform(0.01, 1, n)
    beta = rng.integers(1, 100, n).astype(float)
    return alpha, beta, gamma, feas


@given(seed=st.integers(0, 9999))
@settings(max_examples=30)
def test_alg3_no_worse_participation_than_topk(seed):
    """Algorithm 3's replacement loop can only increase the number of
    transmitting devices vs. plain top-K (the paper's Fig. 7 mechanism)."""
    rng = np.random.default_rng(seed)
    alpha, beta, gamma, feas = _instance(rng)
    a3 = select_aou_alg3(alpha, beta, gamma, feas, np.random.default_rng(0))
    tk = select_topk(alpha, beta, gamma, feas, np.random.default_rng(0))
    assert a3.transmitted.sum() >= tk.transmitted.sum()
    assert a3.selected.sum() <= gamma.shape[0]


@given(seed=st.integers(0, 9999))
def test_selection_consistency(seed):
    """Transmitted implies selected + assigned; channels are exclusive."""
    rng = np.random.default_rng(seed)
    alpha, beta, gamma, feas = _instance(rng)
    out = select_aou_alg3(alpha, beta, gamma, feas, np.random.default_rng(1))
    assert np.all(out.selected[out.transmitted])
    ch = out.channel_of[out.transmitted]
    assert np.all(ch >= 0)
    assert len(set(ch.tolist())) == len(ch)  # one device per sub-channel
    # transmitted devices sit on Prop-1-feasible pairs
    ids = np.where(out.transmitted)[0]
    assert np.all(feas[out.channel_of[ids], ids])


def test_alg3_replaces_infeasible_with_next_priority():
    """Deterministic scenario: top device has no feasible channel and must
    be replaced by the next one in the priority list."""
    alpha = np.array([1.0, 0.5, 0.4, 0.3])
    beta = np.ones(4)
    gamma = np.ones((2, 4))
    feas = np.array([[False, True, True, True],
                     [False, True, True, True]])
    out = select_aou_alg3(alpha, beta, gamma, feas, np.random.default_rng(0))
    assert not out.transmitted[0]
    assert out.transmitted[[1, 2]].all()
