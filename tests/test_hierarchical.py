"""Multi-cell (hierarchical) harness: engine matrix + trace contract.

Separate from test_fl_substrate.py so it runs even without hypothesis
(that module importorskips itself away).  Pins the DESIGN.md §10 claim:
the fused multi-cell scan engine replays the host loop's per-cell
transmitted sets, losses, and latencies for every policy family.
"""
import numpy as np
import pytest

from repro.core import RoundPolicy
from repro.fl import HierSimConfig, run_hierarchical


def test_hierarchical_output_contract():
    cfg = HierSimConfig(rounds=5, n_samples=150, n_cells=2,
                        devices_per_cell=8, subchannels_per_cell=3)
    out = run_hierarchical(cfg)
    assert out["loss"].shape == (5,)
    assert out["latency"].shape == (5,)
    assert out["tx"].shape == (5, 2, 8)
    assert np.isfinite(out["loss"]).all()
    assert (out["latency"] >= 0).all()
    assert out["wall_s"] > 0


def test_hierarchical_rejects_unknown_engine():
    with pytest.raises(ValueError):
        run_hierarchical(HierSimConfig(rounds=1), engine="warp")


@pytest.mark.slow
def test_hierarchical_engine_equivalence():
    """scan == loop: same per-cell transmitted sets, same losses/latencies,
    across the proposed and benchmark policy families."""
    for policy in (RoundPolicy(), RoundPolicy(ds="random", ra="fix"),
                   RoundPolicy(ds="cluster", sa="random")):
        cfg = HierSimConfig(rounds=4, n_samples=150, policy=policy)
        a = run_hierarchical(cfg, engine="loop")
        b = run_hierarchical(cfg, engine="scan")
        assert np.array_equal(a["tx"], b["tx"]), policy.label
        np.testing.assert_allclose(a["loss"], b["loss"], rtol=2e-5)
        np.testing.assert_allclose(a["latency"], b["latency"], rtol=2e-5)


@pytest.mark.slow
def test_hierarchical_scan_three_cells_converges():
    out = run_hierarchical(
        HierSimConfig(rounds=10, n_samples=200, n_cells=3), engine="scan")
    assert out["loss"][-1] < out["loss"][0]
