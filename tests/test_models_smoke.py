"""Deliverable (f): per-architecture smoke tests — a REDUCED variant of each
assigned family (2 layers, d_model <= 512, <= 4 experts) runs one forward +
one train step on CPU; output shapes asserted, no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import ShardCtx, forward, init_params, lm_loss, param_count
from repro.train.optimizer import make_optimizer
from repro.train.train_step import make_train_step

# Whole-module: one train step per architecture is the long tail of tier-1.
pytestmark = pytest.mark.slow

ALL = sorted(ARCHS)


def _batch(cfg, b=2, s=32, key=None):
    key = jax.random.PRNGKey(7) if key is None else key
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab),
        "fl_weights": jnp.ones((b,), jnp.float32),
    }
    if cfg.family == "vlm":
        batch["image_embeds"] = 0.02 * jax.random.normal(
            key, (b, cfg.n_patches, cfg.d_model), jnp.bfloat16)
        batch["mrope_pos"] = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None, :, None], (b, s, 3))
    if cfg.family == "audio":
        batch["enc_frames"] = 0.02 * jax.random.normal(
            key, (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ALL)
def test_reduced_constraints(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers <= 8
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4
    assert cfg.family == get_config(arch).family


@pytest.mark.parametrize("arch", ALL)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 32
    out = forward(cfg, params, _batch(cfg, b, s), mode="train")
    logits = out[0]
    assert logits.shape == (b, s, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    if cfg.mtp:
        assert out[2].shape == (b, s, cfg.vocab)


@pytest.mark.parametrize("arch", ALL)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = make_optimizer("adamw", 1e-3)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt, ShardCtx(), remat=False))
    batch = _batch(cfg)
    p2, _, metrics = step(params, opt_state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params actually changed
    moved = any(
        float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()) > 0
        for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(p2))
    )
    assert moved
    # second step decreases loss on the same batch (sanity of gradients)
    _, _, m2 = step(p2, opt.init(p2), batch)
    assert float(m2["loss"]) < loss


def test_fl_weights_change_gradients():
    """The eq.-(34) weighting is live: different cohort weights => different
    loss/grads."""
    cfg = get_config("yi-6b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, b=4)
    l1, _ = lm_loss(cfg, params, batch)
    batch2 = dict(batch, fl_weights=jnp.asarray([1.0, 0.0, 0.0, 0.0]))
    l2, _ = lm_loss(cfg, params, batch2)
    assert abs(float(l1) - float(l2)) > 1e-6


def test_zero_weights_guarded():
    cfg = get_config("yi-6b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, b=2)
    batch["fl_weights"] = jnp.zeros((2,), jnp.float32)
    loss, _ = lm_loss(cfg, params, batch)
    assert bool(jnp.isfinite(loss))


def test_param_counts_nontrivial():
    for arch in ALL:
        cfg = get_config(arch).reduced()
        n = param_count(init_params(cfg, jax.random.PRNGKey(0)))
        assert n > 1e5, arch
