"""Property suite for the two-tier async hierarchy (`fl.hier_async`).

Hypothesis drives the PURE pieces the engine is assembled from —
staleness weighting, the shared `commit_event` rule reused at both tiers
(device-indexed at the cell tier, cell-indexed at the global tier), and
the virtual-clock recursion — over adversarial inputs; deterministic
tests then pin the same invariants on the real engine's recorded traces
under the churn scenario, and on the coupled cross-cell fading process.

Imports `given`/`st` via the `_hyp` shim: without hypothesis only the
`@given` tests skip (each with a reason), the deterministic ones run.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st

from repro.core import WirelessConfig
from repro.fl.async_loop import commit_event
from repro.fl.hierarchical import HierSimConfig, run_hier_many
from repro.fl.server import AsyncAggregation, staleness_weight
from repro.scenarios import FadingProcess, sample_coupled_fading, \
    sample_fading

# --------------------------------------------------------------------------
# staleness weights: exact fresh-commit identity + normalization
# --------------------------------------------------------------------------

EXPONENTS = st.floats(min_value=0.0, max_value=4.0,
                      allow_nan=False, allow_infinity=False)


@settings(max_examples=100, deadline=None)
@given(exponent=EXPONENTS)
def test_staleness_fresh_commit_weight_exactly_one(exponent):
    """f(0) == 1.0 EXACTLY for every exponent — both tiers rely on this
    for the bit-exact sync limit (a fresh commit's eq.-34 weight must be
    beta * 1.0 == beta, no rounding)."""
    w = staleness_weight(jnp.int32(0), jnp.float32(exponent))
    assert float(w) == 1.0
    # ... and clamped below zero staleness too (never-dispatched slots).
    assert float(staleness_weight(jnp.int32(-3), jnp.float32(exponent))) == 1.0


@settings(max_examples=100, deadline=None)
@given(stale=st.lists(st.integers(min_value=0, max_value=10_000),
                      min_size=1, max_size=32),
       exponent=EXPONENTS)
def test_staleness_weights_normalized_and_monotone(stale, exponent):
    """Two-tier staleness weights live in (0, 1] and never increase with
    staleness: w(s) = (1+s)^-a."""
    s = jnp.asarray(sorted(stale), jnp.int32)
    w = np.asarray(staleness_weight(s, jnp.float32(exponent)), np.float64)
    assert ((w > 0.0) & (w <= 1.0)).all()
    assert (np.diff(w) <= 1e-12).all()


# --------------------------------------------------------------------------
# the shared commit rule, exercised at the GLOBAL tier's shapes:
# rem/active are cell-indexed (C,), buffer bounded by the cell count
# --------------------------------------------------------------------------

@settings(max_examples=200, deadline=None)
@given(data=st.data(),
       n_cells=st.integers(min_value=1, max_value=12))
def test_global_commit_bounded_by_cell_count_buffer(data, n_cells):
    """Global-tier commit events never exceed the cell-count buffer
    bound (ties at the commit horizon may overshoot `buffer`, but never
    the C slots — exactly the tie-commit behavior the uniform-clock sync
    limit relies on), commit only in-flight cells, and the event latency
    is the exact remaining time of some in-flight cell (or 0 when the
    sky is empty)."""
    rem = np.asarray(data.draw(st.lists(
        st.floats(min_value=1e-3, max_value=1e3, allow_nan=False,
                  allow_infinity=False, width=32),
        min_size=n_cells, max_size=n_cells)), np.float32)
    active = np.asarray(data.draw(st.lists(
        st.booleans(), min_size=n_cells, max_size=n_cells)))
    buffer = data.draw(st.integers(min_value=1, max_value=n_cells))
    delta, commit = commit_event(jnp.asarray(rem), jnp.asarray(active),
                                 jnp.int32(buffer), n_cells)
    delta, commit = float(delta), np.asarray(commit)
    assert commit.sum() <= min(n_cells, active.sum())
    assert (commit <= active).all()
    assert delta >= 0.0
    if active.any():
        assert commit.sum() >= 1          # something always commits
        assert delta in rem[active].astype(np.float64).tolist()
        # everything that arrived by the commit horizon commits (up to
        # the k-slot rank cap the engine enforces with k == n_cells)
        arrived = active & (rem <= np.float32(delta))
        assert commit.sum() == min(arrived.sum(), n_cells)
    else:
        assert commit.sum() == 0 and delta == 0.0


@settings(max_examples=100, deadline=None)
@given(data=st.data())
def test_virtual_clocks_non_decreasing_any_trace(data):
    """The per-cell virtual-clock recursion rem' = rem - delta keeps
    every in-flight remainder non-negative and the committed-time axis
    cumsum(delta) non-decreasing, for ANY dispatch/active pattern —
    churn and slowdowns only change the dispatched times, never the
    recursion."""
    n = data.draw(st.integers(min_value=1, max_value=8))
    events = data.draw(st.integers(min_value=1, max_value=20))
    rem = np.zeros(n, np.float32)
    active = np.zeros(n, bool)
    clock = 0.0
    for _ in range(events):
        free = ~active
        dispatch = np.asarray(data.draw(st.lists(
            st.booleans(), min_size=n, max_size=n))) & free
        times = np.asarray(data.draw(st.lists(
            st.floats(min_value=1e-3, max_value=1e3, allow_nan=False,
                      allow_infinity=False, width=32),
            min_size=n, max_size=n)), np.float32)
        active = active | dispatch
        rem = np.where(dispatch, times, rem)
        buffer = data.draw(st.integers(min_value=1, max_value=n))
        delta, commit = commit_event(jnp.asarray(rem), jnp.asarray(active),
                                     jnp.int32(buffer), n)
        delta, commit = np.float32(delta), np.asarray(commit)
        assert delta >= 0.0               # the clock never runs backward
        clock_next = clock + float(delta)
        assert clock_next >= clock
        clock = clock_next
        active = active & ~commit
        rem = np.where(active, rem - delta, np.float32(0.0))
        assert (rem >= 0.0).all()         # no in-flight upload overshoots


# --------------------------------------------------------------------------
# deterministic: the real engine's traces satisfy the same invariants
# --------------------------------------------------------------------------

_CFG = dict(dataset="mnist", rounds=8, n_cells=3, devices_per_cell=6,
            subchannels_per_cell=2, n_samples=96, batch=16, local_steps=2,
            eval_every=2, scenario="churn")


@pytest.fixture(scope="module")
def churn_hist():
    cfg = HierSimConfig(**_CFG, aggregation=AsyncAggregation(buffer=1),
                        global_aggregation=AsyncAggregation(buffer=1))
    return run_hier_many([cfg])[0]


def test_engine_commit_bounds_under_churn(churn_hist):
    h = churn_hist
    c_n = _CFG["n_cells"]
    assert (h.async_trace["cell_committed"].sum(axis=1) <= c_n).all()
    assert (h.async_trace["g_pending"] <= c_n).all()
    assert not h.async_trace["overflow"].any()
    # commits only ever devices with an uncommitted dispatch
    n = c_n * _CFG["devices_per_cell"]
    in_flight = np.zeros(n, bool)
    for e in range(_CFG["rounds"]):
        in_flight |= h.tx_trace[e]
        assert (h.commit_trace[e] <= in_flight).all(), e
        in_flight &= ~h.commit_trace[e]


def test_engine_clocks_non_decreasing_under_churn(churn_hist):
    h = churn_hist
    assert (h.latency_all >= 0).all()
    assert (np.diff(np.cumsum(h.latency_all)) >= 0).all()
    assert (h.async_trace["latency_cells"] >= 0).all()
    assert (h.age_trace >= 1).all()


# --------------------------------------------------------------------------
# coupled cross-cell fading: marginals survive the mixture
# --------------------------------------------------------------------------

_WCFG = WirelessConfig(n_devices=24, n_subchannels=4)


def test_coupled_fading_zero_coupling_bitwise_uncoupled():
    """coupling=0 must consume the rng stream exactly as C independent
    per-cell draws — the anchor that keeps C=1 hierarchies on the flat
    world stream."""
    proc = FadingProcess(kind="ar1", rho=0.8)
    a = sample_coupled_fading(np.random.default_rng(7), _WCFG, proc,
                              rounds=20, n_cells=3, coupling=0.0)
    rng = np.random.default_rng(7)
    b = np.stack([sample_fading(rng, _WCFG, proc, 20) for _ in range(3)])
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("kind,rho", [("iid", 0.0), ("ar1", 0.6),
                                      ("ar1", 0.95)])
@pytest.mark.parametrize("coupling", [0.25, 0.7, 1.0])
def test_coupled_fading_preserves_exp1_marginals(kind, rho, coupling):
    """The cross-cell mixture sqrt(c)*shared + sqrt(1-c)*local of two
    independent CN(0,1) AR(1) streams with the same rho is again CN(0,1)
    AR(1), so per-cell power gains keep Exp(1) marginals (mean 1, var 1)
    at ANY coupling."""
    proc = FadingProcess(kind=kind, rho=rho)
    g2 = sample_coupled_fading(np.random.default_rng(11), _WCFG, proc,
                               rounds=400, n_cells=4, coupling=coupling)
    assert g2.shape == (4, 400, 4, 24)
    assert (g2 >= 0).all()
    for c in range(4):
        assert abs(g2[c].mean() - 1.0) < 0.05
        assert abs(g2[c].var() - 1.0) < 0.12


def test_coupled_fading_correlates_cells():
    """Coupling is real: the cross-cell correlation of the power gains
    increases with the coupling coefficient (and is ~0 uncoupled)."""
    proc = FadingProcess(kind="ar1", rho=0.7)

    def xcorr(coupling):
        g2 = sample_coupled_fading(np.random.default_rng(3), _WCFG, proc,
                                   rounds=300, n_cells=2, coupling=coupling)
        a, b = g2[0].ravel(), g2[1].ravel()
        return np.corrcoef(a, b)[0, 1]

    lo, mid, hi = xcorr(0.0), xcorr(0.5), xcorr(0.95)
    assert abs(lo) < 0.05
    assert lo < mid < hi
    assert hi > 0.6


def test_coupled_fading_validates_coupling():
    proc = FadingProcess(kind="iid")
    for bad in (-0.1, 1.01):
        with pytest.raises(ValueError):
            sample_coupled_fading(np.random.default_rng(0), _WCFG, proc,
                                  rounds=4, n_cells=2, coupling=bad)
