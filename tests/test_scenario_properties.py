"""Trace-statistics property tests for the scenario processes
(hypothesis-guarded, following the tests/test_property_invariants.py
convention: each @given test skips individually without hypothesis,
via the tests/_hyp.py shim).

Pins the distributional contracts documented in
src/repro/scenarios/processes.py:

  * AR(1) fading: |g|^2 stays Exp(1)-stationary (mean 1, variance 1) at
    every lag while the POWER autocorrelation at lag 1 is rho^2 — and the
    rho=0 special case is statistically indistinguishable from the
    legacy i.i.d. draw (mean 1, no lag-1 correlation);
  * Markov churn: the availability chain mixes to its stationary rate
    p_join / (p_join + p_drop); straggler slowdowns appear at the
    configured marginal rate and never below 1;
  * harvest energy: budgets respect the floor and hit the configured
    mean fraction of E^max.
"""
import numpy as np
import pytest

from _hyp import given, settings, st  # per-test skip without hypothesis

from repro.core import WirelessConfig
from repro.scenarios import (
    ChurnProcess,
    EnergyProcess,
    FadingProcess,
    sample_churn,
    sample_energy,
    sample_fading,
)

CFG = WirelessConfig(n_devices=64, n_subchannels=4)


def _lag1_power_corr(g2: np.ndarray) -> float:
    """Empirical lag-1 correlation of |g|^2 pooled over all (k, n) chains."""
    a = g2[:-1].reshape(-1)
    b = g2[1:].reshape(-1)
    return float(np.corrcoef(a, b)[0, 1])


@settings(max_examples=10, deadline=None)
@given(rho=st.floats(0.0, 0.95), seed=st.integers(0, 999))
def test_ar1_fading_moments_and_autocorrelation(rho, seed):
    rng = np.random.default_rng(seed)
    g2 = sample_fading(rng, CFG, FadingProcess("ar1", rho=rho), rounds=200)
    n = g2.size
    # Exp(1) marginals at every lag: mean 1, var 1 (3-sigma-ish bands for
    # ~51k correlated samples; correlation inflates the estimator noise).
    assert abs(g2.mean() - 1.0) < 0.15
    assert abs(g2.var() - 1.0) < 0.35
    # power autocorrelation: corr(|g_t|^2, |g_{t+1}|^2) = rho^2
    assert abs(_lag1_power_corr(g2) - rho * rho) < 0.08
    assert n == 200 * 4 * 64


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 999))
def test_iid_fading_is_uncorrelated_rho0_limit(seed):
    """The legacy i.i.d. draw == the rho=0 AR(1) law, statistically."""
    iid = sample_fading(np.random.default_rng(seed), CFG,
                        FadingProcess("iid"), rounds=200)
    ar0 = sample_fading(np.random.default_rng(seed), CFG,
                        FadingProcess("ar1", rho=0.0), rounds=200)
    for g2 in (iid, ar0):
        assert abs(g2.mean() - 1.0) < 0.1
        assert abs(_lag1_power_corr(g2)) < 0.05


@settings(max_examples=10, deadline=None)
@given(p_drop=st.floats(0.05, 0.9), p_join=st.floats(0.1, 0.95),
       straggler=st.floats(0.0, 0.8), seed=st.integers(0, 999))
def test_churn_marginal_rates(p_drop, p_join, straggler, seed):
    rounds, n = 400, 64
    proc = ChurnProcess("markov", p_drop=p_drop, p_join=p_join,
                        straggler_prob=straggler, slowdown_max=4.0)
    avail, slow = sample_churn(np.random.default_rng(seed), proc, rounds, n)
    assert avail[0].all()                       # chains start available
    stationary = p_join / (p_join + p_drop)
    # discard the burn-in half so the all-up start doesn't bias the rate
    rate = avail[rounds // 2:].mean()
    assert abs(rate - stationary) < 0.08
    assert (slow >= 1.0).all() and (slow <= 4.0).all()
    # stragglers appear only on available devices, at the marginal rate
    assert ((slow > 1.0) <= avail).all()
    if straggler > 0:
        obs = (slow[avail] > 1.0).mean()
        assert abs(obs - straggler) < 0.06


@settings(max_examples=10, deadline=None)
@given(mean_frac=st.floats(0.3, 2.0), floor_frac=st.floats(0.0, 0.25),
       seed=st.integers(0, 999))
def test_harvest_energy_floor_and_mean(mean_frac, floor_frac, seed):
    proc = EnergyProcess("harvest", mean_frac=mean_frac,
                         floor_frac=floor_frac)
    e = sample_energy(np.random.default_rng(seed), CFG, proc, rounds=300)
    assert e.shape == (300, CFG.n_devices)
    assert (e >= floor_frac * CFG.e_max_j - 1e-15).all()
    scale = mean_frac * CFG.e_max_j
    assert abs(e.mean() - scale) < 0.05 * max(scale, CFG.e_max_j)
