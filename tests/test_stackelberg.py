"""Round orchestration: Stackelberg plan invariants + all benchmark policies."""
import numpy as np
import pytest
from _hyp import given, settings, st  # per-test skip without hypothesis

from repro.core import (
    RoundPolicy,
    WirelessConfig,
    init_aou,
    make_clusters,
    plan_round,
    sample_channel_gains,
    sample_topology,
)

CFG = WirelessConfig()


def _round(seed=0, policy=RoundPolicy(), cfg=CFG, round_idx=0):
    rng = np.random.default_rng(seed)
    topo = sample_topology(rng, cfg)
    h2 = sample_channel_gains(rng, cfg, topo)
    beta = rng.integers(5, 60, cfg.n_devices).astype(float)
    aou = init_aou(cfg.n_devices)
    clusters = make_clusters(cfg.n_devices, cfg.n_subchannels, rng)
    fixed = np.arange(cfg.n_subchannels)
    plan = plan_round(aou, beta, h2, cfg, rng, policy=policy,
                      round_idx=round_idx, clusters=clusters, fixed_ids=fixed)
    return plan, beta, h2


ALL_POLICIES = [
    RoundPolicy(ds=ds, ra=ra, sa=sa)
    for ds in ("alg3", "aou_topk", "random", "cluster", "fixed")
    for ra in ("mo", "fix")
    for sa in ("matching", "random")
]


@pytest.mark.parametrize("policy", ALL_POLICIES, ids=lambda p: p.label)
def test_all_policies_produce_valid_plans(policy):
    plan, beta, h2 = _round(3, policy)
    n = CFG.n_devices
    assert plan.selected.shape == (n,)
    assert plan.transmitted.sum() <= CFG.n_subchannels
    # energy of transmitting devices within budget
    tx = plan.transmitted
    assert np.all(plan.energy_per_device[tx] <= CFG.e_max_j * (1 + 1e-6))
    # latency == max time over transmitting devices (eq. 9)
    if tx.any():
        assert plan.latency_s == pytest.approx(plan.time_per_device[tx].max())
    else:
        assert plan.latency_s == 0.0


@given(seed=st.integers(0, 2000))
@settings(max_examples=20)
def test_aou_update_matches_transmissions(seed):
    plan, _, _ = _round(seed)
    ages = plan.aou_next.age
    assert np.all(ages[plan.transmitted] == 1)
    assert np.all(ages[~plan.transmitted] == 2)  # started at 1, incremented


def test_leader_objective_alg3_vs_topk():
    """Leader side of the game: Algorithm 3 (follower-predicting) should not
    lose weighted participation (eq. 42) vs the non-predicting top-K
    selection — the replacement loop trades priority for feasibility, which
    can only help once unmatched devices contribute 0.  (Alg. 3 is a greedy
    heuristic, so we assert an aggregate win rate, not per-instance
    optimality.)"""
    aou = init_aou(CFG.n_devices)
    alpha = aou.weights
    wins = 0
    for s in range(25):
        p_a3, beta, _ = _round(s, RoundPolicy(ds="alg3"))
        p_tk, beta2, _ = _round(s, RoundPolicy(ds="aou_topk"))
        obj_a3 = (alpha * beta * p_a3.transmitted).sum()
        obj_tk = (alpha * beta2 * p_tk.transmitted).sum()
        if obj_a3 >= obj_tk - 1e-9:
            wins += 1
    assert wins >= 20


def test_follower_latency_not_worse_than_random_sa():
    """Definition 1 (follower): M-SA latency <= R-SA latency for the same
    selected set, on average."""
    wins = 0
    for s in range(25):
        p_m, _, _ = _round(s, RoundPolicy(ds="fixed", sa="matching"))
        p_r, _, _ = _round(s, RoundPolicy(ds="fixed", sa="random"))
        # compare only when both transmit the same set
        if (p_m.transmitted == p_r.transmitted).all() and p_m.transmitted.any():
            if p_m.latency_s <= p_r.latency_s + 1e-9:
                wins += 1
        else:
            wins += 1  # different participation -> not comparable
    assert wins >= 20


def test_cluster_rotation():
    p0, _, _ = _round(5, RoundPolicy(ds="cluster"), round_idx=0)
    p1, _, _ = _round(5, RoundPolicy(ds="cluster"), round_idx=1)
    assert not np.array_equal(np.where(p0.selected)[0], np.where(p1.selected)[0])


def test_fixed_policy_selects_same_devices():
    p0, _, _ = _round(5, RoundPolicy(ds="fixed"), round_idx=0)
    p1, _, _ = _round(5, RoundPolicy(ds="fixed"), round_idx=3)
    np.testing.assert_array_equal(p0.selected, p1.selected)
