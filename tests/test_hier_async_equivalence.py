"""Differential harness: the two-tier buffered async hierarchy vs its limits.

The city-scale engine (`fl.hier_async`, DESIGN.md §15) is pinned from
three directions:

  * degenerate SYNC limit — full buffers at BOTH tiers make every cell
    commit its whole dispatch at its own event and every cell flight
    commit at the same global event, so the two-tier event loop must
    reproduce the synchronous hierarchy (`engine="scan"`) BIT-EXACTLY
    across the policy x scenario matrix; uniform per-device clocks
    collapse ANY buffer pair to the same limit;
  * degenerate FLAT limit — a hierarchy of ONE cell has a single global
    slot whose commits mirror the cell commits one-for-one, so every
    trace must equal the flat `engine="async"` path bit-for-bit;
  * program identity — vmapped grid members == solo runs, sharded ==
    unsharded, and the segmented carry (`build_hier_async_runner(
    segmented=True)`) chains into exactly the one-scan trajectory.

Set REPRO_DIFF_BACKEND=pallas to solve Γ through the interpret-mode
Pallas projection backend (CI's hier-async-differential job runs the
default).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RoundPolicy
from repro.fl import AsyncAggregation, SimConfig, run_many
from repro.fl.hier_async import build_hier_async_runner, init_hier_async_carry
from repro.fl.hierarchical import (
    HierSimConfig,
    _apply_hier_dynamics,
    _hier_scan_inputs,
    _prepare_hier,
    _solve_hier_horizons,
    run_hier_many,
    run_hierarchical,
)
from repro.fl.sim import _eval_rounds, _group_trainer_and_policies

RA_BACKEND = os.environ.get("REPRO_DIFF_BACKEND") or None

_SMALL = dict(rounds=6, n_cells=2, devices_per_cell=8, subchannels_per_cell=3,
              n_samples=96, batch=16, local_steps=2, eval_every=2)

# The pinned RoundPolicy x scenario matrix (>= 8 combos): the proposed
# policy across the scenario presets, plus baseline policies crossed with
# the stressful ones.
POLICY_SCENARIOS = [
    ("alg3", "mo", "matching", "static"),
    ("alg3", "mo", "matching", "corr_fading"),
    ("alg3", "mo", "matching", "mobility"),
    ("alg3", "mo", "matching", "churn"),
    ("alg3", "mo", "matching", "urban"),
    ("aou_topk", "mo", "matching", "churn"),
    ("random", "fix", "random", "urban"),
    ("cluster", "mo", "random", "churn"),
    ("fixed", "fix", "matching", "urban"),
    ("random", "mo", "matching", "harvest"),
]


def _cfg(**kw):
    base = dict(_SMALL, dataset="mnist")
    base.update(kw)
    return HierSimConfig(**base)


def _assert_bit_exact(sync, asy):
    """The sync-limit contract: EVERYTHING the sync hierarchy records
    must match bit-for-bit, every cell dispatch must commit at its own
    event, and every cell flight must commit at the same global event."""
    np.testing.assert_array_equal(sync.tx_trace, asy.tx_trace)
    np.testing.assert_array_equal(sync.age_trace, asy.age_trace)
    np.testing.assert_array_equal(sync.latency_all, asy.latency_all)
    np.testing.assert_array_equal(sync.energy_all, asy.energy_all)
    np.testing.assert_array_equal(sync.global_loss, asy.global_loss)
    np.testing.assert_array_equal(sync.accuracy, asy.accuracy)
    np.testing.assert_array_equal(sync.n_selected, asy.n_selected)
    np.testing.assert_array_equal(sync.n_transmitted, asy.n_transmitted)
    np.testing.assert_array_equal(asy.commit_trace, sync.tx_trace)
    assert not asy.async_trace["overflow"].any()
    assert asy.async_trace["n_pending"].max() == 0
    assert asy.async_trace["g_pending"].max() == 0


def _assert_hist_equal(a, b):
    """Full bitwise trace identity between two async hierarchy runs."""
    np.testing.assert_array_equal(a.tx_trace, b.tx_trace)
    np.testing.assert_array_equal(a.commit_trace, b.commit_trace)
    np.testing.assert_array_equal(a.age_trace, b.age_trace)
    np.testing.assert_array_equal(a.latency_all, b.latency_all)
    np.testing.assert_array_equal(a.energy_all, b.energy_all)
    np.testing.assert_array_equal(a.global_loss, b.global_loss)
    np.testing.assert_array_equal(a.async_trace["n_pending"],
                                  b.async_trace["n_pending"])
    np.testing.assert_array_equal(a.async_trace["cell_committed"],
                                  b.async_trace["cell_committed"])
    np.testing.assert_array_equal(a.async_trace["latency_cells"],
                                  b.async_trace["latency_cells"])


# --------------------------------------------------------------------------
# (a) full buffers at both tiers == the sync hierarchy
# --------------------------------------------------------------------------

@pytest.mark.parametrize("ds,ra,sa,scenario", POLICY_SCENARIOS,
                         ids=[f"{d}-{r}-{s}-{sc}"
                              for d, r, s, sc in POLICY_SCENARIOS])
def test_hier_async_full_buffers_bit_exact_vs_scan(ds, ra, sa, scenario):
    """engine="async" with the full-buffer barrier at BOTH tiers ==
    engine="scan", bit-for-bit, across the policy x scenario matrix."""
    cfg = _cfg(policy=RoundPolicy(ds=ds, ra=ra, sa=sa), scenario=scenario)
    sync = run_hier_many([cfg], engine="scan", ra_backend=RA_BACKEND)[0]
    asy = run_hier_many([cfg], engine="async", ra_backend=RA_BACKEND)[0]
    _assert_bit_exact(sync, asy)


def test_hier_async_full_buffers_any_staleness_bit_exact():
    """With full buffers no commit is ever stale at either tier, so the
    staleness presets cannot perturb the limit (f(0) == 1.0 exactly)."""
    cfg = _cfg(scenario="churn")
    sync = run_hier_many([cfg], engine="scan", ra_backend=RA_BACKEND)[0]
    for agg, g_agg in (
            (AsyncAggregation(buffer="full", staleness="poly"),
             AsyncAggregation(buffer="full", staleness="poly")),
            ("async_full", "async_full"),
            (AsyncAggregation(buffer="full", staleness="const",
                              exponent=0.0), "sync")):
        asy = run_hier_many(
            [_cfg(scenario="churn", aggregation=agg,
                  global_aggregation=g_agg)],
            ra_backend=RA_BACKEND)[0]
        _assert_bit_exact(sync, asy)


def test_hier_uniform_clocks_any_buffers_degenerate_to_sync(monkeypatch):
    """With uniform per-device clocks every upload of an event ties at
    the cell tier AND every cell flight ties at the global tier, so ANY
    buffer pair commits everything together — the two-tier event loop
    collapses to the synchronous barrier even at buffer=1/g_buffer=1.
    Uniform clocks are forced by flattening the solved Γ to a constant
    (slowdown-free scenario: `apply_dynamics` re-stretching IS
    non-uniform clocks)."""
    from repro.fl import hierarchical as hier_mod

    orig = hier_mod._solve_hier_horizons

    def flat_gamma(preps, backend, **kw):
        ras_list, secs = orig(preps, backend, **kw)
        flat = []
        for ras in ras_list:
            flat.append([
                type(ra)(tau=ra.tau, p=ra.p,
                         time_s=np.where(ra.feasible, 1.0, np.inf),
                         energy_j=ra.energy_j, feasible=ra.feasible,
                         iterations=ra.iterations)
                for ra in ras])
        return flat, secs

    monkeypatch.setattr(hier_mod, "_solve_hier_horizons", flat_gamma)
    cfg = _cfg(scenario="static")
    sync = run_hier_many([cfg], engine="scan", ra_backend=RA_BACKEND)[0]
    asy = run_hier_many(
        [_cfg(scenario="static",
              aggregation=AsyncAggregation(buffer=1),
              global_aggregation=AsyncAggregation(buffer=1))],
        ra_backend=RA_BACKEND)[0]
    _assert_bit_exact(sync, asy)


# --------------------------------------------------------------------------
# (b) a hierarchy of one cell == the flat async engine
# --------------------------------------------------------------------------

@pytest.mark.parametrize("aggregation,scenario", [
    ("async", "urban"), ("async_const", "churn"),
    (AsyncAggregation(buffer=1, staleness="poly", exponent=1.0), "churn"),
])
def test_single_cell_hierarchy_bit_exact_vs_flat_async(aggregation, scenario):
    """C=1 collapses the global tier to a single slot committing in
    lockstep with the cell server, so every trace — dispatches, commits,
    clocks, losses — must equal the flat `engine="async"` path
    bit-for-bit."""
    flat = SimConfig(dataset="mnist", n_devices=8, n_subchannels=3,
                     rounds=6, n_samples=96, batch=16, local_steps=2,
                     eval_every=2, aggregation=aggregation,
                     scenario=scenario)
    hier = _cfg(n_cells=1, aggregation=aggregation, scenario=scenario)
    hf = run_many([flat], engine="async", ra_backend=RA_BACKEND)[0]
    hh = run_hier_many([hier], engine="async", ra_backend=RA_BACKEND)[0]
    for name in ("global_loss", "accuracy", "latency_all", "energy_all",
                 "tx_trace", "age_trace", "commit_trace", "cum_time_s",
                 "n_selected", "n_transmitted"):
        np.testing.assert_array_equal(getattr(hf, name), getattr(hh, name),
                                      err_msg=name)
    for k in ("n_pending", "rem_dispatch", "overflow"):
        np.testing.assert_array_equal(hf.async_trace[k], hh.async_trace[k],
                                      err_msg=k)
    # The lone global slot flies exactly when the cell commits something.
    cell_commits = hh.commit_trace.any(axis=1)
    np.testing.assert_array_equal(
        hh.async_trace["cell_committed"][:, 0], cell_commits)


def test_single_cell_sync_hierarchy_matches_flat_scan():
    """The C=1 anchor of the anchor: the sync hierarchy itself consumes
    the flat world stream bit-identically at one cell."""
    flat = SimConfig(dataset="mnist", n_devices=8, n_subchannels=3,
                     rounds=6, n_samples=96, batch=16, local_steps=2,
                     eval_every=2, scenario="urban")
    hier = _cfg(n_cells=1, scenario="urban")
    hf = run_many([flat], engine="scan", ra_backend=RA_BACKEND)[0]
    hh = run_hier_many([hier], engine="scan", ra_backend=RA_BACKEND)[0]
    for name in ("global_loss", "accuracy", "latency_all", "energy_all",
                 "tx_trace", "age_trace"):
        np.testing.assert_array_equal(getattr(hf, name), getattr(hh, name),
                                      err_msg=name)


# --------------------------------------------------------------------------
# (c) program identity: vmap == solo, shard == vmap
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_hier_async_vmap_matches_solo():
    """run_hier_many's vmapped two-tier engine == per-cell solo runs,
    bit-exact, across a seed x aggregation grid (one compiled program
    per shape — the four commit-policy operands are traced data)."""
    cfgs = [_cfg(seed=s, scenario="churn", aggregation=a,
                 global_aggregation=g)
            for s in (0, 1) for a, g in (("async", "async"),
                                         ("async_const", "sync"),
                                         ("sync", "async"))]
    vmapped = run_hier_many(cfgs, engine="async", ra_backend=RA_BACKEND)
    for c, v in zip(cfgs, vmapped):
        solo = run_hier_many([c], engine="async", ra_backend=RA_BACKEND)[0]
        _assert_hist_equal(v, solo)


@pytest.mark.slow
def test_hier_async_sharded_dispatch_matches_vmap():
    """shard=True on 2 forced host devices == unsharded vmap, bit-for-bit
    (separate process: device count must be set before JAX initializes)."""
    code = """
import numpy as np
from repro.fl.hierarchical import HierSimConfig, run_hier_many
cfgs = [HierSimConfig(dataset="mnist", rounds=4, n_cells=2,
                      devices_per_cell=6, subchannels_per_cell=2,
                      n_samples=48, batch=8, local_steps=2, eval_every=2,
                      seed=s, scenario="churn", aggregation="async")
        for s in (0, 1, 2)]
sh = run_hier_many(cfgs, engine="async", shard=True)
un = run_hier_many(cfgs, engine="async", shard=False)
for a, b in zip(sh, un):
    assert np.array_equal(a.tx_trace, b.tx_trace)
    assert np.array_equal(a.commit_trace, b.commit_trace)
    assert np.array_equal(a.global_loss, b.global_loss)
    assert np.array_equal(a.latency_all, b.latency_all)
print("HIER_SHARD_OK")
"""
    env = dict(os.environ,
               XLA_FLAGS=(os.environ.get("XLA_FLAGS", "") +
                          " --xla_force_host_platform_device_count=2"),
               PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "HIER_SHARD_OK" in proc.stdout


# --------------------------------------------------------------------------
# (d) segmented carry: chained segments == one unsegmented scan
# --------------------------------------------------------------------------

def test_hier_segmented_carry_matches_one_scan():
    """`build_hier_async_runner(segmented=True)` must chain the full
    15-slot two-tier carry across segments so that serving the grid in
    pieces is bit-identical to one unsegmented scan on EVERY ys trace —
    the property that lets the sustained-service harness stream the
    city."""
    cfg = _cfg(rounds=8, scenario="urban", aggregation="async",
               global_aggregation=AsyncAggregation(buffer=1))
    prep = _prepare_hier(cfg)
    ras_list, _ = _solve_hier_horizons([prep], RA_BACKEND)
    ras = _apply_hier_dynamics(prep, ras_list[0])
    model, trainer, policies, _ = _group_trainer_and_policies([cfg])
    data = _hier_scan_inputs(prep, ras, int(prep.x.shape[2]))
    spec = AsyncAggregation(buffer=None, staleness="poly")
    g_spec = AsyncAggregation(buffer=1)
    data["buffer"] = jnp.int32(spec.resolve_buffer(
        cfg.devices_per_cell, cfg.subchannels_per_cell))
    data["stale_exp"] = jnp.float32(spec.stale_exponent())
    data["server_lr"] = jnp.float32(spec.server_lr)
    data["g_buffer"] = jnp.int32(g_spec.resolve_buffer(cfg.n_cells,
                                                       cfg.n_cells))
    data["g_stale_exp"] = jnp.float32(g_spec.stale_exponent())
    data["g_server_lr"] = jnp.float32(g_spec.server_lr)
    eval_mask = np.zeros(cfg.rounds, bool)
    eval_mask[_eval_rounds(cfg.rounds, cfg.eval_every)] = True

    whole = jax.jit(build_hier_async_runner(
        model, trainer, policies, n_cells=cfg.n_cells,
        k=cfg.subchannels_per_cell, n=cfg.devices_per_cell,
        rounds=cfg.rounds, eval_mask=eval_mask))(data)

    seg_len = 4
    seg_run = jax.jit(build_hier_async_runner(
        model, trainer, policies, n_cells=cfg.n_cells,
        k=cfg.subchannels_per_cell, n=cfg.devices_per_cell,
        rounds=seg_len, eval_mask=np.ones(seg_len, bool),
        segmented=True))
    carry = init_hier_async_carry(data["params0"], data["key0"],
                                  cfg.n_cells, cfg.devices_per_cell)
    chunks = []
    per_round = ("gamma", "feas", "energy", "sel_perms", "assign_perms")
    for t0 in range(0, cfg.rounds, seg_len):
        seg = dict(data, t0=jnp.int32(t0),
                   **{k: data[k][t0:t0 + seg_len] for k in per_round})
        carry, ys = seg_run(seg, carry)
        chunks.append(jax.tree_util.tree_map(np.asarray, ys))
    chained = jax.tree_util.tree_map(
        lambda *leaves: np.concatenate(leaves), *chunks)

    whole = jax.tree_util.tree_map(np.asarray, whole)
    assert set(chained) == set(whole)
    for name in whole:
        if name in ("loss", "acc", "gnorm"):
            # Segment eval masks differ (every event) from the whole
            # run's eval_every sampling; compare where BOTH evaluated.
            ev = eval_mask
            np.testing.assert_array_equal(whole[name][ev],
                                          chained[name][ev], err_msg=name)
        else:
            np.testing.assert_array_equal(whole[name], chained[name],
                                          err_msg=name)


# --------------------------------------------------------------------------
# satellites: eval-trace gap + async-beats-sync under churn
# --------------------------------------------------------------------------

def test_hierarchical_eval_every_full_traces():
    """The PR-2 `cum_time_s` lesson, hierarchical edition: under
    eval_every=5, latency/energy/tx/age must still be recorded for EVERY
    round (bit-equal to the eval_every=1 run), loss/accuracy sampled at
    the eval rounds, and cum_time_s accumulated over ALL rounds."""
    dense = run_hierarchical(_cfg(rounds=10, eval_every=1, scenario="urban"),
                             engine="scan", ra_backend=RA_BACKEND)
    sparse = run_hierarchical(_cfg(rounds=10, eval_every=5, scenario="urban"),
                              engine="scan", ra_backend=RA_BACKEND)
    np.testing.assert_array_equal(sparse["eval_rounds"], [0, 5, 9])
    for name in ("latency", "energy", "tx", "age"):
        assert sparse[name].shape[0] == 10, name
        np.testing.assert_array_equal(sparse[name], dense[name],
                                      err_msg=name)
    np.testing.assert_array_equal(sparse["loss"],
                                  dense["loss"][[0, 5, 9]])
    np.testing.assert_array_equal(sparse["accuracy"],
                                  dense["accuracy"][[0, 5, 9]])
    np.testing.assert_allclose(sparse["cum_time_s"],
                               np.cumsum(dense["latency"])[[0, 5, 9]])
    # Same contract through the async engine.
    asparse = run_hierarchical(
        _cfg(rounds=10, eval_every=5, scenario="urban",
             aggregation="async"), ra_backend=RA_BACKEND)
    for name in ("latency", "energy", "tx", "age", "committed"):
        assert asparse[name].shape[0] == 10, name


@pytest.mark.parametrize("g_buffer", [1, "full"])
def test_hier_async_cum_time_monotonic_under_churn(g_buffer):
    """The two-tier buffered servers never wait longer than the two-tier
    eq.-9 barrier: async cumulative simulated time <= sync under the
    straggler scenario, for partial cell buffers at either global
    policy."""
    for seed in (0, 1):
        cfg = _cfg(rounds=10, seed=seed, scenario="churn")
        sync = run_hier_many([cfg], engine="scan", ra_backend=RA_BACKEND)[0]
        asy = run_hier_many(
            [_cfg(rounds=10, seed=seed, scenario="churn",
                  aggregation=AsyncAggregation(buffer=1),
                  global_aggregation=AsyncAggregation(buffer=g_buffer))],
            ra_backend=RA_BACKEND)[0]
        assert asy.cum_time_s[-1] <= sync.cum_time_s[-1]
        assert (asy.latency_all >= 0).all()
        assert not asy.async_trace["overflow"].any()


def test_hier_engine_validation():
    with pytest.raises(ValueError):
        run_hierarchical(_cfg(), engine="warp")
    with pytest.raises(ValueError):
        run_hier_many([_cfg()], engine="loop")
    with pytest.raises(ValueError):
        run_hier_many([_cfg(aggregation="warp")])
    with pytest.raises(ValueError):
        _prepare_hier(_cfg(cell_coupling=1.5))
