"""End-to-end behaviour of the paper's system: the Stackelberg control plane
driving real FL training — the trends the paper's figures claim."""
import numpy as np
import pytest

from repro.core import RoundPolicy, WirelessConfig
from repro.fl import SimConfig, run_many, run_simulation

# Whole-module: multi-policy end-to-end simulations, the slow tier-1 half.
pytestmark = pytest.mark.slow


def test_proposed_scheme_beats_fixed_ds():
    """Fig. 3's clearest ordering: Fixed-DS (least data) loses to Alg. 3."""
    kw = dict(dataset="mnist", rounds=40, n_samples=400, eval_every=10,
              local_steps=3, seed=1)
    prop = run_simulation(SimConfig(policy=RoundPolicy(ds="alg3"), **kw))
    fixd = run_simulation(SimConfig(policy=RoundPolicy(ds="fixed"), **kw))
    assert prop.global_loss[-1] < fixd.global_loss[-1]


def test_proposed_uses_all_subchannels():
    """Fig. 7: Alg. 3 keeps all K sub-channels busy (on average more than
    random selection, which loses devices to Prop-1 infeasibility)."""
    kw = dict(dataset="mnist", rounds=25, n_samples=300, eval_every=1, seed=0)
    prop = run_simulation(SimConfig(policy=RoundPolicy(ds="alg3"), **kw))
    rand = run_simulation(SimConfig(policy=RoundPolicy(ds="random"), **kw))
    assert prop.n_transmitted.mean() >= rand.n_transmitted.mean()
    assert prop.n_transmitted.mean() >= 3.0  # K = 4


def test_mo_ra_participation_beats_fix_ra():
    """Figs. 8-9: MO-RA keeps more devices feasible than FIX-RA."""
    kw = dict(dataset="mnist", rounds=25, n_samples=300, eval_every=1, seed=0,
              pt_dbm=8.0)
    mo = run_simulation(SimConfig(policy=RoundPolicy(ds="random", ra="mo"), **kw))
    fx = run_simulation(SimConfig(policy=RoundPolicy(ds="random", ra="fix"), **kw))
    assert mo.n_transmitted.mean() >= fx.n_transmitted.mean()


def test_radius_degrades_participation():
    """Fig. 6 mechanism: larger radius -> worse channels -> Prop-1 locks out
    more devices."""
    near = run_simulation(SimConfig(dataset="mnist", rounds=20, n_samples=200,
                                    radius_m=200.0, eval_every=1, seed=3,
                                    policy=RoundPolicy(ds="random")))
    far = run_simulation(SimConfig(dataset="mnist", rounds=20, n_samples=200,
                                   radius_m=1500.0, eval_every=1, seed=3,
                                   policy=RoundPolicy(ds="random")))
    assert near.n_transmitted.mean() > far.n_transmitted.mean()


def test_run_many_matches_individual_runs():
    """run_many shares one batched whole-horizon Γ solve across sims; each
    trajectory must equal its standalone run_simulation twin (mixed RA
    policies exercise both the batched MO-RA and closed-form FIX-RA paths)."""
    cfgs = [
        SimConfig(dataset="mnist", rounds=6, n_samples=120, eval_every=2,
                  seed=s, policy=RoundPolicy(ds="random", ra=ra))
        for s, ra in ((0, "mo"), (1, "mo"), (2, "fix"))
    ]
    batched = run_many(cfgs)
    for cfg, hist in zip(cfgs, batched):
        solo = run_simulation(cfg)
        np.testing.assert_allclose(hist.global_loss, solo.global_loss, rtol=1e-6)
        np.testing.assert_allclose(hist.latency_s, solo.latency_s, rtol=1e-9)
        np.testing.assert_array_equal(hist.n_transmitted, solo.n_transmitted)


def test_energy_budget_increases_participation():
    """Fig. 8: bigger E^max -> more feasible devices."""
    lo = run_simulation(SimConfig(dataset="mnist", rounds=20, n_samples=200,
                                  e_max_j=0.005, eval_every=1, seed=2,
                                  policy=RoundPolicy(ds="random")))
    hi = run_simulation(SimConfig(dataset="mnist", rounds=20, n_samples=200,
                                  e_max_j=0.1, eval_every=1, seed=2,
                                  policy=RoundPolicy(ds="random")))
    assert hi.n_transmitted.mean() >= lo.n_transmitted.mean()
