"""Algorithm-3 edge cases (core.selection) — branches the main suite never
hit: all-infeasible Γ, fewer devices than sub-channels, max_iter exhaustion,
and deterministic tie-breaking of the eq. (43) priority list.

Deliberately hypothesis-free so the whole module runs on minimal installs
(the property suites skip without hypothesis)."""
import numpy as np
import pytest

from repro.core import priority_list, select_aou_alg3, select_topk


def _uniform_instance(k, n, feas):
    gamma = np.ones((k, n))
    alpha = np.linspace(1.0, 0.1, n)
    beta = np.ones(n)
    return alpha, beta, gamma, feas


def test_alg3_all_infeasible_gamma():
    """No feasible pair anywhere: the replacement loop must walk the whole
    priority list, transmit nobody, and terminate."""
    k, n = 3, 7
    alpha, beta, gamma, _ = _uniform_instance(k, n, None)
    feas = np.zeros((k, n), dtype=bool)
    out = select_aou_alg3(alpha, beta, gamma, feas, np.random.default_rng(0))
    assert out.transmitted.sum() == 0
    assert out.channel_of.tolist() == [-1] * n
    assert out.selected.sum() == k          # a candidate set was still formed
    assert 1 <= out.iterations <= n         # terminated, list exhausted
    # Every device entered the candidate buffer at some point: the last
    # batch is whatever remained when Q ran dry.
    assert np.all(out.selected[out.selected_ids])


def test_alg3_fewer_devices_than_subchannels():
    """n < K: the candidate buffer shrinks to n and matching still works."""
    k, n = 5, 3
    alpha, beta, gamma, _ = _uniform_instance(k, n, None)
    feas = np.ones((k, n), dtype=bool)
    out = select_aou_alg3(alpha, beta, gamma, feas, np.random.default_rng(0))
    assert out.selected.sum() == n
    assert out.transmitted.sum() == n
    ch = out.channel_of[out.transmitted]
    assert len(set(ch.tolist())) == n       # distinct sub-channels
    assert out.iterations == 1              # nothing to replace


def test_alg3_max_iter_exhaustion():
    """max_iter=1 freezes the first candidate set even though replacements
    could have fixed the infeasible slot."""
    alpha = np.array([1.0, 0.5, 0.4, 0.3])
    beta = np.ones(4)
    gamma = np.ones((2, 4))
    feas = np.array([[False, True, True, True],
                     [False, True, True, True]])
    limited = select_aou_alg3(alpha, beta, gamma, feas,
                              np.random.default_rng(0), max_iter=1)
    assert limited.iterations == 1
    assert not limited.transmitted[0]
    assert limited.transmitted.sum() == 1   # only the feasible top-2 member
    free = select_aou_alg3(alpha, beta, gamma, feas, np.random.default_rng(0))
    assert free.iterations > 1
    assert free.transmitted.sum() == 2      # replacement rescued the slot


def test_alg3_stops_when_priority_list_exhausted():
    """Replacements stop the moment Q runs dry mid-iteration."""
    k, n = 2, 3
    alpha, beta, gamma, _ = _uniform_instance(k, n, None)
    feas = np.array([[True, False, False],
                     [True, False, False]])
    out = select_aou_alg3(alpha, beta, gamma, feas, np.random.default_rng(0))
    assert out.transmitted.sum() == 1
    assert out.iterations <= n


def test_priority_ties_broken_by_device_id():
    """Exact alpha*beta ties order by device id (stable sort), and scaling
    alpha by a positive constant — the eq. (7) normalizer — cannot reorder
    anything."""
    alpha = np.array([2.0, 1.0, 2.0, 1.0])
    beta = np.array([3.0, 6.0, 3.0, 6.0])   # all products == 6
    assert priority_list(alpha, beta).tolist() == [0, 1, 2, 3]
    alpha2 = np.array([4.0, 5.0, 5.0, 4.0])
    beta2 = np.array([5.0, 4.0, 4.0, 5.0])  # all products == 20
    assert priority_list(alpha2, beta2).tolist() == [0, 1, 2, 3]
    # Distinct priorities: any positive rescaling preserves the order.
    a = np.array([7.0, 2.0, 9.0, 4.0])
    b = np.array([3.0, 5.0, 1.0, 8.0])
    np.testing.assert_array_equal(priority_list(a, b),
                                  priority_list(a * 0.125, b))


def test_topk_vs_alg3_on_tied_priorities():
    """With every priority tied, top-K must take the K lowest device ids."""
    k, n = 3, 6
    alpha, beta, gamma, _ = _uniform_instance(k, n, None)
    alpha = np.ones(n)
    feas = np.ones((k, n), dtype=bool)
    out = select_topk(alpha, beta, gamma, feas, np.random.default_rng(0))
    assert sorted(out.selected_ids.tolist()) == [0, 1, 2]
