"""Algorithm 2 (swap matching): stability (Def. 3), convergence, quality."""
import numpy as np
import pytest
from _hyp import given, settings, st  # per-test skip without hypothesis

from repro.core import (
    U_MAX,
    is_two_sided_exchange_stable,
    random_assignment,
    swap_matching,
)
from repro.core.matching import prepare_utility


def _random_instance(rng, k, n_sel, infeasible_frac=0.3):
    gamma = rng.exponential(size=(k, n_sel)) * 5
    feas = rng.uniform(size=(k, n_sel)) > infeasible_frac
    return gamma, feas


@given(
    k=st.integers(2, 8),
    seed=st.integers(0, 10_000),
    infeasible=st.floats(0.0, 0.8),
)
@settings(max_examples=40)
def test_result_is_2es(k, seed, infeasible):
    """Definition 3: no swap-blocking pair remains at termination."""
    rng = np.random.default_rng(seed)
    gamma, feas = _random_instance(rng, k, k, infeasible)
    res = swap_matching(gamma, feas, rng)
    gamma_u = prepare_utility(gamma, feas)
    assert is_two_sided_exchange_stable(gamma_u, res.assignment)
    # one-to-one
    assert len(set(res.assignment.tolist())) == k


@given(k=st.integers(2, 7), seed=st.integers(0, 10_000))
def test_swaps_strictly_reduce_sum_utility(k, seed):
    """Every executed swap strictly reduces total utility => convergence
    (the paper's convergence argument)."""
    rng = np.random.default_rng(seed)
    gamma, feas = _random_instance(rng, k, k)
    gamma_u = prepare_utility(gamma, feas)
    init = rng.permutation(k)
    res = swap_matching(gamma, feas, rng, initial=init)
    u_init = gamma_u[init, np.arange(k)].sum()
    u_fin = res.utilities.sum()
    assert u_fin <= u_init + 1e-9


def test_matching_beats_random_on_average(rng):
    """M-SA vs R-SA: stable matching should not be worse in expectation
    (mechanism behind Fig. 4)."""
    wins = 0
    for s in range(30):
        r = np.random.default_rng(s)
        gamma, feas = _random_instance(r, 4, 4)
        m = swap_matching(gamma, feas, r)
        ra = random_assignment(gamma, feas, r)
        if m.utilities.sum() <= ra.utilities.sum() + 1e-9:
            wins += 1
    assert wins >= 24  # stable matching at least ties in >= 80% of cases


def test_infeasible_devices_marked():
    gamma = np.array([[1.0, 2.0], [3.0, 4.0]])
    feas = np.array([[False, True], [False, True]])  # device 0 fully infeasible
    res = swap_matching(gamma, feas, np.random.default_rng(0))
    i0 = list(res.assignment).index(res.assignment[0])
    assert not res.feasible[0]
    assert res.utilities[0] == U_MAX


def test_known_optimal_2x2():
    """2x2 with dominant diagonal: swap matching must find the min-sum
    assignment (2ES = optimal for 2 players)."""
    gamma = np.array([[1.0, 10.0], [10.0, 1.0]])
    feas = np.ones((2, 2), bool)
    res = swap_matching(gamma, feas, np.random.default_rng(0), initial=np.array([1, 0]))
    assert res.utilities.sum() == 2.0
