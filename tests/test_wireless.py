"""System model (eqs. 1-10) + Propositions 1-2."""
import numpy as np
import pytest
from _hyp import given, st  # per-test skip without hypothesis

from repro.core import (
    WirelessConfig,
    comm_energy,
    comm_rate,
    comm_time,
    compute_energy,
    compute_time,
    is_infeasible,
    min_comm_energy,
    sample_channel_gains,
    sample_topology,
    total_energy,
    total_time,
)

CFG = WirelessConfig()


def test_topology_within_radius(rng):
    topo = sample_topology(rng, CFG)
    assert topo.n_devices == CFG.n_devices
    assert np.all(topo.distances_m <= CFG.radius_m)
    assert np.all(topo.distances_m >= 1.0)


def test_channel_shape_and_positivity(rng):
    topo = sample_topology(rng, CFG)
    h2 = sample_channel_gains(rng, CFG, topo)
    assert h2.shape == (CFG.n_subchannels, CFG.n_devices)
    assert np.all(h2 > 0)


def test_units_sanity():
    # Table-I magnitudes: 25 samples at tau=1 -> 0.25 s compute, 0.025 J.
    assert compute_time(1.0, 25, CFG) == pytest.approx(0.25)
    assert compute_energy(1.0, 25, CFG) == pytest.approx(0.025)
    # 1 Mbit over a unit-SNR channel at full power ~ 1 s.
    assert comm_time(1.0, 1.0, CFG) == pytest.approx(1.0)


@given(
    tau1=st.floats(0.05, 1.0), tau2=st.floats(0.05, 1.0),
    p1=st.floats(0.01, 1.0), p2=st.floats(0.01, 1.0),
    h2=st.floats(1e-3, 1e3), beta=st.integers(1, 200),
)
def test_prop2_monotonicity(tau1, tau2, p1, p2, h2, beta):
    """Proposition 2: T decreasing, E increasing in (tau, p)."""
    lo_t, hi_t = sorted((tau1, tau2))
    lo_p, hi_p = sorted((p1, p2))
    assert total_time(hi_t, hi_p, beta, h2, CFG) <= total_time(lo_t, lo_p, beta, h2, CFG) + 1e-12
    assert total_energy(hi_t, hi_p, beta, h2, CFG) >= total_energy(lo_t, lo_p, beta, h2, CFG) - 1e-12


@given(h2=st.floats(1e-6, 1e4), p=st.floats(1e-6, 1.0))
def test_prop1_min_energy_is_infimum(h2, p):
    """E^cm(p) > inf_p E^cm for every p>0 (eq. 15 really is the infimum)."""
    assert comm_energy(p, h2, CFG) >= min_comm_energy(h2, CFG) * (1 - 1e-9)


@given(h2=st.floats(1e-6, 1e4))
def test_prop1_threshold(h2):
    """Exactly eq. (15)."""
    lhs = np.log(2) * CFG.pt_w * CFG.model_bits
    rhs = CFG.e_max_j * CFG.bandwidth_hz * h2
    assert bool(is_infeasible(h2, CFG)) == (lhs >= rhs)


def test_rate_increases_with_power():
    h2 = 3.0
    r = comm_rate(np.linspace(0.01, 1, 50), h2, CFG)
    assert np.all(np.diff(r) > 0)
