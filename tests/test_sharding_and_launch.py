"""Sharding rules + HLO analysis + (subprocess) a real dry-run combo."""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.analytic import HW, analytic_cost, model_flops, param_counts
from repro.launch.hlo_analysis import (
    collective_stats, parse_computations, while_trip_counts)
from repro.configs.base import INPUT_SHAPES

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class _FakeMesh:
    shape = {"model": 16, "data": 16}
    axis_names = ("data", "model")


def test_param_specs_rules():
    from repro.models.transformer import init_params
    from repro.sharding.partition import param_spec

    cfg = get_config("yi-6b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    mesh = _FakeMesh()
    flat = jax.tree_util.tree_leaves_with_path(params)
    specs = {
        "/".join(str(getattr(k, "key", k)) for k in path): param_spec(path, leaf, mesh)
        for path, leaf in flat
    }
    # fan-out projections shard last dim; fan-in shard first (after stack dim)
    assert specs["s0_l0/attn/wq/w"] == P(None, None, "model")
    assert specs["s0_l0/attn/wo/w"] == P(None, "model", None)
    assert specs["s0_l0/ffn/down/w"] == P(None, "model", None)
    assert specs["s0_l0/ln1/g"] == P(None, None)
    assert specs["lm_head/w"] == P(None, "model")


def test_param_specs_moe_and_odd_vocab():
    from repro.models.transformer import init_params
    from repro.sharding.partition import param_spec

    mesh = _FakeMesh()
    cfg = get_config("granite-moe-3b-a800m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0), ep_size=2)
    flat = jax.tree_util.tree_leaves_with_path(params)
    specs = {
        "/".join(str(getattr(k, "key", k)) for k in path): param_spec(path, leaf, mesh)
        for path, leaf in flat
    }
    # expert bank: (L, E_pad, d, ff); E_pad=4 not divisible by 16 -> replicated,
    # but at full scale E=48 shards (validated in the dry-run itself).
    assert specs["s0_l0/moe/gate"][0] is None
    # whisper's 51865 vocab is not divisible by 16 -> embed replicated
    wcfg = get_config("whisper-base")
    import jax.numpy as jnp
    fake_embed = jax.ShapeDtypeStruct((wcfg.vocab, wcfg.d_model), jnp.bfloat16)
    from jax.tree_util import DictKey
    spec = param_spec((DictKey("embed"), DictKey("w")), fake_embed, mesh)
    assert spec == P(None, None)


def test_hlo_collective_parse_and_trip_counts():
    hlo = """
HloModule m

%body.1 (p: (s32[], f32[128])) -> (s32[], f32[128]) {
  %x = f32[128]{0} all-reduce(f32[128]{0} %y), replica_groups={}
  ROOT %t = (s32[], f32[128]) tuple(%i, %x)
}

%cond.1 (p: (s32[], f32[128])) -> pred[] {
  %c = s32[] constant(28)
  ROOT %cmp = pred[] compare(s32[] %i, s32[] %c), direction=LT
}

ENTRY %main () -> f32[128] {
  %w = (s32[], f32[128]) while((s32[], f32[128]) %init), condition=%cond.1, body=%body.1
  %g = bf16[64]{0} all-gather(bf16[32]{0} %z), dimensions={0}
  ROOT %r = f32[128] get-tuple-element(%w), index=1
}
"""
    comps = parse_computations(hlo)
    assert set(comps) >= {"body.1", "cond.1", "main"}
    trips = while_trip_counts(comps)
    assert trips["body.1"] == 28
    stats = collective_stats(hlo)
    assert stats["all-reduce"] == 28 * 128 * 4          # loop-corrected
    assert stats["all-gather"] == 64 * 2
    assert stats["raw_total"] == 128 * 4 + 64 * 2


def test_analytic_param_counts_match_real():
    """Analytic N within 2% of the actual parameter tree for every arch
    (full config via eval_shape -- no allocation)."""
    from repro.models.transformer import init_params

    for arch in ("qwen2-7b", "yi-6b", "rwkv6-7b", "granite-moe-3b-a800m",
                 "jamba-v0.1-52b", "deepseek-v3-671b", "qwen1.5-110b"):
        cfg = get_config(arch)
        shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
        real = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(shapes))
        pred = param_counts(cfg)["total"]
        assert abs(pred - real) / real < 0.02, (arch, pred, real)


def test_analytic_flops_sane():
    cfg = get_config("qwen2-7b")
    shape = INPUT_SHAPES["train_4k"]
    mf = model_flops(cfg, shape)
    # 6ND within 25% of the analytic forward*3 for a dense model
    assert 0.75 < mf["six_nd_active"] / mf["train_total"] < 1.25
    roof = analytic_cost(cfg, shape, HW(chips=256))
    assert roof["dominant"] == "compute_s"
    assert 0.8 < roof["useful_ratio"] < 1.25


def test_known_param_totals():
    """Headline parameter counts match the papers' names (within 15%)."""
    expect = {
        "deepseek-v3-671b": 671e9,
        "qwen1.5-110b": 111e9,
        "qwen2-7b": 7.6e9,
        "yi-6b": 6.1e9,
        "jamba-v0.1-52b": 52e9,
        "rwkv6-7b": 7.0e9,
    }
    for arch, n in expect.items():
        got = param_counts(get_config(arch))["total"]
        assert abs(got - n) / n < 0.15, (arch, got / 1e9)


@pytest.mark.slow
def test_dryrun_subprocess_one_combo():
    """End-to-end deliverable (e) check: a full lower+compile on the 16x16
    mesh in a fresh process (512 forced host devices)."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "whisper-base", "--shape", "decode_32k"],
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        capture_output=True, text=True, timeout=900,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert "1 passed, 0 failed" in out.stdout


@pytest.mark.slow
def test_multidevice_execution_subprocess():
    """EXECUTE (not just compile) sharded FL-weighted train steps on an
    8-device host mesh: loss must decrease."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.multidevice_demo", "--steps", "4"],
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        capture_output=True, text=True, timeout=900,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert "loss" in out.stdout


# --------------------------------------------------------------------------
# device-axis sharding of the whole-horizon Γ solve (DESIGN.md §13)
# --------------------------------------------------------------------------

def test_gamma_shard_matches_vmap_single_device():
    """shard=True on one device must go through the shard_map path and
    still be bit-identical to the plain vmap dispatch."""
    from repro.core import WirelessConfig, solve_pairs_fused

    rng = np.random.default_rng(13)
    n = 96
    cfg = WirelessConfig()
    beta = rng.integers(5, 60, n).astype(float)
    h2 = rng.exponential(size=(4, n)) * 3
    sh = solve_pairs_fused(beta[None, :], h2, cfg, shard=True)
    un = solve_pairs_fused(beta[None, :], h2, cfg, shard=False)
    for field in ("feasible", "iterations", "tau", "p", "time_s", "energy_j"):
        np.testing.assert_array_equal(getattr(sh, field), getattr(un, field),
                                      err_msg=field)


@pytest.mark.slow
def test_gamma_shard_two_devices_subprocess():
    """shard=True on 2 forced host devices == unsharded, bit-for-bit, with
    a pad-and-drop row count that does NOT divide the device count
    (separate process: device count must be set before JAX initializes)."""
    code = """
import numpy as np
from repro.core import WirelessConfig, solve_pairs_fused
cfg = WirelessConfig()
rng = np.random.default_rng(17)
n = 77                                     # K*n odd vs 2 devices: pad-and-drop
beta = rng.integers(5, 60, n).astype(float)
h2 = rng.exponential(size=(3, 4, n)) * 3   # whole-horizon tensor
sh = solve_pairs_fused(beta[None, None, :], h2, cfg, shard=True)
un = solve_pairs_fused(beta[None, None, :], h2, cfg, shard=False)
for field in ("feasible", "iterations", "tau", "p", "time_s", "energy_j"):
    assert np.array_equal(getattr(sh, field), getattr(un, field),
                          equal_nan=True), field
print("GAMMA_SHARD_OK")
"""
    env = dict(os.environ,
               XLA_FLAGS=(os.environ.get("XLA_FLAGS", "") +
                          " --xla_force_host_platform_device_count=2"),
               PYTHONPATH=os.path.join(REPO, "src") + os.pathsep +
                          os.environ.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "GAMMA_SHARD_OK" in proc.stdout
