"""Differential suite for the fused-stage Algorithm 1 driver
(`core.monotonic_jax.solve_pairs_fused`) and the fully fused Pallas kernel
(`kernels.polyblock_fused`) against the step driver (`solve_pairs_jit`).

Set REPRO_DIFF_BACKEND=pallas to run the driver grid with the single-kernel
solve (interpret mode off-TPU) — the CI differential job does exactly that,
mirroring tests/test_scan_equivalence.py.
"""
import os

import numpy as np
import pytest

from repro.core import WirelessConfig, solve_pairs_fused, solve_pairs_jit
from repro.core.feasibility import is_infeasible

CFG = WirelessConfig()

# The step driver with the backend that replays each fused backend's
# projection arithmetic exactly: bisection backends mirror "bisect";
# Newton-family backends ("newton", "mixed", and the CPU default None)
# converge to the same root as "newton" at ~1e-12 relative.
_REF_OF = {"bisect": "bisect", "pallas": "bisect"}

BACKENDS = ["mixed", "bisect"]
_env = os.environ.get("REPRO_DIFF_BACKEND")
if _env and _env not in BACKENDS:
    BACKENDS.append(_env)


def _random_batch(seed=0, k=4, n=96, scale=3.0):
    rng = np.random.default_rng(seed)
    h2 = rng.exponential(size=(k, n)) * scale
    beta = rng.integers(5, 60, n).astype(float)
    return beta, h2


def _rel(a, b):
    return np.max(np.abs(a - b) / np.maximum(np.abs(a), 1e-30))


@pytest.mark.parametrize("backend", BACKENDS)
def test_fused_driver_matches_step(backend):
    """Acceptance contract: <= 1e-6 relative on tau/p/time_s/energy_j for
    feasible pairs, identical feasibility and iteration counts."""
    beta, h2 = _random_batch(seed=1)
    ref = solve_pairs_jit(beta[None, :], h2, CFG,
                          backend=_REF_OF.get(backend, "newton"))
    fused = solve_pairs_fused(beta[None, :], h2, CFG, backend=backend)
    np.testing.assert_array_equal(ref.feasible, fused.feasible)
    np.testing.assert_array_equal(ref.iterations, fused.iterations)
    f = ref.feasible
    assert f.any() and not f.all()
    for field in ("tau", "p", "time_s", "energy_j"):
        assert _rel(getattr(ref, field)[f], getattr(fused, field)[f]) < 1e-6, field
    # infeasible pairs keep the sentinel contract
    assert np.all(np.isinf(fused.time_s[~f]))
    assert np.all(np.isnan(fused.tau[~f]))


@pytest.mark.parametrize("seed", range(4))
def test_fused_driver_differential_grid(seed):
    """The CI differential grid: varied channel scales so retirement
    schedules differ across rows (the compaction stages see ragged
    active sets)."""
    beta, h2 = _random_batch(seed=seed, n=64, scale=[0.5, 2.0, 8.0, 30.0][seed])
    ref = solve_pairs_jit(beta[None, :], h2, CFG)
    fused = solve_pairs_fused(beta[None, :], h2, CFG)
    np.testing.assert_array_equal(ref.feasible, fused.feasible)
    f = ref.feasible
    if f.any():
        assert _rel(ref.time_s[f], fused.time_s[f]) < 1e-6


def test_fused_driver_horizon_tensor():
    """Whole-horizon (rounds, K, N) input: shape preserved, per-round
    slices match the step driver."""
    rng = np.random.default_rng(5)
    rounds, k, n = 6, 4, 24
    beta = rng.integers(5, 60, n).astype(float)
    h2_all = rng.exponential(size=(rounds, k, n)) * 3
    ref = solve_pairs_jit(beta[None, None, :], h2_all, CFG)
    fused = solve_pairs_fused(beta[None, None, :], h2_all, CFG)
    assert fused.time_s.shape == (rounds, k, n)
    np.testing.assert_array_equal(ref.feasible, fused.feasible)
    f = ref.feasible
    assert _rel(ref.time_s[f], fused.time_s[f]) < 1e-6


def test_fused_driver_all_infeasible_and_tiny():
    """Degenerate batches: an all-infeasible batch and a 1-pair batch
    must not trip the staged compaction (empty active set at stage 0)."""
    res = solve_pairs_fused(np.array([40.0]), np.array([1e-9]),
                            WirelessConfig(e_max_j=1e-6))
    assert not res.feasible[0] and np.isinf(res.time_s[0])
    one = solve_pairs_fused(np.array([10.0]), np.array([10.0]), CFG)
    assert one.feasible[0] and np.isfinite(one.time_s[0])


def test_fused_pallas_kernel_f64_bit_identical():
    """The fully fused kernel in f64 interpret mode replays the jnp
    "bisect" step driver bit-for-bit: same vertex trajectory, same
    eq. (26) retirements, identical floats out (DESIGN.md §13)."""
    pytest.importorskip("jax")
    beta, h2 = _random_batch(seed=7, n=48)
    ref = solve_pairs_jit(beta[None, :], h2, CFG, backend="bisect")
    res = solve_pairs_fused(beta[None, :], h2, CFG, backend="pallas")
    np.testing.assert_array_equal(ref.feasible, res.feasible)
    np.testing.assert_array_equal(ref.iterations, res.iterations)
    f = ref.feasible
    assert f.any()
    for field in ("tau", "p", "time_s"):
        np.testing.assert_array_equal(getattr(ref, field)[f],
                                      getattr(res, field)[f], err_msg=field)


def test_fused_pallas_kernel_f32_study():
    """fp32-accumulation study (DESIGN.md §13): the f32 kernel keeps the
    iteration trajectory of the f64 solve and lands within 1e-4 relative
    (this batch has no eps-boundary retirements; that case is pinned in
    test_kernels.py::test_polyblock_fused_solve_interpret_vs_oracle)."""
    pytest.importorskip("jax")
    from jax.experimental import enable_x64

    from repro.kernels.polyblock_fused.ops import polyblock_solve_fused

    beta, h2 = _random_batch(seed=9, n=48)
    bf, hf = np.broadcast_to(beta, h2.shape).reshape(-1), h2.reshape(-1)
    keep = ~is_infeasible(hf, CFG, np.full(hf.size, CFG.e_max_j))
    bf, hf = bf[keep], hf[keep]
    assert keep.any()
    with enable_x64():
        t64, p64, s64, i64 = polyblock_solve_fused(
            bf, hf, CFG.e_max_j, CFG, interpret=True, dtype=np.float64)
    t32, p32, s32, i32 = polyblock_solve_fused(
        bf, hf, CFG.e_max_j, CFG, interpret=True, dtype=np.float32)
    np.testing.assert_array_equal(np.asarray(i64), np.asarray(i32))
    for a, b in ((t64, t32), (p64, p32), (s64, s32)):
        assert _rel(np.asarray(a), np.asarray(b, np.float64)) < 1e-4


def test_fused_pallas_kernel_tile_independence():
    """Result must not depend on the (bm, 128) tiling or on how much
    padding the wrapper adds."""
    pytest.importorskip("jax")
    from repro.kernels.polyblock_fused.ops import polyblock_solve_fused

    beta, h2 = _random_batch(seed=11, n=80)
    bf, hf = np.broadcast_to(beta, h2.shape).reshape(-1), h2.reshape(-1)
    keep = ~is_infeasible(hf, CFG, np.full(hf.size, CFG.e_max_j))
    bf, hf = bf[keep][:130], hf[keep][:130]      # ragged: 2 tiles + padding
    outs = [polyblock_solve_fused(bf, hf, CFG.e_max_j, CFG, interpret=True,
                                  dtype=np.float32, bm=bm) for bm in (1, 4, 8)]
    for other in outs[1:]:
        for a, b in zip(outs[0], other):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
