"""Serving correctness: prefill + ring-buffer decode == full forward, for
every architecture family, including beyond-window sliding-window decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import decode_step, forward, init_cache, init_params

ALL = sorted(ARCHS)


def _batches(cfg, key, b, s, nd):
    toks = jax.random.randint(key, (b, s + nd), 0, cfg.vocab)
    pre = {"tokens": toks[:, :s]}
    full = {"tokens": toks}
    if cfg.family == "vlm":
        img = 0.02 * jax.random.normal(key, (b, cfg.n_patches, cfg.d_model), jnp.bfloat16)
        mp = jnp.broadcast_to(jnp.arange(s + nd, dtype=jnp.int32)[None, :, None],
                              (b, s + nd, 3))
        pre.update(image_embeds=img, mrope_pos=mp[:, :s])
        full.update(image_embeds=img, mrope_pos=mp)
    if cfg.family == "audio":
        fr = 0.02 * jax.random.normal(key, (b, cfg.encoder_seq, cfg.d_model))
        pre["enc_frames"] = fr
        full["enc_frames"] = fr
    return toks, pre, full


@pytest.mark.parametrize("arch", ALL)
def test_prefill_decode_matches_full(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(11)
    params = init_params(cfg, key)
    b, s, nd = 2, 32, 3
    toks, pre, full = _batches(cfg, key, b, s, nd)
    _, _, cache = forward(cfg, params, pre, mode="prefill", cache_headroom=nd)
    ref = forward(cfg, params, full, mode="train")[0]
    for d in range(nd):
        db = {"token": toks[:, s + d : s + d + 1], "pos": jnp.asarray(s + d, jnp.int32)}
        if cfg.family == "vlm":
            db["mrope_pos"] = jnp.full((b, 1, 3), s + d, jnp.int32)
        got, cache = decode_step(cfg, params, db, cache)
        a = np.asarray(got[:, 0].astype(jnp.float32))
        r = np.asarray(ref[:, s + d].astype(jnp.float32))
        err = np.abs(a - r).max() / (np.abs(r).max() + 1e-9)
        assert err < 4e-2, (arch, d, err)


def test_sliding_window_ring_beyond_window():
    """Decode past the window: ring overwrite must match a full forward of
    the same sliding-window config."""
    import dataclasses

    cfg = dataclasses.replace(get_config("yi-6b").reduced(), sliding_window=16)
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    b, s, nd = 1, 24, 8  # decode well past the 16-token window
    toks = jax.random.randint(key, (b, s + nd), 0, cfg.vocab)
    _, _, cache = forward(cfg, params, {"tokens": toks[:, :s]},
                          mode="prefill", cache_headroom=nd)
    # physical cache is capped at the window
    assert cache["s0_l0"]["k"].shape[2] == 16
    ref = forward(cfg, params, {"tokens": toks}, mode="train")[0]
    for d in range(nd):
        db = {"token": toks[:, s + d : s + d + 1], "pos": jnp.asarray(s + d, jnp.int32)}
        got, cache = decode_step(cfg, params, db, cache)
        a = np.asarray(got[:, 0].astype(jnp.float32))
        r = np.asarray(ref[:, s + d].astype(jnp.float32))
        err = np.abs(a - r).max() / (np.abs(r).max() + 1e-9)
        assert err < 4e-2, (d, err)


def test_cold_cache_decode_runs_all_archs():
    """init_cache + serve from scratch (the dry-run decode path)."""
    for arch in ALL:
        cfg = get_config(arch).reduced()
        params = init_params(cfg, jax.random.PRNGKey(0))
        b = 2
        enc = (0.02 * jnp.ones((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
               if cfg.family == "audio" else None)
        cache = init_cache(cfg, b, 64, enc_out=enc)
        db = {"token": jnp.zeros((b, 1), jnp.int32), "pos": jnp.asarray(0, jnp.int32)}
        if cfg.family == "vlm":
            db["mrope_pos"] = jnp.zeros((b, 1, 3), jnp.int32)
        logits, cache2 = decode_step(cfg, params, db, cache)
        assert logits.shape == (b, 1, cfg.vocab)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch


# --------------------------------------------------------------------------
# serve_loop driver: warm-up step + single device->host pull
# --------------------------------------------------------------------------

def test_serve_loop_warmup_and_single_host_pull(monkeypatch, capsys):
    """The decode loop must (a) run one DISCARDED warm-up serve step so
    tok/s excludes the first-step compile, (b) keep tokens on device and
    pull the generation to host exactly once — the old per-step
    `np.asarray(tok)` forced a device sync every iteration."""
    from repro.launch import serve as serve_mod

    calls = {"serve": 0}
    real_steps = serve_mod._jitted_steps

    def counting_steps(cfg, headroom, ctx):
        prefill, serve = real_steps(cfg, headroom, ctx)

        def counting_serve(params, db, cache):
            calls["serve"] += 1
            return serve(params, db, cache)

        return prefill, counting_serve

    class CountingNp:
        asarray_calls = 0

        def asarray(self, *a, **k):
            CountingNp.asarray_calls += 1
            return np.asarray(*a, **k)

        def __getattr__(self, name):
            return getattr(np, name)

    monkeypatch.setattr(serve_mod, "_jitted_steps", counting_steps)
    monkeypatch.setattr(serve_mod, "np", CountingNp())

    batch, new_tokens = 2, 5
    out = serve_mod.serve_loop("qwen2-7b-smoke", batch=batch, prompt_len=8,
                               new_tokens=new_tokens, seed=0)
    assert out.shape == (batch, new_tokens + 1)   # prefill token + decoded
    assert out.dtype == np.int32
    # exactly one extra (warm-up) serve call beyond the measured steps
    assert calls["serve"] == new_tokens + 1
    # ONE host pull for the whole generation, none inside the loop
    assert CountingNp.asarray_calls == 1
    # and the throughput line no longer blames first-step compile
    logged = capsys.readouterr().out
    assert "steady-state decode" in logged
    assert "incl. first-step compile" not in logged


def test_serve_loop_warmup_does_not_perturb_generation(monkeypatch):
    """Greedy decode is deterministic: the discarded warm-up step (serve
    outputs are not donated) must leave the generated tokens identical to
    a loop that never warmed up."""
    from repro.launch import serve as serve_mod

    out = serve_mod.serve_loop("qwen2-7b-smoke", batch=2, prompt_len=8,
                               new_tokens=4, seed=3)

    real_steps = serve_mod._jitted_steps

    def skip_warmup_steps(cfg, headroom, ctx):
        prefill, serve = real_steps(cfg, headroom, ctx)
        state = {"first": True}

        def serve_no_warm(params, db, cache):
            if state.pop("first", None):
                # return inputs untouched: the warm-up becomes a no-op
                return db["token"], None, cache
            return serve(params, db, cache)

        return prefill, serve_no_warm

    monkeypatch.setattr(serve_mod, "_jitted_steps", skip_warmup_steps)
    again = serve_mod.serve_loop("qwen2-7b-smoke", batch=2, prompt_len=8,
                                 new_tokens=4, seed=3)
    assert np.array_equal(out, again)
