"""Serving correctness: prefill + ring-buffer decode == full forward, for
every architecture family, including beyond-window sliding-window decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import decode_step, forward, init_cache, init_params

ALL = sorted(ARCHS)


def _batches(cfg, key, b, s, nd):
    toks = jax.random.randint(key, (b, s + nd), 0, cfg.vocab)
    pre = {"tokens": toks[:, :s]}
    full = {"tokens": toks}
    if cfg.family == "vlm":
        img = 0.02 * jax.random.normal(key, (b, cfg.n_patches, cfg.d_model), jnp.bfloat16)
        mp = jnp.broadcast_to(jnp.arange(s + nd, dtype=jnp.int32)[None, :, None],
                              (b, s + nd, 3))
        pre.update(image_embeds=img, mrope_pos=mp[:, :s])
        full.update(image_embeds=img, mrope_pos=mp)
    if cfg.family == "audio":
        fr = 0.02 * jax.random.normal(key, (b, cfg.encoder_seq, cfg.d_model))
        pre["enc_frames"] = fr
        full["enc_frames"] = fr
    return toks, pre, full


@pytest.mark.parametrize("arch", ALL)
def test_prefill_decode_matches_full(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(11)
    params = init_params(cfg, key)
    b, s, nd = 2, 32, 3
    toks, pre, full = _batches(cfg, key, b, s, nd)
    _, _, cache = forward(cfg, params, pre, mode="prefill", cache_headroom=nd)
    ref = forward(cfg, params, full, mode="train")[0]
    for d in range(nd):
        db = {"token": toks[:, s + d : s + d + 1], "pos": jnp.asarray(s + d, jnp.int32)}
        if cfg.family == "vlm":
            db["mrope_pos"] = jnp.full((b, 1, 3), s + d, jnp.int32)
        got, cache = decode_step(cfg, params, db, cache)
        a = np.asarray(got[:, 0].astype(jnp.float32))
        r = np.asarray(ref[:, s + d].astype(jnp.float32))
        err = np.abs(a - r).max() / (np.abs(r).max() + 1e-9)
        assert err < 4e-2, (arch, d, err)


def test_sliding_window_ring_beyond_window():
    """Decode past the window: ring overwrite must match a full forward of
    the same sliding-window config."""
    import dataclasses

    cfg = dataclasses.replace(get_config("yi-6b").reduced(), sliding_window=16)
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    b, s, nd = 1, 24, 8  # decode well past the 16-token window
    toks = jax.random.randint(key, (b, s + nd), 0, cfg.vocab)
    _, _, cache = forward(cfg, params, {"tokens": toks[:, :s]},
                          mode="prefill", cache_headroom=nd)
    # physical cache is capped at the window
    assert cache["s0_l0"]["k"].shape[2] == 16
    ref = forward(cfg, params, {"tokens": toks}, mode="train")[0]
    for d in range(nd):
        db = {"token": toks[:, s + d : s + d + 1], "pos": jnp.asarray(s + d, jnp.int32)}
        got, cache = decode_step(cfg, params, db, cache)
        a = np.asarray(got[:, 0].astype(jnp.float32))
        r = np.asarray(ref[:, s + d].astype(jnp.float32))
        err = np.abs(a - r).max() / (np.abs(r).max() + 1e-9)
        assert err < 4e-2, (d, err)


def test_cold_cache_decode_runs_all_archs():
    """init_cache + serve from scratch (the dry-run decode path)."""
    for arch in ALL:
        cfg = get_config(arch).reduced()
        params = init_params(cfg, jax.random.PRNGKey(0))
        b = 2
        enc = (0.02 * jnp.ones((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
               if cfg.family == "audio" else None)
        cache = init_cache(cfg, b, 64, enc_out=enc)
        db = {"token": jnp.zeros((b, 1), jnp.int32), "pos": jnp.asarray(0, jnp.int32)}
        if cfg.family == "vlm":
            db["mrope_pos"] = jnp.zeros((b, 1, 3), jnp.int32)
        logits, cache2 = decode_step(cfg, params, db, cache)
        assert logits.shape == (b, 1, cfg.vocab)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch
