"""Optional-hypothesis shim: per-TEST skips instead of per-MODULE skips.

The property-test modules used to open with
``pytest.importorskip("hypothesis")``, which silently skipped every
deterministic test that happened to share the module (~30 tests on a
box without hypothesis).  Importing the decorators from here instead
keeps those modules importable everywhere: with hypothesis installed
nothing changes; without it only the ``@given`` tests skip, each with an
explicit reason, and the deterministic tests in the same files run.

Usage (replaces the importorskip + ``from hypothesis import ...`` pair):

    from _hyp import given, settings, st

The stubs only need to survive module-level decoration (``@given(...)``
marks the test skipped; ``@settings(...)`` is a pass-through; ``st``
absorbs arbitrary strategy-building attribute/call chains) — a stubbed
test body never executes.  tests/conftest.py documents the expected
skip inventory.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Absorbs any strategy-construction chain (st.lists(st.floats()
        .filter(...)) ...) — never executed, only built at import."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _Strategy()

    def given(*args, **kwargs):
        return pytest.mark.skip(
            reason="hypothesis not installed (property test)")

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco
