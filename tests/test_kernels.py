"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(deliverable (c): per-kernel allclose against ref.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fedavg_agg.ops import fedavg_aggregate, fedavg_aggregate_tree
from repro.kernels.fedavg_agg.ref import fedavg_agg_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rwkv6_wkv.ops import wkv6_pallas
from repro.kernels.rwkv6_wkv.ref import wkv6_scan_ref

KEY = jax.random.PRNGKey(0)


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

FLASH_CASES = [
    # (B, Sq, Sk, Hq, Hkv, D, causal, window, dtype, tol)
    (2, 128, 128, 4, 2, 64, True, 0, jnp.float32, 2e-5),
    (1, 256, 256, 2, 2, 32, True, 64, jnp.float32, 2e-5),
    (2, 128, 256, 4, 1, 64, True, 0, jnp.float32, 2e-5),    # right-aligned q
    (1, 128, 128, 2, 2, 128, False, 0, jnp.float32, 2e-5),
    (1, 128, 128, 4, 4, 64, True, 0, jnp.bfloat16, 2e-2),
    (1, 64, 64, 1, 1, 16, True, 16, jnp.float32, 2e-5),
]


@pytest.mark.parametrize("case", FLASH_CASES, ids=[str(c[:8]) for c in FLASH_CASES])
def test_flash_attention_sweep(case):
    b, sq, sk, hq, hkv, d, causal, window, dtype, tol = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, sq, hq, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, sk, hkv, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, sk, hkv, d)).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          bq=64, bk=64, interpret=True)
    g = hq // hkv
    kr = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1)
    vr = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1)
    ref = attention_ref(q.transpose(0, 2, 1, 3), kr, vr,
                        causal=causal, window=window).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_flash_attention_block_shape_independence():
    """Result must not depend on the BlockSpec tiling."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    outs = [
        flash_attention(q, k, v, bq=bq, bk=bk, interpret=True)
        for (bq, bk) in [(64, 64), (128, 128), (128, 64), (256, 256)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o), atol=1e-5)


# --------------------------------------------------------------------------
# WKV6
# --------------------------------------------------------------------------

WKV_CASES = [
    # (B, T, H, hs, bt, dtype, tol)
    (2, 64, 2, 32, 16, jnp.float32, 1e-4),
    (1, 128, 4, 64, 128, jnp.float32, 1e-4),
    (2, 96, 1, 16, 32, jnp.float32, 1e-4),
    (1, 64, 2, 64, 64, jnp.bfloat16, 5e-2),
]


@pytest.mark.parametrize("case", WKV_CASES, ids=[str(c[:5]) for c in WKV_CASES])
def test_wkv6_sweep(case):
    b, t, h, hs, bt, dtype, tol = case
    ks = jax.random.split(KEY, 6)
    r = jax.random.normal(ks[0], (b, t, h, hs)).astype(dtype)
    k = jax.random.normal(ks[1], (b, t, h, hs)).astype(dtype)
    v = jax.random.normal(ks[2], (b, t, h, hs)).astype(dtype)
    w = (jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, hs))) * 0.5 + 0.45).astype(dtype)
    u = (0.1 * jax.random.normal(ks[4], (h, hs))).astype(dtype)
    s0 = (0.1 * jax.random.normal(ks[5], (b, h, hs, hs))).astype(jnp.float32)
    y1, sf1 = wkv6_pallas(r, k, v, w, u, s0, bt=bt, interpret=True)
    y2, sf2 = wkv6_scan_ref(
        *(x.astype(jnp.float32) for x in (r, k, v, w)), u.astype(jnp.float32), s0)
    np.testing.assert_allclose(np.asarray(y1, np.float32), np.asarray(y2),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(sf1), np.asarray(sf2), atol=tol, rtol=tol)


def test_wkv6_chunking_independence():
    """Final state and outputs identical across time-block sizes."""
    b, t, h, hs = 1, 64, 2, 32
    ks = jax.random.split(KEY, 6)
    r, k, v = (jax.random.normal(ks[i], (b, t, h, hs)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, hs))) * 0.5 + 0.45
    u = 0.1 * jax.random.normal(ks[4], (h, hs))
    s0 = 0.1 * jax.random.normal(ks[5], (b, h, hs, hs))
    y_ref, s_ref = wkv6_pallas(r, k, v, w, u, s0, bt=64, interpret=True)
    for bt in (8, 16, 32):
        y, s = wkv6_pallas(r, k, v, w, u, s0, bt=bt, interpret=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)
        np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=1e-5)


# --------------------------------------------------------------------------
# fedavg aggregation
# --------------------------------------------------------------------------

@pytest.mark.parametrize("k,n,dtype", [
    (4, 1000, jnp.float32),
    (8, 4096, jnp.float32),
    (3, 77, jnp.float32),
    (4, 512, jnp.bfloat16),
    (1, 64, jnp.float32),
])
def test_fedavg_sweep(k, n, dtype):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (k, n)).astype(dtype)
    w = jnp.abs(jax.random.normal(ks[1], (k,)))
    w = w * (jax.random.uniform(ks[1], (k,)) > 0.3)  # some zero slots
    out = fedavg_aggregate(x, w, bn=256, interpret=True)
    ref = fedavg_agg_ref(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_fedavg_all_zero_weights():
    x = jnp.ones((3, 100))
    out = fedavg_aggregate(x, jnp.zeros((3,)), bn=64, interpret=True)
    assert bool(jnp.isfinite(out).all())
    np.testing.assert_allclose(np.asarray(out), 0.0)


def test_fedavg_tree_matches_server_aggregate():
    """The kernel path must agree with repro.fl.server.aggregate (eq. 34)."""
    from repro.fl.server import aggregate

    tree = {
        "a": jax.random.normal(KEY, (4, 10, 3)),
        "b": {"w": jax.random.normal(KEY, (4, 7))},
    }
    w = jnp.asarray([1.0, 2.0, 0.0, 0.5])
    g = jax.tree_util.tree_map(lambda x: jnp.zeros_like(x[0]), tree)
    ref = aggregate(g, tree, w)
    got = fedavg_aggregate_tree(tree, w, bn=16, interpret=True)
    for a, b in zip(jax.tree_util.tree_leaves(ref), jax.tree_util.tree_leaves(got)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_wkv6_pallas_integrated_in_model():
    """rwkv6 forward with the Pallas WKV (interpret) matches the ref scan."""
    import dataclasses
    from repro.configs import get_config
    from repro.models import forward, init_params

    cfg_ref = get_config("rwkv6-7b").reduced()
    cfg_pal = dataclasses.replace(cfg_ref, rwkv_wkv_impl="pallas")
    params = init_params(cfg_ref, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(KEY, (2, 32), 0, cfg_ref.vocab)}
    l_ref = forward(cfg_ref, params, batch, mode="train")[0]
    l_pal = forward(cfg_pal, params, batch, mode="train")[0]
    np.testing.assert_allclose(
        np.asarray(l_ref, np.float32), np.asarray(l_pal, np.float32),
        atol=5e-2, rtol=5e-2)


def test_flash_attention_integrated_in_model():
    """Dense forward with attn_impl="pallas" (interpret) matches the ref."""
    import dataclasses
    from repro.configs import get_config
    from repro.models import forward, init_params

    cfg_ref = get_config("yi-6b").reduced()
    cfg_pal = dataclasses.replace(cfg_ref, attn_impl="pallas")
    params = init_params(cfg_ref, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(KEY, (2, 128), 0, cfg_ref.vocab)}
    l_ref = forward(cfg_ref, params, batch, mode="train")[0]
    l_pal = forward(cfg_pal, params, batch, mode="train")[0]
    np.testing.assert_allclose(
        np.asarray(l_ref, np.float32), np.asarray(l_pal, np.float32),
        atol=5e-2, rtol=5e-2)


# --------------------------------------------------------------------------
# fused polyblock solve (whole Algorithm 1 in one kernel, DESIGN.md §13)
# --------------------------------------------------------------------------

def _fused_solve_inputs(n=140, seed=21):
    from repro.core import WirelessConfig
    from repro.core.feasibility import is_infeasible

    cfg = WirelessConfig()
    rng = np.random.default_rng(seed)
    h2 = (rng.exponential(size=n) * 3).astype(np.float64)
    beta = rng.integers(5, 60, n).astype(np.float64)
    keep = ~is_infeasible(h2, cfg, np.full(n, cfg.e_max_j))
    assert keep.any()
    return beta[keep], h2[keep], cfg


def test_polyblock_fused_solve_interpret_vs_oracle():
    """Kernel (f32 interpret) vs the jnp bisect driver — same Algorithm 1.

    fp32-study contract (DESIGN.md §13): pairs whose retirement test
    |Δf| <= eps is decided clear of f32 noise keep the f64 iteration
    trajectory exactly and land within 1e-4 relative; a boundary pair
    (|Δf| within f32 noise of eps = 0.01, ~1% of a random batch) may
    retire one iteration early or late, and is then still pinned by the
    eq. 26 tolerance itself: |time_s - ref| <= eps."""
    from repro.core import solve_pairs_jit
    from repro.kernels.polyblock_fused.ops import polyblock_solve_fused

    beta, h2, cfg = _fused_solve_inputs()
    ref = solve_pairs_jit(beta, h2, cfg, backend="bisect")
    tau, p, time_s, iters = polyblock_solve_fused(
        beta, h2, cfg.e_max_j, cfg, interpret=True, dtype=np.float32)
    same = ref.iterations == np.asarray(iters)
    assert same.mean() > 0.97, f"trajectory drift on {(~same).mean():.1%}"
    assert np.abs(ref.iterations - np.asarray(iters)).max() <= 1
    for got, want in ((tau, ref.tau), (p, ref.p), (time_s, ref.time_s)):
        np.testing.assert_allclose(np.asarray(got, np.float64)[same],
                                   want[same], rtol=1e-4, atol=0)
    # boundary retirements stay within the polyblock tolerance itself
    assert np.all(np.abs(np.asarray(time_s, np.float64)[~same]
                         - ref.time_s[~same]) <= 0.01 + 1e-6)


def test_polyblock_fused_solve_compiled_matches_interpret():
    """Compiled-vs-interpret parity of the fused kernel (the other half of
    the fp32 study; compiled Pallas needs a real accelerator backend)."""
    if jax.default_backend() == "cpu":
        pytest.skip("compiled Pallas unavailable on CPU (interpret only)")
    from repro.kernels.polyblock_fused.ops import polyblock_solve_fused

    beta, h2, cfg = _fused_solve_inputs()
    interp = polyblock_solve_fused(beta, h2, cfg.e_max_j, cfg,
                                   interpret=True, dtype=np.float32)
    comp = polyblock_solve_fused(beta, h2, cfg.e_max_j, cfg,
                                 interpret=False, dtype=np.float32)
    np.testing.assert_array_equal(np.asarray(interp[3]), np.asarray(comp[3]))
    for a, b in zip(interp[:3], comp[:3]):
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(b, np.float64),
                                   rtol=1e-5, atol=0)
