"""Serving example: prefill a prompt, then decode tokens through the
ring-buffer KV/state caches — the same serve_step the decode_32k/long_500k
dry-run shapes lower, here on a reduced config with a correctness check
against the full forward pass.

  PYTHONPATH=src python examples/serve_model.py --arch rwkv6-7b-smoke
  PYTHONPATH=src python examples/serve_model.py --arch deepseek-v3-671b-smoke
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import decode_step, forward, init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b-smoke")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    a = ap.parse_args()

    cfg = get_config(a.arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    b, s, nd = 2, a.prompt_len, a.new_tokens
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)

    batch = {"tokens": toks}
    if cfg.family == "vlm":
        batch["image_embeds"] = 0.02 * jax.random.normal(
            key, (b, cfg.n_patches, cfg.d_model), jnp.bfloat16)
        batch["mrope_pos"] = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None, :, None], (b, s, 3))
    if cfg.family == "audio":
        batch["enc_frames"] = 0.02 * jax.random.normal(
            key, (b, cfg.encoder_seq, cfg.d_model))

    print(f"arch={cfg.name} family={cfg.family}")
    t0 = time.time()
    logits, _, cache = forward(cfg, params, batch, mode="prefill",
                               cache_headroom=nd)
    print(f"prefill {s} tokens: {time.time()-t0:.2f}s")
    for name, leaf in jax.tree_util.tree_leaves_with_path(cache):
        pass
    n_cache = sum(x.size * x.dtype.itemsize
                  for x in jax.tree_util.tree_leaves(cache))
    print(f"cache size: {n_cache/2**20:.2f} MiB")

    step = jax.jit(lambda p, db, c: decode_step(cfg, p, db, c))
    out_tokens = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    t0 = time.time()
    for d in range(nd):
        db = {"token": tok, "pos": jnp.asarray(s + d, jnp.int32)}
        if cfg.family == "vlm":
            db["mrope_pos"] = jnp.full((b, 1, 3), s + d, jnp.int32)
        lg, cache = step(params, db, cache)
        tok = jnp.argmax(lg[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(np.asarray(tok[:, 0]))
    dt = time.time() - t0
    print(f"decoded {nd} tokens in {dt:.2f}s ({dt/nd*1e3:.0f} ms/token incl. "
          f"first-call compile)")
    print("greedy continuation (batch 0):", [int(t[0]) for t in out_tokens])


if __name__ == "__main__":
    main()
