"""Reproduce the paper's headline comparison from ONE declarative sweep.

Runs the Fig.-3-style device-selection comparison — the proposed Algorithm 3
vs the random / fixed / cluster baselines (all with MO-RA + M-SA) — over
several seeds through the vmapped scan engine, then writes:

  results/<name>/v####/sweep.json     versioned metrics + curves artifact
  results/<name>/v####/figures/*.svg  convergence curves (vs round and vs
                                      simulated time), sub-channel
                                      utilization bars, latency CDF

  PYTHONPATH=src python examples/reproduce_figures.py              # reduced
  PYTHONPATH=src python examples/reproduce_figures.py --full       # paper scale
  PYTHONPATH=src python examples/reproduce_figures.py --smoke      # CI smoke
  PYTHONPATH=src python examples/reproduce_figures.py --engine loop  # reference

Scenario robustness (DESIGN.md §11): pass one or more --scenario presets
(static / corr_fading / mobility / churn / harvest / urban) to cross the
policy grid with time-varying environments — the whole policy x scenario
x seed grid still dispatches as one compiled scan program:

  PYTHONPATH=src python examples/reproduce_figures.py \
      --name scenario_robustness --scenario static --scenario corr_fading

Sync-vs-async server disciplines (DESIGN.md §12): pass one or more
--aggregation presets (sync / async / async_const / async_full) to add
the server-aggregation axis — async cells share the sync cells' worlds
and Γ solves and route through the buffered event engine, and the
gallery gains the time-to-target comparison figure:

  PYTHONPATH=src python examples/reproduce_figures.py \
      --name async_vs_sync --ds alg3 \
      --aggregation sync --aggregation async \
      --scenario static --scenario churn --scenario urban

Every run appends a NEW version directory; RESULTS.md documents the
gallery generated from these artifacts.
"""
import argparse

from repro.core import PAPER_BASELINE_DS
from repro.experiments import SweepSpec, run_sweep


def build_spec(args: argparse.Namespace) -> SweepSpec:
    scenarios = tuple(args.scenario) if args.scenario else ("static",)
    aggregation = tuple(args.aggregation) if args.aggregation else ("sync",)
    if args.smoke:       # CI: 2 policies x 2 seeds, minutes on 2 CPU cores
        return SweepSpec(
            name=args.name, datasets="mnist",
            ds=tuple(args.ds) if args.ds else ("alg3", "random"),
            scenarios=scenarios, aggregation=aggregation,
            seeds=(0, 1), rounds=12, n_devices=12, n_subchannels=4,
            target_loss=args.target_loss,
            overrides={"n_samples": 128, "batch": 16, "eval_every": 3,
                       "local_steps": 2})
    if args.full:        # paper scale (Table I / Sec. VI)
        return SweepSpec(
            name=args.name, datasets="mnist",
            ds=tuple(args.ds) if args.ds else PAPER_BASELINE_DS,
            scenarios=scenarios, aggregation=aggregation,
            seeds=tuple(range(args.seeds)), rounds=300,
            n_devices=20, n_subchannels=4, target_loss=args.target_loss)
    # default: reduced scale, same scheme ordering (DESIGN.md §2)
    return SweepSpec(
        name=args.name, datasets="mnist",
        ds=tuple(args.ds) if args.ds else PAPER_BASELINE_DS,
        scenarios=scenarios, aggregation=aggregation,
        seeds=tuple(range(args.seeds)), rounds=60,
        n_devices=20, n_subchannels=4, target_loss=args.target_loss,
        overrides={"n_samples": 500, "eval_every": 5})


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--name", default="fig3_convergence",
                    help="sweep/artifact name under --results-root")
    ap.add_argument("--results-root", default="results")
    ap.add_argument("--seeds", type=int, default=2,
                    help="number of world seeds (0..seeds-1)")
    ap.add_argument("--target-loss", type=float, default=1.0,
                    help="rounds/time-to-target threshold")
    ap.add_argument("--engine", choices=("scan", "loop"), default="scan")
    ap.add_argument("--full", action="store_true", help="paper scale")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI grid (2 policies x 2 seeds)")
    ap.add_argument("--scenario", action="append", default=None,
                    metavar="PRESET",
                    help="environment scenario preset (repeatable; adds a "
                         "scenario axis to the grid — see repro.scenarios)")
    ap.add_argument("--aggregation", action="append", default=None,
                    metavar="PRESET",
                    help="server-aggregation preset (repeatable; sync / "
                         "async / async_const / async_full — async cells "
                         "run the buffered event engine, DESIGN.md §12)")
    ap.add_argument("--ds", action="append", default=None, metavar="SCHEME",
                    help="device-selection scheme axis override "
                         "(repeatable; default: the per-mode policy grid)")
    args = ap.parse_args()

    spec = build_spec(args)
    print(f"sweep {spec.name!r}: {spec.n_cells} cells "
          f"({len(spec.policies)} policies x {len(spec.scenarios)} scenarios "
          f"x {len(spec.aggregation)} aggregations x {len(spec.seeds)} "
          f"seeds), {spec.rounds} rounds, engine={args.engine}")
    res = run_sweep(spec, engine=args.engine,
                    results_root=args.results_root, figures=True)
    print(f"wrote {res.out_dir}/sweep.json "
          f"(+ figures/) in {res.record['wall_s']:.1f}s")

    print(f"\n{'policy':34s} {'final loss':>10s} {'rounds→{:g}'.format(spec.target_loss):>10s} "
          f"{'util':>6s} {'cum lat (s)':>12s}")
    rows: dict[str, list[dict]] = {}
    many_sc = len(spec.scenarios) > 1
    many_ag = len(spec.aggregation) > 1
    for c in res.record["cells"]:
        label = c["policy"]["label"]
        if many_sc:   # never pool metrics across environments
            label = f"{label} @{c['scenario']}"
        if many_ag:   # ... nor across server disciplines
            label = f"{label} [{c['aggregation']}]"
        rows.setdefault(label, []).append(c["metrics"])
    for label, ms in rows.items():
        import numpy as np
        r2t = [m["rounds_to_target"] for m in ms]
        r2t_s = ("-" if any(r is None for r in r2t)
                 else f"{np.mean(r2t):.1f}")
        print(f"{label:34s} {np.mean([m['final_loss'] for m in ms]):10.4f} "
              f"{r2t_s:>10s} "
              f"{np.mean([m['mean_subchannel_utilization'] for m in ms]):6.2f} "
              f"{np.mean([m['cumulative_latency_s'] for m in ms]):12.1f}")


if __name__ == "__main__":
    main()
