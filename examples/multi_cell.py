"""Multi-cell (hierarchical) FLOWN: two base stations each run the paper's
full Stackelberg round over their own devices/channels; cell models merge
by transmitted data size — the FL semantics of the multi-pod mesh's `pod`
axis (DESIGN.md §2, repro.fl.hierarchical).

Runs the device-resident scan engine (one fused `lax.scan` over rounds,
cells unrolled in its body — same engine matrix as the single-cell
harness, DESIGN.md §10); pass --engine loop for the host reference.

  PYTHONPATH=src python examples/multi_cell.py [--engine loop]
"""
import argparse

from repro.core import RoundPolicy
from repro.fl import HierSimConfig, run_hierarchical


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--engine", choices=("scan", "loop"), default="scan")
    engine = ap.parse_args().engine
    for name, ds in [("proposed", "alg3"), ("random", "random")]:
        out = run_hierarchical(HierSimConfig(
            rounds=30, policy=RoundPolicy(ds=ds), seed=0), engine=engine)
        print(f"2-cell {name:10s} [{engine}]: loss {out['loss'][0]:.3f} -> "
              f"{out['loss'][-1]:.3f}  "
              f"mean round latency {out['latency'].mean():.2f}s "
              f"(max over cells, cells parallel)  "
              f"wall {out['wall_s']:.1f}s")


if __name__ == "__main__":
    main()
