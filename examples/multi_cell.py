"""Multi-cell (hierarchical) FLOWN: two base stations each run the paper's
full Stackelberg round over their own devices/channels; cell models merge
by transmitted data size — the FL semantics of the multi-pod mesh's `pod`
axis (DESIGN.md §2, repro.fl.hierarchical).

  PYTHONPATH=src python examples/multi_cell.py
"""
import numpy as np

from repro.core import RoundPolicy
from repro.fl import HierSimConfig, run_hierarchical


def main():
    for name, ds in [("proposed", "alg3"), ("random", "random")]:
        out = run_hierarchical(HierSimConfig(
            rounds=30, policy=RoundPolicy(ds=ds), seed=0))
        print(f"2-cell {name:10s}: loss {out['loss'][0]:.3f} -> "
              f"{out['loss'][-1]:.3f}  "
              f"mean round latency {out['latency'].mean():.2f}s "
              f"(max over cells, cells parallel)")


if __name__ == "__main__":
    main()
