"""Beyond-paper ablation: AoU-weighted selection under label-skewed NON-IID
data (Dirichlet partition).

The paper evaluates imbalanced IID only. Under label skew each device's
update is more distinctive, so skipping a device for many rounds leaves a
bigger hole in the aggregate — AoU's freshness prior should earn a LARGER
margin over random selection than in the IID setting. This script measures
that margin at two Dirichlet concentrations.

  PYTHONPATH=src python examples/non_iid_aou.py
"""
import numpy as np

from repro.core import RoundPolicy
from repro.fl import SimConfig, run_simulation


def run(rounds=60, n_samples=500, seeds=(0, 1)):
    print(f"{'partition':22s} {'proposed':>9s} {'random':>9s} {'margin':>8s}")
    for label, kw in [
        ("imbalanced IID", dict(partition="iid")),
        ("dirichlet a=0.5", dict(partition="dirichlet", dirichlet_alpha=0.5)),
        ("dirichlet a=0.1", dict(partition="dirichlet", dirichlet_alpha=0.1)),
    ]:
        res = {}
        for name, ds in [("proposed", "alg3"), ("random", "random")]:
            losses = []
            for s in seeds:
                h = run_simulation(SimConfig(
                    dataset="mnist", rounds=rounds, n_samples=n_samples,
                    policy=RoundPolicy(ds=ds), seed=s, eval_every=rounds // 4,
                    **kw))
                losses.append(h.global_loss[-1])
            res[name] = float(np.mean(losses))
        margin = (res["random"] - res["proposed"]) / res["random"] * 100
        print(f"{label:22s} {res['proposed']:9.4f} {res['random']:9.4f} "
              f"{margin:+7.1f}%")


if __name__ == "__main__":
    run()
