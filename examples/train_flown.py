"""End-to-end driver (the paper's kind: FL TRAINING): run the full FLOWN
pipeline — wireless channel simulation, Stackelberg round planning, real
local training on all selected devices, eq.-(34) aggregation — for a few
hundred rounds on each dataset and scheme, with checkpoints and a CSV log.

  PYTHONPATH=src python examples/train_flown.py                # mnist, 300 rounds
  PYTHONPATH=src python examples/train_flown.py --dataset sst2 --rounds 100
  PYTHONPATH=src python examples/train_flown.py --all-schemes
"""
import argparse
import csv
import os

import numpy as np

from repro.core import RoundPolicy
from repro.fl import SimConfig, run_simulation

SCHEMES = {
    "proposed": RoundPolicy(ds="alg3"),
    "aou_topk": RoundPolicy(ds="aou_topk"),
    "random": RoundPolicy(ds="random"),
    "cluster": RoundPolicy(ds="cluster"),
    "fixed": RoundPolicy(ds="fixed"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="mnist", choices=["mnist", "cifar10", "sst2"])
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--scheme", default="proposed", choices=sorted(SCHEMES))
    ap.add_argument("--all-schemes", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="results/flown")
    a = ap.parse_args()

    os.makedirs(a.out, exist_ok=True)
    schemes = sorted(SCHEMES) if a.all_schemes else [a.scheme]
    for name in schemes:
        h = run_simulation(SimConfig(
            dataset=a.dataset, rounds=a.rounds, policy=SCHEMES[name],
            seed=a.seed, eval_every=max(a.rounds // 50, 1)))
        path = os.path.join(a.out, f"{a.dataset}_{name}.csv")
        with open(path, "w", newline="") as f:
            w = csv.writer(f)
            w.writerow(["round", "global_loss", "accuracy", "latency_s",
                        "cum_time_s", "n_transmitted", "energy_j"])
            for i in range(len(h.rounds)):
                w.writerow([h.rounds[i], h.global_loss[i], h.accuracy[i],
                            h.latency_s[i], h.cum_time_s[i],
                            h.n_transmitted[i], h.energy_j[i]])
        print(f"{a.dataset}/{name}: loss {h.global_loss[0]:.3f} -> "
              f"{h.global_loss[-1]:.3f}, acc {h.accuracy[-1]:.3f}, "
              f"convergence time {h.cum_time_s[-1]:.0f}s "
              f"({h.wall_s:.0f}s wall) -> {path}")


if __name__ == "__main__":
    main()
