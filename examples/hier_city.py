"""City-scale two-tier comparison: sync vs async aggregation at BOTH tiers.

Runs the hierarchical sweep grid — one shared-mobility city of C cells,
each cell a buffered staleness-weighted event loop, the global server
itself a buffered staleness-weighted aggregator over cell commits
(DESIGN.md §15) — crossing the cell-tier and global-tier server
disciplines under device churn, then writes:

  results/<name>/v####/sweep.json     versioned metrics + curves artifact
  results/<name>/v####/figures/*.svg  per-discipline facets + the
                                      time-to-target comparison

  PYTHONPATH=src python examples/hier_city.py            # reduced artifact
  PYTHONPATH=src python examples/hier_city.py --smoke    # CI smoke grid

The headline row is `churn · async/g.async` vs `churn · sync/g.sync`:
with stragglers at both tiers, the fully asynchronous hierarchy reaches
the target loss in less simulated time than the doubly-barriered one
(neither tier ever waits for the slowest device / slowest cell).
"""
import argparse

from repro.experiments import SweepSpec, run_sweep


def build_spec(args: argparse.Namespace) -> SweepSpec:
    disciplines = dict(aggregation=("sync", "async"),
                       global_aggregation=("sync", "async"))
    if args.smoke:       # CI: 4 cells x 4 discipline combos, minutes on CPU
        return SweepSpec(
            name=args.name, datasets="mnist", ds=("alg3",),
            scenarios=("churn",), cell_counts=(4,), **disciplines,
            seeds=(0,), rounds=12, n_devices=16, n_subchannels=8,
            target_loss=args.target_loss,
            overrides={"n_samples": 128, "batch": 16, "eval_every": 3,
                       "local_steps": 2})
    # default: reduced city (4 cells x 8 devices), still one compiled
    # program per (discipline, shape) group
    return SweepSpec(
        name=args.name, datasets="mnist", ds=("alg3",),
        scenarios=("churn",), cell_counts=(4,), **disciplines,
        seeds=tuple(range(args.seeds)), rounds=args.rounds,
        n_devices=32, n_subchannels=8, target_loss=args.target_loss,
        overrides={"n_samples": 400, "batch": 32, "eval_every": 5,
                   "local_steps": 2})


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--name", default="hier_async",
                    help="sweep/artifact name under --results-root")
    ap.add_argument("--results-root", default="results")
    ap.add_argument("--seeds", type=int, default=2,
                    help="number of world seeds (0..seeds-1)")
    ap.add_argument("--rounds", type=int, default=60,
                    help="event horizon per cell run")
    ap.add_argument("--target-loss", type=float, default=1.0,
                    help="time-to-target threshold")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI grid (1 seed, 12 events)")
    args = ap.parse_args()

    spec = build_spec(args)
    print(f"hier sweep {spec.name!r}: {spec.n_cells} cells "
          f"(C={spec.cell_counts[0]} towers, {len(spec.aggregation)} cell-"
          f"tier x {len(spec.global_aggregation)} global-tier disciplines "
          f"x {len(spec.seeds)} seeds), {spec.rounds} events")
    res = run_sweep(spec, results_root=args.results_root, figures=True)
    print(f"wrote {res.out_dir}/sweep.json "
          f"(+ figures/) in {res.record['wall_s']:.1f}s")

    import numpy as np
    print(f"\n{'discipline (cell/global)':26s} {'final loss':>10s} "
          f"{'t→{:g} (s)'.format(spec.target_loss):>12s} {'cum lat (s)':>12s}")
    rows: dict[tuple, list[dict]] = {}
    for c in res.record["cells"]:
        rows.setdefault((c["aggregation"], c["global_aggregation"]),
                        []).append(c["metrics"])
    t2t_by_disc = {}
    for (ag, g), ms in sorted(rows.items()):
        t2t = [m["time_to_target_s"] for m in ms]
        t2t_s = "-" if any(t is None for t in t2t) else f"{np.mean(t2t):.1f}"
        if not any(t is None for t in t2t):
            t2t_by_disc[(ag, g)] = float(np.mean(t2t))
        print(f"{ag + '/g.' + g:26s} "
              f"{np.mean([m['final_loss'] for m in ms]):10.4f} "
              f"{t2t_s:>12s} "
              f"{np.mean([m['cumulative_latency_s'] for m in ms]):12.1f}")
    sync2, async2 = (t2t_by_disc.get(("sync", "sync")),
                     t2t_by_disc.get(("async", "async")))
    if sync2 is not None and async2 is not None:
        print(f"\nasync two-tier vs sync two-tier time-to-target: "
              f"{async2:.1f}s vs {sync2:.1f}s "
              f"({sync2 / async2:.2f}x faster)" if async2 < sync2 else
              f"\nWARNING: async two-tier ({async2:.1f}s) did not beat "
              f"sync ({sync2:.1f}s) at this scale")


if __name__ == "__main__":
    main()
