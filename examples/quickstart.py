"""Quickstart: the paper in ~60 seconds.

1. Solve ONE Stackelberg round: MO-RA (Alg. 1) -> M-SA (Alg. 2) -> AoU
   device selection (Alg. 3), and print the round plan.
2. Run a short wireless-FL simulation comparing the proposed scheme against
   random device selection on synthetic MNIST.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    RoundPolicy,
    WirelessConfig,
    init_aou,
    plan_round,
    sample_channel_gains,
    sample_topology,
)
from repro.fl import SimConfig, run_simulation


def one_round():
    print("=" * 60)
    print("ONE STACKELBERG ROUND  (N=20 devices, K=4 sub-channels)")
    print("=" * 60)
    cfg = WirelessConfig()
    rng = np.random.default_rng(0)
    topo = sample_topology(rng, cfg)
    h2 = sample_channel_gains(rng, cfg, topo)
    beta = rng.integers(10, 50, cfg.n_devices).astype(float)
    aou = init_aou(cfg.n_devices)

    plan = plan_round(aou, beta, h2, cfg, rng, policy=RoundPolicy())
    print(f"Prop-1 feasible (device,channel) pairs: "
          f"{plan.feasible.sum()}/{plan.feasible.size}")
    print(f"selected devices : {np.where(plan.selected)[0].tolist()}")
    print(f"transmitting     : {np.where(plan.transmitted)[0].tolist()}")
    for n in np.where(plan.transmitted)[0]:
        print(f"  device {n:2d}: sub-channel {plan.channel_of[n]}, "
              f"tau*={plan.tau[n]:.3f} p*={plan.p[n]:.3f} "
              f"T={plan.time_per_device[n]:.2f}s "
              f"E={plan.energy_per_device[n]*1e3:.1f}mJ "
              f"(budget {cfg.e_max_j*1e3:.0f}mJ)")
    print(f"round latency (eq. 9): {plan.latency_s:.2f}s")


def short_sim():
    print()
    print("=" * 60)
    print("30-ROUND FL SIMULATION  (synthetic MNIST, real training)")
    print("=" * 60)
    for name, ds in [("proposed (Alg.3 + MO-RA + M-SA)", "alg3"),
                     ("random device selection", "random")]:
        h = run_simulation(SimConfig(dataset="mnist", rounds=30,
                                     policy=RoundPolicy(ds=ds),
                                     n_samples=400, eval_every=10))
        print(f"{name:36s} loss {h.global_loss[0]:.3f} -> {h.global_loss[-1]:.3f}"
              f"  acc {h.accuracy[-1]:.3f}  conv-time {h.cum_time_s[-1]:.0f}s")


if __name__ == "__main__":
    one_round()
    short_sim()
