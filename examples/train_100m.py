"""End-to-end LM training driver at ~100M parameters: a scaled-down
qwen2-style dense config trained for a few hundred steps on the synthetic
token pipeline, with FL cohort weighting driven by the Stackelberg planner
(the paper's technique as a first-class train_step feature) and periodic
checkpoints.

NOTE: ~100M params on a CPU container is slow (~seconds/step); the default
runs 100 steps with seq 256. On a real TPU mesh the same script scales via
repro.launch (pjit shardings come from repro.sharding.partition).

  PYTHONPATH=src python examples/train_100m.py --steps 100
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.data.pipeline import synthetic_lm_stream
from repro.launch.train import fl_round_weights
from repro.core import RoundPolicy, WirelessConfig, init_aou, sample_topology
from repro.models import init_params, param_count
from repro.train.optimizer import make_optimizer
from repro.train.train_step import make_train_step


def make_100m_config():
    """~100M-param dense decoder in the qwen2 family."""
    base = get_config("qwen2-7b")
    return dataclasses.replace(
        base, name="qwen2-100m", n_layers=8, d_model=640, n_heads=10,
        n_kv_heads=2, d_ff=2560, vocab=32768, sliding_window=0,
        long_context="", optimizer="adamw",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--out", default="results/ckpt_100m.npz")
    a = ap.parse_args()

    cfg = make_100m_config()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    print(f"{cfg.name}: {param_count(params)/1e6:.1f}M params")

    opt = make_optimizer("adamw", a.lr)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt, remat=False))
    stream = synthetic_lm_stream(0, a.batch, a.seq, cfg.vocab)

    # FL cohort weighting from the Stackelberg planner (8 cohorts).
    rng = np.random.default_rng(0)
    wcfg = WirelessConfig(n_devices=8, n_subchannels=4)
    fl_state = {"topo": sample_topology(rng, wcfg), "aou": init_aou(8)}
    beta = rng.integers(10, 50, 8).astype(np.float64)
    policy = RoundPolicy()

    t0 = time.time()
    for step in range(a.steps):
        b = next(stream)
        w, plan, lat = fl_round_weights(fl_state, beta, wcfg, rng, policy)
        row_w = w[np.arange(a.batch) % 8]
        if row_w.sum() == 0:
            row_w = np.ones(a.batch)
        batch = {
            "tokens": jnp.asarray(b["tokens"]),
            "labels": jnp.asarray(b["labels"]),
            "fl_weights": jnp.asarray(row_w, jnp.float32),
        }
        params, opt_state, m = step_fn(params, opt_state, batch)
        if step % 10 == 0 or step == a.steps - 1:
            print(f"step {step:4d}  loss {float(m['loss']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.2f}  "
                  f"round_latency {lat:.2f}s  "
                  f"({(time.time()-t0)/(step+1):.2f}s/step)")
        if a.ckpt_every and (step + 1) % a.ckpt_every == 0:
            save_checkpoint(a.out, params, step=step + 1)
            print(f"  checkpoint -> {a.out}")
    print(f"done in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
